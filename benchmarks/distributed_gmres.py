"""Distributed GMRES scaling: the sharding study the paper's 2 GB wall

motivates.  Runs the row-sharded solver on 8 fake host devices (subprocess,
so the main process keeps its 1-device view) and reports:

  - wall time vs the single-device solver,
  - collective op counts/bytes from the lowered HLO (the real scaling
    quantity: per Arnoldi step CGS2 needs exactly 1 all-gather + 2 psums
    vs MGS's j+1 collective rounds; the banded kernel-path rows swap the
    all-gather for an O(halo) neighbor exchange, and the sharded s-step
    solver drops to ~4 rounds per s steps).

Everything drives the UNIFIED solver path — ``gmres_sharded`` /
``gmres_sstep_sharded`` are thin shard_map wrappers over the same cycle
the single-device rows run; there is no standalone local cycle here.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CODE = textwrap.dedent("""
    import json, time
    import jax, jax.numpy as jnp
    from repro.core import (gmres, gmres_sharded, gmres_sstep,
                            gmres_sstep_sharded, operators, stencils)
    from repro.compat import make_mesh
    from repro.roofline import (parse_collectives,
                                innermost_loop_collectives)

    def coll_stats(jsol, *args):
        # Whole-program counts AND the innermost while-body counts: the
        # latter is the per-Arnoldi-step collective schedule (whole-program
        # counts dilute it with prologue/epilogue collectives).
        hlo = jsol.lower(*args).compile().as_text()
        colls = parse_collectives(hlo)
        nops = sum(c.count for c in colls)
        cbytes = sum(c.result_bytes * c.count for c in colls)
        _, loop = innermost_loop_collectives(hlo)
        loop_ops = sum(c.count for c in loop)
        loop_psums = sum(c.count for c in loop if c.kind == "all-reduce")
        return nops, cbytes, loop_ops, loop_psums

    def timed(jsol, *args):
        r = jsol(*args); r.x.block_until_ready()
        t0 = time.perf_counter(); r = jsol(*args); r.x.block_until_ready()
        return r, time.perf_counter() - t0

    def row(n, gs, t_single, t, r, stats):
        nops, cbytes, loop_ops, loop_psums = stats
        return {"n": n, "gs": gs, "t_single_us": t_single * 1e6,
                "t_sharded_us": t * 1e6, "steps": int(r.inner_steps),
                "restarts": int(r.restarts), "collective_ops": nops,
                "collective_bytes": cbytes, "loop_coll_ops": loop_ops,
                "loop_psums": loop_psums}

    out = []
    mesh = make_mesh((8,), ('model',))
    for n in (2048, 8192):
        a = operators.random_diagdom(jax.random.PRNGKey(0), n)
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))

        single = jax.jit(lambda a, b: gmres(a, b, m=20, tol=1e-5, gs='cgs2'))
        _, t_single = timed(single, a, b)

        # s-step (communication-avoiding), single-device wall time; its
        # value is the ROUND count: (s + 4)/s rounds per step vs 4 (CGS2).
        # steps = one full m=20 cycle (residual checks are per-cycle).
        # Collective counts are PARSED from the lowered HLO like every
        # other row (a local program honestly counts 0) — no placeholder.
        ssol = jax.jit(lambda a, b: gmres_sstep(a, b, s=4, blocks=5,
                                                tol=1e-5))
        stats = coll_stats(ssol, a, b)
        r, t = timed(ssol, a, b)
        out.append(row(n, "SINGLEDEV_sstep4", t_single, t, r, stats))

        for gs, pc in (('cgs2', None), ('mgs', None),
                       ('cgs2', 'block_jacobi'),
                       ('cgs2_pipelined', None)):
            sol = lambda a, b, gs=gs, pc=pc: gmres_sharded(
                mesh, 'model', a, b, m=20, tol=1e-5, gs=gs, precond=pc)
            jsol = jax.jit(sol)
            stats = coll_stats(jsol, a, b)
            r, t = timed(jsol, a, b)
            out.append(row(n, gs + ("+bj" if pc else ""), t_single, t, r,
                           stats))

    # --- the shard-aware KERNEL path: banded stencil operators ----------
    # halo exchange instead of all-gather per matvec (watch
    # collective_bytes collapse vs the dense rows above), split-phase
    # CGS2 structure, the pipelined single-reduce scheme (1 psum per
    # step), and the CA s-step solver at ~4 rounds per s steps.
    # Restart budgets are capped: the interesting quantities (per-step
    # collective schedule, wall time per step) don't need full Poisson
    # convergence, which is slow unpreconditioned.
    for nx in (32, 64):
        n = nx * nx
        op = stencils.poisson_2d(nx, nx)       # jnp backend: the halo REF
        bb = jnp.sin(jnp.arange(n) * 0.37)     # path; kernels are bench-
        single = jax.jit(lambda o, v: gmres(   # marked in kernel_bench
            o, v, m=20, tol=1e-4, max_restarts=40, gs='cgs2'))
        _, t_single = timed(single, op, bb)
        for tag, sol in (
            ('banded_cgs2', lambda o, v: gmres_sharded(
                mesh, 'model', o, v, m=20, tol=1e-4, max_restarts=40,
                gs='cgs2')),
            ('banded_pipelined', lambda o, v: gmres_sharded(
                mesh, 'model', o, v, m=20, tol=1e-4, max_restarts=40,
                gs='cgs2_pipelined')),
            ('banded_sstep4', lambda o, v: gmres_sstep_sharded(
                mesh, 'model', o, v, s=4, blocks=5, tol=1e-4,
                max_restarts=40)),
            ('banded_sstep4_pipelined', lambda o, v: gmres_sstep_sharded(
                mesh, 'model', o, v, s=4, blocks=5, tol=1e-4,
                max_restarts=40, gs='cgs2_pipelined')),
        ):
            jsol = jax.jit(sol)
            stats = coll_stats(jsol, op, bb)
            r, t = timed(jsol, op, bb)
            out.append(row(n, tag, t_single, t, r, stats))
    print(json.dumps(out))
""")


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", _CODE], env=env,
                         capture_output=True, text=True, timeout=1800)
    if res.returncode != 0:
        print(f"distributed_gmres_FAILED,0,{res.stderr[-200:]!r}")
        return []
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    print("name,us_per_call,derived")
    for r in rows:
        tag = (f"gmres_{r['gs'].replace('SINGLEDEV_', '')}_n{r['n']}"
               if r["gs"].startswith("SINGLEDEV_")
               else f"gmres_sharded8_{r['gs']}_n{r['n']}")
        print(f"{tag},{r['t_sharded_us']:.0f},"
              f"single_dev_us={r['t_single_us']:.0f};steps={r['steps']};"
              f"coll_ops={r['collective_ops']};"
              f"coll_bytes={r['collective_bytes']};"
              f"loop_coll_ops={r['loop_coll_ops']};"
              f"loop_psums={r['loop_psums']}")
    return rows


if __name__ == "__main__":
    main()
