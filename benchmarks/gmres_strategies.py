"""Paper Table 1 analogue: GMRES offload-strategy comparison over N.

The paper measured wall-time speedup of three R GPU packages vs
pracma::gmres on an NVIDIA 840M.  This container has no accelerator, so the
axis being measured shifts exactly the way DESIGN.md SS2 describes: the
strategies differ in WHERE the dispatch/fusion boundary sits —

    serial_numpy       per-op host dispatch      (pracma)
    offload_matvec     per-matvec device call + 2 boundary crossings (gmatrix)
    transfer_per_call  + full A re-transfer per call               (gputools)
    device_resident    ONE fused XLA program, zero boundary ops    (gpuR-vcl)

On CPU the "device" is XLA:cpu, so the measured speedup isolates the
dispatch/fusion effect the paper could not separate from raw GPU FLOPs.
The TPU projection of the same programs is in the roofline table.

All strategies solve the SAME diagonally-dominant dense system to the same
tolerance; correctness is asserted, matching solutions across strategies.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import strategies
from repro.core.operators import random_diagdom

SIZES_QUICK = (1_000, 2_000, 4_000)
SIZES_FULL = (1_000, 2_000, 3_000, 4_000, 5_000, 6_000, 7_000, 8_000,
              9_000, 10_000)


def _time(fn, *args, repeats=3, **kw):
    best = np.inf
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        jax.block_until_ready(getattr(result, "x", result))
        best = min(best, time.perf_counter() - t0)
    return best, result


def run(full: bool = False, m: int = 30, tol: float = 1e-5):
    sizes = SIZES_FULL if full else SIZES_QUICK
    rows = []
    for n in sizes:
        a = np.asarray(random_diagdom(jax.random.PRNGKey(0), n), np.float32)
        b = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (n,)),
                       np.float32)
        t_serial, (x_ref, beta, *_rest) = _time(
            strategies.serial_numpy, a, b, m=m, tol=tol, repeats=2)
        assert beta / np.linalg.norm(b) < 10 * tol
        row = {"N": n, "serial_numpy_s": t_serial}
        for name in ("offload_matvec", "transfer_per_call"):
            t, (x, *_r) = _time(strategies.STRATEGIES[name], a, b, m=m,
                                tol=tol, repeats=2)
            np.testing.assert_allclose(x, x_ref, rtol=2e-2, atol=1e-3)
            row[f"{name}_x"] = t_serial / t
        # device_resident: exclude compile (steady-state, like the paper's
        # warm GPU timings), include execution only
        solve = lambda: strategies.device_resident(a, b, m=m, tol=tol)
        solve()                                    # compile warmup
        t, res = _time(lambda: solve(), repeats=3)
        np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=2e-2,
                                   atol=1e-3)
        row["device_resident_x"] = t_serial / t
        rows.append(row)
    return rows


def main(full: bool = False):
    rows = run(full=full)
    print("name,us_per_call,derived")
    for r in rows:
        base_us = r["serial_numpy_s"] * 1e6
        print(f"gmres_serial_N{r['N']},{base_us:.0f},speedup=1.00")
        for k in ("offload_matvec", "transfer_per_call", "device_resident"):
            sp = r[f"{k}_x"]
            print(f"gmres_{k}_N{r['N']},{base_us / sp:.0f},speedup={sp:.2f}")
    return rows


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
