"""Kernel-layer benchmarks.

Two kinds of numbers:
  1. wall-time of the jit'd REFERENCE path on this CPU (what we can measure
     here — XLA-fused jnp, the same HLO the dry-run lowers), and
  2. STRUCTURAL metrics of the Pallas kernels (VMEM working set per grid
     step, arithmetic intensity, HBM traffic) — the quantities that
     determine TPU performance, derivable without hardware.

Every row carries a ``mode`` tag saying what its ``us`` column IS:

  modeled    default — ``us`` times the jnp reference; the headline numbers
             are the modeled structural metrics (HBM bytes, psum schedule)
  measured   ``--measure`` on an accelerator — ``us`` times the actual
             Pallas kernel, compiled for the attached device
  interpret  ``--measure`` on CPU — the kernel path runs under the Pallas
             interpreter (functional check + relative timing only; absolute
             times are NOT device wall times)

All rows are also dumped to ``BENCH_kernels.json`` so the perf trajectory
is machine-diffable across PRs (``tools/bench_gate.py`` enforces it).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.roofline import HBM_BW, PEAK_FLOPS

# Set by main(); families that have a kernel path consult it via _pick().
MODE = "modeled"


def _detect_mode() -> str:
    """measured on a real accelerator, interpret under CPU emulation."""
    return "measured" if jax.default_backend() != "cpu" else "interpret"


def _pick(kernel_fn, ref_fn):
    """--measure times the kernel path; the default times the reference."""
    return ref_fn if MODE == "modeled" else kernel_fn


def _interp() -> bool:
    return MODE == "interpret"


def _tag(rows):
    """Mode-stamp rows of a family that actually swaps in the kernel path
    under --measure; model-only families keep the default 'modeled' tag."""
    for r in rows:
        r["mode"] = MODE
    return rows


def _time(fn, *args, repeats=5):
    fn(*args)                      # warmup/compile
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def matvec_rows(sizes=(1024, 4096, 8192)):
    from repro.kernels import matvec_tiled

    rows = []
    mv = jax.jit(_pick(lambda a, x: matvec_tiled(a, x, interpret=_interp()),
                       ref.matvec))
    for n in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        t = _time(mv, a, x)
        flops = 2 * n * n
        bytes_ = 4 * (n * n + 2 * n)
        # Pallas tile (256, 512) f32: A tile 512 KiB + x tile 2 KiB in VMEM
        rows.append({
            "name": f"matvec_n{n}",
            "us": t * 1e6,
            "derived": (f"AI={flops / bytes_:.2f}flop/B "
                        f"tpu_mem_bound={bytes_ / HBM_BW * 1e6:.1f}us "
                        f"vmem_tile_kib=514"),
        })
    return _tag(rows)


def gs_rows(ns=(8192, 65536), m1=33):
    from repro.kernels import cgs2_fused

    rows = []
    gs = jax.jit(_pick(lambda v, w, mk: cgs2_fused(v, w, mk,
                                                   interpret=_interp()),
                       ref.cgs2))
    for n in ns:
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        w = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(gs, v, w, mask)
        # fused kernel: V streamed twice per pass (4x per CGS2);
        # jnp reference: V streamed 4x + h round-trips; fusion saves the
        # intermediate (m1, n_tiles) partials + w re-reads
        bytes_fused = 4 * (4 * m1 * n + 2 * n) * 1.0
        rows.append({
            "name": f"cgs2_m{m1}_n{n}",
            "us": t * 1e6,
            "derived": (f"tpu_mem_bound={bytes_fused / HBM_BW * 1e6:.1f}us "
                        f"passes_over_V=4"),
        })
    return _tag(rows)


def fused_step_traffic(n: int, m1: int, s: int = 4):
    """Modeled per-Arnoldi-step HBM bytes: fused kernel vs unfused pair.

    Unfused = the matvec kernel (A, v in; f32 w out) followed by the
    streaming cgs2 kernel (V streamed TWICE per GS pass x 2 passes, w
    re-read per pass, h + w' written) — w and h round-trip through HBM
    between the two kernels and between passes.

    Fused (kernels/arnoldi_fused.py) = A, v_j and V each streamed ONCE per
    step (the basis is VMEM-resident through both CGS2 passes); only the
    final h and reorthogonalized w'' are ever written.
    """
    unfused = (s * (n * n + n) + 4 * n                       # matvec
               + 2 * (2 * s * m1 * n + 2 * s * n             # cgs2: V 2x/pass,
                      + 4 * m1 + 4 * n))                     #   w 2x, h+w' out
    fused = (s * (n * n + n + m1 * n)                        # A, v_j, V once
             + 4 * (m1 + n))                                 # h, w'' out
    return fused, unfused


def fused_step_rows(cases=((96, 97), (384, 129), (1024, 513), (4096, 33))):
    """Fused Arnoldi-step kernel vs the unfused matvec+cgs2 pair.

    (n, m1) cases span the paper's regimes: full-memory GMRES(n) on small
    systems (n=96 is the tier-1 Poisson config; m1 = n+1), deep restarts,
    and the large-n/shallow-restart tail where the A stream dominates both
    paths and fusion's win is the eliminated vector round-trips.
    """
    from repro.kernels import arnoldi_fused

    rows = []
    stepped = jax.jit(_pick(
        lambda a, vb, j: arnoldi_fused.arnoldi_step(a, vb, j,
                                                    interpret=_interp()),
        arnoldi_fused.arnoldi_step_ref))
    for n, m1 in cases:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n)) / np.sqrt(n)
        vb = jax.random.normal(jax.random.PRNGKey(1), (m1, n)) / np.sqrt(n)
        t = _time(stepped, a, vb, m1 // 2)
        fused, unfused = fused_step_traffic(n, m1)
        ratio = fused / unfused
        rows.append({
            "name": f"fused_arnoldi_step_n{n}_m{m1 - 1}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_unfused_pair": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/unfused_hbm={ratio:.2f} "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.1f}us "
                        f"tpu_mem_bound_unfused={unfused / HBM_BW * 1e6:.1f}us "
                        f"A_and_V_streamed_once=1 w_h_roundtrips=0"),
        })
    return _tag(rows)


def block_matvec_rows(cases=((2048, 8), (4096, 16))):
    """True block multi-RHS mat-vec: one A stream for k RHS vs k GEMVs.

    ``vmap`` of the GEMV pallas_call re-streams A once per lane (the batch
    axis becomes an outer grid dim) — the measured reference contrast is
    jnp's batched GEMV vs one GEMM, the modeled contrast is k A-streams
    vs one.
    """
    rows = []
    gemm = jax.jit(lambda a, x: a @ x)
    gemv_per_lane = jax.jit(lambda a, x: jax.vmap(lambda c: a @ c,
                                                  in_axes=1, out_axes=1)(x))
    for n, k in cases:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n, k))
        t_gemm = _time(gemm, a, x)
        t_lanes = _time(gemv_per_lane, a, x)
        bytes_block = 4 * (n * n + 2 * n * k)
        bytes_lanes = 4 * k * (n * n + 2 * n)
        rows.append({
            "name": f"block_matvec_n{n}_k{k}",
            "us": t_gemm * 1e6,
            "us_vmapped_gemv": t_lanes * 1e6,
            "hbm_bytes_block": bytes_block,
            "hbm_bytes_k_gemv": bytes_lanes,
            "traffic_ratio": bytes_block / bytes_lanes,
            "derived": (f"block/k_gemv_hbm={bytes_block / bytes_lanes:.2f} "
                        f"ai_gain={k}x "
                        f"tpu_mem_bound_block={bytes_block / HBM_BW * 1e6:.1f}us"),
        })
    return rows


def spmv_traffic(n: int, width: int, nbands: int, s: int = 4):
    """Modeled per-matvec HBM bytes: ELL / banded SpMV vs the dense GEMV.

    ELL streams the (n, width) values in storage dtype plus the int32 cols,
    and reads/writes x/y once; the banded kernel streams only the band
    stack (offsets are static).  Dense GEMV streams the full (n, n) matrix
    — for stencil systems that is O(n/width) more traffic, which is why
    sparse GMRES iterations are matvec-cheap and orthogonalization-bound.
    """
    ell = n * width * (s + 4) + 2 * s * n            # values + cols, x + y
    banded = nbands * n * s + 2 * s * n              # bands, x + y
    dense = s * (n * n + 2 * n)
    return ell, banded, dense


def spmv_rows(grids=((64, 64), (128, 128), (256, 256))):
    """Sparse SpMV rows: measured jnp-reference wall time + modeled traffic.

    Each grid is a 2-D Poisson five-point system (core/stencils.py) run
    through both sparse formats.  CPU wall-times are the jnp reference path
    (the XLA lowering the dry-run uses); the TPU-relevant quantities are
    the modeled HBM bytes and their ratio to the dense GEMV stream.
    """
    from repro.core import stencils
    from repro.kernels import spmv

    rows = []
    for nx, ny in grids:
        n = nx * ny
        banded = stencils.poisson_2d(nx, ny)
        ell = banded.to_ell()
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        ell_fn = _pick(lambda v: spmv.ell_matvec(ell.values, ell.cols, v,
                                                 interpret=_interp()),
                       lambda v: ell(v))
        band_fn = _pick(lambda v: spmv.banded_matvec(banded.bands, v,
                                                     banded.offsets,
                                                     interpret=_interp()),
                        lambda v: banded(v))
        t_ell = _time(jax.jit(ell_fn), x)
        t_banded = _time(jax.jit(band_fn), x)
        width = ell.values.shape[1]
        nbands = banded.bands.shape[0]
        b_ell, b_banded, b_dense = spmv_traffic(n, width, nbands)
        rows.append({
            "name": f"spmv_ell_poisson2d_{nx}x{ny}",
            "us": t_ell * 1e6,
            "hbm_bytes_ell": b_ell,
            "hbm_bytes_dense_gemv": b_dense,
            "traffic_ratio": b_ell / b_dense,
            "derived": (f"ell/dense_hbm={b_ell / b_dense:.4f} "
                        f"width={width} "
                        f"tpu_mem_bound={b_ell / HBM_BW * 1e6:.2f}us "
                        f"x_vmem_resident_kib={4 * n // 1024}"),
        })
        rows.append({
            "name": f"spmv_banded_poisson2d_{nx}x{ny}",
            "us": t_banded * 1e6,
            "hbm_bytes_banded": b_banded,
            "hbm_bytes_dense_gemv": b_dense,
            "traffic_ratio": b_banded / b_dense,
            "derived": (f"banded/dense_hbm={b_banded / b_dense:.4f} "
                        f"nbands={nbands} "
                        f"tpu_mem_bound={b_banded / HBM_BW * 1e6:.2f}us "
                        f"gather_free=1"),
        })
    return _tag(rows)


def sell_traffic(n: int, storage_entries: int, ell_width: int,
                 identity_perm: bool, s: int = 4):
    """Modeled per-matvec HBM bytes: sliced-ELL vs the plain ELL stream.

    Plain ELL pads EVERY row to the global max width; sliced ELL stores
    each slice at its own width, so its matrix stream is the actual
    storage rectangle sum.  A sorted layout additionally reads the int32
    row permutation to scatter y back (4n bytes); identity-order builds
    (regular stencils under sort='auto') skip it — that is the
    never-worse contract the gate enforces on stencil rows.
    """
    ell = n * ell_width * (s + 4) + 2 * s * n
    sell = (storage_entries * (s + 4)
            + (0 if identity_perm else 4 * n) + 2 * s * n)
    return ell, sell


def sell_spmv_rows(graph_ns=(2048, 4096), grids=((64, 64), (128, 128))):
    """Sliced-ELL SpMV rows: power-law graphs (the win) + stencils (the
    never-worse guard).

    Power-law graph Laplacians (core/graphs.py) have hub rows that set
    plain ELL's global width while the median row is ~100x narrower —
    the padding plain ELL streams from HBM every matvec is the format's
    entire cost.  Sliced ELL bins nnz-sorted rows into fixed-height
    slices padded to their OWN width; the acceptance bar
    (tools/bench_gate.py rule 7) is a >= 3x modeled traffic cut there
    and <= 1.05x on the regular stencil rows, where sort='auto' keeps
    identity order and the format degenerates to ELL.
    """
    from repro.core import graphs, stencils
    from repro.kernels import spmv

    rows = []

    def _row(name, op, ell_op):
        n = op.shape[0]
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        sell_fn = _pick(lambda v: spmv.sell_matvec(op.bin_values,
                                                   op.bin_cols, v,
                                                   interpret=_interp()),
                        lambda v: op(v))
        t = _time(jax.jit(sell_fn), x)
        width = ell_op.values.shape[1]
        nnz = int(np.count_nonzero(np.asarray(ell_op.values)))
        store = int(op.storage_entries)
        b_ell, b_sell = sell_traffic(n, store, width, op.identity_perm)
        rows.append({
            "name": name,
            "us": t * 1e6,
            "hbm_bytes_ell": b_ell,
            "hbm_bytes_sell": b_sell,
            "traffic_ratio": b_sell / b_ell,
            "derived": (f"sell/ell_hbm={b_sell / b_ell:.4f} "
                        f"ell_width={width} bins={len(op.bin_values)} "
                        f"identity_perm={int(op.identity_perm)} "
                        f"pad_overhead={store / max(nnz, 1) - 1:.3f} "
                        f"ell_pad_overhead="
                        f"{n * width / max(nnz, 1) - 1:.3f} "
                        f"tpu_mem_bound={b_sell / HBM_BW * 1e6:.2f}us"),
        })

    for n in graph_ns:
        op = graphs.graph_laplacian(n, seed=0, fmt="sell", backend="pallas")
        _row(f"sell_spmv_powerlaw_n{n}", op, op.to_ell())
    for nx, ny in grids:
        op = stencils.poisson_2d(nx, ny, fmt="sell", backend="pallas")
        ell = stencils.poisson_2d(nx, ny, fmt="ell")
        _row(f"sell_spmv_poisson2d_{nx}x{ny}", op, ell)
    return _tag(rows)


def graph_rows(cases=((1024, 8, 12, 16), (2048, 4, 12, 8))):
    """PageRank-burst serving rows: sliced-ELL handles under the
    continuous-batching server.

    Each case submits ``nreq`` personalized-PageRank solves
    ((I - alpha P) x = (1 - alpha) v, core/graphs.py) of one power-law
    web graph through ``repro.serve.SolverServer`` keyed on a
    ``slicedell`` handle, and reports the same packed / sequential /
    ideal lockstep-cycle contract as the solver_serve_* family (gate
    rule 4).  The A-traffic column uses the sliced-ELL stream — the
    matrix every resident lane shares per Arnoldi step — so the row
    composes the serving win with the format win.
    """
    import math

    from repro.core import graphs
    from repro.serve import SolverServer
    from repro.serve.handles import operator_fmt

    forced = os.environ.get("REPRO_KERNELS")
    if MODE == "modeled":
        os.environ["REPRO_KERNELS"] = "ref"
    try:
        rows = []
        for n, k, m, nreq in cases:
            op, make_rhs = graphs.pagerank_system(n, seed=0, fmt="sell",
                                                  backend="pallas")
            assert operator_fmt(op) == "slicedell", operator_fmt(op)
            rng = np.random.default_rng(0)
            # Mixed personalization tolerances, tightest first (the same
            # longest-processing-time packing the solver_serve rows use):
            # heterogeneous restart counts are what early retirement packs.
            tols = [1e-6, 1e-5, 1e-4, 1e-3]
            work = sorted(tols[i % len(tols)] for i in range(nreq))
            srv = SolverServer(op, m=m, k=k, max_pending=2 * nreq)
            t0 = time.perf_counter()
            rids = [srv.submit(np.asarray(make_rhs(rng.random(n) + 0.1)),
                               tol=t, max_restarts=100) for t in work]
            packed = srv.run()
            wall = time.perf_counter() - t0
            outs = [srv.results[r] for r in rids]
            assert all(o.status == "done" for o in outs), \
                f"pagerank serve solve failed: {[o.status for o in outs]}"
            restarts = [o.restarts for o in outs]
            seq = sum(restarts)
            ideal = max(math.ceil(seq / k), max(restarts))
            a_step = int(op.storage_entries) * 8  # values + int32 cols
            rows.append({
                "name": f"graph_pagerank_serve_n{n}_k{k}_req{nreq}",
                "us": wall * 1e6 / nreq,
                "cycles_packed": packed,
                "cycles_sequential": seq,
                "cycles_ideal": ideal,
                "hbm_bytes_packed_A": packed * m * a_step,
                "hbm_bytes_sequential_A": seq * m * a_step,
                "traffic_ratio": packed / seq,
                "derived": (f"packed/sequential_cycles={packed / seq:.3f} "
                            f"packed/ideal={packed / ideal:.3f} "
                            f"fmt={srv.handle.key.fmt} "
                            f"bins={len(op.bin_values)} "
                            f"mass_err={max(abs(float(np.sum(o.x)) - 1.0) for o in outs):.2e}"),
            })
        return _tag(rows)
    finally:
        if forced is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = forced


def _record_measured_blocks(cases=((4096, 9), (16384, 9))):
    """--measure autotune: race the ELL kernel's row-block candidates on
    THIS device and overwrite the persistent tuning cache with each
    winner (``tuning.record_tuned``), so every later operator call that
    hits the same (n, width, dtype, k) key — solver, server, bench —
    uses the measured block instead of the VMEM-model guess.  Keys
    mirror the ``SparseOperator`` call site exactly.
    """
    from repro.kernels import spmv, tuning

    recorded = {}
    for n, width in cases:
        rng = np.random.default_rng(0)
        vals = jnp.asarray(rng.standard_normal((n, width)), jnp.float32)
        cols = jnp.asarray(rng.integers(0, n, (n, width)), jnp.int32)
        x = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
        best, best_t = None, float("inf")
        for bm in (128, 256, 512, 1024):
            if bm > n:
                break
            fn = jax.jit(lambda v, bm=bm: spmv.ell_matvec(
                vals, cols, v, block_m=bm, interpret=_interp()))
            t = _time(fn, x, repeats=3)
            if t < best_t:
                best, best_t = bm, t
        key = tuning.record_tuned(tuning.choose_spmv_block, best,
                                  n, width, "float32", k=1)
        recorded[key] = best
        print(f"# autotune: {key} -> block_m={best} ({best_t * 1e6:.0f}us)")
    return recorded


def sstep_powers_traffic(n: int, nbands: int, s: int):
    """Modeled HBM bytes for s Krylov powers: fused banded kernel vs s SpMVs.

    The fused kernel (kernels/matrix_powers.py) holds the band stack AND
    the operand in VMEM: bands + x stream in once, the (s, n) power block
    streams out once, and no intermediate u_j ever exists in HBM.  Unfused,
    every power is a separate banded SpMV launch (bands re-streamed, u in,
    w out) plus the normalization round-trip (w re-read for the norm/scale,
    u written) that the kernel runs in-register.
    """
    fused = (nbands * n + n + s * n) * 4
    unfused = s * (nbands * n + 2 * n) * 4 + s * 2 * n * 4
    return fused, unfused


def sstep_powers_rows(grids=((64, 64, 2), (128, 128, 4), (256, 256, 8))):
    """s-step matrix-powers rows: measured jnp ref + modeled fused traffic.

    Each case runs the five-point Poisson power sequence; the measured
    number is the sequential-scan jnp reference (what the kernel replaces),
    the modeled numbers are the one-launch banded kernel's HBM bytes vs the
    s separate SpMV launches.  (The dense variant's A stream is irreducible
    — once per power — so only the banded rows carry a traffic headline.)
    """
    from repro.core import stencils
    from repro.kernels import matrix_powers

    rows = []
    for nx, ny, s in grids:
        n = nx * ny
        op = stencils.poisson_2d(nx, ny)
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        x = x / jnp.linalg.norm(x)
        eps = float(jnp.finfo(jnp.float32).eps) * 100
        powers = jax.jit(_pick(
            lambda v: matrix_powers.banded_powers(op.bands, v, op.offsets, s,
                                                  interpret=_interp()),
            lambda v: matrix_powers.matrix_powers_ref(op, v, s, eps)))
        t = _time(powers, x)
        nbands = op.bands.shape[0]
        fused, unfused = sstep_powers_traffic(n, nbands, s)
        ratio = fused / unfused
        rows.append({
            "name": f"sstep_powers_banded_poisson2d_{nx}x{ny}_s{s}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_s_spmv": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/s_spmv_hbm={ratio:.2f} "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.2f}us "
                        f"A_hbm_passes=1 u_roundtrips=0 "
                        f"bands_vmem_kib={nbands * n * 4 // 1024}"),
        })
    return _tag(rows)


def block_gs_traffic(m1: int, n: int, s: int):
    """Modeled HBM bytes per s-step block orthogonalization (CGS2+CholQR).

    Fused (kernels/block_gs.py): the basis is VMEM-resident per pass, so V
    streams ONCE per CGS2 pass (2 total) and the power block streams in/out
    once per pass; the CholQR Gram matrices accumulate in-register.
    Unfused jnp: each pass streams V twice (projection + update) and each
    CholQR re-streams the block for the Gram matrix and again for the
    triangular solve.
    """
    fused = 2 * (m1 * n + 2 * s * n) * 4
    unfused = 2 * (2 * m1 * n + 2 * s * n) * 4 + 2 * 3 * s * n * 4
    return fused, unfused


def block_gs_rows(cases=((21, 4096, 4), (33, 16384, 4), (65, 8192, 8)),
                  batched_cases=((31, 4096, 8), (31, 16384, 4))):
    """Block Gram-Schmidt rows: s-step block pass + the batched-lane form.

    (m1, n, s) span shallow/deep restart regimes.  The batched rows model
    ``gmres_batched``'s per-lane CGS2 (s = 1, one basis per lane): the
    kernel holds each lane's basis resident for BOTH passes — one V stream
    per Arnoldi step vs the vmapped reference's four.
    """
    from repro.kernels import block_gs

    rows = []
    for m1, n, s in cases:
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        w = jax.random.normal(jax.random.PRNGKey(1), (s, n))
        tin = jnp.eye(s)
        mask = jnp.ones((m1,), jnp.float32)
        pass_fn = _pick(lambda v, w, t, mk: block_gs.block_gs_pass(
            v, w, t, mk, interpret=_interp()), block_gs.block_gs_pass_ref)
        t = _time(jax.jit(pass_fn), v, w, tin, mask)
        fused, unfused = block_gs_traffic(m1, n, s)
        ratio = fused / unfused
        rows.append({
            "name": f"block_gs_m{m1 - 1}_n{n}_s{s}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_unfused": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/unfused_hbm={ratio:.2f} "
                        f"passes_over_V=2of4 W_roundtrips=0 "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.1f}us"),
        })
    # batched per-lane CGS2 (gmres_batched): k lanes, one basis each
    for m1, n, k in batched_cases:
        fused_lane = (m1 * n + 2 * n) * 4          # V once, w in, w'' out
        unfused_lane = (4 * m1 * n + 4 * n) * 4    # V 2x/pass, w 2x/pass
        ratio = fused_lane / unfused_lane
        vb = jax.random.normal(jax.random.PRNGKey(2), (k, m1, n)) / np.sqrt(n)
        wb = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        maskb = jnp.ones((k, m1), jnp.float32)
        batched_fn = _pick(lambda v, w, mk: block_gs.batched_cgs2(
            v, w, mk, interpret=_interp()), jax.vmap(ref.cgs2))
        t = _time(jax.jit(batched_fn), vb, wb, maskb)
        rows.append({
            "name": f"block_gs_batched_m{m1 - 1}_n{n}_k{k}",
            "us": t * 1e6,
            "hbm_bytes_fused": k * fused_lane,
            "hbm_bytes_vmapped_cgs2": k * unfused_lane,
            "traffic_ratio": ratio,
            "derived": (f"fused/vmapped_hbm={ratio:.2f} "
                        f"per_lane_V_streams=1of4 "
                        f"lane_vmem_kib={m1 * n * 4 // 1024}"),
        })
    return _tag(rows)


def sharded_cgs2_traffic(m1: int, n: int, p: int):
    """Modeled per-shard HBM bytes for the split-phase CGS2 pair vs the
    single-device streaming kernel at the same GLOBAL n.

    Per CGS2 (two passes) the split pair streams the local basis twice per
    pass (project kernel + update kernel — the same count as the fused
    kernel's two-phase grid), the w shard twice per pass, and writes the
    orthogonalized shard once per pass; h crosses HBM around each phase.
    The single-device fused kernel moves the same structure over the full
    n.  The point of the row: per-shard traffic is 1/P of the global
    stream while the collective payload is 2 h-vectors (8*m1 bytes) per
    CGS2 — constant in n.
    """
    ln = n // p
    per_shard = 2 * (2 * m1 * ln + 2 * ln + ln + 4 * m1) * 4
    single = 2 * (2 * m1 * n + 2 * n + n + 4 * m1) * 4
    psum_bytes = 2 * m1 * 4
    return per_shard, single, psum_bytes


def sharded_rows(cases=((33, 65536, 8), (33, 262144, 8), (65, 65536, 4)),
                 grids=((128, 128, 8), (256, 256, 8))):
    """Row-sharded kernel-path rows: split-phase CGS2 + halo SpMV.

    ``us`` is the measured jnp reference arithmetic of ONE shard on this
    host (the same convention as every other row: the reference the
    kernel replaces); the modeled numbers carry the story — per-shard
    HBM bytes scale 1/P while the exchanged bytes are O(m1) for the CGS2
    psums and O(halo) for the SpMV halo exchange, vs the O(n) all-gather
    the pre-PR-5 fallback implied.
    """
    from repro.core import stencils
    from repro.kernels import spmv

    rows = []
    for m1, n, p in cases:
        ln = n // p
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, ln)) / np.sqrt(ln)
        w = jax.random.normal(jax.random.PRNGKey(1), (ln,))
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(jax.jit(ref.cgs2), v, w, mask)
        shard, single, psum_bytes = sharded_cgs2_traffic(m1, n, p)
        rows.append({
            "name": f"sharded_cgs2_m{m1 - 1}_n{n}_p{p}",
            "us": t * 1e6,
            "hbm_bytes_per_shard": shard,
            "hbm_bytes_single_device": single,
            "traffic_ratio": shard / single,
            "derived": (f"shard/single_hbm={shard / single:.3f} "
                        f"psum_payload_B={psum_bytes} "
                        f"collective_rounds_per_step=2 "
                        f"tpu_mem_bound_shard={shard / HBM_BW * 1e6:.1f}us"),
        })
    for nx, ny, p in grids:
        n = nx * ny
        ln = n // p
        op = stencils.poisson_2d(nx, ny)
        nbands = op.bands.shape[0]
        halo = max(abs(int(o)) for o in op.offsets)
        x = jax.random.normal(jax.random.PRNGKey(1), (ln,))
        bands_local = op.bands[:, :ln]
        t = _time(jax.jit(lambda bl, xl: spmv.banded_matvec_halo_ref(
            bl, jnp.pad(xl, (halo, halo)), op.offsets)), bands_local, x)
        shard = (nbands * ln + (ln + 2 * halo) + ln) * 4
        single = (nbands * n + 2 * n) * 4
        exch = 2 * halo * 4
        gather = (n - ln) * 4
        rows.append({
            "name": f"sharded_spmv_banded_poisson2d_{nx}x{ny}_p{p}",
            "us": t * 1e6,
            "hbm_bytes_per_shard": shard,
            "hbm_bytes_single_device": single,
            "traffic_ratio": shard / single,
            "derived": (f"shard/single_hbm={shard / single:.3f} "
                        f"halo_exchange_B={exch} allgather_B={gather} "
                        f"exchange/gather={exch / gather:.2e} "
                        f"x_vmem_resident_kib={4 * (ln + 2 * halo) // 1024}"),
        })
        ell = op.to_ell()
        width = ell.values.shape[1]
        vals_local = ell.values[:ln]
        cols_local = jnp.clip(ell.cols[:ln] + halo, 0, ln + 2 * halo - 1)
        t_ell = _time(jax.jit(lambda vl, cl, xl: spmv.ell_matvec_ref(
            vl, cl, jnp.pad(xl, (halo, halo)))), vals_local, cols_local, x)
        shard_e = (ln * width * (4 + 4) + (ln + 2 * halo) * 4 + ln * 4)
        single_e = (n * width * (4 + 4) + 2 * n * 4)
        rows.append({
            "name": f"sharded_spmv_ell_poisson2d_{nx}x{ny}_p{p}",
            "us": t_ell * 1e6,
            "hbm_bytes_per_shard": shard_e,
            "hbm_bytes_single_device": single_e,
            "traffic_ratio": shard_e / single_e,
            "derived": (f"shard/single_hbm={shard_e / single_e:.3f} "
                        f"halo_exchange_B={exch} allgather_B={gather} "
                        f"halo={halo} width={width}"),
        })
    return rows


_PIPE_CODE = textwrap.dedent("""
    import json, sys
    import jax, jax.numpy as jnp
    from repro.core import gmres_sharded, stencils
    from repro.compat import make_mesh
    from repro.roofline import innermost_loop_collectives

    # DENSE 2-D Poisson: dense storage exercises the all-gather matvec
    # schedule (the 2x claim), the Poisson spectrum makes convergence
    # genuinely iterative — restart parity is exact, not a coin flip at
    # the tolerance floor like diag-dominant random systems (which
    # converge in ~5 steps and stop AT the fp32 noise level).
    nx, m = int(sys.argv[1]), int(sys.argv[2])
    n = nx * nx
    op = stencils.poisson_2d(nx, nx)
    a = jnp.zeros((n, n), op.bands.dtype)
    for d, off in enumerate(op.offsets):
        off = int(off)
        if off >= 0:
            a = a + jnp.diag(op.bands[d, :n - off], k=off)
        else:
            a = a + jnp.diag(op.bands[d, -off:], k=off)
    b = jnp.sin(jnp.arange(n) * 0.37)
    mesh = make_mesh((4,), ('model',))
    out = {}
    for tag, gs in (("split", "cgs2"), ("pipelined", "cgs2_pipelined")):
        jsol = jax.jit(lambda a, b, gs=gs: gmres_sharded(
            mesh, 'model', a, b, m=m, tol=1e-4, gs=gs, max_restarts=60))
        hlo = jsol.lower(a, b).compile().as_text()
        _, ops = innermost_loop_collectives(hlo)
        out["loop_coll_ops_" + tag] = sum(o.count for o in ops)
        out["loop_psums_" + tag] = sum(o.count for o in ops
                                       if o.kind == "all-reduce")
        r = jsol(a, b)
        out["restarts_" + tag] = int(r.restarts)
        out["residual_" + tag] = float(r.residual)
    print(json.dumps(out))
""")


def _pipelined_hlo_counts(nx: int, m: int):
    """Lower both sharded schemes on 4 fake devices; parse the inner loop.

    Subprocess so the parent keeps its 1-device view (the same trick as
    benchmarks/distributed_gmres.py).  Raises on failure — the row is the
    PR's acceptance evidence and must not silently degrade to a placeholder.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _PIPE_CODE, str(nx), str(m)],
                         env=env, capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"pipelined HLO probe failed: {res.stderr[-500:]}")
    return json.loads(res.stdout.strip().splitlines()[-1])


def pipelined_rows(cases=((20, 16384), (30, 65536)), hlo_case=(32, 20)):
    """Pipelined single-reduce CGS2 rows: psum schedule + the HLO proof.

    Schedule rows: the split-phase path psums 3 scalars-ish payloads per
    Arnoldi step (projection pass 1, projection pass 2, norm); the
    single-reduce scheme fuses them into ONE (m1+1, 2)-block psum
    ([V @ [z, v_j]; norms] — projections plus the measured Gram row)
    whose launch overlaps the next SpMV.  ``us`` times the local recovery
    arithmetic (payload + delayed-reorthogonalization algebra) — the
    compute added to save two latency-bound rounds.

    The ``pipelined_hlo_p4`` row lowers BOTH sharded solvers (dense 2-D
    Poisson, hlo_case = (nx, m)) on 4 fake devices and reads the collective
    schedule off the innermost while body of the optimized HLO — the PR's
    acceptance metric (>= 2x fewer collectives per step at residual parity)
    asserted by tools/bench_gate.py.
    """
    from repro.core import arnoldi

    rows = []
    for m, n in cases:
        m1 = m + 1
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        z = jax.random.normal(jax.random.PRNGKey(1), (n,))
        gram = jnp.eye(m1)

        def _step(v, z, gram, j=m // 2):
            payload = arnoldi.sr_payload_ref(v, z, j, None)
            return arnoldi.sr_recover(payload, gram, j)

        t = _time(jax.jit(_step), v, z, gram)
        split_bytes = (2 * m1 + 1) * 4      # h psum x2 + the norm scalar
        payload_bytes = 2 * (m1 + 1) * 4    # [V@[z,v_j]; norms] block
        rows.append({
            "name": f"pipelined_schedule_m{m}_n{n}",
            "us": t * 1e6,
            "psums_per_step_split": 3,
            "psums_per_step_pipelined": 1,
            "psum_bytes_split": split_bytes,
            "psum_bytes_pipelined": payload_bytes,
            "derived": (f"psum_rounds=1of3 "
                        f"payload_B={payload_bytes} split_B={split_bytes} "
                        f"overlapped_with_next_spmv=1"),
        })
    c = _pipelined_hlo_counts(*hlo_case)
    ratio = c["loop_coll_ops_split"] / max(c["loop_coll_ops_pipelined"], 1)
    rows.append({
        "name": (f"pipelined_hlo_p4_poisson{hlo_case[0]}x{hlo_case[0]}"
                 f"_m{hlo_case[1]}"),
        "us": 0.0,
        "loop_coll_ops_split": c["loop_coll_ops_split"],
        "loop_coll_ops_pipelined": c["loop_coll_ops_pipelined"],
        "loop_psums_split": c["loop_psums_split"],
        "loop_psums_pipelined": c["loop_psums_pipelined"],
        "restarts_split": c["restarts_split"],
        "restarts_pipelined": c["restarts_pipelined"],
        "loop_coll_ratio": ratio,
        "derived": (f"loop_coll_ops={c['loop_coll_ops_split']}->"
                    f"{c['loop_coll_ops_pipelined']} ({ratio:.2f}x) "
                    f"loop_psums={c['loop_psums_split']}->"
                    f"{c['loop_psums_pipelined']} "
                    f"restarts={c['restarts_split']}vs"
                    f"{c['restarts_pipelined']} "
                    f"residual_split={c['residual_split']:.2e} "
                    f"residual_pipelined={c['residual_pipelined']:.2e}"),
    })
    return rows


def precision_restart_rows(grids=((24, 24), (32, 32)), dense_ns=(512,),
                           m: int = 20, tol: float = 1e-4):
    """compute_dtype=bf16 precision-vs-restarts sweep (ROADMAP item).

    Each case solves the SAME system twice — f32 basis vs bf16 basis
    storage — through the jnp cgs2 path and reports the convergence cost
    (extra inner steps / restarts) against the modeled basis-stream
    saving: the Krylov basis is streamed 4x per CGS2 step, so bf16
    storage halves the dominant orthogonalization traffic and the row's
    ``traffic_ratio`` is 0.5 * steps_bf16 / steps_f32 — below 1.0 means
    the precision trade WINS end-to-end on basis bytes.
    """
    from repro.core import gmres, stencils
    from repro.core.operators import random_diagdom

    def _sweep(name, op, b, n):
        f32 = jax.jit(lambda op, b: gmres(op, b, m=m, tol=tol,
                                          max_restarts=400))
        bf16 = jax.jit(lambda op, b: gmres(op, b, m=m, tol=tol,
                                           max_restarts=400,
                                           compute_dtype=jnp.bfloat16))
        r32 = f32(op, b)
        t = _time(bf16, op, b)
        r16 = bf16(op, b)
        s32, s16 = int(r32.inner_steps), int(r16.inner_steps)
        m1 = m + 1
        bytes32 = s32 * 4 * m1 * n * 4
        bytes16 = s16 * 4 * m1 * n * 2
        return {
            "name": name,
            "us": t * 1e6,
            "hbm_bytes_basis_f32": bytes32,
            "hbm_bytes_basis_bf16": bytes16,
            "traffic_ratio": bytes16 / bytes32 if bytes32 else 1.0,
            "derived": (f"bf16/f32_basis_hbm={bytes16 / max(bytes32, 1):.2f} "
                        f"steps_f32={s32} steps_bf16={s16} "
                        f"restarts_f32={int(r32.restarts)} "
                        f"restarts_bf16={int(r16.restarts)} "
                        f"conv_f32={int(r32.converged)} "
                        f"conv_bf16={int(r16.converged)}"),
        }

    rows = []
    for nx, ny in grids:
        n = nx * ny
        op = stencils.poisson_2d(nx, ny)
        b = jnp.sin(jnp.arange(n) * 0.37)
        rows.append(_sweep(f"precision_restarts_poisson2d_{nx}x{ny}_bf16",
                           op, b, n))
    for n in dense_ns:
        a = random_diagdom(jax.random.PRNGKey(3), n)
        b = jax.random.normal(jax.random.PRNGKey(4), (n,))
        rows.append(_sweep(f"precision_restarts_diagdom_n{n}_bf16", a, b, n))
    return rows


_PRECOND_PIPE_CODE = textwrap.dedent("""
    import json, sys
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core import gmres_sharded, stencils
    from repro.roofline import innermost_loop_collectives

    # BANDED stencil: the halo-exchange mat-vec path, which is what the
    # Chebyshev apply rides sharded (order ppermutes, zero psums) — the
    # row proves preconditioning leaves the pipelined one-psum-per-step
    # schedule intact.
    nx, m = int(sys.argv[1]), int(sys.argv[2])
    op = stencils.poisson_2d(nx, nx)
    b = jnp.sin(jnp.arange(nx * nx) * 0.37)
    mesh = make_mesh((4,), ('model',))
    out = {}
    for tag, pc in (("unprecond", None), ("cheb", "chebyshev")):
        jsol = jax.jit(lambda bb, pc=pc: gmres_sharded(
            mesh, 'model', op, bb, m=m, tol=1e-4, max_restarts=80,
            gs='cgs2_pipelined', precond=pc))
        hlo = jsol.lower(b).compile().as_text()
        _, ops = innermost_loop_collectives(hlo)
        out["loop_psums_" + tag] = sum(o.count for o in ops
                                       if o.kind == "all-reduce")
        out["loop_coll_ops_" + tag] = sum(o.count for o in ops)
        r = jsol(b)
        out["restarts_" + tag] = int(r.restarts)
        out["converged_" + tag] = bool(r.converged)
    print(json.dumps(out))
""")


def _precond_hlo_counts(nx: int, m: int):
    """Lower the sharded pipelined solve with/without Chebyshev on 4 fake
    devices; read the collective schedule off the innermost while body.
    Subprocess so the parent keeps its 1-device view; raises on failure —
    the row is acceptance evidence and must not degrade to a placeholder.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run(
        [sys.executable, "-c", _PRECOND_PIPE_CODE, str(nx), str(m)],
        env=env, capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        raise RuntimeError(f"precond HLO probe failed: {res.stderr[-500:]}")
    return json.loads(res.stdout.strip().splitlines()[-1])


def precond_rows(grids=((12, 12), (16, 16)), m: int = 16, tol: float = 1e-5,
                 hlo_case=(16, 16)):
    """Preconditioning rows: restart counts, modeled cost, fused traffic.

    ``precond_restarts_*``: the SAME system solved at the SAME tol,
    unpreconditioned vs Chebyshev(4) vs banded ILU(0) (vs line-Jacobi for
    reference), through the jnp ref path pinned via ``force_kernel_mode``
    (ref and kernel arithmetic are test-pinned identical, and restart
    counts are what the row measures).  ``cost_adjusted_steps`` prices
    each inner step at ``1 + matvec_equiv`` mat-vec equivalents from the
    protocol's ``cost()`` model — the honest fewer-steps-vs-dearer-steps
    ledger.  ``tools/bench_gate.py`` gates ``restarts_precond * factor <=
    restarts_unprecond`` (factor 2 — the acceptance bar) on the chebyshev
    and banded_ilu0 rows.

    ``precond_cheb_fused_traffic_*``: the fused recurrence kernel
    (``banded_cheb_apply``) streams the band stack ONCE per apply with
    the iterate VMEM-resident, vs ``order`` full mat-vec round trips for
    the unfused loop — the same one-HBM-pass structure as the s-step
    matrix-powers kernel it shares plumbing with.

    ``precond_pipelined_hlo_p4``: lowers the 4-shard pipelined solve
    with and without Chebyshev and proves the preconditioned inner loop
    keeps the one-psum-per-step schedule (``psums_per_step_pipelined``
    picks up bench_gate's ==1 absolute check).
    """
    from repro.core import gmres, stencils
    from repro.core import preconditioners as pc_mod
    from repro.kernels import tuning

    rows = []
    systems = [("poisson2d", stencils.poisson_2d),
               ("convdiff2d", stencils.convection_diffusion_2d)]
    for nx, ny in grids:
        n = nx * ny
        for sysname, make in systems:
            op = make(nx, ny)
            b = jnp.sin(jnp.arange(n) * 0.37)
            with tuning.force_kernel_mode("ref"):
                plain = gmres(op, b, m=m, tol=tol, max_restarts=200)
                r0, s0 = int(plain.restarts), int(plain.inner_steps)
                for pcname, pc in (
                        ("chebyshev4", pc_mod.chebyshev(op, order=4)),
                        ("banded_ilu0", pc_mod.banded_ilu0(op)),
                        ("line_jacobi", pc_mod.line_jacobi(op))):
                    sol = jax.jit(lambda bb, pc=pc: gmres(
                        op, bb, m=m, tol=tol, max_restarts=200, precond=pc))
                    t = _time(sol, b)
                    res = sol(b)
                    rr, ss = int(res.restarts), int(res.inner_steps)
                    mveq = 1.0 + pc.cost().matvec_equiv
                    rows.append({
                        "name": f"precond_restarts_{sysname}_{nx}x{ny}_"
                                f"{pcname}",
                        "us": t * 1e6,
                        "restarts_unprecond": r0,
                        "restarts_precond": rr,
                        "matvec_equiv": round(mveq, 3),
                        "cost_adjusted_steps": round(ss * mveq, 1),
                        "derived": (
                            f"restarts {r0}->{rr} steps {s0}->{ss} "
                            f"cost/step={mveq:.2f}x "
                            f"adj_steps={ss * mveq:.0f} vs {s0} "
                            f"conv={int(res.converged)} "
                            f"residual={float(res.residual):.2e}"),
                    })
    # Fused-recurrence HBM traffic: one band stream per apply vs order.
    for nx, order in ((64, 4), (128, 6)):
        n = nx * nx
        nbands = 5
        per_mv = 4 * (nbands * n + 2 * n)       # bands + read z + write w
        fused = 4 * (nbands * n + 2 * n)        # ONE pass, z/v VMEM-resident
        loop = order * per_mv
        rows.append({
            "name": f"precond_cheb_fused_traffic_n{n}_s{order}",
            "us": 0.0,
            "hbm_bytes_fused": fused,
            "hbm_bytes_loop": loop,
            "traffic_ratio": fused / loop,
            "derived": (f"fused/loop_hbm={fused / loop:.2f} "
                        f"order={order} nbands={nbands} "
                        f"(band stack streamed once per apply)"),
        })
    if hlo_case is not None:
        nx, mm = hlo_case
        c = _precond_hlo_counts(nx, mm)
        steps = max(c["restarts_cheb"], 1)
        rows.append({
            "name": "precond_pipelined_hlo_p4",
            "us": 0.0,
            "psums_per_step_pipelined": c["loop_psums_cheb"],
            "loop_psums_pipelined": c["loop_psums_cheb"],
            "loop_coll_ops_pipelined": c["loop_coll_ops_cheb"],
            "restarts_unprecond": c["restarts_unprecond"],
            "restarts_precond": c["restarts_cheb"],
            "derived": (
                f"4-shard pipelined inner loop: "
                f"psums {c['loop_psums_unprecond']} (unprecond) -> "
                f"{c['loop_psums_cheb']} (chebyshev) "
                f"coll_ops {c['loop_coll_ops_unprecond']} -> "
                f"{c['loop_coll_ops_cheb']} "
                f"restarts {c['restarts_unprecond']} -> "
                f"{c['restarts_cheb']} "
                f"conv={int(c['converged_cheb'])}"),
        })
    return rows


def attention_rows(cases=((1, 8, 8, 1024, 128), (1, 8, 2, 2048, 128))):
    rows = []
    attn = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    for (b, hq, hkv, s, d) in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
        t = _time(attn, q, k, v)
        flops = 4 * b * hq * s * s * d * 0.5      # causal half
        tpu_compute = flops / PEAK_FLOPS
        rows.append({
            "name": f"flash_attn_b{b}h{hq}kv{hkv}s{s}",
            "us": t * 1e6,
            "derived": (f"flops={flops / 1e9:.1f}G "
                        f"tpu_compute_bound={tpu_compute * 1e6:.1f}us "
                        f"vmem_per_step_kib={(128 * d * 4 * 3 + 128 * 128 * 4) // 1024}"),
        })
    return rows


def solver_serve_rows(cases=((160, 8, 10, 32), (160, 4, 10, 16),
                             (64, 4, 8, 8))):
    """Continuous-batching server rows: the lanes x early-retirement claim.

    Each case serves ``nreq`` heterogeneous solves (mixed tolerances,
    tightest submitted first — longest-processing-time packing) of one
    convection-diffusion system through ``repro.serve.SolverServer`` and
    counts actual lockstep cycles (``cycles_packed``) against two
    baselines derived from the SAME run's per-request restart counts:

      cycles_sequential   sum_i restarts_i — one solve at a time,
      cycles_ideal        max(ceil(sum_i restarts_i / k), max_i
                          restarts_i) — the lanes x early-retirement
                          model's floor (perfect packing, no tail).

    The acceptance contract (tools/bench_gate.py): packed completes in
    fewer cycles than sequential AND within 1.1x of ideal.  The HBM
    story is the same ratio in bytes: every cycle streams A once per
    Arnoldi step for ALL resident lanes, so packed A-traffic is
    cycles_packed/cycles_sequential of the one-lane-at-a-time stream.

    Under the default (modeled) mode the server runs the pure-jnp ref
    dispatch — these rows measure SCHEDULING, not kernels; ``--measure``
    lets the handle's normal dispatch pick interpret/compiled cycles.
    """
    import math

    from repro.core import operators
    from repro.serve import SolverServer

    forced = os.environ.get("REPRO_KERNELS")
    if MODE == "modeled":
        os.environ["REPRO_KERNELS"] = "ref"
    try:
        rows = []
        for n, k, m, nreq in cases:
            op = operators.DenseOperator(
                operators.convection_diffusion(n, beta=0.4))
            rng = np.random.default_rng(0)
            tols = [1e-5, 1e-4, 1e-3, 1e-2]
            work = sorted(tols[i % len(tols)] for i in range(nreq))
            srv = SolverServer(op, m=m, k=k, max_pending=2 * nreq)
            t0 = time.perf_counter()
            rids = [srv.submit(rng.standard_normal(n), tol=t,
                               max_restarts=100) for t in work]
            packed = srv.run()
            wall = time.perf_counter() - t0
            outs = [srv.results[r] for r in rids]
            assert all(o.status == "done" for o in outs), \
                f"serve bench solve failed: {[o.status for o in outs]}"
            restarts = [o.restarts for o in outs]
            seq = sum(restarts)
            ideal = max(math.ceil(seq / k), max(restarts))
            met = srv.metrics()
            a_step = 4 * n * n                   # one A stream per step
            rows.append({
                "name": f"solver_serve_n{n}_k{k}_req{nreq}",
                "us": wall * 1e6 / nreq,
                "cycles_packed": packed,
                "cycles_sequential": seq,
                "cycles_ideal": ideal,
                "hbm_bytes_packed_A": packed * m * a_step,
                "hbm_bytes_sequential_A": seq * m * a_step,
                "traffic_ratio": packed / seq,
                "derived": (f"packed/sequential_cycles={packed / seq:.3f} "
                            f"packed/ideal={packed / ideal:.3f} "
                            f"occupancy={met['occupancy']:.2f} "
                            f"retired_done={met['retired_done']} "
                            f"retired_failed={met['retired_failed']} "
                            f"retirement_rate={met['retirement_rate']:.2f} "
                            f"handle_lru_misses="
                            f"{met['handle_cache']['misses']}"),
            })
        return _tag(rows)
    finally:
        if forced is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = forced


def recovery_rows(cases=((96, 4), (256, 8))):
    """Self-healing solver rows: fault-free overhead + recovery parity.

    Each case solves one convection-diffusion system four ways and
    reports DETERMINISTIC cycle counts (never wall time — the gate must
    not flake in CI):

      restarts_plain        plain fused ``gmres`` — the baseline,
      cycles_fault_free     ``gmres_self_healing`` with nothing armed —
                            must take the fused fast path, so its
                            committed-cycle count IS the baseline's,
      cycles_stepped        an armed-but-never-firing schedule forces the
                            cycle-stepped loop; it commits exactly the
                            cycles the fused while_loop would,
      restarts_recovered    a NaN injected into the first cycle: the
                            ladder discards it, re-runs one rung down,
                            and the recovered solve's restart count must
                            stay within +1 of fault-free.

    The acceptance contract (tools/bench_gate.py rule 5): both overhead
    ratios <= 1.02 and ``recovery_extra_restarts`` <= 1.  ``us`` times
    the fault-free self-healing call; ``us_plain`` the plain solve —
    informational, the gate only reads the cycle counts.
    """
    from repro.core import operators
    from repro.core.gmres import gmres
    from repro.core.recovery import gmres_self_healing
    from repro.runtime import faultinject

    forced = os.environ.get("REPRO_KERNELS")
    if MODE == "modeled":
        os.environ["REPRO_KERNELS"] = "ref"
    try:
        rows = []
        for n, m in cases:
            op = operators.DenseOperator(
                operators.convection_diffusion(n, beta=0.4))
            rng = np.random.default_rng(0)
            b = jnp.asarray(rng.standard_normal(n), jnp.float32)
            tol = 1e-5

            plain = lambda: np.asarray(gmres(
                op, b, m=m, tol=tol, max_restarts=100,
                gs="cgs2_pipelined").x)
            t_plain = _time(plain, repeats=2)
            ref_res = gmres(op, b, m=m, tol=tol, max_restarts=100,
                            gs="cgs2_pipelined")
            assert bool(ref_res.converged), f"recovery bench case n={n} " \
                                            f"m={m} did not converge"
            r0 = int(ref_res.restarts)

            heal = lambda: np.asarray(gmres_self_healing(
                op, b, m=m, tol=tol, max_restarts=100)[0].x)
            t_heal = _time(heal, repeats=2)
            res_ff, rep_ff = gmres_self_healing(op, b, m=m, tol=tol,
                                                max_restarts=100)
            assert rep_ff.fast_path, "fault-free solve left the fast path"
            c_ff = rep_ff.cycles

            with faultinject.inject("core.cycle", at=10 ** 9):
                res_st, rep_st = gmres_self_healing(op, b, m=m, tol=tol,
                                                    max_restarts=100)
            c_st = rep_st.cycles

            with faultinject.inject("core.cycle_nan", at=0):
                res_rec, rep_rec = gmres_self_healing(op, b, m=m, tol=tol,
                                                      max_restarts=100)
            assert bool(res_rec.converged), "injected solve did not recover"
            r2 = int(res_rec.restarts)

            rows.append({
                "name": f"recovery_selfheal_n{n}_m{m}",
                "us": t_heal * 1e6,
                "us_plain": t_plain * 1e6,
                "restarts_plain": r0,
                "cycles_fault_free": c_ff,
                "cycles_stepped": c_st,
                "overhead_ratio": c_ff / r0,
                "stepped_overhead_ratio": c_st / r0,
                "restarts_recovered": r2,
                "recovery_extra_restarts": r2 - r0,
                "stepdowns_recovered": rep_rec.stepdowns,
                "derived": (f"fastpath_cycles={c_ff}=={r0}plain "
                            f"stepped_cycles={c_st} "
                            f"recovered_restarts={r2} ({r2 - r0:+d}) "
                            f"stepdowns={rep_rec.stepdowns} "
                            f"selfheal/plain_wall="
                            f"{t_heal / max(t_plain, 1e-12):.2f}"),
            })
        return _tag(rows)
    finally:
        faultinject.reset()
        if forced is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = forced


def _validate_rows(rows):
    """Schema guard (what the CI smoke run asserts): every row carries the
    universal keys, names are unique, traffic rows have both byte counts,
    every row is mode-tagged."""
    names = [r["name"] for r in rows]
    assert len(set(names)) == len(names), "duplicate row names"
    for r in rows:
        assert isinstance(r["name"], str) and isinstance(r["derived"], str)
        assert r["us"] >= 0.0
        assert r.get("mode") in ("modeled", "measured", "interpret"), \
            f"{r['name']}: missing/bad mode tag {r.get('mode')!r}"
        if "traffic_ratio" in r:
            hbm = [k for k in r if k.startswith("hbm_bytes_")]
            assert len(hbm) == 2, (f"{r['name']}: traffic row needs 2 "
                                   f"hbm_bytes_* keys, has {hbm}")


def main(json_path: str = "BENCH_kernels.json", smoke: bool = False,
         measure: bool = False):
    global MODE
    MODE = _detect_mode() if measure else "modeled"
    if measure:
        # Autotune-by-measurement: persist the timing winners BEFORE the
        # row families run, so their operator calls pick them up.
        _record_measured_blocks(cases=((4096, 9),) if smoke
                                else ((4096, 9), (16384, 9)))
    if smoke:
        # CI schema guard: one cheap case per row family — EVERY family,
        # so no row's schema can drift unchecked — through the same code
        # paths as the full run.
        rows = (matvec_rows(sizes=(1024,)) + gs_rows(ns=(8192,))
                + fused_step_rows(cases=((96, 97),))
                + block_matvec_rows(cases=((2048, 8),))
                + spmv_rows(grids=((64, 64),))
                + sell_spmv_rows(graph_ns=(512,), grids=((64, 64),))
                + graph_rows(cases=((256, 4, 10, 6),))
                + sstep_powers_rows(grids=((64, 64, 4),))
                + block_gs_rows(cases=((21, 4096, 4),),
                                batched_cases=((31, 2048, 2),))
                + sharded_rows(cases=((33, 16384, 4),),
                               grids=((64, 64, 4),))
                + pipelined_rows(cases=((10, 4096),), hlo_case=(16, 8))
                + precision_restart_rows(grids=((16, 16),), dense_ns=(),
                                         tol=1e-3)
                + precond_rows(grids=((12, 12),), hlo_case=None)
                + solver_serve_rows(cases=((64, 4, 8, 8),))
                + recovery_rows(cases=((96, 4),))
                + attention_rows(cases=((1, 2, 2, 256, 64),)))
    else:
        rows = (matvec_rows() + gs_rows() + fused_step_rows()
                + block_matvec_rows() + spmv_rows() + sell_spmv_rows()
                + graph_rows() + sstep_powers_rows()
                + block_gs_rows() + sharded_rows() + pipelined_rows()
                + precision_restart_rows() + precond_rows()
                + solver_serve_rows() + recovery_rows() + attention_rows())
    for r in rows:
        r.setdefault("mode", MODE)
    _validate_rows(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")
    fused_ratios = {r["name"]: round(r["traffic_ratio"], 3)
                    for r in rows if "traffic_ratio" in r}
    # disjoint prefixes: "block_gs_m" (s-step block pass) vs
    # "block_gs_batched" (per-lane CGS2) have different baselines
    for prefix in ("fused_arnoldi", "sstep_powers", "block_gs_m",
                   "block_gs_batched"):
        best = min((v for k, v in fused_ratios.items()
                    if k.startswith(prefix)), default=None)
        if best is not None:
            print(f"# {prefix} best modeled HBM ratio: {best:.2f} "
                  f"(< 0.60 target met: {best < 0.60})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "kernel_bench",
                       "backend": jax.default_backend(),
                       "device": jax.devices()[0].device_kind,
                       "mode": MODE,
                       "rows": rows}, f, indent=1)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset (one case per family) — the CI "
                         "schema guard")
    ap.add_argument("--measure", action="store_true",
                    help="time the Pallas kernel path instead of the jnp "
                         "reference: compiled on an attached accelerator "
                         "(rows tagged 'measured'), interpreter on CPU "
                         "(rows tagged 'interpret'; relative timing only)")
    ap.add_argument("--json", default=None,
                    help="output path ('' to skip writing).  Default: "
                         "BENCH_kernels.json for a full run; NOT written "
                         "in --smoke mode (the committed file records the "
                         "full suite only)")
    args = ap.parse_args()
    if args.json is None:
        args.json = "" if args.smoke or args.measure else "BENCH_kernels.json"
    main(json_path=args.json, smoke=args.smoke, measure=args.measure)
