"""Kernel-layer benchmarks.

Two kinds of numbers:
  1. wall-time of the jit'd REFERENCE path on this CPU (what we can measure
     here — XLA-fused jnp, the same HLO the dry-run lowers), and
  2. STRUCTURAL metrics of the Pallas kernels (VMEM working set per grid
     step, arithmetic intensity, HBM traffic) — the quantities that
     determine TPU performance, derivable without hardware.

All rows are also dumped to ``BENCH_kernels.json`` so the perf trajectory
is machine-diffable across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, repeats=5):
    fn(*args)                      # warmup/compile
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def matvec_rows(sizes=(1024, 4096, 8192)):
    rows = []
    mv = jax.jit(ref.matvec)
    for n in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        t = _time(mv, a, x)
        flops = 2 * n * n
        bytes_ = 4 * (n * n + 2 * n)
        # Pallas tile (256, 512) f32: A tile 512 KiB + x tile 2 KiB in VMEM
        rows.append({
            "name": f"matvec_n{n}",
            "us": t * 1e6,
            "derived": (f"AI={flops / bytes_:.2f}flop/B "
                        f"tpu_mem_bound={bytes_ / HBM_BW * 1e6:.1f}us "
                        f"vmem_tile_kib=514"),
        })
    return rows


def gs_rows(ns=(8192, 65536), m1=33):
    rows = []
    gs = jax.jit(ref.cgs2)
    for n in ns:
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        w = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(gs, v, w, mask)
        # fused kernel: V streamed twice per pass (4x per CGS2);
        # jnp reference: V streamed 4x + h round-trips; fusion saves the
        # intermediate (m1, n_tiles) partials + w re-reads
        bytes_fused = 4 * (4 * m1 * n + 2 * n) * 1.0
        rows.append({
            "name": f"cgs2_m{m1}_n{n}",
            "us": t * 1e6,
            "derived": (f"tpu_mem_bound={bytes_fused / HBM_BW * 1e6:.1f}us "
                        f"passes_over_V=4"),
        })
    return rows


def fused_step_traffic(n: int, m1: int, s: int = 4):
    """Modeled per-Arnoldi-step HBM bytes: fused kernel vs unfused pair.

    Unfused = the matvec kernel (A, v in; f32 w out) followed by the
    streaming cgs2 kernel (V streamed TWICE per GS pass x 2 passes, w
    re-read per pass, h + w' written) — w and h round-trip through HBM
    between the two kernels and between passes.

    Fused (kernels/arnoldi_fused.py) = A, v_j and V each streamed ONCE per
    step (the basis is VMEM-resident through both CGS2 passes); only the
    final h and reorthogonalized w'' are ever written.
    """
    unfused = (s * (n * n + n) + 4 * n                       # matvec
               + 2 * (2 * s * m1 * n + 2 * s * n             # cgs2: V 2x/pass,
                      + 4 * m1 + 4 * n))                     #   w 2x, h+w' out
    fused = (s * (n * n + n + m1 * n)                        # A, v_j, V once
             + 4 * (m1 + n))                                 # h, w'' out
    return fused, unfused


def fused_step_rows(cases=((96, 97), (384, 129), (1024, 513), (4096, 33))):
    """Fused Arnoldi-step kernel vs the unfused matvec+cgs2 pair.

    (n, m1) cases span the paper's regimes: full-memory GMRES(n) on small
    systems (n=96 is the tier-1 Poisson config; m1 = n+1), deep restarts,
    and the large-n/shallow-restart tail where the A stream dominates both
    paths and fusion's win is the eliminated vector round-trips.
    """
    from repro.kernels import arnoldi_fused

    rows = []
    stepped = jax.jit(arnoldi_fused.arnoldi_step_ref)
    for n, m1 in cases:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n)) / np.sqrt(n)
        vb = jax.random.normal(jax.random.PRNGKey(1), (m1, n)) / np.sqrt(n)
        t = _time(stepped, a, vb, m1 // 2)
        fused, unfused = fused_step_traffic(n, m1)
        ratio = fused / unfused
        rows.append({
            "name": f"fused_arnoldi_step_n{n}_m{m1 - 1}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_unfused_pair": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/unfused_hbm={ratio:.2f} "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.1f}us "
                        f"tpu_mem_bound_unfused={unfused / HBM_BW * 1e6:.1f}us "
                        f"A_and_V_streamed_once=1 w_h_roundtrips=0"),
        })
    return rows


def block_matvec_rows(cases=((2048, 8), (4096, 16))):
    """True block multi-RHS mat-vec: one A stream for k RHS vs k GEMVs.

    ``vmap`` of the GEMV pallas_call re-streams A once per lane (the batch
    axis becomes an outer grid dim) — the measured reference contrast is
    jnp's batched GEMV vs one GEMM, the modeled contrast is k A-streams
    vs one.
    """
    rows = []
    gemm = jax.jit(lambda a, x: a @ x)
    gemv_per_lane = jax.jit(lambda a, x: jax.vmap(lambda c: a @ c,
                                                  in_axes=1, out_axes=1)(x))
    for n, k in cases:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n, k))
        t_gemm = _time(gemm, a, x)
        t_lanes = _time(gemv_per_lane, a, x)
        bytes_block = 4 * (n * n + 2 * n * k)
        bytes_lanes = 4 * k * (n * n + 2 * n)
        rows.append({
            "name": f"block_matvec_n{n}_k{k}",
            "us": t_gemm * 1e6,
            "us_vmapped_gemv": t_lanes * 1e6,
            "hbm_bytes_block": bytes_block,
            "hbm_bytes_k_gemv": bytes_lanes,
            "traffic_ratio": bytes_block / bytes_lanes,
            "derived": (f"block/k_gemv_hbm={bytes_block / bytes_lanes:.2f} "
                        f"ai_gain={k}x "
                        f"tpu_mem_bound_block={bytes_block / HBM_BW * 1e6:.1f}us"),
        })
    return rows


def spmv_traffic(n: int, width: int, nbands: int, s: int = 4):
    """Modeled per-matvec HBM bytes: ELL / banded SpMV vs the dense GEMV.

    ELL streams the (n, width) values in storage dtype plus the int32 cols,
    and reads/writes x/y once; the banded kernel streams only the band
    stack (offsets are static).  Dense GEMV streams the full (n, n) matrix
    — for stencil systems that is O(n/width) more traffic, which is why
    sparse GMRES iterations are matvec-cheap and orthogonalization-bound.
    """
    ell = n * width * (s + 4) + 2 * s * n            # values + cols, x + y
    banded = nbands * n * s + 2 * s * n              # bands, x + y
    dense = s * (n * n + 2 * n)
    return ell, banded, dense


def spmv_rows(grids=((64, 64), (128, 128), (256, 256))):
    """Sparse SpMV rows: measured jnp-reference wall time + modeled traffic.

    Each grid is a 2-D Poisson five-point system (core/stencils.py) run
    through both sparse formats.  CPU wall-times are the jnp reference path
    (the XLA lowering the dry-run uses); the TPU-relevant quantities are
    the modeled HBM bytes and their ratio to the dense GEMV stream.
    """
    from repro.core import stencils

    rows = []
    for nx, ny in grids:
        n = nx * ny
        banded = stencils.poisson_2d(nx, ny)
        ell = banded.to_ell()
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        t_ell = _time(jax.jit(lambda v: ell(v)), x)
        t_banded = _time(jax.jit(lambda v: banded(v)), x)
        width = ell.values.shape[1]
        nbands = banded.bands.shape[0]
        b_ell, b_banded, b_dense = spmv_traffic(n, width, nbands)
        rows.append({
            "name": f"spmv_ell_poisson2d_{nx}x{ny}",
            "us": t_ell * 1e6,
            "hbm_bytes_ell": b_ell,
            "hbm_bytes_dense_gemv": b_dense,
            "traffic_ratio": b_ell / b_dense,
            "derived": (f"ell/dense_hbm={b_ell / b_dense:.4f} "
                        f"width={width} "
                        f"tpu_mem_bound={b_ell / HBM_BW * 1e6:.2f}us "
                        f"x_vmem_resident_kib={4 * n // 1024}"),
        })
        rows.append({
            "name": f"spmv_banded_poisson2d_{nx}x{ny}",
            "us": t_banded * 1e6,
            "hbm_bytes_banded": b_banded,
            "hbm_bytes_dense_gemv": b_dense,
            "traffic_ratio": b_banded / b_dense,
            "derived": (f"banded/dense_hbm={b_banded / b_dense:.4f} "
                        f"nbands={nbands} "
                        f"tpu_mem_bound={b_banded / HBM_BW * 1e6:.2f}us "
                        f"gather_free=1"),
        })
    return rows


def sstep_powers_traffic(n: int, nbands: int, s: int):
    """Modeled HBM bytes for s Krylov powers: fused banded kernel vs s SpMVs.

    The fused kernel (kernels/matrix_powers.py) holds the band stack AND
    the operand in VMEM: bands + x stream in once, the (s, n) power block
    streams out once, and no intermediate u_j ever exists in HBM.  Unfused,
    every power is a separate banded SpMV launch (bands re-streamed, u in,
    w out) plus the normalization round-trip (w re-read for the norm/scale,
    u written) that the kernel runs in-register.
    """
    fused = (nbands * n + n + s * n) * 4
    unfused = s * (nbands * n + 2 * n) * 4 + s * 2 * n * 4
    return fused, unfused


def sstep_powers_rows(grids=((64, 64, 2), (128, 128, 4), (256, 256, 8))):
    """s-step matrix-powers rows: measured jnp ref + modeled fused traffic.

    Each case runs the five-point Poisson power sequence; the measured
    number is the sequential-scan jnp reference (what the kernel replaces),
    the modeled numbers are the one-launch banded kernel's HBM bytes vs the
    s separate SpMV launches.  (The dense variant's A stream is irreducible
    — once per power — so only the banded rows carry a traffic headline.)
    """
    from repro.core import stencils
    from repro.kernels import matrix_powers

    rows = []
    for nx, ny, s in grids:
        n = nx * ny
        op = stencils.poisson_2d(nx, ny)
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        x = x / jnp.linalg.norm(x)
        eps = float(jnp.finfo(jnp.float32).eps) * 100
        powers = jax.jit(lambda v: matrix_powers.matrix_powers_ref(
            op, v, s, eps))
        t = _time(powers, x)
        nbands = op.bands.shape[0]
        fused, unfused = sstep_powers_traffic(n, nbands, s)
        ratio = fused / unfused
        rows.append({
            "name": f"sstep_powers_banded_poisson2d_{nx}x{ny}_s{s}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_s_spmv": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/s_spmv_hbm={ratio:.2f} "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.2f}us "
                        f"A_hbm_passes=1 u_roundtrips=0 "
                        f"bands_vmem_kib={nbands * n * 4 // 1024}"),
        })
    return rows


def block_gs_traffic(m1: int, n: int, s: int):
    """Modeled HBM bytes per s-step block orthogonalization (CGS2+CholQR).

    Fused (kernels/block_gs.py): the basis is VMEM-resident per pass, so V
    streams ONCE per CGS2 pass (2 total) and the power block streams in/out
    once per pass; the CholQR Gram matrices accumulate in-register.
    Unfused jnp: each pass streams V twice (projection + update) and each
    CholQR re-streams the block for the Gram matrix and again for the
    triangular solve.
    """
    fused = 2 * (m1 * n + 2 * s * n) * 4
    unfused = 2 * (2 * m1 * n + 2 * s * n) * 4 + 2 * 3 * s * n * 4
    return fused, unfused


def block_gs_rows(cases=((21, 4096, 4), (33, 16384, 4), (65, 8192, 8)),
                  batched_cases=((31, 4096, 8), (31, 16384, 4))):
    """Block Gram-Schmidt rows: s-step block pass + the batched-lane form.

    (m1, n, s) span shallow/deep restart regimes.  The batched rows model
    ``gmres_batched``'s per-lane CGS2 (s = 1, one basis per lane): the
    kernel holds each lane's basis resident for BOTH passes — one V stream
    per Arnoldi step vs the vmapped reference's four.
    """
    from repro.kernels import block_gs

    rows = []
    for m1, n, s in cases:
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        w = jax.random.normal(jax.random.PRNGKey(1), (s, n))
        tin = jnp.eye(s)
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(jax.jit(block_gs.block_gs_pass_ref), v, w, tin, mask)
        fused, unfused = block_gs_traffic(m1, n, s)
        ratio = fused / unfused
        rows.append({
            "name": f"block_gs_m{m1 - 1}_n{n}_s{s}",
            "us": t * 1e6,
            "hbm_bytes_fused": fused,
            "hbm_bytes_unfused": unfused,
            "traffic_ratio": ratio,
            "derived": (f"fused/unfused_hbm={ratio:.2f} "
                        f"passes_over_V=2of4 W_roundtrips=0 "
                        f"tpu_mem_bound_fused={fused / HBM_BW * 1e6:.1f}us"),
        })
    # batched per-lane CGS2 (gmres_batched): k lanes, one basis each
    for m1, n, k in batched_cases:
        fused_lane = (m1 * n + 2 * n) * 4          # V once, w in, w'' out
        unfused_lane = (4 * m1 * n + 4 * n) * 4    # V 2x/pass, w 2x/pass
        ratio = fused_lane / unfused_lane
        vb = jax.random.normal(jax.random.PRNGKey(2), (k, m1, n)) / np.sqrt(n)
        wb = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        maskb = jnp.ones((k, m1), jnp.float32)
        t = _time(jax.jit(jax.vmap(ref.cgs2)), vb, wb, maskb)
        rows.append({
            "name": f"block_gs_batched_m{m1 - 1}_n{n}_k{k}",
            "us": t * 1e6,
            "hbm_bytes_fused": k * fused_lane,
            "hbm_bytes_vmapped_cgs2": k * unfused_lane,
            "traffic_ratio": ratio,
            "derived": (f"fused/vmapped_hbm={ratio:.2f} "
                        f"per_lane_V_streams=1of4 "
                        f"lane_vmem_kib={m1 * n * 4 // 1024}"),
        })
    return rows


def sharded_cgs2_traffic(m1: int, n: int, p: int):
    """Modeled per-shard HBM bytes for the split-phase CGS2 pair vs the
    single-device streaming kernel at the same GLOBAL n.

    Per CGS2 (two passes) the split pair streams the local basis twice per
    pass (project kernel + update kernel — the same count as the fused
    kernel's two-phase grid), the w shard twice per pass, and writes the
    orthogonalized shard once per pass; h crosses HBM around each phase.
    The single-device fused kernel moves the same structure over the full
    n.  The point of the row: per-shard traffic is 1/P of the global
    stream while the collective payload is 2 h-vectors (8*m1 bytes) per
    CGS2 — constant in n.
    """
    ln = n // p
    per_shard = 2 * (2 * m1 * ln + 2 * ln + ln + 4 * m1) * 4
    single = 2 * (2 * m1 * n + 2 * n + n + 4 * m1) * 4
    psum_bytes = 2 * m1 * 4
    return per_shard, single, psum_bytes


def sharded_rows(cases=((33, 65536, 8), (33, 262144, 8), (65, 65536, 4)),
                 grids=((128, 128, 8), (256, 256, 8))):
    """Row-sharded kernel-path rows: split-phase CGS2 + halo SpMV.

    ``us`` is the measured jnp reference arithmetic of ONE shard on this
    host (the same convention as every other row: the reference the
    kernel replaces); the modeled numbers carry the story — per-shard
    HBM bytes scale 1/P while the exchanged bytes are O(m1) for the CGS2
    psums and O(halo) for the SpMV halo exchange, vs the O(n) all-gather
    the pre-PR-5 fallback implied.
    """
    from repro.core import stencils
    from repro.kernels import spmv

    rows = []
    for m1, n, p in cases:
        ln = n // p
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, ln)) / np.sqrt(ln)
        w = jax.random.normal(jax.random.PRNGKey(1), (ln,))
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(jax.jit(ref.cgs2), v, w, mask)
        shard, single, psum_bytes = sharded_cgs2_traffic(m1, n, p)
        rows.append({
            "name": f"sharded_cgs2_m{m1 - 1}_n{n}_p{p}",
            "us": t * 1e6,
            "hbm_bytes_per_shard": shard,
            "hbm_bytes_single_device": single,
            "traffic_ratio": shard / single,
            "derived": (f"shard/single_hbm={shard / single:.3f} "
                        f"psum_payload_B={psum_bytes} "
                        f"collective_rounds_per_step=2 "
                        f"tpu_mem_bound_shard={shard / HBM_BW * 1e6:.1f}us"),
        })
    for nx, ny, p in grids:
        n = nx * ny
        ln = n // p
        op = stencils.poisson_2d(nx, ny)
        nbands = op.bands.shape[0]
        halo = max(abs(int(o)) for o in op.offsets)
        x = jax.random.normal(jax.random.PRNGKey(1), (ln,))
        bands_local = op.bands[:, :ln]
        t = _time(jax.jit(lambda bl, xl: spmv.banded_matvec_halo_ref(
            bl, jnp.pad(xl, (halo, halo)), op.offsets)), bands_local, x)
        shard = (nbands * ln + (ln + 2 * halo) + ln) * 4
        single = (nbands * n + 2 * n) * 4
        exch = 2 * halo * 4
        gather = (n - ln) * 4
        rows.append({
            "name": f"sharded_spmv_banded_poisson2d_{nx}x{ny}_p{p}",
            "us": t * 1e6,
            "hbm_bytes_per_shard": shard,
            "hbm_bytes_single_device": single,
            "traffic_ratio": shard / single,
            "derived": (f"shard/single_hbm={shard / single:.3f} "
                        f"halo_exchange_B={exch} allgather_B={gather} "
                        f"exchange/gather={exch / gather:.2e} "
                        f"x_vmem_resident_kib={4 * (ln + 2 * halo) // 1024}"),
        })
        ell = op.to_ell()
        width = ell.values.shape[1]
        vals_local = ell.values[:ln]
        cols_local = jnp.clip(ell.cols[:ln] + halo, 0, ln + 2 * halo - 1)
        t_ell = _time(jax.jit(lambda vl, cl, xl: spmv.ell_matvec_ref(
            vl, cl, jnp.pad(xl, (halo, halo)))), vals_local, cols_local, x)
        shard_e = (ln * width * (4 + 4) + (ln + 2 * halo) * 4 + ln * 4)
        single_e = (n * width * (4 + 4) + 2 * n * 4)
        rows.append({
            "name": f"sharded_spmv_ell_poisson2d_{nx}x{ny}_p{p}",
            "us": t_ell * 1e6,
            "hbm_bytes_per_shard": shard_e,
            "hbm_bytes_single_device": single_e,
            "traffic_ratio": shard_e / single_e,
            "derived": (f"shard/single_hbm={shard_e / single_e:.3f} "
                        f"halo_exchange_B={exch} allgather_B={gather} "
                        f"halo={halo} width={width}"),
        })
    return rows


def precision_restart_rows(grids=((24, 24), (32, 32)), dense_ns=(512,),
                           m: int = 20, tol: float = 1e-4):
    """compute_dtype=bf16 precision-vs-restarts sweep (ROADMAP item).

    Each case solves the SAME system twice — f32 basis vs bf16 basis
    storage — through the jnp cgs2 path and reports the convergence cost
    (extra inner steps / restarts) against the modeled basis-stream
    saving: the Krylov basis is streamed 4x per CGS2 step, so bf16
    storage halves the dominant orthogonalization traffic and the row's
    ``traffic_ratio`` is 0.5 * steps_bf16 / steps_f32 — below 1.0 means
    the precision trade WINS end-to-end on basis bytes.
    """
    from repro.core import gmres, stencils
    from repro.core.operators import random_diagdom

    def _sweep(name, op, b, n):
        f32 = jax.jit(lambda op, b: gmres(op, b, m=m, tol=tol,
                                          max_restarts=400))
        bf16 = jax.jit(lambda op, b: gmres(op, b, m=m, tol=tol,
                                           max_restarts=400,
                                           compute_dtype=jnp.bfloat16))
        r32 = f32(op, b)
        t = _time(bf16, op, b)
        r16 = bf16(op, b)
        s32, s16 = int(r32.inner_steps), int(r16.inner_steps)
        m1 = m + 1
        bytes32 = s32 * 4 * m1 * n * 4
        bytes16 = s16 * 4 * m1 * n * 2
        return {
            "name": name,
            "us": t * 1e6,
            "hbm_bytes_basis_f32": bytes32,
            "hbm_bytes_basis_bf16": bytes16,
            "traffic_ratio": bytes16 / bytes32 if bytes32 else 1.0,
            "derived": (f"bf16/f32_basis_hbm={bytes16 / max(bytes32, 1):.2f} "
                        f"steps_f32={s32} steps_bf16={s16} "
                        f"restarts_f32={int(r32.restarts)} "
                        f"restarts_bf16={int(r16.restarts)} "
                        f"conv_f32={int(r32.converged)} "
                        f"conv_bf16={int(r16.converged)}"),
        }

    rows = []
    for nx, ny in grids:
        n = nx * ny
        op = stencils.poisson_2d(nx, ny)
        b = jnp.sin(jnp.arange(n) * 0.37)
        rows.append(_sweep(f"precision_restarts_poisson2d_{nx}x{ny}_bf16",
                           op, b, n))
    for n in dense_ns:
        a = random_diagdom(jax.random.PRNGKey(3), n)
        b = jax.random.normal(jax.random.PRNGKey(4), (n,))
        rows.append(_sweep(f"precision_restarts_diagdom_n{n}_bf16", a, b, n))
    return rows


def attention_rows(cases=((1, 8, 8, 1024, 128), (1, 8, 2, 2048, 128))):
    rows = []
    attn = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    for (b, hq, hkv, s, d) in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
        t = _time(attn, q, k, v)
        flops = 4 * b * hq * s * s * d * 0.5      # causal half
        tpu_compute = flops / PEAK_FLOPS
        rows.append({
            "name": f"flash_attn_b{b}h{hq}kv{hkv}s{s}",
            "us": t * 1e6,
            "derived": (f"flops={flops / 1e9:.1f}G "
                        f"tpu_compute_bound={tpu_compute * 1e6:.1f}us "
                        f"vmem_per_step_kib={(128 * d * 4 * 3 + 128 * 128 * 4) // 1024}"),
        })
    return rows


def _validate_rows(rows):
    """Schema guard (what the CI smoke run asserts): every row carries the
    universal keys, names are unique, traffic rows have both byte counts."""
    names = [r["name"] for r in rows]
    assert len(set(names)) == len(names), "duplicate row names"
    for r in rows:
        assert isinstance(r["name"], str) and isinstance(r["derived"], str)
        assert r["us"] >= 0.0
        if "traffic_ratio" in r:
            hbm = [k for k in r if k.startswith("hbm_bytes_")]
            assert len(hbm) == 2, (f"{r['name']}: traffic row needs 2 "
                                   f"hbm_bytes_* keys, has {hbm}")


def main(json_path: str = "BENCH_kernels.json", smoke: bool = False):
    if smoke:
        # CI schema guard: one cheap case per row family — EVERY family,
        # so no row's schema can drift unchecked — through the same code
        # paths as the full run.
        rows = (matvec_rows(sizes=(1024,)) + gs_rows(ns=(8192,))
                + fused_step_rows(cases=((96, 97),))
                + block_matvec_rows(cases=((2048, 8),))
                + spmv_rows(grids=((64, 64),))
                + sstep_powers_rows(grids=((64, 64, 4),))
                + block_gs_rows(cases=((21, 4096, 4),),
                                batched_cases=((31, 2048, 2),))
                + sharded_rows(cases=((33, 16384, 4),),
                               grids=((64, 64, 4),))
                + precision_restart_rows(grids=((16, 16),), dense_ns=(),
                                         tol=1e-3)
                + attention_rows(cases=((1, 2, 2, 256, 64),)))
    else:
        rows = (matvec_rows() + gs_rows() + fused_step_rows()
                + block_matvec_rows() + spmv_rows() + sstep_powers_rows()
                + block_gs_rows() + sharded_rows()
                + precision_restart_rows() + attention_rows())
    _validate_rows(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")
    fused_ratios = {r["name"]: round(r["traffic_ratio"], 3)
                    for r in rows if "traffic_ratio" in r}
    # disjoint prefixes: "block_gs_m" (s-step block pass) vs
    # "block_gs_batched" (per-lane CGS2) have different baselines
    for prefix in ("fused_arnoldi", "sstep_powers", "block_gs_m",
                   "block_gs_batched"):
        best = min((v for k, v in fused_ratios.items()
                    if k.startswith(prefix)), default=None)
        if best is not None:
            print(f"# {prefix} best modeled HBM ratio: {best:.2f} "
                  f"(< 0.60 target met: {best < 0.60})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"suite": "kernel_bench",
                       "backend": jax.default_backend(),
                       "rows": rows}, f, indent=1)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset (one case per family) — the CI "
                         "schema guard")
    ap.add_argument("--json", default=None,
                    help="output path ('' to skip writing).  Default: "
                         "BENCH_kernels.json for a full run; NOT written "
                         "in --smoke mode (the committed file records the "
                         "full suite only)")
    args = ap.parse_args()
    if args.json is None:
        args.json = "" if args.smoke else "BENCH_kernels.json"
    main(json_path=args.json, smoke=args.smoke)
