"""Kernel-layer benchmarks.

Two kinds of numbers:
  1. wall-time of the jit'd REFERENCE path on this CPU (what we can measure
     here — XLA-fused jnp, the same HLO the dry-run lowers), and
  2. STRUCTURAL metrics of the Pallas kernels (VMEM working set per grid
     step, arithmetic intensity, HBM traffic) — the quantities that
     determine TPU performance, derivable without hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, repeats=5):
    fn(*args)                      # warmup/compile
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def matvec_rows(sizes=(1024, 4096, 8192)):
    rows = []
    mv = jax.jit(ref.matvec)
    for n in sizes:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n))
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        t = _time(mv, a, x)
        flops = 2 * n * n
        bytes_ = 4 * (n * n + 2 * n)
        # Pallas tile (256, 512) f32: A tile 512 KiB + x tile 2 KiB in VMEM
        rows.append({
            "name": f"matvec_n{n}",
            "us": t * 1e6,
            "derived": (f"AI={flops / bytes_:.2f}flop/B "
                        f"tpu_mem_bound={bytes_ / HBM_BW * 1e6:.1f}us "
                        f"vmem_tile_kib=514"),
        })
    return rows


def gs_rows(ns=(8192, 65536), m1=33):
    rows = []
    gs = jax.jit(ref.cgs2)
    for n in ns:
        v = jax.random.normal(jax.random.PRNGKey(0), (m1, n)) / np.sqrt(n)
        w = jax.random.normal(jax.random.PRNGKey(1), (n,))
        mask = jnp.ones((m1,), jnp.float32)
        t = _time(gs, v, w, mask)
        # fused kernel: V streamed twice per pass (4x per CGS2);
        # jnp reference: V streamed 4x + h round-trips; fusion saves the
        # intermediate (m1, n_tiles) partials + w re-reads
        bytes_fused = 4 * (4 * m1 * n + 2 * n) * 1.0
        rows.append({
            "name": f"cgs2_m{m1}_n{n}",
            "us": t * 1e6,
            "derived": (f"tpu_mem_bound={bytes_fused / HBM_BW * 1e6:.1f}us "
                        f"passes_over_V=4"),
        })
    return rows


def attention_rows(cases=((1, 8, 8, 1024, 128), (1, 8, 2, 2048, 128))):
    rows = []
    attn = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
    for (b, hq, hkv, s, d) in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
        t = _time(attn, q, k, v)
        flops = 4 * b * hq * s * s * d * 0.5      # causal half
        tpu_compute = flops / PEAK_FLOPS
        rows.append({
            "name": f"flash_attn_b{b}h{hq}kv{hkv}s{s}",
            "us": t * 1e6,
            "derived": (f"flops={flops / 1e9:.1f}G "
                        f"tpu_compute_bound={tpu_compute * 1e6:.1f}us "
                        f"vmem_per_step_kib={(128 * d * 4 * 3 + 128 * 128 * 4) // 1024}"),
        })
    return rows


def main():
    rows = matvec_rows() + gs_rows() + attention_rows()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us']:.0f},{r['derived']}")
    return rows


if __name__ == "__main__":
    main()
