"""Render the SSRoofline table from the dry-run JSONL records."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(paths=None):
    paths = paths or sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.jsonl")))
    recs = []
    for p in paths:
        with open(p) as f:
            for line in f:
                recs.append(json.loads(line))
    # newest record per cell wins (re-runs append)
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:8.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:8.2f}ms"
    return f"{x * 1e6:8.1f}us"


def table(recs, mesh="16x16"):
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} SKIPPED "
                        f"({r['reason'][:60]}...)")
            continue
        if r["status"] != "ok":
            rows.append(f"{r['arch']:26s} {r['shape']:12s} ERROR")
            continue
        rf = r["roofline"]
        dom = rf["bottleneck"]
        frac = (max(rf["compute_s"], 1e-30)
                / max(rf["compute_s"], rf["memory_s"], rf["collective_s"]))
        rows.append(
            f"{r['arch']:26s} {r['shape']:12s} "
            f"C={fmt_s(rf['compute_s'])} M={fmt_s(rf['memory_s'])} "
            f"X={fmt_s(rf['collective_s'])} dom={dom:10s} "
            f"roofline_frac={frac:5.2f} useful={rf['useful_ratio']:6.3f}")
    return rows


def main():
    recs = load()
    if not recs:
        print("roofline_table,0,no dryrun records — run repro.launch.dryrun")
        return []
    print("name,us_per_call,derived")
    for mesh in ("16x16", "2x16x16"):
        ok = [r for r in recs if r["mesh"] == mesh and r["status"] == "ok"]
        if not ok:
            continue
        for r in ok:
            rf = r["roofline"]
            dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            print(f"roofline_{mesh}_{r['arch']}_{r['shape']},"
                  f"{dom_s * 1e6:.0f},"
                  f"dom={rf['bottleneck']};compute_s={rf['compute_s']:.3e};"
                  f"memory_s={rf['memory_s']:.3e};"
                  f"collective_s={rf['collective_s']:.3e};"
                  f"useful={rf['useful_ratio']:.3f}")
    return recs


if __name__ == "__main__":
    main()
    print()
    for mesh in ("16x16", "2x16x16"):
        print(f"=== {mesh} ===")
        for row in table(load(), mesh):
            print(row)
