"""Benchmark driver — one section per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run            # quick
    PYTHONPATH=src python -m benchmarks.run --full     # paper's full N sweep

Sections:
  [1] gmres_strategies   — paper Table 1 / Figure 5 analogue
  [2] kernel_bench       — Pallas kernel layer (wall CPU + TPU structural)
  [3] distributed_gmres  — sharded-solver scaling + collective schedule
  [4] roofline_table     — SSRoofline terms for every dry-run cell
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import (distributed_gmres, gmres_strategies,
                            kernel_bench, roofline_table)

    print("# [1] GMRES offload strategies (paper Table 1 analogue)")
    gmres_strategies.main(full=full)
    print()
    print("# [2] kernel layer")
    kernel_bench.main()
    print()
    print("# [3] distributed GMRES (8-way row-sharded, fake devices)")
    distributed_gmres.main()
    print()
    print("# [4] roofline terms from the multi-pod dry-run")
    roofline_table.main()


if __name__ == "__main__":
    main()
