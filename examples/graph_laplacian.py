"""Power-law graphs + sliced-ELL storage — irregular sparsity done right.

    PYTHONPATH=src python examples/graph_laplacian.py

The stencil examples have the same nonzero count in every row, so plain
ELL (pad all rows to the max width) wastes nothing.  Real graphs do not
cooperate: a power-law web graph has a few hub rows with hundreds of
neighbors and a long tail with a handful, and padding EVERY row to the
hub width makes the matrix stream mostly zeros.  This walkthrough:

1. Samples a power-law (Chung-Lu) graph and builds its Laplacian in the
   sliced-ELL format (``SlicedEllOperator``): rows sorted by nonzero
   count, cut into fixed-height slices, each slice padded only to its
   own widest row.
2. Compares storage and modeled HBM traffic against plain ELL — the
   >= 3x cut the bench gate (tools/bench_gate.py rule 7) enforces.
3. Solves personalized-PageRank systems (I - alpha P) x = (1 - alpha) v
   through the continuous-batching ``SolverServer`` on a ``slicedell``
   handle — a burst of random-walk queries against one shared graph.
"""
import numpy as np

from repro.core import graphs
from repro.serve import SolverServer
from repro.serve.handles import operator_fmt


def main():
    # -- 1. the graph and its sliced-ELL Laplacian -------------------------
    n = 1024
    op = graphs.graph_laplacian(n, seed=0, fmt="sell", backend="pallas")
    ell = op.to_ell()
    deg = np.count_nonzero(np.asarray(ell.values), axis=1)
    print(f"[1] power-law graph Laplacian: n={n}, max degree={deg.max()}, "
          f"median degree={int(np.median(deg))}, "
          f"{len(op.bin_values)} slices (heights x widths: "
          f"{[(v.shape[0], v.shape[1]) for v in op.bin_values]})")

    # -- 2. the storage/traffic story --------------------------------------
    # Plain ELL pads every row to the hub width; sliced ELL pads each
    # slice to its own width.  The matrix stream per matvec is 8 bytes an
    # entry (f32 value + int32 col), so stored entries ~= HBM traffic.
    ell_entries = ell.values.shape[0] * ell.values.shape[1]
    nnz = int(deg.sum())
    store = int(op.storage_entries)
    print(f"[2] stored entries: ell={ell_entries:,} "
          f"(pad {ell_entries / nnz - 1:.0%}) sell={store:,} "
          f"(pad {store / nnz - 1:.0%}) — "
          f"{ell_entries / store:.1f}x cut, nnz={nnz:,}")
    assert ell_entries / store >= 3.0, "power-law cut below the gate bar"

    # -- 3. a PageRank burst through the solver server ---------------------
    # Each request is a personalized random-walk query: same graph (one
    # handle, keyed fmt='slicedell'), different personalization vector v.
    alpha = 0.85
    pr_op, make_rhs = graphs.pagerank_system(n, alpha=alpha, seed=0,
                                             fmt="sell", backend="pallas")
    print(f"[3] serving (I - {alpha} P) x = {1 - alpha:.2f} v with a "
          f"{operator_fmt(pr_op)!r} handle")
    srv = SolverServer(pr_op, m=12, k=4)
    rng = np.random.default_rng(1)
    rids = [srv.submit(np.asarray(make_rhs(rng.random(n) + 0.1)),
                       tol=1e-5, max_restarts=100) for _ in range(8)]
    cycles = srv.run()
    outs = [srv.results[r] for r in rids]
    restarts = [o.restarts for o in outs]
    mass = [float(np.sum(o.x)) for o in outs]
    print(f"    {len(rids)} queries in {cycles} lockstep cycles "
          f"(sequential would take {sum(restarts)}); statuses="
          f"{sorted(set(o.status for o in outs))}, "
          f"max |sum(x) - 1| = {max(abs(s - 1) for s in mass):.1e}")

    assert all(o.status == "done" for o in outs)
    assert cycles < sum(restarts)
    assert max(abs(s - 1) for s in mass) < 1e-3   # PageRank mass conservation
    print("OK")


if __name__ == "__main__":
    main()
