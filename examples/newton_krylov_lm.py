"""GMRES inside LM training: the Newton-Krylov optimizer on a reduced

tinyllama, vs AdamW on the same stream — the paper's solver deployed as a
first-class training feature (DESIGN.md SS3).

    PYTHONPATH=src python examples/newton_krylov_lm.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import SyntheticLM
from repro.models import build
from repro.optim import adamw, newton_krylov


def main(steps: int = 8):
    cfg = configs.get("tinyllama-1.1b").reduced(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, loss_chunk=32)
    model = build(cfg)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

    def loss_fn(p, batch):
        return model.loss(p, batch)[0]

    # ---- Newton-Krylov (GMRES inner solver) ----
    params = model.init(jax.random.PRNGKey(0))
    nk_init, nk_update = newton_krylov(loss_fn, m=8, tol=1e-2, damping=10.0)
    nk_state = nk_init(params)
    upd = jax.jit(nk_update)
    print("Newton-Krylov (GMRES m=8):")
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(step))
        params, nk_state, m = upd(params, nk_state, batch)
        print(f"  step {step}: loss={float(m['loss']):.4f} "
              f"gmres_steps={int(m['gmres_steps'])} "
              f"damping={float(m['damping']):.2f}")

    # ---- AdamW baseline, same stream ----
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def adam_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    print("AdamW:")
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(step))
        params, opt_state, loss = adam_step(params, opt_state, batch)
        print(f"  step {step}: loss={float(loss):.4f}")


if __name__ == "__main__":
    main()
