"""Preconditioned GMRES: fewer steps beats faster steps.

    PYTHONPATH=src python examples/preconditioned_gmres.py

Every kernel in this repo makes an Arnoldi step cheaper; a preconditioner
deletes steps outright — and each deleted step deletes its collective
rounds too.  This walkthrough runs the restart-count comparison the
``precond_*`` benchmark rows gate:

1. Solve the 2-D Poisson and convection-diffusion model problems
   unpreconditioned and with each production preconditioner, at the SAME
   tolerance, and compare restart counts.
2. Show the cost model: restarts are not free to cut — every inner step
   now pays ``1 + matvec_equiv`` mat-vec equivalents — and verify the
   trade still wins.
3. Peek at the Chebyshev spectral interval: why the estimator must bound
   the spectrum from ABOVE, and what it picked here.
4. Solve through the serve layer with a preconditioned handle, and show
   admission refusing a mismatched preconditioner with the field named.
"""
import numpy as np

import jax.numpy as jnp

from repro.core import gmres, stencils
from repro.core import preconditioners as P
from repro.serve.request import AdmissionError
from repro.serve.server import SolverServer


def main():
    nx = 12
    n = nx * nx
    systems = {
        "poisson_2d": stencils.poisson_2d(nx),
        "convection_diffusion_2d": stencils.convection_diffusion_2d(nx),
    }
    b = jnp.sin(jnp.arange(n) * 0.37)

    # -- 1 + 2. restart counts and cost-adjusted steps --------------------
    for sysname, op in systems.items():
        preconds = {
            "none": None,
            "jacobi": P.jacobi(op),
            "chebyshev(4)": P.chebyshev(op, order=4),
            "line_jacobi": P.line_jacobi(op),
            "banded_ilu0": P.banded_ilu0(op),
        }
        print(f"\n[{sysname}] n={n}, m=16, tol=1e-5")
        print(f"    {'precond':<14} {'restarts':>8} {'steps':>6} "
              f"{'cost/step':>9} {'residual':>10}")
        base = None
        for name, pc in preconds.items():
            res = gmres(op, b, m=16, tol=1e-5, max_restarts=100, precond=pc)
            assert bool(res.converged), f"{name} failed to converge"
            mveq = 1.0 + (pc.cost().matvec_equiv if pc is not None else 0.0)
            r = int(res.restarts)
            base = r if base is None else base
            print(f"    {name:<14} {r:>8} {int(res.inner_steps):>6} "
                  f"{mveq:>8.2f}x {float(res.residual):>10.2e}"
                  + ("" if r <= base else "   (!)"))
        # The acceptance bar the bench gate holds: >= 2x fewer restarts.
        for strong in ("chebyshev(4)", "banded_ilu0"):
            res = gmres(op, b, m=16, tol=1e-5, max_restarts=100,
                        precond=preconds[strong])
            assert 2 * int(res.restarts) <= base, (strong, sysname)

    # -- 3. the Chebyshev interval ----------------------------------------
    op = systems["poisson_2d"]
    lam_min, lam_max = P.estimate_interval(op)
    print(f"\n[interval] Chebyshev interval for poisson_2d: "
          f"[{lam_min:.3f}, {lam_max:.3f}]")
    print("    lam_max is the Gershgorin UPPER bound: one eigenvalue above")
    print("    it would flip A.M^-1 indefinite and stall the outer solve;")
    print("    overestimating merely wastes a little polynomial efficiency.")

    # -- 4. the serve layer -----------------------------------------------
    srv = SolverServer(op, m=10, k=4, precond=P.chebyshev(op, order=4))
    rid = srv.submit(np.asarray(b), tol=1e-4, max_restarts=60)
    srv.run()
    out = srv.results[rid]
    print(f"\n[serve] preconditioned handle: status={out.status} "
          f"restarts={out.restarts}")
    assert out.status == "done"

    try:
        SolverServer(op, m=10, k=4,
                     precond=P.banded_ilu0(stencils.poisson_2d(6)))
    except AdmissionError as e:
        print(f"[serve] mismatch refused at admission: {e.reason}")


if __name__ == "__main__":
    main()
