"""Quickstart: the paper's experiment in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Solve a dense nonsymmetric system with restarted GMRES(m) (the paper's
   algorithm) fully on-device.
2. Read the solve's convergence trace and health diagnosis off the
   result (docs/robustness.md).
3. Compare the paper's four offload strategies on the same system.
4. Run the row-sharded distributed solver on whatever devices exist.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core import gmres, gmres_sharded, operators, strategies


def main():
    n = 1_500
    key = jax.random.PRNGKey(0)
    a = operators.random_diagdom(key, n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))

    # -- 1. device-resident solve (gpuR-vcl strategy, fully fused) --------
    res = strategies.device_resident(a, b, m=30, tol=1e-6)
    relres = float(res.residual / jnp.linalg.norm(b))
    print(f"[1] GMRES(30): converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} inner={int(res.inner_steps)} "
          f"relres={relres:.2e}")

    # -- 2. convergence trace + health diagnosis --------------------------
    # Every result carries a bounded ring of TRUE per-cycle residual norms
    # (inf-padded until full) and a jit-computed health status.
    from repro.core.gmres import STATUS_NAMES
    d = res.diagnostics
    trace = np.asarray(res.residual_history)
    trace = trace[np.isfinite(trace)] / float(jnp.linalg.norm(b))
    print(f"[2] health={STATUS_NAMES[int(d.status)]} "
          f"last {len(trace)} cycles relres: "
          + " ".join(f"{r:.1e}" for r in trace))

    # -- 3. the paper's strategy comparison (Table 1 analogue) ------------
    a_np, b_np = np.asarray(a), np.asarray(b)
    print("[3] strategy timings (N=1500):")
    for name, fn in strategies.STRATEGIES.items():
        t0 = time.perf_counter()
        out = fn(a_np, b_np, m=30, tol=1e-5)
        jax.block_until_ready(getattr(out, "x", out[0]))
        print(f"    {name:18s} {1e3 * (time.perf_counter() - t0):8.1f} ms")

    # -- 4. distributed solve over the host mesh --------------------------
    ndev = len(jax.devices())
    mesh = make_mesh((ndev,), ("model",))
    res_d = gmres_sharded(mesh, "model", a[:1024, :1024], b[:1024],
                          m=30, tol=1e-6)
    print(f"[4] sharded over {ndev} device(s): converged="
          f"{bool(res_d.converged)} residual={float(res_d.residual):.2e}")


if __name__ == "__main__":
    main()
