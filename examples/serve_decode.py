"""Batched serving example: device-resident KV cache decode loop.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys

from repro.launch import serve


def main():
    return serve.main([
        "--arch", "mixtral-8x22b", "--reduced",
        "--batch", "4", "--prompt-len", "16", "--gen", "32",
    ] + sys.argv[1:])


if __name__ == "__main__":
    main()
