"""Row-sharded GMRES on the shard-aware kernel path — a runnable tour.

The paper's experiments stop at N = 10000 because the whole dense matrix
had to fit one 2 GB card.  This example removes that wall: the operator
is row-sharded over a mesh axis and the SAME gmres cycle runs per shard,
with the per-shard kernel variants dispatched automatically —

  * dense operators all-gather the operand, then run the tiled local GEMV;
  * banded/ELL stencil operators exchange only their ``halo`` boundary
    rows with mesh neighbors (2 ppermutes, O(halo) bytes — not O(n));
  * orthogonalization runs the split-phase CGS2 kernel pair with the h
    psum between the phases;
  * the s-step solver does one exchange + one psum per s powers (the
    communication-avoiding matrix-powers kernel).

Run on any machine — 4 fake host devices are requested before jax loads:

    JAX_PLATFORMS=cpu python examples/sharded_gmres.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                                       # noqa: E402
import jax.numpy as jnp                                          # noqa: E402

from repro.compat import make_mesh                               # noqa: E402
from repro.core import (gmres, gmres_sharded, gmres_sstep_sharded,  # noqa: E402
                        operators, stencils)


def main():
    ndev = jax.device_count()
    nshards = 4 if ndev >= 4 else 1
    mesh = make_mesh((nshards,), ("rows",))
    print(f"devices: {ndev} ({jax.default_backend()}), "
          f"mesh: {nshards}-way row sharding")

    # -- 1. dense: the paper's setting, beyond one device's memory -------
    n = 1024
    a = operators.random_diagdom(jax.random.PRNGKey(0), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    res = gmres_sharded(mesh, "rows", a, b, m=30, tol=1e-5)
    rel = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    print(f"[dense   n={n}] converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} rel_resid={rel:.2e}")

    # -- 2. banded stencil: halo exchange instead of all-gather ----------
    nx = 32
    op = stencils.poisson_2d(nx, nx, backend="pallas")
    n = nx * nx
    b = jnp.sin(jnp.arange(n) * 0.37)
    res = gmres_sharded(mesh, "rows", op, b, m=30, tol=1e-5,
                        max_restarts=200)
    rel = float(jnp.linalg.norm(op.todense() @ res.x - b)
                / jnp.linalg.norm(b))
    print(f"[banded  n={n}] converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} rel_resid={rel:.2e} "
          f"(halo=±{max(abs(int(o)) for o in op.offsets)} rows exchanged "
          f"per matvec)")

    # -- 3. same stencil through the ELL gather path ---------------------
    res = gmres_sharded(mesh, "rows", op.to_ell(), b, m=30, tol=1e-5,
                        max_restarts=200)
    print(f"[ell     n={n}] converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} resid={float(res.residual):.2e}")

    # -- 4. communication-avoiding s-step: 1 exchange + 1 psum per s -----
    res = gmres_sstep_sharded(mesh, "rows", op, b, s=4, blocks=5, tol=1e-5,
                              max_restarts=100)
    print(f"[sstep4  n={n}] converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} resid={float(res.residual):.2e}")

    # parity spot-check against the single-device cycle (same code!)
    ref = gmres(op, b, m=30, tol=1e-5, max_restarts=200)
    res = gmres_sharded(mesh, "rows", op, b, m=30, tol=1e-5,
                        max_restarts=200)
    err = float(jnp.linalg.norm(res.x - ref.x) / jnp.linalg.norm(ref.x))
    print(f"[parity] sharded vs single-device solution diff: {err:.2e}")
    assert err < 2e-3


if __name__ == "__main__":
    main()
