"""Preconditioned GMRES on a convection-diffusion operator — the classic

nonsymmetric Krylov benchmark the paper's method targets.  Compares the
paper's unpreconditioned solver against the beyond-paper polynomial and
(block-)Jacobi preconditioners, and the CGS (paper listing) vs MGS vs CGS2
orthogonalization schemes.

    PYTHONPATH=src python examples/solve_convection_diffusion.py
"""
import jax
import jax.numpy as jnp

from repro.core import gmres, operators, preconditioners


def main():
    n = 1_024
    a = operators.convection_diffusion(n, beta=0.7)
    b = jnp.sin(jnp.arange(n) * 0.1)

    print(f"convection-diffusion, n={n}, GMRES(20), tol=1e-4 (fp32)")
    print(f"{'scheme':8s} {'precond':14s} {'restarts':>8s} {'steps':>6s} "
          f"{'resid':>10s}")
    for gs in ("cgs", "mgs", "cgs2"):
        res = gmres(a, b, m=20, tol=1e-4, gs=gs, max_restarts=300)
        print(f"{gs:8s} {'none':14s} {int(res.restarts):8d} "
              f"{int(res.inner_steps):6d} {float(res.residual):10.2e}")

    for name, builder in (
        ("jacobi", lambda: preconditioners.jacobi(a)),
        ("block_jacobi", lambda: preconditioners.block_jacobi(a, 64)),
        ("neumann(2)", lambda: preconditioners.neumann(a, order=2)),
    ):
        res = gmres(a, b, m=20, tol=1e-4, gs="cgs2", max_restarts=300,
                    precond=builder())
        print(f"{'cgs2':8s} {name:14s} {int(res.restarts):8d} "
              f"{int(res.inner_steps):6d} {float(res.residual):10.2e}")


if __name__ == "__main__":
    main()
