"""GMRES-as-a-service walkthrough: continuous batching over solver lanes.

    PYTHONPATH=src python examples/solver_server.py

1. Stand up a SolverServer over one operator: k lockstep lanes fed from a
   backpressured queue, compiled once through the solver-handle LRU.
2. Submit a burst of heterogeneous requests (mixed tolerances + restart
   budgets, one of them hopeless, one of them poisoned with NaN).
3. Drain it and read the outcome ledger + serve metrics — then compare
   total lockstep cycles against the sequential and ideal baselines.
"""
import math

import numpy as np

from repro.core import operators
from repro.serve import DONE, FAILED, REJECTED, SolverServer


def main():
    n, k, m = 160, 8, 10
    op = operators.DenseOperator(operators.convection_diffusion(n, beta=0.4))
    rng = np.random.default_rng(0)

    # -- 1. the server: one operator, k lanes, handle compiled lazily -----
    srv = SolverServer(op, m=m, k=k, max_pending=64)
    print(f"[1] server up: handle key {srv.handle.key!r}")

    # -- 2. a heterogeneous burst ------------------------------------------
    # Tight tolerances first (longest-processing-time packing), a lane-
    # budget casualty, and a poisoned rhs that must die at admission.
    rids = {}
    for i in range(3 * k):
        tol = [1e-5, 1e-4, 1e-3, 1e-2][i % 4]
        rids[srv.submit(rng.standard_normal(n), tol=tol,
                        max_restarts=100)] = tol
    hopeless = srv.submit(rng.standard_normal(n), tol=1e-12, max_restarts=3)
    bad = rng.standard_normal(n)
    bad[7] = np.nan
    poisoned = srv.submit(bad)
    print(f"[2] submitted {len(rids)} solves + 1 hopeless + 1 poisoned; "
          f"queue depth {len(srv.ingress)}")
    assert srv.results[poisoned].status == REJECTED  # never reached a lane

    # -- 3. drain and read the ledger --------------------------------------
    ticks = srv.run()
    byst = {DONE: 0, FAILED: 0, REJECTED: 1}
    for rid in rids:
        byst[srv.results[rid].status] += 1
    byst[srv.results[hopeless].status] += 1
    met = srv.metrics()
    restarts = [srv.results[r].restarts for r in rids]
    seq = sum(restarts) + srv.results[hopeless].restarts
    ideal = max(math.ceil(seq / k), max(restarts))
    print(f"[3] drained in {ticks} lockstep cycles "
          f"(sequential {seq}, ideal {ideal}, "
          f"packed/ideal {ticks / ideal:.2f})")
    print(f"    outcomes: {byst[DONE]} done, {byst[FAILED]} failed, "
          f"{byst[REJECTED]} rejected")
    print(f"    occupancy={met['occupancy']:.2f} "
          f"retirement_rate={met['retirement_rate']:.2f}/cycle "
          f"handle_lru={met['handle_cache']}")
    assert ticks < seq, "continuous batching must beat sequential"


if __name__ == "__main__":
    main()
