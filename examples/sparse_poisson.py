"""Sparse GMRES on a 2-D Poisson problem — the SpMV operator path.

    PYTHONPATH=src python examples/sparse_poisson.py

The paper solves dense random systems, but the home turf of GMRES is
sparse: discretized PDEs whose matrices have a handful of nonzeros per
row.  This walkthrough solves the classic model problem — the five-point
Poisson stencil on a square grid — through the sparse operator subsystem:

1. Build the system WITHOUT ever materializing the (n, n) matrix: the
   stencil constructors (core/stencils.py) assemble five band vectors.
2. Solve it with the same ``gmres`` call the dense examples use — the
   operator carries its own mat-vec dispatch (``backend="pallas"`` routes
   through the Pallas SpMV kernels; on CPU they run in interpret mode).
3. Cross-check the two sparse formats (banded and ELL) against the dense
   solve, and show the modeled HBM-traffic win that makes sparse matvecs
   nearly free on TPU.
4. Batch multiple right-hand sides through one shared stream of the bands.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gmres, gmres_batched, stencils


def main():
    # -- 1. the system: -Laplace(u) = f on a 24x24 interior grid ----------
    # Row i couples site (ix, iy) to its four neighbors; Dirichlet
    # boundaries are free because out-of-range couplings read a zero halo.
    nx = ny = 24
    n = nx * ny
    banded = stencils.poisson_2d(nx, ny, backend="pallas")
    print(f"[1] 2-D Poisson, {nx}x{ny} grid: n={n}, "
          f"{banded.bands.shape[0]} bands, offsets={banded.offsets}")

    # A smooth forcing term, flattened in the same x-fastest site order.
    ix = jnp.arange(nx) / nx
    iy = jnp.arange(ny) / ny
    f = (jnp.sin(jnp.pi * ix)[None, :] * jnp.sin(jnp.pi * iy)[:, None])
    b = f.reshape(-1)

    # -- 2. solve through the banded stencil kernel ------------------------
    # No solver-side changes vs the dense quickstart: gmres only ever calls
    # the operator.  On CPU the Pallas kernel runs in interpret mode.
    res = gmres(banded, b, m=30, tol=1e-5, max_restarts=200)
    relres = float(res.residual / jnp.linalg.norm(b))
    print(f"[2] banded/pallas GMRES(30): converged={bool(res.converged)} "
          f"restarts={int(res.restarts)} inner={int(res.inner_steps)} "
          f"relres={relres:.2e}")

    # -- 3. ELL format + dense cross-check ---------------------------------
    # The same matrix in ELL form exercises the gather SpMV kernel; the
    # dense materialization (fine at n=576, unthinkable at n=10^6) is the
    # ground truth both sparse solves must reproduce.
    ell = stencils.poisson_2d(nx, ny, fmt="ell", backend="pallas")
    res_ell = gmres(ell, b, m=30, tol=1e-5, max_restarts=200)
    a_dense = banded.todense()
    res_dense = gmres(a_dense, b, m=30, tol=1e-5, max_restarts=200)
    drift_ell = float(jnp.abs(res_ell.x - res_dense.x).max())
    drift_banded = float(jnp.abs(res.x - res_dense.x).max())
    print(f"[3] format parity vs dense solve: |x_ell - x_dense|max="
          f"{drift_ell:.2e}  |x_banded - x_dense|max={drift_banded:.2e}")

    # The reason to bother: per-matvec HBM traffic (f32, modeled as in
    # benchmarks/kernel_bench.py).  Dense GEMV streams all n^2 entries;
    # the stencil streams 5 bands.
    width = ell.values.shape[1]
    bytes_ell = n * width * 8 + 8 * n
    bytes_banded = 5 * n * 4 + 8 * n
    bytes_dense = 4 * (n * n + 2 * n)
    print(f"    modeled HBM bytes/matvec: dense={bytes_dense:,} "
          f"ell={bytes_ell:,} ({bytes_ell / bytes_dense:.1%}) "
          f"banded={bytes_banded:,} ({bytes_banded / bytes_dense:.1%})")

    # -- 4. block multi-RHS: one band stream feeds every lane --------------
    # gmres_batched stacks the k current Krylov vectors into an (n, k)
    # operand, so each Arnoldi step streams the bands exactly once.
    sources = jnp.stack([
        b,
        jnp.zeros((n,)).at[n // 2 + nx // 2].set(1.0),   # point source
        jax.random.normal(jax.random.PRNGKey(0), (n,)),   # rough data
    ])
    res_b = gmres_batched(banded, sources, m=30, tol=1e-5, max_restarts=200)
    print(f"[4] batched over {sources.shape[0]} RHS: "
          f"converged={bool(res_b.converged.all())} "
          f"restarts={np.asarray(res_b.restarts).tolist()}")

    assert bool(res.converged) and bool(res_ell.converged)
    assert drift_ell < 1e-4 and drift_banded < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
