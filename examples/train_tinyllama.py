"""End-to-end training driver: reduced tinyllama on synthetic data with the
fault-tolerant runner, checkpointing, and real optimizer steps.

    PYTHONPATH=src python examples/train_tinyllama.py
"""
import sys

from repro.launch import train


def main():
    return train.main([
        "--arch", "tinyllama-1.1b", "--reduced",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--ckpt-every", "50",
    ] + sys.argv[1:])


if __name__ == "__main__":
    main()
