"""repro: GMRES-on-JAX solver framework + multi-pod LM training/serving.

Reproduction + TPU-native extension of "The performances of R GPU
implementations of the GMRES method" (Oancea & Pospisil, 2018).
"""

__version__ = "1.0.0"
