from repro.checkpoint import checkpoint
from repro.checkpoint.checkpoint import (save, restore, latest_step,
                                         AsyncCheckpointer, cleanup)

__all__ = ["checkpoint", "save", "restore", "latest_step",
           "AsyncCheckpointer", "cleanup"]
