"""Checkpointing: atomic, checksummed, async-capable, restart-ready.

Layout per step:
    <dir>/step_000123/
        shard_00000.npz     flattened leaves (np arrays)
        manifest.json       treedef repr, leaf paths, shapes, dtypes, crc32s
    <dir>/LATEST            text file with the newest complete step dir

Writes go to ``step_x.tmp`` then ``os.rename`` — readers never observe a
partial checkpoint (the fault-tolerance contract runtime/ relies on).
``save_async`` runs the serialization off the training thread; ``wait()``
joins before the next save (off-critical-path checkpointing).
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in flat:
        out.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path))
    return out


def save(directory: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Blocking checkpoint write; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves, _ = _flatten(tree)
    arrays = [np.asarray(l) for l in leaves]
    crcs = [int(zlib.crc32(a.tobytes())) for a in arrays]
    np.savez(os.path.join(tmp, "shard_00000.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "paths": _paths(tree),
        "shapes": [list(a.shape) for a in arrays],
        "dtypes": [str(a.dtype) for a in arrays],
        "crc32": crcs,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):     # idempotent re-save
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.rename(os.path.join(directory, "LATEST.tmp"),
              os.path.join(directory, "LATEST"))
    return final


class AsyncCheckpointer:
    """Serialize off the training thread; at most one write in flight."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, *, extra=None):
        self.wait()
        # device -> host copy happens HERE (cheap, before threading) so the
        # training loop can donate/overwrite device buffers immediately.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _run():
            try:
                save(self.directory, step, host_tree, extra=extra)
            except BaseException as e:   # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore(directory: str, tree_like: Any, *, step: Optional[int] = None):
    """Load into the structure of ``tree_like`` (verifies paths + crc32)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))
    arrays = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    for a, crc in zip(arrays, manifest["crc32"]):
        if int(zlib.crc32(a.tobytes())) != crc:
            raise IOError(f"checkpoint corruption at step {step}")
    ref_paths = _paths(tree_like)
    if ref_paths != manifest["paths"]:
        raise ValueError("checkpoint tree structure mismatch: "
                         f"{len(ref_paths)} leaves vs {len(manifest['paths'])}")
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    return jax.tree_util.tree_unflatten(treedef, arrays), manifest


def cleanup(directory: str, keep: int = 3):
    """Retain the newest ``keep`` complete checkpoints."""
    import shutil
    if not os.path.isdir(directory):
        return
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
