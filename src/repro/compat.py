"""Version-compatibility shims for the jax API surface this repo targets.

The codebase is written against the current mesh API —
``jax.make_mesh(shape, names, axis_types=(AxisType.Auto, ...))`` — but
older jax releases predate ``jax.sharding.AxisType`` and the ``axis_types``
kwarg.  Every call site here wants the fully-Auto default, which is exactly
what those older releases do unconditionally, so the shim simply drops the
kwarg when the running jax doesn't know it.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types on any jax version."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` on any jax version.

    Newer jax exposes it at the top level with a ``check_vma`` knob; older
    releases ship ``jax.experimental.shard_map.shard_map`` with the same
    semantics under ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict.

    Newer jax returns the dict directly; older releases wrap it in a
    one-element list (one entry per executable).
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across its two constructor signatures.

    Newer jax: ``AbstractMesh(axis_shapes, axis_names, axis_types=...)``;
    older jax: ``AbstractMesh(((name, size), ...))``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))
    return jax.sharding.AbstractMesh(
        axis_shapes, axis_names,
        axis_types=(axis_type.Auto,) * len(axis_names))
