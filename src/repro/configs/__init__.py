"""Assigned architecture registry: one module per arch, exact public configs.

``get(name)`` -> ModelConfig; ``REGISTRY`` lists all ten assigned archs.
Reduced smoke variants come from ``get(name).reduced()``.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "whisper_small",
    "granite_3_8b",
    "qwen2_7b",
    "tinyllama_1_1b",
    "granite_3_2b",
    "zamba2_7b",
    "xlstm_125m",
    "llama4_maverick_400b_a17b",
    "mixtral_8x22b",
    "pixtral_12b",
]

# CLI aliases (assignment spelling -> module name)
ALIASES = {
    "whisper-small": "whisper_small",
    "granite-3-8b": "granite_3_8b",
    "qwen2-7b": "qwen2_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "granite-3-2b": "granite_3_2b",
    "zamba2-7b": "zamba2_7b",
    "xlstm-125m": "xlstm_125m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x22b": "mixtral_8x22b",
    "pixtral-12b": "pixtral_12b",
}


def get(name: str):
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
