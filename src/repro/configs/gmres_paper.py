"""The paper's own experiment config: dense nonsymmetric systems,
N = 1000..10000, restarted GMRES(m=30), tol 1e-6 (pracma default-ish),
four offload strategies.  Used by benchmarks/gmres_strategies.py."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GmresExperiment:
    sizes: tuple = (1_000, 2_000, 3_000, 4_000, 5_000,
                    6_000, 7_000, 8_000, 9_000, 10_000)
    restart_m: int = 30
    tol: float = 1e-6
    max_restarts: int = 50
    strategies: tuple = ("serial_numpy", "offload_matvec",
                         "transfer_per_call", "device_resident")
    # distributed extension (beyond the paper's 2 GB wall)
    sharded_sizes: tuple = (16_384, 65_536)


CONFIG = GmresExperiment()
