"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 on every SECOND layer
(interleaved, llama4-style) + 1 shared expert — the interleave + shared
expert is what makes 48L/5120/8192/128e consistent with ~400B total / ~17B
active.  [hf:meta-llama/Llama-4-Maverick-17B-128E]

bf16 optimizer moments: 400B fp32 moments would not fit 256 x 16 GB HBM
(napkin math in EXPERIMENTS.md SSDry-run)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=202_048,
    num_experts=128,
    top_k=1,
    moe_every=2,
    num_shared_experts=1,
    rope_theta=500_000.0,
    moment_dtype="bfloat16",
)
