"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2 every layer, sliding-window attention
[arXiv:2401.04088]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6_144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=32_768,
    num_experts=8,
    top_k=2,
    moe_every=1,
    window=4_096,
    rope_theta=1_000_000.0,
    moment_dtype="bfloat16",
)
