"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — mistral-nemo-style decoder; pixtral-ViT frontend STUBBED
(input_specs provides 1024 precomputed patch embeddings at width 1024)
[hf:mistralai/Pixtral-12B-2409]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5_120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    num_patches=1_024,
    rope_theta=1_000_000.0,
)
