"""whisper-small [audio]: enc-dec, conv frontend stubbed.

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865  [arXiv:2212.04356]
Encoder: 12 layers over 1500 precomputed frame embeddings (stub = output of
the two conv1d layers).  Decoder shapes follow the assignment's seq_len.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    encoder_layers=12,
    encoder_seq=1_500,
    positions="sinusoidal",
    tie_embeddings=True,
    norm_eps=1e-5,
)
