"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — mLSTM blocks
with an sLSTM every 4th block (d_ff=0: no MLPs)  [arXiv:2405.04517]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=4,
    ssm_chunk=256,
)
