"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention block every
6 layers (13 sites, weight-tied)  [arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3_584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    ssm_chunk=256,
)
