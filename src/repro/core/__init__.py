"""repro.core — the paper's contribution: restarted GMRES(m) on JAX.

Public API:
  gmres, gmres_batched       single-device (or shard-local) solver
  gmres_sharded              shard_map row-sharded distributed solver —
                             a thin wrapper over the SAME gmres cycle
  gmres_sstep_sharded        row-sharded communication-avoiding s-step
  strategies.*               the paper's four offload strategies
  operators.*                dense / sparse / sliced-ELL / banded /
                             matrix-free operators
  stencils.*                 classic sparse test problems (Poisson 2D/3D,
                             convection-diffusion) as structured operators
  graphs.*                   power-law graph workloads (Laplacians,
                             PageRank-style systems) — the irregular-
                             sparsity regime the sliced-ELL format targets
  preconditioners.*          Jacobi / block-Jacobi / polynomial
"""
from repro.core.gmres import gmres, gmres_batched, gmres_jit, GmresResult
from repro.core.sstep import gmres_sstep
from repro.core.distributed import (gmres_sharded, gmres_sstep_sharded,
                                    make_sharded_solver, shard_specs)
from repro.core import (arnoldi, givens, graphs, operators, preconditioners,
                        stencils, strategies)

__all__ = [
    "gmres", "gmres_batched", "gmres_jit", "GmresResult", "gmres_sstep",
    "gmres_sharded", "gmres_sstep_sharded", "make_sharded_solver",
    "shard_specs",
    "arnoldi", "givens", "graphs", "operators", "preconditioners",
    "stencils", "strategies",
]
