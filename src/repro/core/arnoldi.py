"""Arnoldi iteration step: builds the Krylov basis one vector at a time.

Three orthogonalization schemes:

- ``cgs``  — classical Gram-Schmidt, the scheme in the paper's listing
             (lines 3-4): h_i = (A v_j, v_i) for all i, then one update.
- ``mgs``  — modified Gram-Schmidt, the numerically standard serial scheme
             (what pracma::gmres uses); j sequential level-1 dots.
- ``cgs2`` — classical Gram-Schmidt **twice** (reorthogonalized).  The
             TPU-native adaptation: 2x (V @ w) GEMVs + 2x (V^T h) updates —
             level-2 / MXU work and exactly TWO collective rounds when the
             basis is row-sharded, vs. j rounds for MGS.  Stability is
             equivalent to MGS-with-reorth (Giraud, Langou, Rozloznik 2005).

The basis ``V`` is stored **row-major (m+1, n)** — basis vector j is row j —
so dynamic-index writes are contiguous and ``V @ w`` is a single GEMV.

All schemes take an optional ``axis_name``: when set, vectors are the local
shard of a row-sharded (over n) vector and every inner product is completed
with a ``psum`` over that mesh axis.  This is the entire difference between
the single-device and the distributed solver.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax


def _psum(x, axis_name):
    return x if axis_name is None else lax.psum(x, axis_name)


def _dot(a, b, axis_name):
    return _psum(jnp.dot(a, b), axis_name)


def norm(v, axis_name=None):
    return jnp.sqrt(_psum(jnp.vdot(v, v).real, axis_name))


class ArnoldiStep(NamedTuple):
    v_next: jax.Array  # candidate basis vector (normalized), local shard
    h: jax.Array       # Hessenberg column, length m+1 (entries > j+1 zero)
    h_last: jax.Array  # h[j+1] = ||w|| before normalization (breakdown probe)


def _row_mask(m1: int, j, dtype):
    """mask[i] = 1 for i <= j else 0 — selects the valid basis rows."""
    return (jnp.arange(m1) <= j).astype(dtype)


def cgs_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """Classical GS (the paper's listing): one projection pass."""
    m1 = v_basis.shape[0]
    mask = _row_mask(m1, j, w.dtype)
    h = _psum(v_basis @ w, axis_name) * mask          # (m+1,)  one GEMV
    w = w - h @ v_basis                                # rank-(j+1) update
    return _finalize(w, h, j, axis_name)


def cgs2_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """CGS2: classical GS applied twice (full reorthogonalization)."""
    m1 = v_basis.shape[0]
    mask = _row_mask(m1, j, w.dtype)
    h1 = _psum(v_basis @ w, axis_name) * mask
    w = w - h1 @ v_basis
    h2 = _psum(v_basis @ w, axis_name) * mask          # second pass
    w = w - h2 @ v_basis
    return _finalize(w, h1 + h2, j, axis_name)


def mgs_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """Modified GS: sequential projections (numerically standard, serial)."""
    m1 = v_basis.shape[0]

    def body(i, carry):
        w, h = carry
        active = (i <= j).astype(w.dtype)
        hi = _dot(v_basis[i], w, axis_name) * active
        w = w - hi * v_basis[i]
        return w, h.at[i].set(hi)

    w, h = lax.fori_loop(0, m1, body, (w, jnp.zeros((m1,), w.dtype)))
    return _finalize(w, h, j, axis_name)


def _finalize(w, h, j, axis_name) -> ArnoldiStep:
    h_last = norm(w, axis_name)
    eps = jnp.asarray(jnp.finfo(w.dtype).tiny ** 0.5, w.dtype)
    v_next = w / jnp.maximum(h_last, eps)  # breakdown-guarded
    h = h.at[j + 1].set(h_last)
    return ArnoldiStep(v_next=v_next, h=h, h_last=h_last)


_SCHEMES: dict = {"cgs": cgs_step, "cgs2": cgs2_step, "mgs": mgs_step}


def step(scheme: str) -> Callable:
    try:
        return _SCHEMES[scheme]
    except KeyError:
        raise ValueError(f"unknown gram-schmidt scheme {scheme!r}; "
                         f"options: {sorted(_SCHEMES)}") from None
