"""Arnoldi iteration step: builds the Krylov basis one vector at a time.

Four orthogonalization schemes:

- ``cgs``  — classical Gram-Schmidt, the scheme in the paper's listing
             (lines 3-4): h_i = (A v_j, v_i) for all i, then one update.
- ``mgs``  — modified Gram-Schmidt, the numerically standard serial scheme
             (what pracma::gmres uses); j sequential level-1 dots.
- ``cgs2`` — classical Gram-Schmidt **twice** (reorthogonalized).  The
             TPU-native adaptation: 2x (V @ w) GEMVs + 2x (V^T h) updates —
             level-2 / MXU work and exactly TWO collective rounds when the
             basis is row-sharded, vs. j rounds for MGS.  Stability is
             equivalent to MGS-with-reorth (Giraud, Langou, Rozloznik 2005).
- ``cgs2_fused`` — the same CGS2 arithmetic executed by the Pallas
             kernels (``kernels/cgs2.py``).  Single-shard: the fused
             kernel — projection and update share one grid, h never
             round-trips to HBM.  Row-sharded (``axis_name`` set): the
             SPLIT-PHASE pair — a per-shard project kernel, the h psum
             at the shard_map level, a per-shard update kernel — so the
             distributed solve stays on the kernel path with the
             collective at the only place the scheme admits it.
             Compiled on TPU, interpreted on CPU, and automatically the
             plain ``cgs2`` reference when Pallas is unavailable.

The basis ``V`` is stored **row-major (m+1, n)** — basis vector j is row j —
so dynamic-index writes are contiguous and ``V @ w`` is a single GEMV.

All schemes take an optional ``axis_name``: when set, vectors are the local
shard of a row-sharded (over n) vector and every inner product is completed
with a ``psum`` over that mesh axis.  This is the entire difference between
the single-device and the distributed solver.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax


def _psum(x, axis_name):
    return x if axis_name is None else lax.psum(x, axis_name)


def _dot(a, b, axis_name):
    return _psum(jnp.dot(a, b), axis_name)


def norm(v, axis_name=None):
    return jnp.sqrt(_psum(jnp.vdot(v, v).real, axis_name))


class ArnoldiStep(NamedTuple):
    v_next: jax.Array  # candidate basis vector (normalized), local shard
    h: jax.Array       # Hessenberg column, length m+1 (entries > j+1 zero)
    h_last: jax.Array  # h[j+1] = ||w|| before normalization (breakdown probe)


def _row_mask(m1: int, j, dtype):
    """mask[i] = 1 for i <= j else 0 — selects the valid basis rows."""
    return (jnp.arange(m1) <= j).astype(dtype)


def cgs_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """Classical GS (the paper's listing): one projection pass."""
    m1 = v_basis.shape[0]
    mask = _row_mask(m1, j, w.dtype)
    h = _psum(v_basis @ w, axis_name) * mask          # (m+1,)  one GEMV
    w = w - h @ v_basis                                # rank-(j+1) update
    return _finalize(w, h, j, axis_name)


def cgs2_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """CGS2: classical GS applied twice (full reorthogonalization)."""
    m1 = v_basis.shape[0]
    mask = _row_mask(m1, j, w.dtype)
    h1 = _psum(v_basis @ w, axis_name) * mask
    w = w - h1 @ v_basis
    h2 = _psum(v_basis @ w, axis_name) * mask          # second pass
    w = w - h2 @ v_basis
    return _finalize(w, h1 + h2, j, axis_name)


def mgs_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """Modified GS: sequential projections (numerically standard, serial)."""
    m1 = v_basis.shape[0]

    def body(i, carry):
        w, h = carry
        active = (i <= j).astype(w.dtype)
        hi = _dot(v_basis[i], w, axis_name) * active
        w = w - hi * v_basis[i]
        return w, h.at[i].set(hi)

    w, h = lax.fori_loop(0, m1, body, (w, jnp.zeros((m1,), w.dtype)))
    return _finalize(w, h, j, axis_name)


def cgs2_fused_step(v_basis, w, j, axis_name=None) -> ArnoldiStep:
    """CGS2 via the Pallas kernels (kernels/cgs2.py).

    Single-shard: the fused kernel (projection and update share one grid).
    Row-sharded: the split-phase pair, cut where the h psum must cross
    shards — project kernel, psum, update kernel, per pass — so the
    distributed solve runs the same per-shard kernel arithmetic instead of
    bailing to the reference (the pre-PR-5 behavior).  Backends without
    Pallas support fall back to the psum-correct jnp reference; on CPU the
    kernels run in interpret mode (what CI tests).
    """
    from repro.kernels import tuning

    mode = tuning.kernel_mode()
    if mode == "ref":
        return cgs2_step(v_basis, w, j, axis_name)

    from repro.kernels import cgs2 as cgs2_k

    m1, n = v_basis.shape
    mask = _row_mask(m1, j, jnp.float32)
    bn = tuning.choose_gs_block(m1, n, jnp.dtype(v_basis.dtype).name)
    if axis_name is not None:
        h, w2 = cgs2_k.cgs2_split(v_basis, w, mask, axis_name, block_n=bn,
                                  interpret=mode == "interpret")
    else:
        h, w2 = cgs2_k.cgs2(v_basis, w, mask, block_n=bn,
                            interpret=mode == "interpret")
    return finalize(w2.astype(w.dtype), h.astype(w.dtype), j, axis_name)


# --------------------------------------------------------------------------
# Single-reduce CGS2 (gs="cgs2_pipelined"): payload + replicated recovery
# --------------------------------------------------------------------------
#
# The split-phase CGS2 step pays three collective rounds (h1 psum, h2 psum,
# norm psum).  The single-reduce scheme packs everything one step needs into
# ONE stacked payload over the column block W = [z, v_j]:
#
#     p = psum([ mask * (V @ [z, v_j]) ; z.z, v_j.v_j ])   -- (m+2, 2)
#
# Column 0 is the projection of the fresh mat-vec output; column 1 is the
# MEASURED row j of the basis Gram matrix G = V V^T — v_j was built (and
# normalized) last step, so its actual inner products against the older
# rows carry every rounding error of that update.  This measurement is the
# load-bearing part: a G maintained by algebraic prediction alone (the
# g_col = (h1 - G h_tot)/s recurrence of the classical derivation) cannot
# see update/normalization rounding, and the norm recovery's cancellation
# amplifies the resulting G drift by ~||h||^2/||w''||^2 per step —
# orthogonality collapses within a handful of steps on fast-converging
# systems.  With G measured, the recovery is replicated O(m^2) algebra:
#
#     h1     = mask * p[:m1, 0]       zeta = p[m1, 0] = ||z||^2
#     G[j,:] = G[:,j] = mask * p[:m1, 1]   (measured, overwrites the j row)
#     h2     = mask * (h1 - G h1)     (delayed reorthogonalization)
#     h_tot  = h1 + h2                w'' = z - h_tot @ V   (single update)
#     ||w''||^2 = zeta - 2 h_tot.h1 + h_tot.G.h_tot   (exact quadratic form)
#
# No second projection pass, no separate norm psum, no predicted Gram
# column.  The G entries are immutable once measured (basis rows never
# change), so G converges to the true floating-point Gram matrix of the
# basis as built; each restart still recomputes the TRUE residual, which
# is what the +-1-restart parity contract absorbs.


def sr_payload_ref(v_basis, z, j, axis_name=None):
    """psum-safe jnp reference for the fused payload (one psum).

    Returns the psum-completed (m1 + 1, 2) block
    ``[mask * (V @ [z, v_j]); z.z, v_j.v_j]`` — column 0 the projection of
    the mat-vec output, column 1 the measured Gram row of basis row j.
    """
    acc = jnp.promote_types(z.dtype, jnp.float32)
    mask = _row_mask(v_basis.shape[0], j, acc)
    vj = lax.dynamic_index_in_dim(v_basis, j, axis=0, keepdims=False)
    w2 = jnp.stack([z, vj.astype(z.dtype)], axis=1).astype(acc)
    h = (v_basis.astype(acc) @ w2) * mask[:, None]
    nrm = jnp.sum(w2 * w2, axis=0, keepdims=True)
    return _psum(jnp.concatenate([h, nrm], axis=0), axis_name)


def sr_payload(v_basis, z, j, axis_name=None):
    """Fused single-reduce payload psum — ONE collective per Arnoldi step.

    Dispatches to the Pallas payload kernel under the standard policy
    (compiled on TPU / interpret on CPU / jnp reference otherwise, plus the
    ``tuning.gs_payload_fits`` VMEM gate) and completes the psum here so
    callers see the GLOBAL payload either way.
    """
    from repro.kernels import tuning

    m1, n = v_basis.shape
    mode = tuning.kernel_mode()
    dtn = jnp.dtype(v_basis.dtype).name
    if mode == "ref" or not tuning.gs_payload_fits(m1, n, dtn):
        return sr_payload_ref(v_basis, z, j, axis_name)

    from repro.kernels import cgs2 as cgs2_k

    mask = _row_mask(m1, j, jnp.float32)
    vj = lax.dynamic_index_in_dim(v_basis, j, axis=0, keepdims=False)
    w2 = jnp.stack([z, vj.astype(z.dtype)], axis=1)
    bn = tuning.choose_gs_block(m1, n, dtn)
    p = cgs2_k.gs_project_norm_partial(v_basis, w2, mask, block_n=bn,
                                       interpret=mode == "interpret")
    return _psum(p, axis_name)


def sr_recover(payload, gram, j):
    """Replicated single-reduce recovery (no collectives, O(m^2) flops).

    payload: the psum-completed (m1+1, 2) block; gram: the maintained
    (m1, m1) basis Gram matrix (identity at cycle start); j: current step
    index.

    Returns ``(h_tot, s_norm, zeta, gram')`` — the combined two-pass
    Hessenberg coefficients, the recovered norm ||w''||, the raw ||z||^2,
    and the Gram matrix with row/column j overwritten by the MEASURED
    inner products of basis row j (payload column 1).
    """
    m1 = gram.shape[0]
    mask = _row_mask(m1, j, payload.dtype)
    h1 = payload[:m1, 0] * mask
    zeta = jnp.maximum(payload[m1, 0], 0.0)
    g_row = payload[:m1, 1] * mask        # measured V @ v_j (diag at j)
    gram = lax.dynamic_update_slice(gram, g_row[None, :], (j, 0))
    gram = lax.dynamic_update_slice(gram, g_row[:, None], (0, j))
    h2 = (h1 - gram @ h1) * mask          # second pass against measured G
    h_tot = h1 + h2
    delta = zeta - 2.0 * (h_tot @ h1) + h_tot @ (gram @ h_tot)
    s_norm = jnp.sqrt(jnp.maximum(delta, 0.0))
    return h_tot, s_norm, zeta, gram


def finalize(w, h, j, axis_name=None) -> ArnoldiStep:
    """Normalize the orthogonalized w and record the h[j+1] breakdown probe.

    Shared epilogue of every scheme — and the re-entry point for the fused
    Arnoldi-step kernel (core/gmres.py), which produces (w, h) in one
    ``pallas_call`` and hands the norm/psum back to this layer.
    """
    h_last = norm(w, axis_name)
    eps = jnp.asarray(jnp.finfo(w.dtype).tiny ** 0.5, w.dtype)
    v_next = w / jnp.maximum(h_last, eps)  # breakdown-guarded
    h = h.at[j + 1].set(h_last)
    return ArnoldiStep(v_next=v_next, h=h, h_last=h_last)


_finalize = finalize  # internal alias (pre-existing call sites)

_SCHEMES: dict = {"cgs": cgs_step, "cgs2": cgs2_step, "mgs": mgs_step,
                  "cgs2_fused": cgs2_fused_step}


def step(scheme: str) -> Callable:
    if scheme == "cgs2_pipelined":
        # Stateful scheme (carries a Gram matrix and the pipelined matvec
        # across steps) — implemented as a dedicated cycle in core/gmres.py,
        # not as a per-step function.  Callers that can only run stateless
        # steps (e.g. the batched solver) degrade it to plain CGS2.
        raise ValueError(
            "gs='cgs2_pipelined' is a whole-cycle scheme handled inside "
            "gmres(); use step('cgs2') for a stateless equivalent")
    try:
        return _SCHEMES[scheme]
    except KeyError:
        raise ValueError(f"unknown gram-schmidt scheme {scheme!r}; "
                         f"options: {sorted(_SCHEMES)} + ['cgs2_pipelined']"
                         ) from None
