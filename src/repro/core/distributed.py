"""Distributed GMRES: the paper's device-memory wall, removed by sharding.

The paper could not exceed N = 10000 because A (N^2 doubles) had to fit a
2 GB card.  Here the operator is **row-sharded** across a mesh axis: chip
p owns row block p of the matrix storage (dense rows, ELL rows, or band
columns) and the matching shard of every Krylov vector.  Per Arnoldi step
the communication is:

  - the operand exchange for the mat-vec — an all-gather (n values) for
    dense A, or a ``halo_exchange`` of O(halo) boundary values for
    banded/ELL operators (the Ioannidis et al. 1906.04051 picture);
  - psum-completed inner products — 2 rounds for CGS2, j rounds for MGS —

which is exactly why CGS2 is the distributed scheme of choice, and why
the s-step solver (one exchange + one psum per s steps on banded systems)
is the communication-avoiding end of the same line.

There is ONE cycle implementation.  Everything here is a thin
``shard_map`` wrapper: the body enters ``kernels.tuning.shard_context``
(so operators and schemes dispatch their per-shard kernel variants — the
split-phase CGS2 pair, halo SpMV, CA matrix powers) and calls the very
same ``gmres`` / ``gmres_sstep`` the single-device solve uses,
parameterized by ``axis_name``.  No Arnoldi loop, no Givens rotation, no
orthogonalization scheme lives in this file — distribution is a
deployment config, not a fork of the numerics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import operators as op_mod
from repro.core.gmres import Diagnostics, gmres, GmresResult
from repro.core.sstep import gmres_sstep
from repro.kernels import tuning


def shard_specs(op, axis: str):
    """Row-sharding PartitionSpec pytree for an explicit operator.

    The returned object mirrors the operator's pytree structure with a
    spec at every array leaf — exactly what ``shard_map``'s ``in_specs``
    (and, via ``NamedSharding``, ``jax.jit``'s ``in_shardings``) want:

      DenseOperator   a      -> P(axis, None)     row blocks
      SparseOperator  values -> P(axis, None)     row blocks (cols too;
                                column indices stay GLOBAL — the sharded
                                ``__call__`` remaps them per shard)
      BandedOperator  bands  -> P(None, axis)     column blocks of the
                                band stack == row blocks of the matrix
      SlicedEllOperator        REPLICATED (P(None, ...) everywhere): the
                                global nnz sort breaks contiguous row
                                ownership, and the payload is the
                                COMPRESSED form — its sharded ``__call__``
                                slices local rows itself (halo path when
                                the bandwidth bound allows, all-gather
                                otherwise)
    """
    if isinstance(op, op_mod.DenseOperator):
        return op_mod.DenseOperator(P(axis, None), op.backend)
    if isinstance(op, op_mod.SparseOperator):
        return op_mod.SparseOperator(P(axis, None), P(axis, None),
                                     op.backend, op.halo)
    if isinstance(op, op_mod.BandedOperator):
        return op_mod.BandedOperator(P(None, axis), op.offsets, op.backend)
    if isinstance(op, op_mod.SlicedEllOperator):
        return op_mod.SlicedEllOperator(
            tuple(P(None, None) for _ in op.bin_values),
            tuple(P(None, None) for _ in op.bin_cols),
            P(None), op.backend, op.halo, op.slice_height, op.identity_perm)
    raise TypeError(
        f"gmres_sharded needs an explicit-storage operator (Dense/Sparse/"
        f"Banded/SlicedEll) or a dense array; got {type(op).__name__} — "
        f"matrix-free operators already compose with shard_map directly via "
        f"gmres(..., axis_name=...)")


def _run_sharded(mesh: Mesh, axis: str, op, b, x0, caller: str, body):
    """Shared wrapper skeleton of the sharded entry points.

    Validates divisibility, shards (op, b, x0) by ``shard_specs``, runs
    ``body(op_local, b_local, x0_local) -> GmresResult`` per shard inside
    the dispatch layer's ``shard_context``, and gathers the solution so
    callers see the replicated global x.  The entry points below differ
    ONLY in which shared cycle ``body`` calls.
    """
    nshards = mesh.shape[axis]
    n = b.shape[0]
    if n % nshards:
        raise ValueError(f"{caller}: n={n} not divisible by the "
                         f"{nshards}-way mesh axis")
    if op.shape[0] != n:
        raise ValueError(f"{caller}: operator {op.shape} vs b {b.shape}")
    if x0 is None:
        x0 = jnp.zeros_like(b)

    def solve_local(op_local, b_local, x0_local):
        with tuning.shard_context(axis, nshards):
            res = body(op_local, b_local, x0_local)
            # x is a local shard; gather it so callers see the global x.
            x_full = lax.all_gather(res.x, axis, tiled=True)
            return res._replace(x=x_full)

    # Mirrors GmresResult's pytree EXACTLY (including Diagnostics): a new
    # result field needs a replicated spec here or shard_map rejects the
    # body's output.  Everything but x is replicated scalars/rings — the
    # psum-completed betas are identical on every shard.
    out_specs = GmresResult(
        x=P(), residual=P(), restarts=P(), converged=P(), inner_steps=P(),
        done=P(),
        diagnostics=Diagnostics(status=P(), residual_history=P(),
                                history_len=P()),
    )
    fn = compat.shard_map(
        solve_local,
        mesh=mesh,
        in_specs=(shard_specs(op, axis), P(axis), P(axis)),
        out_specs=out_specs,
        check_vma=False,
    )
    return fn(op, b, x0)


def _local_block_jacobi(a_local: jax.Array, axis: str):
    """Shard-LOCAL block-Jacobi preconditioner: each shard factorizes its

    own diagonal block of A and applies it with ZERO communication.  This
    is the distributed-optimization lever for Krylov methods: every Arnoldi
    step costs one operand exchange, so cutting steps k-fold cuts
    collective rounds k-fold while the preconditioner itself stays
    collective-free (SSPerf hillclimb 3).
    """
    rows, n = a_local.shape
    p = lax.axis_index(axis)
    block = lax.dynamic_slice(a_local, (0, p * rows), (rows, rows))
    lu, piv = jax.scipy.linalg.lu_factor(block)

    def apply(v_local):
        return jax.scipy.linalg.lu_solve((lu, piv), v_local)

    return apply


_SHARD_PRECONDS = ("block_jacobi", "jacobi", "chebyshev",
                   "banded_block_jacobi")


def _resolve_shard_precond(precond, op, caller: str):
    """Resolve ``precond=`` for the sharded wrappers, EAGERLY.

    Returns ``build(op_local, axis) -> callable | None`` to run inside the
    shard_map body.  Strings name the built-in shard-safe members: any
    eager setup (Chebyshev interval estimation, the dense block-Jacobi
    check) happens HERE against the global operator, and the per-shard
    ``rebind`` swaps in local storage inside the trace.  A
    ``Preconditioner`` instance must be ``shard_aware`` — anything else
    raises NOW, at the call boundary, instead of failing inside shard_map
    (or worse, silently applying a global-frame M^{-1} to local shards).
    """
    if precond is None:
        return lambda op_local, axis: None
    from repro.core import preconditioners as pc_mod
    if isinstance(precond, str):
        if precond not in _SHARD_PRECONDS:
            raise ValueError(
                f"{caller}: unknown precond {precond!r}; options: "
                f"{[None, *_SHARD_PRECONDS]}")
        if precond == "block_jacobi":
            if not isinstance(op, op_mod.DenseOperator):
                raise ValueError(
                    f"{caller}: precond='block_jacobi' needs a dense "
                    f"operator (it factorizes the diagonal block of A); "
                    f"banded operators take 'banded_block_jacobi'")
            return lambda op_local, axis: _local_block_jacobi(
                op_local.a, axis)
        if precond == "banded_block_jacobi":
            if not isinstance(op, op_mod.BandedOperator):
                raise ValueError(
                    f"{caller}: precond='banded_block_jacobi' needs a "
                    f"BandedOperator (its setup walks the band pattern); "
                    f"dense operators take 'block_jacobi'")
            pc = pc_mod.banded_block_jacobi(op)
        elif precond == "jacobi":
            pc = pc_mod.jacobi(op)
        else:
            pc = pc_mod.chebyshev(op)
        return lambda op_local, axis: pc.rebind(op_local)
    if getattr(precond, "shard_aware", False):
        return lambda op_local, axis: precond.rebind(op_local)
    raise ValueError(
        f"{caller}: precond {getattr(precond, 'name', precond)!r} is not "
        f"shard-aware; pass one of {list(_SHARD_PRECONDS)} or a "
        f"Preconditioner with shard_aware=True (e.g. chebyshev, jacobi, "
        f"banded_block_jacobi) — banded_ilu0's sweeps recur across the "
        f"whole row range and cannot be sharded")


def gmres_sharded(
    mesh: Mesh,
    axis: str,
    a,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    m: int = 30,
    tol: float = 1e-5,
    max_restarts: int = 50,
    gs: str = "cgs2_fused",
    precond=None,
    compute_dtype=None,
) -> GmresResult:
    """Solve Ax=b with the operator row-sharded over ``axis`` of ``mesh``.

    ``a`` may be a GLOBAL dense (n, n) array or any explicit operator
    (``DenseOperator`` / ``SparseOperator`` / ``BandedOperator``) holding
    global storage — the wrapper derives the row-sharding specs from the
    operator type (``shard_specs``) and the per-shard communication
    pattern comes from the operator's own shard-aware mat-vec (all-gather
    for dense, ppermute halo exchange for banded/ELL).  ``b`` is global
    (n,).  Returns a replicated ``GmresResult``.

    The default ``gs="cgs2_fused"`` runs the split-phase CGS2 kernel pair
    per shard (project kernel, h psum, update kernel); it degrades to the
    psum-correct jnp ``cgs2`` wherever Pallas is unavailable, so the
    default is safe on any backend.

    ``precond``: None | "block_jacobi" (dense; shard-local LU of the
    diagonal block) | "banded_block_jacobi" (banded; shard-local ILU(0)
    sweeps) | "jacobi" | "chebyshev" (``order`` mat-vecs through the
    halo-exchange path — ppermutes only, ZERO extra psums, so the
    pipelined one-psum-per-step count is preserved) | any ``shard_aware``
    ``Preconditioner`` instance (rebound per shard).  Everything else
    raises ``ValueError`` here, before the shard_map trace.
    """
    op = op_mod.as_operator(a)
    build_pc = _resolve_shard_precond(precond, op, "gmres_sharded")

    def body(op_local, b_local, x0_local):
        return gmres(
            op_local, b_local, x0_local, m=m, tol=tol,
            max_restarts=max_restarts, gs=gs, axis_name=axis,
            precond=build_pc(op_local, axis), compute_dtype=compute_dtype,
        )

    return _run_sharded(mesh, axis, op, b, x0, "gmres_sharded", body)


def gmres_sstep_sharded(
    mesh: Mesh,
    axis: str,
    a,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    s: int = 4,
    blocks: int = 5,
    tol: float = 1e-5,
    max_restarts: int = 30,
    gs: str = "cgs2",
    precond=None,
) -> GmresResult:
    """Row-sharded s-step GMRES — the communication-avoiding wrapper.

    Same thin shard_map-over-the-shared-cycle shape as ``gmres_sharded``,
    driving ``core.sstep.gmres_sstep``.  On banded operators the block
    step runs the halo matrix-powers kernel (ONE neighbor exchange + ONE
    psum for all s powers) and the split-phase block-GS pair — per s
    steps that is 4 collective rounds where the standard sharded cycle
    pays ~4 PER step.  ``gs="cgs2_pipelined"`` fuses each block-GS pass's
    C and Gram psums into ONE stacked payload reduction (6 -> 4 rounds
    per block; see ``core.sstep.gmres_sstep``).

    ``precond`` accepts the same options as ``gmres_sharded`` (resolved by
    the same ``_resolve_shard_precond``); a non-identity M^{-1} moves the
    power block onto the psum-per-power reference over ``A M^{-1}`` (the
    CA halo-powers kernel streams A's own bands), so preconditioning here
    trades the 2-round block for FEWER blocks — the steps-vs-cost rows in
    ``core/strategies.py`` quantify the trade.
    """
    op = op_mod.as_operator(a)
    build_pc = _resolve_shard_precond(precond, op, "gmres_sstep_sharded")

    def body(op_local, b_local, x0_local):
        return gmres_sstep(op_local, b_local, x0_local, s=s, blocks=blocks,
                           tol=tol, max_restarts=max_restarts,
                           axis_name=axis, gs=gs,
                           precond=build_pc(op_local, axis))

    return _run_sharded(mesh, axis, op, b, x0, "gmres_sstep_sharded", body)


def make_sharded_solver(mesh: Mesh, axis: str, n: int, *, m: int = 30,
                        tol: float = 1e-5, max_restarts: int = 50,
                        gs: str = "cgs2_fused", operator=None):
    """jit-compiled sharded solver with explicit in/out shardings.

    This is the entry the launcher and the dry-run lower: the operator and
    b arrive already device-sharded (NamedSharding derived from the same
    ``shard_specs`` the solver uses), nothing is re-laid-out at the
    boundary.  ``operator``: a template operator whose TYPE/static fields
    determine the shardings — pass e.g. a ``BandedOperator`` to lower the
    stencil solver; the default (None) keeps the raw dense-array calling
    convention, ``solver(a, b)`` with a global (n, n) array.
    """
    from jax.sharding import NamedSharding

    solve = functools.partial(
        gmres_sharded, mesh, axis, m=m, tol=tol, max_restarts=max_restarts,
        gs=gs,
    )
    if operator is None:
        op_sh = NamedSharding(mesh, P(axis, None))   # raw (n, n) array
    else:
        specs = shard_specs(operator, axis)
        op_sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), specs)
    b_sh = NamedSharding(mesh, P(axis))
    return jax.jit(solve, in_shardings=(op_sh, b_sh))
