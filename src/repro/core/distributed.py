"""Distributed GMRES: the paper's device-memory wall, removed by sharding.

The paper could not exceed N = 10000 because A (N^2 doubles) had to fit a
2 GB card.  Here A is **row-sharded** across a mesh axis: chip p owns the
row block A[p*n/P:(p+1)*n/P, :] and the matching shard of every Krylov
vector.  Per Arnoldi step the communication is:

  - one all-gather of the sharded iterate (n values)   — for the mat-vec
  - psum-completed inner products                      — 2 rounds for CGS2,
                                                         j rounds for MGS

which is exactly why CGS2 is the distributed scheme of choice (DESIGN.md §2).

Everything below is `shard_map` over the existing single-device code in
core/gmres.py — the solver body is IDENTICAL, parameterized by ``axis_name``.
That is the framework claim: distribution is a deployment config, not a fork
of the numerics.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.gmres import gmres, GmresResult


def _local_matvec(a_local: jax.Array, axis_name: str) -> Callable:
    """Row-sharded dense mat-vec: all-gather x, local GEMM row block.

    a_local: (n/P, n) row block.  Input/output are (n/P,) local shards.
    """

    def matvec(v_local):
        v_full = lax.all_gather(v_local, axis_name, tiled=True)   # (n,)
        return a_local @ v_full

    return matvec


def _local_block_jacobi(a_local: jax.Array, axis: str):
    """Shard-LOCAL block-Jacobi preconditioner: each shard factorizes its

    own diagonal block of A and applies it with ZERO communication.  This
    is the distributed-optimization lever for Krylov methods: every Arnoldi
    step costs one all-gather, so cutting steps k-fold cuts collective
    rounds k-fold while the preconditioner itself stays collective-free
    (SSPerf hillclimb 3).
    """
    rows, n = a_local.shape
    p = lax.axis_index(axis)
    block = lax.dynamic_slice(a_local, (0, p * rows), (rows, rows))
    lu, piv = jax.scipy.linalg.lu_factor(block)

    def apply(v_local):
        return jax.scipy.linalg.lu_solve((lu, piv), v_local)

    return apply


def gmres_sharded(
    mesh: Mesh,
    axis: str,
    a: jax.Array,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    m: int = 30,
    tol: float = 1e-5,
    max_restarts: int = 50,
    gs: str = "cgs2",
    precond: Optional[str] = None,
) -> GmresResult:
    """Solve Ax=b with A row-sharded over ``axis`` of ``mesh``.

    ``a`` is the GLOBAL (n, n) array (caller may pass it already device-
    sharded); ``b`` global (n,).  Returns a replicated GmresResult.
    ``precond``: None | "block_jacobi" (shard-local, communication-free).
    """

    def solve_local(a_local, b_local):
        mv = _local_matvec(a_local, axis)
        pc = _local_block_jacobi(a_local, axis) if precond == "block_jacobi" \
            else None
        res = gmres(
            mv, b_local, None, m=m, tol=tol, max_restarts=max_restarts,
            gs=gs, axis_name=axis, precond=pc,
        )
        # x is a local shard; gather it so callers see the global solution.
        x_full = lax.all_gather(res.x, axis, tiled=True)
        return res._replace(x=x_full)

    n_axis = mesh.shape[axis]
    assert a.shape[0] % n_axis == 0, (a.shape, n_axis)

    spec_a = P(axis, None)
    spec_b = P(axis)
    out_specs = GmresResult(
        x=P(), residual=P(), restarts=P(), converged=P(), inner_steps=P()
    )
    fn = compat.shard_map(
        solve_local,
        mesh=mesh,
        in_specs=(spec_a, spec_b),
        out_specs=out_specs,
        check_vma=False,
    )
    return fn(a, b)


def make_sharded_solver(mesh: Mesh, axis: str, n: int, *, m: int = 30,
                        tol: float = 1e-5, max_restarts: int = 50,
                        gs: str = "cgs2"):
    """jit-compiled sharded solver with explicit in/out shardings.

    This is the entry the launcher and the dry-run lower: A and b arrive
    already sharded (NamedSharding), nothing is re-laid-out at the boundary.
    """
    solve = functools.partial(
        gmres_sharded, mesh, axis, m=m, tol=tol, max_restarts=max_restarts, gs=gs
    )
    from jax.sharding import NamedSharding

    a_sh = NamedSharding(mesh, P(axis, None))
    b_sh = NamedSharding(mesh, P(axis))
    return jax.jit(solve, in_shardings=(a_sh, b_sh))
