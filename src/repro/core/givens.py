"""Incremental Givens QR for the GMRES Hessenberg least-squares problem.

The paper's step 8 solves ``min_y || beta e_1 - H~_m y ||`` — maintained here
as an incremental QR factorization updated one Hessenberg column at a time
(O(m) per step, O(m N) total as in Kelley 1995), instead of refactorizing.

All functions are shape-static and mask-driven so they live inside
``jax.lax.fori_loop`` bodies under ``jit``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GivensState(NamedTuple):
    """Rotations + rotated RHS for the first ``j`` Hessenberg columns.

    r:  (m, m)   upper-triangular factor (rows/cols beyond j untouched)
    cs: (m,)     rotation cosines (identity-initialized: cs=1)
    sn: (m,)     rotation sines   (identity-initialized: sn=0)
    g:  (m + 1,) rotated RHS; ``|g[j]|`` is the current LS residual norm
    """

    r: jax.Array
    cs: jax.Array
    sn: jax.Array
    g: jax.Array


def init(m: int, beta, dtype=jnp.float32) -> GivensState:
    g = jnp.zeros((m + 1,), dtype=dtype).at[0].set(beta.astype(dtype))
    # R starts as the identity: columns never written (early-exited steps)
    # stay e_j, keeping the triangular solve nonsingular with y_j = 0.
    return GivensState(
        r=jnp.eye(m, dtype=dtype),
        cs=jnp.ones((m,), dtype=dtype),
        sn=jnp.zeros((m,), dtype=dtype),
        g=g,
    )


def _rotation(a, b, eps):
    """Stable Givens rotation zeroing ``b`` against ``a``."""
    denom = jnp.sqrt(a * a + b * b)
    safe = denom > eps
    c = jnp.where(safe, a / jnp.where(safe, denom, 1.0), 1.0)
    s = jnp.where(safe, b / jnp.where(safe, denom, 1.0), 0.0)
    return c, s, jnp.where(safe, denom, a)


def update(state: GivensState, h: jax.Array, j, *, active) -> GivensState:
    """Fold Hessenberg column ``h`` (length m+1, entries > j+1 zero) in as column j.

    ``active`` masks the update out entirely (converged / past-breakdown
    steps write the identity column e_j so the final triangular solve stays
    nonsingular and yields y_j = 0).
    """
    m = state.cs.shape[0]
    dtype = state.g.dtype
    eps = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)

    # Apply previously computed rotations 0..j-1 to the new column.  Rotations
    # at indices >= j are identity (cs=1, sn=0) so a full fixed-length scan is
    # equivalent to the dynamic-length loop and keeps shapes static.
    def apply_rot(i, col):
        c, s = state.cs[i], state.sn[i]
        hi, hi1 = col[i], col[i + 1]
        col = col.at[i].set(c * hi + s * hi1)
        col = col.at[i + 1].set(-s * hi + c * hi1)
        return col

    col = jax.lax.fori_loop(0, m, apply_rot, h.astype(dtype))

    # New rotation zeroing the subdiagonal entry col[j+1] against col[j].
    a = col[j]
    b = col[j + 1]
    c, s, rjj = _rotation(a, b, eps)

    # Rotate the RHS: (g_j, g_{j+1}).
    gj = state.g[j]
    new_gj = c * gj
    new_gj1 = -s * gj

    # Assemble column j of R: rotated col with the (j, j) entry replaced by rjj
    # and the subdiagonal annihilated.  Inactive steps write e_j instead.
    iota = jnp.arange(m + 1)
    col = col.at[j].set(rjj).at[j + 1].set(0.0)
    unit = (iota == j).astype(dtype)
    col = jnp.where(active, col, unit)

    r = state.r.at[:, j].set(col[:m])
    cs = state.cs.at[j].set(jnp.where(active, c, 1.0))
    sn = state.sn.at[j].set(jnp.where(active, s, 0.0))
    # Inactive steps zero g[j]: with the identity column e_j this forces
    # y_j = 0 in back-substitution, so padded steps never touch the solution.
    g = state.g.at[j].set(jnp.where(active, new_gj, 0.0))
    g = g.at[j + 1].set(jnp.where(active, new_gj1, g[j + 1]))
    return GivensState(r=r, cs=cs, sn=sn, g=g)


def residual_norm(state: GivensState, j) -> jax.Array:
    """|g[j+1]| — the LS residual after folding column j (Saad Prop. 6.9)."""
    return jnp.abs(state.g[j + 1])


def solve(state: GivensState, steps=None) -> jax.Array:
    """Back-substitute ``R y = g[:m]``.

    ``steps`` = number of Arnoldi steps actually taken; g entries at or
    beyond it are zeroed so identity-filled (never-run) columns yield
    y_j = 0 and ``x = x0 + V^T y`` is correct for any early-stop point.
    """
    m = state.cs.shape[0]
    if m == 0:
        return state.g[:0]
    g = state.g[:m]
    if steps is not None:
        g = jnp.where(jnp.arange(m) < steps, g, 0.0)
    return jax.scipy.linalg.solve_triangular(state.r, g, lower=False)
