"""Restarted GMRES(m) — jit-compilable, batched, early-stop-masked.

Faithful to the paper's algorithm (Kelley 1995 listing, section 3):

  1.  r0 = b - A x0, v1 = r0/||r0||
  2.  m Arnoldi steps building V_m, H~_m          (arnoldi.py)
  8.  y_m = argmin || beta e1 - H~_m y ||         (givens.py, incremental QR)
  9.  restart with x_m = x0 + V_m y_m until ||r|| < eps

Shape-static by construction: the inner loop always runs ``m`` steps with
converged / broken-down steps masked to no-ops (identity Givens columns,
zeroed g entries), so the whole restarted solve is ONE ``jax.jit`` program —
the ``gpuR``/vcl "everything device-resident" strategy from the paper, taken
to its logical conclusion: not a single scalar leaves the device between
restarts.

The hot loop is kernel-backed: with ``gs="fused"`` and a dense operator the
whole Arnoldi step (mat-vec + CGS2) is ONE ``pallas_call``
(kernels/arnoldi_fused.py) with w and h resident in VMEM; ``gs="cgs2_fused"``
runs the streaming fused Gram-Schmidt kernel (kernels/cgs2.py); and the
``backend="pallas"`` operators route every mat-vec through the tiled
kernels (kernels/matvec.py dense, kernels/spmv.py ELL/banded).  Each path
degrades gracefully — interpret mode on CPU, jnp reference where Pallas is
unavailable or shapes don't fit VMEM.

The same inner cycle, handed an ``axis_name``, becomes the shard_map
distributed solver (core/distributed.py) — and since PR 5 it stays
kernel-backed there too: under the distributed wrapper's
``tuning.shard_context`` the operators run their halo-exchange /
all-gather per-shard mat-vecs and ``gs="cgs2_fused"`` runs the
split-phase CGS2 kernel pair with the h psum between the phases.  There
is exactly ONE cycle implementation for local and distributed solves.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import arnoldi, givens
from repro.core.operators import (EXPLICIT_OPERATORS, DenseOperator,
                                  as_operator)


# --------------------------------------------------------------------------
# Cycle-level health taxonomy (the detection layer of core/recovery.py's
# degradation ladder; see docs/robustness.md).  Codes are int32 so the
# classification runs inside jit and crosses shard_map as a replicated
# scalar.
# --------------------------------------------------------------------------
HEALTHY = 0     # converging (or already converged)
NAN_INF = 1     # residual left the reals — poisoned arithmetic
STAGNATED = 2   # no meaningful decrease across the history window
BREAKDOWN = 3   # residual GREW across a cycle (orthogonalization collapse)
STATUS_NAMES = ("HEALTHY", "NAN_INF", "STAGNATED", "BREAKDOWN")

# Scale-relative thresholds, matching the repo's invariance contract
# (c·A, c·b must classify identically to A, b — both are pure ratios).
BREAKDOWN_GROWTH = 10.0    # beta_k > 10 * beta_{k-1}  ->  BREAKDOWN
STAGNATION_RTOL = 0.99     # beta_k >= 0.99 * beta_{k-window}  ->  STAGNATED


class Diagnostics(NamedTuple):
    """Post-solve health report attached to ``GmresResult.diagnostics``.

    ``residual_history`` is a bounded ring of TRUE per-cycle residual norms
    in chronological order — oldest first, current residual last, ``inf``
    padding on the left until the window fills.  Entry 0 of a full window is
    the residual ``window - 1`` cycles ago; the seed entry is ``||b - A
    x0||`` so examples get a convergence trace without re-solving.
    """
    status: jax.Array            # int32: HEALTHY / NAN_INF / ...
    residual_history: jax.Array  # (window,) chronological, inf-padded
    history_len: jax.Array       # int32: valid trailing entries


def classify_residuals(history, *, converged) -> jax.Array:
    """Classify a residual-history ring into a health status code.

    Pure and jit-safe; ``history`` is the chronological inf-padded ring
    described on ``Diagnostics`` (last entry = current residual).  The
    priority order NAN_INF > BREAKDOWN > STAGNATED matters: a NaN residual
    also fails the growth compare, and a breakdown window is trivially
    stagnant.  A converged solve is HEALTHY regardless of its path.
    """
    history = jnp.asarray(history)
    last = history[-1]
    prev = history[-2] if history.shape[0] > 1 else last
    oldest = history[0]
    nan_inf = jnp.logical_not(jnp.isfinite(last))
    breakdown = (jnp.isfinite(prev) & (last > BREAKDOWN_GROWTH * prev)
                 & jnp.logical_not(converged))
    stagnated = (jnp.isfinite(oldest) & (last >= STAGNATION_RTOL * oldest)
                 & jnp.logical_not(converged))
    code = jnp.where(
        nan_inf, NAN_INF,
        jnp.where(breakdown, BREAKDOWN,
                  jnp.where(stagnated, STAGNATED, HEALTHY)))
    return code.astype(jnp.int32)


class GmresResult(NamedTuple):
    x: jax.Array
    residual: jax.Array      # final true residual norm ||b - A x||
    restarts: jax.Array      # number of restart cycles executed
    converged: jax.Array     # bool
    inner_steps: jax.Array   # total Arnoldi steps actually active
    # converged OR restart budget exhausted.  Scalar for ``gmres``; per-lane
    # for ``gmres_batched``, where a True/False split reads as
    # retired-converged vs retired-FAILED — the distinction the serving
    # layer (repro/serve) keys lane retirement on.
    done: jax.Array = None
    # Cycle-level health report (``Diagnostics``) for the scalar solvers
    # (``gmres`` / ``gmres_sstep``); None on the batched path, where the
    # serving layer owns per-lane health.
    diagnostics: Optional[Diagnostics] = None

    @property
    def residual_history(self):
        """Convergence trace shortcut: ``diagnostics.residual_history``."""
        return None if self.diagnostics is None \
            else self.diagnostics.residual_history


class _CycleState(NamedTuple):
    v: jax.Array             # (m+1, n_local) Krylov basis, row-major
    giv: givens.GivensState
    done: jax.Array          # latched convergence/breakdown flag
    steps: jax.Array         # active step count (== next j)


# Scheme names that request kernel-backed execution; the jnp scheme each one
# degrades to when the kernel path is unavailable (block solver, non-Pallas
# backend, sharded basis, ...).
_FUSED_STEP_SCHEMES = ("fused", "arnoldi_fused")
_SCHEME_FALLBACK = {"fused": "cgs2", "arnoldi_fused": "cgs2",
                    "cgs2_fused": "cgs2", "cgs2_pipelined": "cgs2"}


def _make_step_fn(matvec, precond, gs: str, axis_name, *, identity_precond,
                  m: int, n: int, basis_dtype) -> Callable:
    """Build ``step_fn(v_basis, j) -> ArnoldiStep`` for the inner loop.

    ``gs="fused"`` asks for the single-pallas_call Arnoldi step: mat-vec +
    both CGS2 passes in one kernel, basis VMEM-resident.  That needs a dense
    unpreconditioned single-shard operator and enough VMEM; anything else
    degrades to the streaming cgs2 kernel ("cgs2_fused"), which itself
    degrades to the jnp reference (see arnoldi.cgs2_fused_step).
    """
    if gs in _FUSED_STEP_SCHEMES:
        from repro.kernels import tuning

        mode = tuning.kernel_mode()
        # ``compute_dtype`` narrower than A's storage (bf16 basis over an
        # f32 matrix) downcasts the A STREAM too: tiles enter the kernel at
        # half width and accumulate f32 in-register, halving the dominant
        # HBM term of the step.  The per-restart true residual still runs
        # through the operator's own full-precision matvec, so reported
        # convergence stays trustworthy.
        a_dtype = matvec.a.dtype if isinstance(matvec, DenseOperator) else None
        if (a_dtype is not None
                and tuning.itemsize(basis_dtype) < tuning.itemsize(a_dtype)):
            a_dtype = basis_dtype
        if (axis_name is None and identity_precond and mode != "ref"
                and isinstance(matvec, DenseOperator)
                and tuning.fused_step_fits(m + 1, n, basis_dtype,
                                           a_dtype=a_dtype)):
            from repro.kernels import arnoldi_fused

            interp = mode == "interpret"
            # Pre-pad ONCE to the kernel's tile grid: the basis is
            # loop-carried, so padding it inside the step would copy the
            # whole (m+1, n) array every inner iteration.  The cycle
            # allocates the carry at ``basis_shape`` directly (padded rows
            # and columns stay zero and are masked in the kernel); A is
            # padded here, outside the loop.
            block = tuning.choose_fused_block(n, a_dtype)
            n_pad = tuning._round_up(n, block)
            m1_pad = tuning._round_up(m + 1, tuning.sublane(basis_dtype))
            a_pad = jnp.pad(matvec.a.astype(a_dtype),
                            ((0, n_pad - n), (0, n_pad - n)))

            def fused_step(v_basis, j):
                h, w = arnoldi_fused.arnoldi_step(a_pad, v_basis, j,
                                                  block=block,
                                                  interpret=interp)
                return arnoldi.finalize(w, h[:m + 1], j, None)

            fused_step.basis_shape = (m1_pad, n_pad)
            return fused_step
        gs = "cgs2_fused"

    gs_step = arnoldi.step(gs)

    def step(v_basis, j):
        w = matvec(precond(v_basis[j]))
        return gs_step(v_basis, w, j, axis_name)

    return step


def _gmres_cycle(step_fn, x0, r0, beta, m, tol_abs, precond, basis_dtype):
    """One restart cycle: up to m Arnoldi steps + triangular solve.

    The inner loop is a ``while_loop`` with TRUE early exit, not a masked
    fixed-trip fori_loop: on fast-converging systems a fixed m=30 cycle
    would waste (m - k) full mat-vec + orthogonalization steps as masked
    no-ops (SSPerf: measured 6x overhead at k~5).  Early exit keeps the
    whole solve one XLA program (vmap of while_loop is supported) while
    doing only the work the mathematics needs.

    ``basis_dtype`` is the Krylov-basis storage dtype (the ``compute_dtype``
    knob): bf16 storage halves the V stream while every reduction still
    accumulates in f32, and the true residual recomputed per restart bounds
    the error.
    """
    n = x0.shape[0]
    dtype = x0.dtype
    eps = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)

    # Kernel-backed steps may ask for a tile-aligned carry (see
    # _make_step_fn); padded rows/columns are zero and never touched.
    basis_rows, basis_cols = getattr(step_fn, "basis_shape", (m + 1, n))
    v0 = (r0 / jnp.maximum(beta, eps)).astype(basis_dtype)
    v = jnp.zeros((basis_rows, basis_cols), basis_dtype).at[0, :n].set(v0)
    state = _CycleState(
        v=v,
        giv=givens.init(m, beta, dtype),
        done=beta <= tol_abs,
        steps=jnp.zeros((), jnp.int32),
    )

    def cond(s: _CycleState):
        return jnp.logical_not(s.done) & (s.steps < m)

    def body(s: _CycleState):
        j = s.steps
        # --- Arnoldi: w = A M^{-1} v_j, orthogonalize against V[:j+1] ---
        st = step_fn(s.v, j)
        v = s.v.at[j + 1, :st.v_next.shape[0]].set(st.v_next.astype(basis_dtype))
        # --- Givens: fold column j, track LS residual ---
        giv = givens.update(s.giv, st.h.astype(dtype), j,
                            active=jnp.asarray(True))
        resid = givens.residual_norm(giv, j)
        happy = st.h_last <= eps * 100.0
        done = (resid <= tol_abs) | happy
        return _CycleState(v=v, giv=giv, done=done, steps=j + 1)

    state = lax.while_loop(cond, body, state)
    y = givens.solve(state.giv, state.steps)          # zeros past early stop
    dx = y @ state.v[:m, :n].astype(dtype)            # V^T y with row basis
    x = x0 + precond(dx)
    return x, state.steps


# --------------------------------------------------------------------------
# Pipelined single-reduce cycle (gs="cgs2_pipelined")
# --------------------------------------------------------------------------
class _PipelinedState(NamedTuple):
    v: jax.Array             # (m+1, n_local) Krylov basis, row-major
    z: jax.Array             # op(v_j): the pipelined raw mat-vec carry
    hraw: jax.Array          # (m+1, m) raw Hessenberg columns (recurrence)
    gram: jax.Array          # (m+1, m+1) maintained basis Gram matrix
    giv: givens.GivensState
    done: jax.Array
    steps: jax.Array


def _make_pipelined_fns(matvec, precond, axis_name, *, m: int, n: int,
                        basis_dtype):
    """Build ``(op, update)`` for the pipelined cycle.

    ``op`` is the preconditioned operator A M^{-1}; ``update`` computes
    ``w - h @ V`` through the streaming update kernel under the standard
    dispatch policy (compiled / interpret / jnp reference, VMEM-gated).
    The payload half dispatches inside ``arnoldi.sr_payload``.
    """
    from repro.kernels import tuning

    mode = tuning.kernel_mode()
    dtn = jnp.dtype(basis_dtype).name
    if mode != "ref" and tuning.gs_payload_fits(m + 1, n, dtn):
        from repro.kernels import cgs2 as cgs2_k

        bn = tuning.choose_gs_block(m + 1, n, dtn)
        interp = mode == "interpret"

        def update(v_basis, w, h):
            return cgs2_k.gs_update(v_basis, w, h, block_n=bn,
                                    interpret=interp)
    else:

        def update(v_basis, w, h):
            acc = jnp.promote_types(w.dtype, jnp.float32)
            out = w.astype(acc) - h.astype(acc) @ v_basis.astype(acc)
            return out.astype(w.dtype)

    def op(zv):
        return matvec(precond(zv))

    return op, update


def _gmres_cycle_pipelined(op, update, x0, r0, beta, m, tol_abs, precond,
                           basis_dtype, axis_name):
    """One restart cycle of depth-1 pipelined single-reduce GMRES.

    Per Arnoldi step the body pays exactly ONE collective — the fused
    ``sr_payload`` psum — and issues it BEFORE the step-(j+1) mat-vec,
    consuming it after (Ghysels & Vanroose 2013 style depth-1 pipelining):

        payload_j = psum([mask*(V@z_j); z_j.z_j])     <- the only collective
        u         = op(z_j)                           <- independent: XLA's
                                                         latency-hiding
                                                         scheduler overlaps
                                                         it with the psum
        recover h_tot, ||w''||, Gram column from payload_j (replicated)
        v_{j+1}   = (z_j - h_tot @ V) / ||w''||
        z_{j+1}   = (u - (H h_lt) @ V - h_tot[j] z_j) / ||w''||

    The z recurrence uses op(v_i) = V @ H[:, i] (the Arnoldi relation) so
    the next mat-vec never waits for v_{j+1}: the basis never sees ``op``
    on the critical path behind the reduction.  Cost: one speculative
    mat-vec per cycle is wasted at the final step (the pipeline bubble),
    and the correction inherits recurrence rounding — bounded by the TRUE
    residual recompute at every restart (the +-1-restart parity contract).

    Scale-invariant by construction: z scales linearly with the system, the
    recovered norm with z, and the breakdown probe compares ||w''|| against
    eps * ||z|| (relative), matching PR 3's invariance contract.
    """
    n = x0.shape[0]
    dtype = x0.dtype
    tiny = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)
    eps_rel = jnp.asarray(jnp.finfo(dtype).eps * 100.0, dtype)
    acc = jnp.promote_types(dtype, jnp.float32)

    v0 = (r0 / jnp.maximum(beta, tiny)).astype(dtype)
    v = jnp.zeros((m + 1, n), basis_dtype).at[0].set(v0.astype(basis_dtype))
    state = _PipelinedState(
        v=v,
        z=op(v0),                               # pipeline prologue mat-vec
        hraw=jnp.zeros((m + 1, m), dtype),
        gram=jnp.eye(m + 1, dtype=acc),
        giv=givens.init(m, beta, dtype),
        done=beta <= tol_abs,
        steps=jnp.zeros((), jnp.int32),
    )

    def cond(s: _PipelinedState):
        return jnp.logical_not(s.done) & (s.steps < m)

    def body(s: _PipelinedState):
        j = s.steps
        # --- issue the ONE collective of this step ---
        payload = arnoldi.sr_payload(s.v, s.z, j, axis_name)
        # --- the next mat-vec, independent of the psum result ---
        u = op(s.z)
        # --- consume: replicated recovery of both passes + norm ---
        h_tot, s_norm, zeta, gram = arnoldi.sr_recover(payload, s.gram, j)
        h_tot = h_tot.astype(dtype)
        s_d = s_norm.astype(dtype)
        sg = jnp.maximum(s_d, tiny)
        w2 = update(s.v, s.z, h_tot)            # w'' = z - h_tot @ V
        v_next = w2 / sg
        # correct the speculative mat-vec onto v_{j+1} via the recurrence
        lt = (jnp.arange(m) < j).astype(dtype)
        c_vec = s.hraw @ (h_tot[:m] * lt)       # (m+1,) basis coefficients
        z_next = (update(s.v, u, c_vec) - h_tot[j] * s.z) / sg

        v = s.v.at[j + 1].set(v_next.astype(basis_dtype))
        hcol = h_tot.at[j + 1].set(s_d)
        hraw = s.hraw.at[:, j].set(hcol)
        giv = givens.update(s.giv, hcol, j, active=jnp.asarray(True))
        resid = givens.residual_norm(giv, j)
        happy = s_d <= eps_rel * jnp.sqrt(zeta).astype(dtype)
        done = (resid <= tol_abs) | happy
        return _PipelinedState(v=v, z=z_next, hraw=hraw, gram=gram, giv=giv,
                               done=done, steps=j + 1)

    state = lax.while_loop(cond, body, state)
    y = givens.solve(state.giv, state.steps)
    dx = y @ state.v[:m].astype(dtype)
    x = x0 + precond(dx)
    return x, state.steps


def gmres(
    a,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    m: int = 30,
    tol: float = 1e-5,
    max_restarts: int = 50,
    gs: str = "cgs2",
    precond: Optional[Callable] = None,
    axis_name: Optional[str] = None,
    compute_dtype=None,
    history: int = 8,
) -> GmresResult:
    """Right-preconditioned restarted GMRES(m).

    Args:
      a: the system operator — a dense (n, n) array, any operator from
        ``core.operators`` (``DenseOperator``, ``SparseOperator``,
        ``BandedOperator``, ``FunctionOperator``), or a bare matvec
        callable.  Operators carry their own ``backend=`` ("jnp" |
        "pallas") mat-vec dispatch; the solver never inspects the storage
        format, so sparse systems need no solver-side changes.  With
        ``axis_name`` set, ``a`` maps a LOCAL shard to a LOCAL shard and all
        reductions psum over that mesh axis.
      b: right-hand side, shape (n,) (local shard under ``axis_name``).
      x0: initial guess (zeros by default).
      m: restart length (Krylov subspace dimension per cycle).
      tol: relative residual target, ||b - Ax|| <= tol * ||b||.
      max_restarts: restart-cycle budget.
      gs: "cgs" (paper listing) | "mgs" (serial standard) | "cgs2" (TPU
        path) | "cgs2_fused" (Pallas streaming GS kernel single-shard;
        the split-phase project/psum/update kernel pair when row-sharded)
        | "fused" (whole Arnoldi step in one Pallas kernel; needs an
        unpreconditioned single-shard ``DenseOperator`` and a basis that
        fits VMEM — degrades to "cgs2_fused" otherwise, which itself
        degrades to "cgs2" where Pallas is unavailable)
        | "cgs2_pipelined" (single-reduce CGS2 with depth-1 pipelining:
        ONE fused psum per Arnoldi step — projection coefficients and the
        norm contribution in one stacked payload — issued before and
        consumed after the next mat-vec so the collective hides behind
        compute; kernel-backed payload/update halves with the same
        compiled/interpret/jnp-ref dispatch, psum-safe reference when
        unfit).
      precond: right preconditioner M^{-1} as a callable (identity default).
      axis_name: mesh axis for the row-sharded distributed solve.
      compute_dtype: Krylov-basis storage dtype (e.g. ``jnp.bfloat16``)
        — halves basis HBM traffic; reductions still accumulate in f32 and
        the per-restart true-residual recompute bounds the rounding error.
        On the ``gs="fused"`` path a compute dtype narrower than A's
        storage also downcasts the A STREAM (tiles enter the kernel at the
        narrow width, accumulate f32 in-register).
      history: length of the bounded per-cycle residual-history ring kept
        on ``result.diagnostics`` (static).  Doubles as the stagnation
        window: STAGNATED means the residual failed to drop by at least
        ``1 - STAGNATION_RTOL`` across the last ``history`` cycles.

    Returns GmresResult; residual is the TRUE residual recomputed from x,
    ``diagnostics`` the cycle-level health report (see ``Diagnostics``).
    """
    matvec = as_operator(a)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    check_precond(precond)
    identity_precond = (precond is None
                        or getattr(precond, "is_identity", False))
    if precond is None:
        precond = lambda v: v
    basis_dtype = b.dtype if compute_dtype is None else compute_dtype

    pipelined = gs == "cgs2_pipelined"
    if pipelined:
        op_fn, update_fn = _make_pipelined_fns(
            matvec, precond, axis_name, m=m, n=b.shape[0],
            basis_dtype=basis_dtype)
    else:
        step_fn = _make_step_fn(matvec, precond, gs, axis_name,
                                identity_precond=identity_precond, m=m,
                                n=b.shape[0], basis_dtype=basis_dtype)

    bnorm = arnoldi.norm(b, axis_name)
    tol_abs = jnp.maximum(tol * bnorm, jnp.asarray(0.0, b.dtype))

    def resid_of(x):
        r = b - matvec(x)
        return r, arnoldi.norm(r, axis_name)

    r0, beta0 = resid_of(x0)
    # Bounded residual-history ring, chronological with inf padding on the
    # left; seeded with ||b - A x0|| so the trace starts at cycle 0.
    hist0 = jnp.full((history,), jnp.inf, beta0.dtype).at[-1].set(beta0)

    def cond(carry):
        _, _, beta, k, _, _ = carry
        return (beta > tol_abs) & (k < max_restarts)

    def body(carry):
        x, r, beta, k, steps, hist = carry
        if pipelined:
            x, inner = _gmres_cycle_pipelined(
                op_fn, update_fn, x, r, beta, m, tol_abs, precond,
                basis_dtype, axis_name)
        else:
            x, inner = _gmres_cycle(
                step_fn, x, r, beta, m, tol_abs, precond, basis_dtype
            )
        r, beta = resid_of(x)
        hist = jnp.roll(hist, -1).at[-1].set(beta)
        return x, r, beta, k + 1, steps + inner, hist

    x, r, beta, k, steps, hist = lax.while_loop(
        cond, body,
        (x0, r0, beta0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
         hist0),
    )
    converged = beta <= tol_abs
    diags = Diagnostics(
        status=classify_residuals(hist, converged=converged),
        residual_history=hist,
        history_len=jnp.minimum(k + 1, history).astype(jnp.int32),
    )
    return GmresResult(
        x=x, residual=beta, restarts=k, converged=converged, inner_steps=steps,
        done=converged | (k >= max_restarts), diagnostics=diags,
    )


# --------------------------------------------------------------------------
# Block multi-RHS solver
# --------------------------------------------------------------------------
# Schemes whose arithmetic is CGS2 — the batched block-GS kernel implements
# exactly that, so any of these may ride it in gmres_batched.
_CGS2_FAMILY = ("cgs2", "cgs2_fused", "fused", "arnoldi_fused",
                "cgs2_pipelined")


def _make_batched_gs(gs: str, m: int, n: int, basis_dtype) -> Callable:
    """Build ``batched_gs(v, w, j) -> ArnoldiStep`` (all args lane-batched).

    With a CGS2-family scheme, a kernel-capable backend and per-lane bases
    that fit VMEM, both Gram-Schmidt passes for every lane run through the
    batched block-GS kernel (kernels/block_gs.py): each grid step holds ONE
    lane's (m+1, n) basis resident, streaming it once per Arnoldi step
    instead of the vmapped reference's four.  Everything else — non-CGS2
    schemes, ``kernel_mode() == "ref"``, VMEM overflow — vmaps the jnp
    scheme (kernel scheme names degrade exactly as before).
    """
    if gs in _CGS2_FAMILY:
        from repro.kernels import tuning

        mode = tuning.kernel_mode()
        if mode != "ref" and tuning.block_gs_fits(m + 1, n, basis_dtype):
            from repro.kernels import block_gs

            interp = mode == "interpret"
            # The cycle allocates the lane bases pre-padded to the kernel's
            # tile grid (``basis_shape``, same pattern as the fused Arnoldi
            # path): padding the loop-carried (k, m+1, n) basis inside the
            # step would copy it through HBM every inner iteration.
            m1p, n_pad, _ = tuning.choose_block_gs(
                m + 1, n, 1, jnp.dtype(basis_dtype).name)

            def kernel_gs(v, w, j):
                mask = (jnp.arange(m1p)[None, :] <= j[:, None]).astype(
                    jnp.float32)
                w_pad = jnp.pad(w, ((0, 0), (0, n_pad - n)))  # (k, n_pad):
                h, w2 = block_gs.batched_cgs2(v, w_pad, mask,  # cheap next
                                              interpret=interp)  # to V
                return jax.vmap(arnoldi.finalize)(
                    w2[:, :n].astype(w.dtype), h[:, :m + 1].astype(w.dtype),
                    j)

            kernel_gs.basis_shape = (m1p, n_pad)
            return kernel_gs

    gs_step = arnoldi.step(_SCHEME_FALLBACK.get(gs, gs))
    return lambda v, w, j: jax.vmap(gs_step)(v, w, j)


def _block_cycle(blockmv, vprecond, batched_gs, x0, r0, beta, m, tol_abs,
                 active0, basis_dtype):
    """One restart cycle over k lanes stepping in lockstep.

    Lanes carry their own Krylov basis / Givens state / convergence latch;
    the ONE shared operand is A, which every step streams exactly once as a
    (n, k) block mat-vec.  Masking matches ``vmap(gmres)`` semantics: a
    done lane's Givens updates write identity columns and zeroed g entries,
    so the final per-lane triangular solve is unaffected.
    """
    k, n = x0.shape
    dtype = x0.dtype
    eps = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)

    # Kernel-backed GS may ask for tile-aligned lane bases (see
    # _make_batched_gs); padded rows/columns are zero and never touched.
    basis_rows, basis_cols = getattr(batched_gs, "basis_shape", (m + 1, n))
    v0 = (r0 / jnp.maximum(beta, eps)[:, None]).astype(basis_dtype)
    v = jnp.zeros((k, basis_rows, basis_cols), basis_dtype).at[
        :, 0, :n].set(v0)
    giv = jax.vmap(lambda be: givens.init(m, be, dtype))(beta)
    done = jnp.logical_not(active0) | (beta <= tol_abs)
    steps = jnp.zeros((k,), jnp.int32)

    def cond(carry):
        _, _, done, steps = carry
        return jnp.any(jnp.logical_not(done) & (steps < m))

    def body(carry):
        v, giv, done, steps = carry
        j = steps                                     # per-lane step index
        active = jnp.logical_not(done) & (steps < m)
        # --- the k current Krylov vectors hit A as ONE GEMM ---
        vj = jax.vmap(lambda vb, jj: vb[jj, :n])(v, j).astype(dtype)
        w = blockmv(vprecond(vj))                     # (k, n)
        st = batched_gs(v, w, j)
        v_new = jax.vmap(lambda vb, vn, jj: vb.at[jj + 1, :n].set(vn))(
            v, st.v_next.astype(basis_dtype), j)
        v = jnp.where(active[:, None, None], v_new, v)
        giv = jax.vmap(
            lambda g, h, jj, act: givens.update(g, h, jj, active=act)
        )(giv, st.h.astype(dtype), j, active)
        resid = jax.vmap(givens.residual_norm)(giv, j)
        happy = st.h_last <= eps * 100.0
        done = done | (active & ((resid <= tol_abs) | happy))
        steps = steps + active.astype(jnp.int32)
        return v, giv, done, steps

    v, giv, done, steps = lax.while_loop(cond, body, (v, giv, done, steps))
    y = jax.vmap(givens.solve)(giv, steps)            # (k, m)
    dx = jnp.einsum("km,kmn->kn", y, v[:, :m, :n].astype(dtype))
    x = x0 + vprecond(dx)
    return x, steps


def _block_matvec(op) -> Callable:
    """(k, n) -> (k, n) block mat-vec: ONE matrix stream for all k lanes.

    Explicit-storage operators accept an (n, k) operand natively, so the
    k current Krylov vectors hit the matrix as a single GEMM / block SpMV;
    matrix-free operators vmap (nothing to share).
    """
    if isinstance(op, EXPLICIT_OPERATORS):
        return lambda xs: op(xs.T).T
    return jax.vmap(op)


def check_precond(precond) -> None:
    """Reject non-callable ``precond`` EARLY with the argument named.

    A registry string or a stray object would otherwise surface as a
    TypeError deep inside a jitted cycle; every public solver calls this
    so the contract is uniform (honor it or raise a clear ValueError).
    Registry NAMES are a sharded-wrapper convenience only — they need an
    operator to build against (``make_preconditioner(name, op)``).
    """
    if precond is not None and not callable(precond):
        raise ValueError(
            f"precond must be callable (a Preconditioner instance or a "
            f"plain M^-1 apply fn), got {type(precond).__name__} "
            f"{precond!r}; to use a registry name, build it first: "
            f"preconditioners.make_preconditioner(name, op)")


def _batched_precond(precond) -> Callable:
    """(k, n) -> (k, n) lane-batched M^{-1} apply.

    ``Preconditioner`` instances expose ``batched`` (one shared operator
    stream for all lanes, e.g. the Chebyshev block recurrence); a plain
    callable vmaps; identity short-circuits to a passthrough so the
    unpreconditioned batched path is byte-identical to before.
    """
    check_precond(precond)
    if precond is None or getattr(precond, "is_identity", False):
        return lambda vs: vs
    batched = getattr(precond, "batched", None)
    if batched is not None:
        return batched
    return jax.vmap(precond)


def gmres_batched_cycle(a, b: jax.Array, x: jax.Array, *, m: int = 30,
                        tol_abs=None, active=None, gs: str = "cgs2",
                        precond: Optional[Callable] = None,
                        compute_dtype=None):
    """ONE lockstep restart cycle over k lanes — the serving primitive.

    ``gmres_batched`` drives this same block cycle inside a while_loop
    until every lane is done; the solver server (``repro/serve``) instead
    calls it once per scheduler tick so converged lanes can be RETIRED at
    the restart boundary and refilled with queued requests — the
    decode-loop trick applied to Krylov lanes.  Lane contents are
    mathematically independent (the only shared operand is the one A
    stream of the block mat-vec), so a refilled lane's trajectory is
    exactly a standalone ``gmres`` solve of its system.

    Args:
      a: shared operator (anything ``gmres`` accepts).
      b: (k, n) per-lane right-hand sides (retired lanes may carry zeros).
      x: (k, n) current iterates (fresh lanes start at zero).
      m: restart length (static — part of the compiled cycle's identity).
      tol_abs: (k,) ABSOLUTE per-lane residual targets (callers own the
        tol * ||b|| scaling; zeros default, i.e. never converged).
      active: (k,) bool lane mask; inactive lanes pass through untouched
        and contribute only masked no-op arithmetic to the block GEMM.
      gs / precond / compute_dtype: as in ``gmres_batched``.

    Returns ``(x', beta', inner_steps)``: updated iterates, the TRUE
    per-lane residual norms ``||b - A x'||`` recomputed after the cycle
    (also fresh for just-refilled lanes — this is what retirement
    decisions read), and the per-lane Arnoldi steps taken.
    """
    op = as_operator(a)
    vprecond = _batched_precond(precond)
    basis_dtype = b.dtype if compute_dtype is None else compute_dtype
    batched_gs = _make_batched_gs(gs, m, b.shape[1], basis_dtype)
    blockmv = _block_matvec(op)
    if tol_abs is None:
        tol_abs = jnp.zeros(b.shape[:1], b.dtype)
    if active is None:
        active = jnp.ones(b.shape[:1], bool)

    r = b - blockmv(x)
    beta = jnp.linalg.norm(r, axis=1)
    act = active & (beta > tol_abs)
    x2, inner = _block_cycle(blockmv, vprecond, batched_gs, x, r, beta,
                             m, tol_abs, act, basis_dtype)
    x = jnp.where(act[:, None], x2, x)
    beta = jnp.linalg.norm(b - blockmv(x), axis=1)
    return x, beta, inner


def gmres_batched(a, b: jax.Array, *, m: int = 30, tol=1e-5,
                  max_restarts=50, gs: str = "cgs2",
                  precond: Optional[Callable] = None,
                  compute_dtype=None) -> GmresResult:
    """Batch of right-hand sides, shape (batch, n), shared A — solved BLOCKED.

    Previously this was ``vmap(gmres)``: correct, but each lane's mat-vec
    stayed a GEMV, and on the kernel path a vmapped ``pallas_call`` re-streams
    A from HBM once PER LANE.  Now the k current Krylov vectors are stacked
    into an (n, k) block and hit A as a single GEMM per Arnoldi step — one
    shared HBM stream of A, a k-fold arithmetic-intensity win (this is the
    multi-RHS workload of the paper's Table 1 systems, batched).

    Orthogonalization is kernel-backed too: with a CGS2-family ``gs`` the
    per-lane Gram-Schmidt runs through the batched block-GS kernel
    (kernels/block_gs.py) — one grid step per lane with that lane's basis
    VMEM-resident, cutting its per-step HBM streams from four to one, the
    same way ``block_matvec`` already cut the A streams.  Lanes whose
    bases exceed VMEM (``tuning.block_gs_fits``), non-CGS2 schemes, and
    kernel-free backends vmap the jnp scheme instead.  Per-lane Givens
    state stays lane-parallel via vmap (O(m^2) scalar work, not worth a
    kernel).  Matrix-free operators fall back to a vmapped mat-vec
    (nothing to share).

    Any explicit-storage operator (``DenseOperator``, ``SparseOperator``,
    ``BandedOperator``) rides the block path: their ``__call__`` accepts an
    (n, k) operand natively, so one stream of the matrix (dense tiles, ELL
    values/cols, or stencil bands) feeds all k lanes.

    PER-LANE stopping: ``tol`` and ``max_restarts`` may be scalars (every
    lane alike) or (batch,)-shaped arrays — heterogeneous solves packed
    into one block.  Each lane latches its own convergence against its own
    ``tol * ||b_lane||`` target and its own restart budget; a lane that
    exhausts its budget is retired as FAILED (``done`` True, ``converged``
    False) WITHOUT stalling the cohort — the remaining lanes keep cycling
    and the failed lane rides along as masked no-ops.  The serving layer
    (``repro/serve``) goes one step further and swaps retired lanes for
    queued requests between cycles via ``gmres_batched_cycle``.
    """
    op = as_operator(a)
    vprecond = _batched_precond(precond)
    basis_dtype = b.dtype if compute_dtype is None else compute_dtype
    batched_gs = _make_batched_gs(gs, m, b.shape[1], basis_dtype)
    blockmv = _block_matvec(op)

    bnorm = jnp.linalg.norm(b, axis=1)
    # tol / max_restarts broadcast: scalar or per-lane (batch,) arrays.
    tol_abs = jnp.maximum(jnp.asarray(tol, b.dtype) * bnorm,
                          jnp.asarray(0.0, b.dtype))
    max_restarts = jnp.asarray(max_restarts, jnp.int32)

    def resid_of(x):
        r = b - blockmv(x)
        return r, jnp.linalg.norm(r, axis=1)

    x0 = jnp.zeros_like(b)
    r0, beta0 = resid_of(x0)
    k0 = jnp.zeros(b.shape[:1], jnp.int32)

    def cond(carry):
        _, _, beta, kk, _ = carry
        return jnp.any((beta > tol_abs) & (kk < max_restarts))

    def body(carry):
        x, r, beta, kk, steps = carry
        active = (beta > tol_abs) & (kk < max_restarts)
        x2, inner = _block_cycle(blockmv, vprecond, batched_gs, x, r, beta,
                                 m, tol_abs, active, basis_dtype)
        x = jnp.where(active[:, None], x2, x)
        r, beta = resid_of(x)
        return x, r, beta, kk + active.astype(jnp.int32), steps + inner

    x, r, beta, kk, steps = lax.while_loop(
        cond, body, (x0, r0, beta0, k0, jnp.zeros(b.shape[:1], jnp.int32))
    )
    converged = beta <= tol_abs
    return GmresResult(x=x, residual=beta, restarts=kk, converged=converged,
                       inner_steps=steps,
                       done=converged | (kk >= max_restarts))


@functools.partial(jax.jit,
                   static_argnames=("m", "tol", "max_restarts", "gs",
                                    "compute_dtype"))
def gmres_jit(a, b, *, m=30, tol=1e-5, max_restarts=50, gs="cgs2",
              compute_dtype=None):
    """Convenience fully-jit'd solve (the paper's device-resident strategy).

    Same arguments and semantics as ``gmres`` (which see), with the
    jit-static knobs (``m``, ``tol``, ``gs``, ``compute_dtype``, ...)
    declared so repeated solves at one configuration reuse the compiled
    program.  ``a`` may be any operator ``gmres`` accepts — operators are
    pytrees, so new array payloads do NOT retrace.
    """
    return gmres(a, b, m=m, tol=tol, max_restarts=max_restarts, gs=gs,
                 compute_dtype=compute_dtype)
