"""Restarted GMRES(m) — jit-compilable, batched, early-stop-masked.

Faithful to the paper's algorithm (Kelley 1995 listing, section 3):

  1.  r0 = b - A x0, v1 = r0/||r0||
  2.  m Arnoldi steps building V_m, H~_m          (arnoldi.py)
  8.  y_m = argmin || beta e1 - H~_m y ||         (givens.py, incremental QR)
  9.  restart with x_m = x0 + V_m y_m until ||r|| < eps

Shape-static by construction: the inner loop always runs ``m`` steps with
converged / broken-down steps masked to no-ops (identity Givens columns,
zeroed g entries), so the whole restarted solve is ONE ``jax.jit`` program —
the ``gpuR``/vcl "everything device-resident" strategy from the paper, taken
to its logical conclusion: not a single scalar leaves the device between
restarts.

The same inner cycle, handed an ``axis_name``, becomes the shard_map
distributed solver (core/distributed.py).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import arnoldi, givens
from repro.core.operators import as_operator


class GmresResult(NamedTuple):
    x: jax.Array
    residual: jax.Array      # final true residual norm ||b - A x||
    restarts: jax.Array      # number of restart cycles executed
    converged: jax.Array     # bool
    inner_steps: jax.Array   # total Arnoldi steps actually active


class _CycleState(NamedTuple):
    v: jax.Array             # (m+1, n_local) Krylov basis, row-major
    giv: givens.GivensState
    done: jax.Array          # latched convergence/breakdown flag
    steps: jax.Array         # active step count (== next j)


def _gmres_cycle(matvec, x0, r0, beta, m, tol_abs, gs_step, axis_name,
                 precond):
    """One restart cycle: up to m Arnoldi steps + triangular solve.

    The inner loop is a ``while_loop`` with TRUE early exit, not a masked
    fixed-trip fori_loop: on fast-converging systems a fixed m=30 cycle
    would waste (m - k) full mat-vec + orthogonalization steps as masked
    no-ops (SSPerf: measured 6x overhead at k~5).  Early exit keeps the
    whole solve one XLA program (vmap of while_loop is supported) while
    doing only the work the mathematics needs.
    """
    n = x0.shape[0]
    dtype = x0.dtype
    eps = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)

    v0 = r0 / jnp.maximum(beta, eps)
    v = jnp.zeros((m + 1, n), dtype).at[0].set(v0)
    state = _CycleState(
        v=v,
        giv=givens.init(m, beta, dtype),
        done=beta <= tol_abs,
        steps=jnp.zeros((), jnp.int32),
    )

    def cond(s: _CycleState):
        return jnp.logical_not(s.done) & (s.steps < m)

    def body(s: _CycleState):
        j = s.steps
        # --- Arnoldi: w = A M^{-1} v_j, orthogonalize against V[:j+1] ---
        w = matvec(precond(s.v[j]))
        st = gs_step(s.v, w, j, axis_name)
        v = s.v.at[j + 1].set(st.v_next)
        # --- Givens: fold column j, track LS residual ---
        giv = givens.update(s.giv, st.h, j, active=jnp.asarray(True))
        resid = givens.residual_norm(giv, j)
        happy = st.h_last <= eps * 100.0
        done = (resid <= tol_abs) | happy
        return _CycleState(v=v, giv=giv, done=done, steps=j + 1)

    state = lax.while_loop(cond, body, state)
    y = givens.solve(state.giv, state.steps)          # zeros past early stop
    dx = y @ state.v[:m]                              # V^T y with row basis
    x = x0 + precond(dx)
    return x, state.steps


def gmres(
    a,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    m: int = 30,
    tol: float = 1e-5,
    max_restarts: int = 50,
    gs: str = "cgs2",
    precond: Optional[Callable] = None,
    axis_name: Optional[str] = None,
) -> GmresResult:
    """Right-preconditioned restarted GMRES(m).

    Args:
      a: dense (n, n) array, Operator, or matvec callable.  With
        ``axis_name`` set, ``a`` maps a LOCAL shard to a LOCAL shard and all
        reductions psum over that mesh axis.
      b: right-hand side, shape (n,) (local shard under ``axis_name``).
      x0: initial guess (zeros by default).
      m: restart length (Krylov subspace dimension per cycle).
      tol: relative residual target, ||b - Ax|| <= tol * ||b||.
      max_restarts: restart-cycle budget.
      gs: "cgs" (paper listing) | "mgs" (serial standard) | "cgs2" (TPU path).
      precond: right preconditioner M^{-1} as a callable (identity default).
      axis_name: mesh axis for the row-sharded distributed solve.

    Returns GmresResult; residual is the TRUE residual recomputed from x.
    """
    matvec = as_operator(a)
    gs_step = arnoldi.step(gs)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    if precond is None:
        precond = lambda v: v

    bnorm = arnoldi.norm(b, axis_name)
    tol_abs = jnp.maximum(tol * bnorm, jnp.asarray(0.0, b.dtype))

    def resid_of(x):
        r = b - matvec(x)
        return r, arnoldi.norm(r, axis_name)

    r0, beta0 = resid_of(x0)

    def cond(carry):
        _, _, beta, k, _ = carry
        return (beta > tol_abs) & (k < max_restarts)

    def body(carry):
        x, r, beta, k, steps = carry
        x, inner = _gmres_cycle(
            matvec, x, r, beta, m, tol_abs, gs_step, axis_name, precond
        )
        r, beta = resid_of(x)
        return x, r, beta, k + 1, steps + inner

    x, r, beta, k, steps = lax.while_loop(
        cond, body, (x0, r0, beta0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    )
    return GmresResult(
        x=x, residual=beta, restarts=k, converged=beta <= tol_abs, inner_steps=steps
    )


def gmres_batched(a, b: jax.Array, **kw) -> GmresResult:
    """vmap over a batch of right-hand sides, shape (batch, n), shared A."""
    return jax.vmap(lambda rhs: gmres(a, rhs, **kw))(b)


@functools.partial(jax.jit, static_argnames=("m", "tol", "max_restarts", "gs"))
def gmres_jit(a, b, *, m=30, tol=1e-5, max_restarts=50, gs="cgs2"):
    """Convenience fully-jit'd dense solve (the device-resident strategy)."""
    return gmres(a, b, m=m, tol=tol, max_restarts=max_restarts, gs=gs)
