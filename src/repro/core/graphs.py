"""Power-law graph workloads: Laplacians and PageRank-style systems.

The paper's benchmarks (and the stencil constructors in
``core/stencils.py``) live in the REGULAR sparsity regime — every row has
the same handful of nonzeros.  The serving layer's "many users, many
graphs" scenario lives in the other one: power-law graphs, where node
degree spans orders of magnitude and one hub row makes plain ELL's
pad-to-widest pathological.  That regime is what the sliced-ELL format
exists for (``operators.SlicedEllOperator``), and these generators are
its workload: deterministic in ``seed``, host-side numpy construction
(same contract as ``SparseOperator.from_dense``), returning operators in
the caller's choice of ``fmt``.

Two linear systems per graph:

  ``graph_laplacian``  L = D - A + shift*I.  Symmetric positive definite
      (the shift lifts the zero eigenvalue of the connected component),
      the canonical "diffusion on a network" solve.

  ``pagerank_system``  (I - alpha*P) x = (1 - alpha) v with P = A D^-1
      column-stochastic: the LINEAR-SYSTEM form of PageRank.  For
      alpha < 1 every column sums to 1 - alpha + diag > 0, so the matrix
      is diagonally dominant by columns — nonsymmetric, GMRES territory,
      and each personalization vector v is one request: a burst of them
      through ``serve.SolverServer`` is the graph serving demo
      (``examples/graph_laplacian.py``).

The graph model is Chung-Lu with a pinned hub: node i gets expected
degree w_i = max_degree * (i + 1)^(-1/(gamma - 1)) (a power law in the
degree rank), edge (i, j) appears independently with probability
min(1, w_i w_j / sum(w)), and a deterministic ring i -- i+1 guarantees
connectivity and min degree 2.  Pinning w_0 = max_degree makes the
hub regime (max degree >> median degree) a property of the generator,
not a lucky draw — the bench gate's >= 3x traffic-cut bar needs that.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.operators import (DenseOperator, SlicedEllOperator,
                                  SparseOperator)


def powerlaw_adjacency(n: int, *, gamma: float = 2.3,
                       max_degree: int | None = None,
                       seed: int = 0) -> np.ndarray:
    """Symmetric 0/1 Chung-Lu adjacency (numpy, deterministic in seed).

    ``max_degree`` defaults to n**0.75 — deep in the hub regime for any
    bench-sized n — and caps at n - 1.
    """
    if max_degree is None:
        max_degree = int(round(n ** 0.75))
    max_degree = min(int(max_degree), n - 1)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = max_degree * ranks ** (-1.0 / (gamma - 1.0))
    prob = np.minimum(np.outer(w, w) / w.sum(), 1.0)
    rng = np.random.default_rng(seed)
    upper = np.triu(rng.random((n, n)) < prob, k=1)
    a = (upper | upper.T).astype(np.float64)
    ring = np.arange(n - 1)
    a[ring, ring + 1] = 1.0
    a[ring + 1, ring] = 1.0
    np.fill_diagonal(a, 0.0)
    return a


def _as_operator(a_np: np.ndarray, fmt: str, dtype, slice_height: int,
                 backend: str):
    a_np = a_np.astype(jnp.dtype(dtype).name)
    if fmt == "sell":
        return SlicedEllOperator.from_dense(a_np, slice_height=slice_height,
                                            backend=backend)
    if fmt == "ell":
        return SparseOperator.from_dense(a_np, backend=backend)
    if fmt == "dense":
        return DenseOperator(jnp.asarray(a_np), backend)
    raise ValueError(f"unknown fmt {fmt!r}; options: sell, ell, dense")


def graph_laplacian(n: int, *, gamma: float = 2.3,
                    max_degree: int | None = None, seed: int = 0,
                    shift: float = 1e-2, dtype=jnp.float32,
                    fmt: str = "sell", slice_height: int = 64,
                    backend: str = "jnp"):
    """Shifted graph Laplacian L = D - A + shift*I of a power-law graph."""
    a = powerlaw_adjacency(n, gamma=gamma, max_degree=max_degree, seed=seed)
    lap = np.diag(a.sum(axis=1) + shift) - a
    return _as_operator(lap, fmt, dtype, slice_height, backend)


def pagerank_system(n: int, *, alpha: float = 0.85, gamma: float = 2.3,
                    max_degree: int | None = None, seed: int = 0,
                    dtype=jnp.float32, fmt: str = "sell",
                    slice_height: int = 64, backend: str = "jnp"):
    """PageRank as a linear system: returns (op, make_rhs).

    ``op`` applies I - alpha*P (P column-stochastic on the graph);
    ``make_rhs(v)`` turns a personalization vector v (nonnegative, will
    be normalized to sum 1) into the right-hand side (1 - alpha) * v.
    The solution x of op @ x = make_rhs(v) is the personalized PageRank
    distribution — sums to 1 up to solver tolerance.
    """
    if not 0.0 < alpha < 1.0:
        raise ValueError(f"alpha must be in (0, 1), got {alpha}")
    a = powerlaw_adjacency(n, gamma=gamma, max_degree=max_degree, seed=seed)
    deg = a.sum(axis=0)
    p_mat = a / np.maximum(deg, 1.0)[None, :]
    m = np.eye(n) - alpha * p_mat
    op = _as_operator(m, fmt, dtype, slice_height, backend)

    def make_rhs(v):
        v = jnp.asarray(v, jnp.dtype(dtype))
        v = v / jnp.sum(v)
        return (1.0 - alpha) * v

    return op, make_rhs
