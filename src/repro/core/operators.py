"""Linear operator abstractions for the GMRES solver suite.

The paper solves dense ``Ax = b``; production Krylov use is matrix-free
(Newton--Krylov, preconditioned operators).  Operators are registered as
pytrees so they can be passed through ``jax.jit`` / ``vmap`` / ``shard_map``
boundaries with their array payloads traced and their callables static.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseOperator:
    """Explicit dense matrix operator (the paper's setting).

    ``backend`` selects the mat-vec execution path:

      "jnp"    — ``a @ v`` (XLA-lowered reference; always available)
      "pallas" — the tiled VMEM-streaming kernels (kernels/matvec.py):
                 ``matvec`` for (n,) operands, ``block_matvec`` for (n, k)
                 multi-RHS blocks (ONE shared HBM stream of A for all k
                 columns).  Tile sizes come from the VMEM autotuner
                 (kernels/tuning.py); on CPU the kernel runs in interpret
                 mode, and on backends without Pallas support the call
                 silently degrades to the jnp path.
    """

    a: jax.Array  # (n, n)
    backend: str = "jnp"  # "jnp" | "pallas"

    def __call__(self, v: jax.Array) -> jax.Array:
        # v: (n,) or (n, k)
        if self.backend == "pallas":
            from repro.kernels import tuning

            mode = tuning.kernel_mode()
            if mode != "ref":
                from repro.kernels import matvec as matvec_k

                m, n = self.a.shape
                k = 1 if v.ndim == 1 else v.shape[1]
                bm, bn = tuning.choose_matvec_blocks(
                    m, n, jnp.dtype(self.a.dtype).name, k=k)
                kw = dict(block_m=bm, block_n=bn,
                          interpret=mode == "interpret")
                if v.ndim == 1:
                    return matvec_k.matvec(self.a, v, **kw)
                return matvec_k.block_matvec(self.a, v, **kw)
        return self.a @ v

    @property
    def shape(self):
        return self.a.shape

    @property
    def dtype(self):
        return self.a.dtype

    def tree_flatten(self):
        return (self.a,), self.backend

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux if aux is not None else "jnp")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FunctionOperator:
    """Matrix-free operator ``v -> A @ v``.

    ``captures`` holds any array payload the function closes over so that the
    operator remains a faithful pytree (jit re-tracing sees value changes).
    """

    fn: Callable[..., jax.Array]
    n: int
    captures: Any = ()

    def __call__(self, v: jax.Array) -> jax.Array:
        return self.fn(v, *self.captures) if self.captures else self.fn(v)

    @property
    def shape(self):
        return (self.n, self.n)

    def tree_flatten(self):
        return (self.captures,), (self.fn, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fn, n = aux
        (captures,) = children
        return cls(fn, n, captures)


def as_operator(a) -> Callable[[jax.Array], jax.Array]:
    """Normalize dense arrays / callables to a matvec callable."""
    if isinstance(a, (DenseOperator, FunctionOperator)):
        return a
    if callable(a):
        return a
    return DenseOperator(jnp.asarray(a))


def jvp_operator(f: Callable, primal, *, damping: float = 0.0) -> FunctionOperator:
    """Gauss-Newton / Hessian-free operator: ``v -> J^T J v + damping * v``.

    ``f`` maps a flat parameter vector to a flat residual vector.  The
    operator is the classic jvp/vjp sandwich used by Newton--Krylov
    optimizers; it is symmetric PSD so GMRES converges like MINRES on it.
    """
    n = primal.shape[0]

    def matvec(v, p):
        _, jv = jax.jvp(f, (p,), (v,))
        (jtjv,) = jax.vjp(f, p)[1](jv)
        return jtjv + damping * v

    return FunctionOperator(matvec, n, captures=(primal,))


def hvp_operator(loss: Callable, primal, *, damping: float = 0.0) -> FunctionOperator:
    """Hessian-vector-product operator ``v -> H v + damping v`` (matrix-free)."""
    n = primal.shape[0]

    def matvec(v, p):
        return jax.jvp(jax.grad(loss), (p,), (v,))[1] + damping * v

    return FunctionOperator(matvec, n, captures=(primal,))


def poisson_1d(n: int, dtype=jnp.float32) -> jax.Array:
    """Dense 1-D Poisson (tridiagonal) test matrix — SPD, well-conditioned rows."""
    a = (
        2.0 * jnp.eye(n, dtype=dtype)
        - jnp.eye(n, k=1, dtype=dtype)
        - jnp.eye(n, k=-1, dtype=dtype)
    )
    return a


def convection_diffusion(n: int, beta: float = 0.5, dtype=jnp.float32) -> jax.Array:
    """Nonsymmetric convection-diffusion matrix — the canonical GMRES target."""
    a = (
        2.0 * jnp.eye(n, dtype=dtype)
        + (-1.0 + beta) * jnp.eye(n, k=1, dtype=dtype)
        + (-1.0 - beta) * jnp.eye(n, k=-1, dtype=dtype)
    )
    return a


def random_diagdom(key, n: int, dtype=jnp.float32, *, dominance: float = 2.0) -> jax.Array:
    """Random nonsymmetric diagonally-dominant matrix (paper's rnorm-style setup,

    made well-conditioned so fp32 Krylov converges; the paper used random dense
    matrices from ``rnorm`` which are near-singular without dominance).
    """
    a = jax.random.normal(key, (n, n), dtype=dtype) / jnp.sqrt(n).astype(dtype)
    rowsum = jnp.abs(a).sum(axis=1)
    return a + jnp.diag(dominance * rowsum.astype(dtype))
