"""Linear operator abstractions for the GMRES solver suite.

The paper solves dense ``Ax = b``; production Krylov use is matrix-free
(Newton--Krylov, preconditioned operators) and — above all — sparse:
discretized PDEs where A has O(n) nonzeros and SpMV throughput, not dense
GEMV, dominates the solve.  Five operator classes cover the spectrum:

  DenseOperator      explicit (n, n) matrix (the paper's setting)
  SparseOperator     ELL-format general sparsity (values/cols, fixed width)
  SlicedEllOperator  SELL-C-sigma-style sliced ELL: rows sorted by nonzero
                     count into fixed-height slices, each padded only to
                     its own widest row — the irregular-sparsity format
                     (power-law graphs, where plain ELL's pad-to-widest
                     is pathological)
  BandedOperator     DIA-style band stack + static diagonal offsets
                     (five/seven-point stencils, convection-diffusion)
  FunctionOperator   matrix-free ``v -> A @ v`` callable

Every explicit-storage operator takes ``backend="jnp" | "pallas"``: the
pallas backend routes mat-vecs through the tiled VMEM kernels
(kernels/matvec.py for dense, kernels/spmv.py for sparse/banded) under the
shared ``kernels.tuning.kernel_mode()`` policy — compiled on TPU,
interpret mode on CPU, and a silent degrade to the jnp reference on other
backends or when the working set exceeds VMEM.  The solvers
(``gmres``, ``gmres_batched``, ``newton_krylov``) only ever call the
operator, so sparse systems ride the same code path as dense ones.

Operators are registered as pytrees so they can be passed through
``jax.jit`` / ``vmap`` / ``shard_map`` boundaries with their array
payloads traced and their format/backend metadata static.

ROW-SHARDED execution (PR 5): inside a ``kernels.tuning.shard_context``
(the distributed solvers set it around their shard_map bodies) every
explicit operator treats its payload as the LOCAL row block and its
operand/result as local shards, and dispatches the per-shard
communication pattern itself:

  DenseOperator     all-gather the operand (dense rows touch every
                    column), then the usual tiled local GEMV/GEMM
  BandedOperator    ``halo_exchange`` of the operand's ``halo`` boundary
                    rows (2 neighbor ppermutes, O(halo) bytes), then the
                    stencil kernel over the halo-padded resident shard
  SparseOperator    same halo exchange — the static ``halo`` field bounds
                    max |col - row|, columns are remapped to halo-local
                    coordinates; operators without a halo bound (or wider
                    than a shard) fall back to all-gather + the reference

so the solver layer stays one code path: ``gmres(..., axis_name=...)``
calls the operator exactly like the single-device solve does.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseOperator:
    """Explicit dense matrix operator (the paper's setting).

    ``backend`` selects the mat-vec execution path:

      "jnp"    — ``a @ v`` (XLA-lowered reference; always available)
      "pallas" — the tiled VMEM-streaming kernels (kernels/matvec.py):
                 ``matvec`` for (n,) operands, ``block_matvec`` for (n, k)
                 multi-RHS blocks (ONE shared HBM stream of A for all k
                 columns).  Tile sizes come from the VMEM autotuner
                 (kernels/tuning.py); on CPU the kernel runs in interpret
                 mode, and on backends without Pallas support the call
                 silently degrades to the jnp path.
    """

    a: jax.Array  # (n, n) — or the LOCAL (n/P, n) row block under a
    #               ``tuning.shard_context`` (see module docstring)
    backend: str = "jnp"  # "jnp" | "pallas"

    def __call__(self, v: jax.Array) -> jax.Array:
        # v: (n,) or (n, k) — local shards under a shard_context.
        from repro.kernels import tuning

        axis = tuning.shard_axis()
        if axis is not None:
            # Dense rows touch every column: the operand gather is
            # irreducible.  After it, the local row-block product is the
            # ordinary kernel/jnp path below.
            v = lax.all_gather(v, axis, tiled=True)
        if self.backend == "pallas":
            mode = tuning.kernel_mode()
            if mode != "ref":
                from repro.kernels import matvec as matvec_k

                m, n = self.a.shape
                k = 1 if v.ndim == 1 else v.shape[1]
                bm, bn = tuning.choose_matvec_blocks(
                    m, n, jnp.dtype(self.a.dtype).name, k=k)
                kw = dict(block_m=bm, block_n=bn,
                          interpret=mode == "interpret")
                if v.ndim == 1:
                    return matvec_k.matvec(self.a, v, **kw)
                return matvec_k.block_matvec(self.a, v, **kw)
        return self.a @ v

    @property
    def shape(self):
        return self.a.shape

    @property
    def dtype(self):
        return self.a.dtype

    def tree_flatten(self):
        return (self.a,), self.backend

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux if aux is not None else "jnp")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseOperator:
    """ELL-format sparse operator: fixed-width per-row nonzeros.

    Row i stores its nonzero values in ``values[i, :]`` with their column
    indices in ``cols[i, :]``, zero-padded to the shared ``width`` (padding
    slots hold value 0 at column 0, keeping every gather in-bounds).  The
    rectangular layout is what the TPU row-blocked kernel wants — each
    (block_m, width) tile is dense in VMEM — at the classic ELL cost of
    padding all rows to the widest one.

    ``backend`` selects the mat-vec execution path:

      "jnp"    — gather-and-reduce reference (XLA-lowered; always available)
      "pallas" — the row-blocked gather kernel (kernels/spmv.py) with the
                 operand x held VMEM-resident; block size from
                 ``tuning.choose_spmv_block``.  On CPU the kernel runs in
                 interpret mode; on backends without Pallas support, or
                 when x does not fit VMEM (``tuning.spmv_fits``), the call
                 silently degrades to the jnp path.

    ``__call__`` accepts (n,) vectors or (n, k) multi-RHS blocks (one
    stream of the matrix feeds all k lanes — ``gmres_batched`` rides
    this).  dtype semantics match dense ``a @ v``: the result is the
    promoted (values, v) dtype with f32 accumulation, so bf16 ``values``
    halve matrix traffic without quantizing an f32 operand.

    ``halo`` is the STATIC matrix bandwidth — an upper bound on
    max |col - row| over the NONZERO entries (padding slots excepted).
    ``from_dense`` / ``BandedOperator.to_ell`` record it automatically;
    it is what lets the row-sharded solve replace the all-gather of the
    operand with a fixed-width neighbor halo exchange.  ``halo=None``
    (unknown structure) keeps sharded solves correct via the all-gather
    fallback.
    """

    values: jax.Array   # (n, width) — LOCAL row block under shard_context
    cols: jax.Array     # (n, width) int32, GLOBAL column indices
    backend: str = "jnp"
    halo: Optional[int] = None   # static bandwidth bound (aux data)

    def __call__(self, v: jax.Array) -> jax.Array:
        from repro.kernels import spmv, tuning

        n, width = self.values.shape
        k = 1 if v.ndim == 1 else v.shape[1]
        axis = tuning.shard_axis()
        if axis is not None:
            return self._sharded_call(v, axis, n, width, k)
        if self.backend == "pallas":
            mode = tuning.kernel_mode()
            if mode != "ref" and tuning.spmv_fits(n, width,
                                                  self.values.dtype, k=k):
                bm = tuning.choose_spmv_block(
                    n, width, jnp.dtype(self.values.dtype).name, k=k)
                return spmv.ell_matvec(self.values, self.cols, v,
                                       block_m=bm,
                                       interpret=mode == "interpret")
        return spmv.ell_matvec_ref(self.values, self.cols, v)

    def _sharded_call(self, v: jax.Array, axis: str, n: int, width: int,
                      k: int) -> jax.Array:
        """Row-sharded SpMV: halo exchange + per-shard kernel.

        ``self`` holds the local (n_local, width) row block with GLOBAL
        column indices; ``v`` the matching (n_local, ...) operand shard.
        Without a usable ``halo`` bound (None, or wider than a shard) the
        operand is all-gathered instead — correct for any structure.
        """
        from repro.kernels import spmv, tuning

        halo = self.halo
        if halo is None or halo > n:
            x_full = lax.all_gather(v, axis, tiled=True)
            return spmv.ell_matvec_ref(self.values, self.cols, x_full)
        # Remap global columns into the halo-padded local frame.  Real
        # nonzeros land in [0, n + 2*halo) by the bandwidth bound; padding
        # slots (value 0 at global column 0) clip to 0 and contribute 0.
        # The remap is a pure function of solve constants, so XLA's
        # while-loop LICM hoists it out of the Arnoldi loop (verified in
        # the lowered HLO); do NOT cache the result on the instance —
        # axis_index is a tracer, and a cached tracer leaks across traces.
        p = lax.axis_index(axis)
        cols_local = jnp.clip(self.cols - p * n + halo, 0,
                              n + 2 * halo - 1).astype(jnp.int32)
        x_halo = spmv.halo_exchange(v, halo, axis, tuning.shard_size())
        mode = tuning.kernel_mode()
        if (self.backend == "pallas" and mode != "ref"
                and tuning.spmv_fits(n, width, self.values.dtype, k=k,
                                     halo=halo)):
            bm = tuning.choose_spmv_block(
                n, width, jnp.dtype(self.values.dtype).name, k=k, halo=halo)
            return spmv.ell_matvec_halo(self.values, cols_local, x_halo,
                                        block_m=bm,
                                        interpret=mode == "interpret")
        return spmv.ell_matvec_ref(self.values, cols_local, x_halo)

    @classmethod
    def from_dense(cls, a, *, width: int | None = None,
                   backend: str = "jnp") -> "SparseOperator":
        """Compress a dense (n, n) matrix to ELL form.

        ``width`` defaults to the widest row's nonzero count; passing a
        smaller width raises rather than silently dropping entries.  The
        static ``halo`` (bandwidth) bound for the row-sharded path is
        recorded from the nonzero pattern.
        """
        a_np = np.asarray(a)
        n = a_np.shape[0]
        mask = a_np != 0
        max_nnz = int(mask.sum(axis=1).max()) if n else 0
        if width is None:
            width = max(max_nnz, 1)
        elif width < max_nnz:
            raise ValueError(f"from_dense: width={width} < widest row "
                             f"({max_nnz} nonzeros) — entries would be "
                             f"dropped")
        # Stable argsort puts each row's nonzero columns first, in order.
        order = np.argsort(~mask, axis=1, kind="stable")[:, :width]
        vals = np.take_along_axis(a_np, order, axis=1)
        keep = np.take_along_axis(mask, order, axis=1)
        rows, nz_cols = np.nonzero(mask)
        halo = int(np.abs(nz_cols - rows).max()) if rows.size else 0
        return cls(jnp.asarray(np.where(keep, vals, 0).astype(a_np.dtype)),
                   jnp.asarray(np.where(keep, order, 0).astype(np.int32)),
                   backend, halo)

    def todense(self) -> jax.Array:
        """Materialize the dense (n, n) matrix (tests / small systems)."""
        n, width = self.values.shape
        rows = jnp.repeat(jnp.arange(n), width)
        return (jnp.zeros((n, n), self.values.dtype)
                .at[rows, self.cols.reshape(-1)]
                .add(self.values.reshape(-1)))

    @property
    def shape(self):
        n = self.values.shape[0]
        return (n, n)

    @property
    def dtype(self):
        return self.values.dtype

    def tree_flatten(self):
        return (self.values, self.cols), (self.backend, self.halo)

    @classmethod
    def tree_unflatten(cls, aux, children):
        backend, halo = aux if aux is not None else ("jnp", None)
        return cls(children[0], children[1], backend, halo)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BandedOperator:
    """DIA-style banded operator: ``y[i] = sum_d bands[d, i] * x[i + off_d]``.

    ``bands`` is (nbands, n) — band d holds the matrix entries
    ``A[i, i + offsets[d]]`` at index i — and ``offsets`` is a STATIC tuple
    of diagonal shifts (pytree aux data, so jit retraces on a new stencil
    shape but not on new band values).  Out-of-range reads contribute zero,
    which makes Dirichlet boundaries free: band entries at the grid edge
    simply face a zero halo.

    ``backend`` selects the mat-vec execution path:

      "jnp"    — shifted-window reference (XLA-lowered; always available)
      "pallas" — the stencil kernel (kernels/spmv.py): pure VPU work over
                 dynamic slices of a halo-padded VMEM-resident x, no
                 gather.  Interpret mode on CPU; silent degrade to jnp
                 where Pallas is unavailable or the halo-padded operand
                 exceeds VMEM (``tuning.banded_fits``).

    Accepts (n,) or (n, k) operands; dtype semantics match dense ``a @ v``
    (promoted dtype out, f32 accumulation inside).
    """

    bands: jax.Array    # (nbands, n)
    offsets: tuple      # static, len == nbands
    backend: str = "jnp"

    def __call__(self, v: jax.Array) -> jax.Array:
        from repro.kernels import spmv, tuning

        nbands, n = self.bands.shape
        halo = max(abs(int(o)) for o in self.offsets)
        k = 1 if v.ndim == 1 else v.shape[1]
        axis = tuning.shard_axis()
        if axis is not None:
            return self._sharded_call(v, axis, n, nbands, halo, k)
        if self.backend == "pallas":
            mode = tuning.kernel_mode()
            if mode != "ref" and tuning.banded_fits(n, nbands,
                                                    self.bands.dtype,
                                                    halo=halo, k=k):
                bm = tuning.choose_banded_block(
                    n, nbands, jnp.dtype(self.bands.dtype).name,
                    halo=halo, k=k)
                return spmv.banded_matvec(self.bands, v, self.offsets,
                                          block_m=bm,
                                          interpret=mode == "interpret")
        return spmv.banded_matvec_ref(self.bands, v, self.offsets)

    def _sharded_call(self, v: jax.Array, axis: str, n: int, nbands: int,
                      halo: int, k: int) -> jax.Array:
        """Row-sharded stencil SpMV: ppermute halo exchange + local kernel.

        ``self.bands`` holds the local (nbands, n_local) column block of
        the band stack; out-of-range reads at the GLOBAL edges see the
        zeros ``halo_exchange`` leaves on edge shards, so the semantics
        match the single-device kernel exactly.  A stencil wider than a
        shard (halo > n_local — pathological) falls back to an all-gather
        window.
        """
        from repro.kernels import spmv, tuning

        if halo > n:
            x_full = lax.all_gather(v, axis, tiled=True)
            pad = ((halo, halo), (0, 0)) if x_full.ndim == 2 else (halo, halo)
            x_pad = jnp.pad(x_full, pad)
            start = lax.axis_index(axis) * n
            sizes = ((n + 2 * halo,) if x_full.ndim == 1
                     else (n + 2 * halo, x_full.shape[1]))
            starts = (start,) if x_full.ndim == 1 else (start, 0)
            x_halo = lax.dynamic_slice(x_pad, starts, sizes)
        else:
            x_halo = spmv.halo_exchange(v, halo, axis, tuning.shard_size())
        mode = tuning.kernel_mode()
        if (self.backend == "pallas" and mode != "ref"
                and tuning.banded_fits(n, nbands, self.bands.dtype,
                                       halo=halo, k=k)):
            bm = tuning.choose_banded_block(
                n, nbands, jnp.dtype(self.bands.dtype).name, halo=halo, k=k)
            return spmv.banded_matvec_halo(self.bands, x_halo, self.offsets,
                                           block_m=bm,
                                           interpret=mode == "interpret")
        return spmv.banded_matvec_halo_ref(self.bands, x_halo, self.offsets)

    def to_ell(self, backend: str | None = None) -> SparseOperator:
        """Convert to ELL form (width = nbands; OOB slots become padding)."""
        nbands, n = self.bands.shape
        i = jnp.arange(n)
        cols = jnp.stack([i + off for off in self.offsets], axis=1)
        valid = (cols >= 0) & (cols < n)
        vals = jnp.where(valid, self.bands.T, 0)
        halo = max((abs(int(o)) for o in self.offsets), default=0)
        return SparseOperator(vals, jnp.where(valid, cols, 0).astype(jnp.int32),
                              self.backend if backend is None else backend,
                              halo)

    def todense(self) -> jax.Array:
        """Materialize the dense (n, n) matrix (tests / small systems)."""
        nbands, n = self.bands.shape
        a = jnp.zeros((n, n), self.bands.dtype)
        for d, off in enumerate(self.offsets):
            band = self.bands[d]
            if off >= 0:
                a = a + jnp.diag(band[:n - off], k=off)
            else:
                a = a + jnp.diag(band[-off:], k=off)
        return a

    @property
    def shape(self):
        n = self.bands.shape[1]
        return (n, n)

    @property
    def dtype(self):
        return self.bands.dtype

    def tree_flatten(self):
        return (self.bands,), (self.offsets, self.backend)

    @classmethod
    def tree_unflatten(cls, aux, children):
        offsets, backend = aux
        return cls(children[0], offsets, backend)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SlicedEllOperator:
    """Sliced-ELL (SELL-C-sigma-style) operator for irregular row patterns.

    Plain ELL pads EVERY row to the widest row's nonzero count — fine for
    stencils, pathological for power-law graphs where one hub row inflates
    storage and HBM traffic for all n rows.  Sliced ELL sorts rows by
    nonzero count (descending, stable), cuts the sorted order into
    fixed-height slices of ``slice_height`` rows, pads each slice only to
    its own widest row, and keeps a permutation to recover the original
    row order.  Consecutive same-width slices (the common case after the
    sort) are stored as ONE rectangle, so the payload is a short tuple of
    width BINS:

      bin_values[b]  (rows_b, width_b)  values, sorted-row frame
      bin_cols[b]    (rows_b, width_b)  int32 GLOBAL column indices
      perm           (n,) int32 — perm[i] = original row at sorted slot i

    The mat-vec is one row-binned gather kernel launch per bin
    (``kernels/spmv.sell_matvec``; a handful of launches — the builder
    agglomerates bins to ``max_bins``) over the shared VMEM-resident
    operand, then a scatter through ``perm`` back to original order.
    Traffic is proportional to sum_b rows_b*width_b instead of
    n*max_width — the whole point of the format.

    When sorting would NOT shrink storage (near-uniform row lengths: the
    stencils), ``from_dense``/``from_ell`` keep the ORIGINAL row order
    (``sort="auto"`` — sigma = 1 in SELL-C-sigma terms) so ``perm`` is the
    identity, the scatter disappears, and the layout degenerates to plain
    ELL with per-slice widths: sliced ELL is never worse where ELL was
    already tight.

    Row-sharded solves (``shard_specs`` replicates the payload — the
    global sort breaks contiguous row ownership, and the payload is the
    COMPRESSED form): with a usable ``halo`` bound the operator
    re-materializes its plain-ELL row table once per trace (hoisted out
    of the Arnoldi loop by XLA LICM, same argument as SparseOperator's
    column remap), slices the local row block, and rides the standard
    neighbor halo exchange; otherwise it all-gathers the operand and
    slices the local output rows.  Power-law graphs have halo ~ n (the
    hub touches everything), so they take the all-gather path — which is
    what their structure demands.
    """

    bin_values: tuple   # of (rows_b, width_b) arrays, nnz-sorted row frame
    bin_cols: tuple     # of (rows_b, width_b) int32, GLOBAL columns
    perm: jax.Array     # (n,) int32
    backend: str = "jnp"
    halo: Optional[int] = None      # static bandwidth bound (aux data)
    slice_height: int = 64          # C in SELL-C-sigma (aux data)
    identity_perm: bool = False     # static: builder kept original order

    def __call__(self, v: jax.Array) -> jax.Array:
        from repro.kernels import tuning

        k = 1 if v.ndim == 1 else v.shape[1]
        axis = tuning.shard_axis()
        if axis is not None:
            return self._sharded_call(v, axis, k)
        return self._unsort(self._sorted_matvec(v, k))

    def _sorted_matvec(self, v: jax.Array, k: int) -> jax.Array:
        """Per-bin SpMV producing the output in the SORTED row frame."""
        from repro.kernels import spmv, tuning

        n = self.perm.shape[0]
        if self.backend == "pallas":
            mode = tuning.kernel_mode()
            if mode != "ref" and tuning.sell_fits(n, self.max_width,
                                                  self.dtype, k=k):
                bms = tuple(
                    tuning.choose_sell_block(
                        n, vals.shape[0], vals.shape[1],
                        jnp.dtype(vals.dtype).name, k=k,
                        slice_height=self.slice_height)
                    for vals in self.bin_values)
                return spmv.sell_matvec(self.bin_values, self.bin_cols, v,
                                        block_ms=bms,
                                        interpret=mode == "interpret")
        return spmv.sell_matvec_ref(self.bin_values, self.bin_cols, v)

    def _unsort(self, y_sorted: jax.Array) -> jax.Array:
        if self.identity_perm:
            return y_sorted
        return jnp.zeros_like(y_sorted).at[self.perm].set(y_sorted)

    def _sharded_call(self, v: jax.Array, axis: str, k: int) -> jax.Array:
        """Row-sharded matvec over the REPLICATED sliced payload.

        ``v`` is the local (n/P, ...) operand shard; the result is the
        matching local output shard.  See the class docstring for the two
        communication patterns (halo vs all-gather).
        """
        from repro.kernels import spmv, tuning

        nl = v.shape[0]
        halo = self.halo
        p = lax.axis_index(axis)
        if halo is not None and halo <= nl:
            # Same per-shard pattern as SparseOperator, over the plain-ELL
            # row table re-materialized from the bins: a pure function of
            # solve constants, so XLA LICM hoists it out of the solver's
            # while_loop — the trade is plain-ELL-padded LOCAL traffic for
            # O(halo) exchanged bytes.
            vals, cols = self.to_ell_arrays()
            width = vals.shape[1]
            vals_l = lax.dynamic_slice_in_dim(vals, p * nl, nl, 0)
            cols_l = lax.dynamic_slice_in_dim(cols, p * nl, nl, 0)
            cols_local = jnp.clip(cols_l - p * nl + halo, 0,
                                  nl + 2 * halo - 1).astype(jnp.int32)
            x_halo = spmv.halo_exchange(v, halo, axis, tuning.shard_size())
            mode = tuning.kernel_mode()
            if (self.backend == "pallas" and mode != "ref"
                    and tuning.spmv_fits(nl, width, self.dtype, k=k,
                                         halo=halo)):
                bm = tuning.choose_spmv_block(
                    nl, width, jnp.dtype(self.dtype).name, k=k, halo=halo)
                return spmv.ell_matvec_halo(vals_l, cols_local, x_halo,
                                            block_m=bm,
                                            interpret=mode == "interpret")
            return spmv.ell_matvec_ref(vals_l, cols_local, x_halo)
        x_full = lax.all_gather(v, axis, tiled=True)
        y = self._unsort(self._sorted_matvec(x_full, k))
        return lax.dynamic_slice_in_dim(y, p * nl, nl, 0)

    # -- format conversions -------------------------------------------------
    @classmethod
    def from_dense(cls, a, *, slice_height: int = 64, backend: str = "jnp",
                   sort: bool | str = "auto",
                   max_bins: int = 8) -> "SlicedEllOperator":
        """Compress a dense (n, n) matrix to sliced-ELL form.

        Handles UNSTRUCTURED nonzero patterns: each row's nonzeros are
        packed independently and the static ``halo`` (bandwidth) bound is
        recorded from the pattern, exactly like ``SparseOperator.
        from_dense``.  ``sort="auto"`` sorts rows by nonzero count only
        when that shrinks slice storage by >= 10% (see class docstring);
        pass True/False to force.  Host-side numpy, like every
        ``from_dense`` here.
        """
        a_np = np.asarray(a)
        n = a_np.shape[0]
        mask = a_np != 0
        nnz = mask.sum(axis=1)
        wtab = max(int(nnz.max()) if n else 0, 1)
        order = np.argsort(~mask, axis=1, kind="stable")[:, :wtab]
        vals = np.take_along_axis(a_np, order, axis=1)
        keep = np.take_along_axis(mask, order, axis=1)
        row_vals = np.where(keep, vals, 0).astype(a_np.dtype)
        row_cols = np.where(keep, order, 0)
        rows, nz_cols = np.nonzero(mask)
        halo = int(np.abs(nz_cols - rows).max()) if rows.size else 0
        return cls._build(row_vals, row_cols, nnz, slice_height, backend,
                          halo, sort=sort, max_bins=max_bins)

    @classmethod
    def from_ell(cls, sp: SparseOperator, *, slice_height: int = 64,
                 backend: str | None = None, sort: bool | str = "auto",
                 max_bins: int = 8) -> "SlicedEllOperator":
        """Re-slice a plain-ELL operator (value-0 slots become padding).

        Genuine stored zeros are dropped — same semantics as
        ``from_dense`` on the materialized matrix.
        """
        vals_np = np.asarray(sp.values)
        cols_np = np.asarray(sp.cols)
        mask = vals_np != 0
        nnz = mask.sum(axis=1)
        # Pack each row's nonzero slots first (stable, order-preserving).
        order = np.argsort(~mask, axis=1, kind="stable")
        keep = np.take_along_axis(mask, order, axis=1)
        row_vals = np.where(keep, np.take_along_axis(vals_np, order, 1), 0)
        row_cols = np.where(keep, np.take_along_axis(cols_np, order, 1), 0)
        halo = sp.halo
        if halo is None:
            r, c = np.nonzero(mask)
            halo = int(np.abs(cols_np[r, c] - r).max()) if r.size else 0
        return cls._build(row_vals.astype(vals_np.dtype), row_cols, nnz,
                          slice_height,
                          sp.backend if backend is None else backend,
                          halo, sort=sort, max_bins=max_bins)

    @classmethod
    def _build(cls, row_vals, row_cols, nnz, slice_height, backend, halo,
               *, sort="auto", max_bins=8) -> "SlicedEllOperator":
        """Shared host-side builder over a packed per-row nonzero table.

        ``row_vals``/``row_cols`` are (n, w) numpy arrays with each row's
        nonzeros packed FIRST (slots >= nnz[i] hold value 0 at column 0).
        Slices the (possibly sorted) row order into ``slice_height``
        chunks, then greedily merges adjacent slices until at most
        ``max_bins`` rectangles remain — each merge pads the smaller
        slice up to the wider one, and the merge order minimizes the
        padding added, so the bin count (= kernel launch count) is bounded
        while the storage stays near the per-slice optimum.
        """
        n = row_vals.shape[0]
        c = max(int(slice_height), 1)

        def slice_storage(order):
            return sum(
                len(order[s0:s0 + c]) * int(nnz[order[s0:s0 + c]].max())
                for s0 in range(0, n, c)) if n else 0

        ident = np.arange(n)
        by_nnz = np.argsort(-nnz, kind="stable")
        if sort == "auto":
            use_sort = slice_storage(by_nnz) < 0.9 * slice_storage(ident)
        else:
            use_sort = bool(sort)
        order = by_nnz if use_sort else ident
        # Per-slice exact widths (>= 1 so padding slots exist), merged
        # into [row_start, row_end, width) bins.
        bins = []
        for s0 in range(0, n, c):
            h = min(c, n - s0)
            w = max(int(nnz[order[s0:s0 + h]].max()), 1)
            if bins and bins[-1][2] == w:
                bins[-1][1] += h
            else:
                bins.append([s0, s0 + h, w])
        if not bins:
            bins = [[0, 0, 1]]

        def merge_cost(i):
            (a0, a1, aw), (b0, b1, bw) = bins[i], bins[i + 1]
            w = max(aw, bw)
            return (a1 - a0) * (w - aw) + (b1 - b0) * (w - bw)

        while len(bins) > max(int(max_bins), 1):
            i = min(range(len(bins) - 1), key=merge_cost)
            (a0, a1, aw), (b0, b1, bw) = bins[i], bins[i + 1]
            bins[i:i + 2] = [[a0, b1, max(aw, bw)]]

        bin_values, bin_cols = [], []
        for r0, r1, w in bins:
            rows = order[r0:r1]
            bin_values.append(jnp.asarray(row_vals[rows][:, :w]))
            bin_cols.append(
                jnp.asarray(row_cols[rows][:, :w].astype(np.int32)))
        return cls(tuple(bin_values), tuple(bin_cols),
                   jnp.asarray(order.astype(np.int32)), backend, halo,
                   c, bool(np.array_equal(order, ident)))

    def to_ell_arrays(self):
        """Plain-ELL (values, cols) row table in ORIGINAL row order.

        Width = the widest bin.  Pure jnp — usable under jit/shard_map,
        where it is a function of solve constants and gets hoisted out of
        solver loops (the sharded halo path relies on this).
        """
        n = self.perm.shape[0]
        w = self.max_width
        vs = [jnp.pad(v, ((0, 0), (0, w - v.shape[1])))
              for v in self.bin_values]
        cs = [jnp.pad(col, ((0, 0), (0, w - col.shape[1])))
              for col in self.bin_cols]
        values = (jnp.zeros((n, w), self.dtype)
                  .at[self.perm].set(jnp.concatenate(vs, axis=0)))
        cols = (jnp.zeros((n, w), jnp.int32)
                .at[self.perm].set(jnp.concatenate(cs, axis=0)))
        return values, cols

    def to_ell(self, backend: str | None = None) -> SparseOperator:
        """Expand back to a plain-ELL operator (pad-to-widest)."""
        values, cols = self.to_ell_arrays()
        return SparseOperator(values, cols,
                              self.backend if backend is None else backend,
                              self.halo)

    def todense(self) -> jax.Array:
        """Materialize the dense (n, n) matrix (tests / small systems)."""
        n = self.perm.shape[0]
        a = jnp.zeros((n, n), self.dtype)
        start = 0
        for vals, cols in zip(self.bin_values, self.bin_cols):
            rb, wb = vals.shape
            orig = self.perm[start:start + rb]
            rows = jnp.repeat(orig, wb)
            a = a.at[rows, cols.reshape(-1)].add(vals.reshape(-1))
            start += rb
        return a

    # -- format statistics (static python ints; bench/docs read these) ------
    @property
    def max_width(self) -> int:
        return max(int(v.shape[1]) for v in self.bin_values)

    @property
    def storage_entries(self) -> int:
        """Stored slots incl. slice padding: sum_b rows_b * width_b."""
        return sum(int(v.shape[0]) * int(v.shape[1])
                   for v in self.bin_values)

    @property
    def shape(self):
        n = self.perm.shape[0]
        return (n, n)

    @property
    def dtype(self):
        return self.bin_values[0].dtype

    def tree_flatten(self):
        return ((self.bin_values, self.bin_cols, self.perm),
                (self.backend, self.halo, self.slice_height,
                 self.identity_perm))

    @classmethod
    def tree_unflatten(cls, aux, children):
        bin_values, bin_cols, perm = children
        backend, halo, slice_height, identity_perm = aux
        return cls(tuple(bin_values), tuple(bin_cols), perm, backend, halo,
                   slice_height, identity_perm)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FunctionOperator:
    """Matrix-free operator ``v -> A @ v``.

    ``captures`` holds any array payload the function closes over so that the
    operator remains a faithful pytree (jit re-tracing sees value changes).
    """

    fn: Callable[..., jax.Array]
    n: int
    captures: Any = ()

    def __call__(self, v: jax.Array) -> jax.Array:
        return self.fn(v, *self.captures) if self.captures else self.fn(v)

    @property
    def shape(self):
        return (self.n, self.n)

    def tree_flatten(self):
        return (self.captures,), (self.fn, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        fn, n = aux
        (captures,) = children
        return cls(fn, n, captures)


# Operators with explicit matrix storage: their (n, k) multi-RHS __call__
# lets the block solver stream the matrix ONCE for all k lanes.
EXPLICIT_OPERATORS = (DenseOperator, SparseOperator, BandedOperator,
                      SlicedEllOperator)


def with_dtype(op, dtype):
    """The same explicit operator with its matrix storage cast to ``dtype``.

    Structure (cols/offsets/perm/halo) is untouched — only the value
    stream changes.  This is how the solvers build a reduced-precision
    operand stream (``compute_dtype=bf16``) while keeping the original
    operator for full-precision residual recomputation.
    """
    if isinstance(op, DenseOperator):
        return DenseOperator(op.a.astype(dtype), op.backend)
    if isinstance(op, SparseOperator):
        return SparseOperator(op.values.astype(dtype), op.cols, op.backend,
                              op.halo)
    if isinstance(op, BandedOperator):
        return BandedOperator(op.bands.astype(dtype), op.offsets, op.backend)
    if isinstance(op, SlicedEllOperator):
        return SlicedEllOperator(
            tuple(v.astype(dtype) for v in op.bin_values), op.bin_cols,
            op.perm, op.backend, op.halo, op.slice_height, op.identity_perm)
    raise TypeError(f"with_dtype: no explicit storage on {type(op).__name__}")


def as_operator(a) -> Callable[[jax.Array], jax.Array]:
    """Normalize ``a`` to a matvec callable.

    Operator instances and callables pass through unchanged; raw arrays
    wrap into a ``DenseOperator`` on the jnp backend.
    """
    if isinstance(a, EXPLICIT_OPERATORS + (FunctionOperator,)):
        return a
    if callable(a):
        return a
    return DenseOperator(jnp.asarray(a))


def jvp_operator(f: Callable, primal, *, damping: float = 0.0) -> FunctionOperator:
    """Gauss-Newton / Hessian-free operator: ``v -> J^T J v + damping * v``.

    ``f`` maps a flat parameter vector to a flat residual vector.  The
    operator is the classic jvp/vjp sandwich used by Newton--Krylov
    optimizers; it is symmetric PSD so GMRES converges like MINRES on it.
    """
    n = primal.shape[0]

    def matvec(v, p):
        _, jv = jax.jvp(f, (p,), (v,))
        (jtjv,) = jax.vjp(f, p)[1](jv)
        return jtjv + damping * v

    return FunctionOperator(matvec, n, captures=(primal,))


def hvp_operator(loss: Callable, primal, *, damping: float = 0.0) -> FunctionOperator:
    """Hessian-vector-product operator ``v -> H v + damping v`` (matrix-free)."""
    n = primal.shape[0]

    def matvec(v, p):
        return jax.jvp(jax.grad(loss), (p,), (v,))[1] + damping * v

    return FunctionOperator(matvec, n, captures=(primal,))


def poisson_1d(n: int, dtype=jnp.float32) -> jax.Array:
    """Dense 1-D Poisson (tridiagonal) test matrix — SPD, well-conditioned rows."""
    a = (
        2.0 * jnp.eye(n, dtype=dtype)
        - jnp.eye(n, k=1, dtype=dtype)
        - jnp.eye(n, k=-1, dtype=dtype)
    )
    return a


def convection_diffusion(n: int, beta: float = 0.5, dtype=jnp.float32) -> jax.Array:
    """Nonsymmetric convection-diffusion matrix — the canonical GMRES target."""
    a = (
        2.0 * jnp.eye(n, dtype=dtype)
        + (-1.0 + beta) * jnp.eye(n, k=1, dtype=dtype)
        + (-1.0 - beta) * jnp.eye(n, k=-1, dtype=dtype)
    )
    return a


def random_diagdom(key, n: int, dtype=jnp.float32, *, dominance: float = 2.0) -> jax.Array:
    """Random nonsymmetric diagonally-dominant matrix (paper's rnorm-style setup,

    made well-conditioned so fp32 Krylov converges; the paper used random dense
    matrices from ``rnorm`` which are near-singular without dominance).
    """
    a = jax.random.normal(key, (n, n), dtype=dtype) / jnp.sqrt(n).astype(dtype)
    rowsum = jnp.abs(a).sum(axis=1)
    return a + jnp.diag(dominance * rowsum.astype(dtype))
