"""Right preconditioners for GMRES — beyond-paper additions.

The paper runs unpreconditioned GMRES (pracma's default).  On a pod, a good
preconditioner is the cheapest way to cut collective rounds: fewer Arnoldi
steps = fewer all-gathers.  All preconditioners here are jit-compatible
callables ``v -> M^{-1} v`` built from the dense A (or its local shard).

Polynomial preconditioning is the TPU-sweet-spot choice: it replaces
latency-bound inner products with MXU-bound extra mat-vecs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def identity() -> Callable:
    return lambda v: v


def jacobi(a: jax.Array) -> Callable:
    """Diagonal scaling M = diag(A)."""
    inv_d = 1.0 / jnp.diagonal(a)

    def apply(v):
        return inv_d * v

    return apply


def block_jacobi(a: jax.Array, block: int) -> Callable:
    """Block-diagonal M: invert ``block``-sized diagonal blocks.

    n must be divisible by ``block``; blocks are factorized once (host-side
    cost amortized across the solve) and applied as a batched triangular
    solve pair — a batched level-3 op, MXU-friendly.
    """
    n = a.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    blocks = jnp.stack([a[i * block:(i + 1) * block, i * block:(i + 1) * block]
                        for i in range(nb)])
    lu, piv = jax.vmap(jax.scipy.linalg.lu_factor)(blocks)

    def apply(v):
        vb = v.reshape(nb, block)
        out = jax.vmap(jax.scipy.linalg.lu_solve)((lu, piv), vb)
        return out.reshape(n)

    return apply


def neumann(a: jax.Array, *, order: int = 2, omega: float | None = None) -> Callable:
    """Truncated Neumann series for M^{-1} ~= sum_k (I - w D^{-1} A)^k w D^{-1}.

    Pure mat-vec chain — converts preconditioning work into level-2/3 ops
    with zero extra collectives beyond the mat-vecs themselves.
    """
    inv_d = 1.0 / jnp.diagonal(a)
    if omega is None:
        omega = 1.0

    def apply(v):
        z = omega * inv_d * v
        acc = z
        for _ in range(order):
            z = z - omega * inv_d * (a @ z)
            acc = acc + z
        return acc

    return apply


def chebyshev(a: jax.Array, *, order: int = 4, lam_min: float, lam_max: float) -> Callable:
    """Chebyshev polynomial preconditioner for spectra in [lam_min, lam_max].

    Classic three-term recurrence; like Neumann, trades inner products for
    mat-vecs, but with the optimal polynomial for a known spectral interval.
    """
    theta = 0.5 * (lam_max + lam_min)
    delta = 0.5 * (lam_max - lam_min)
    sigma1 = theta / delta

    def apply(v):
        rho_old = 1.0 / sigma1
        z = v / theta
        z_old = jnp.zeros_like(v)
        for _ in range(order - 1):
            rho = 1.0 / (2.0 * sigma1 - rho_old)
            z_new = rho * (2.0 / delta * (v - a @ z) + rho_old * (z - z_old)) + z
            z_old, z, rho_old = z, z_new, rho
        return z

    return apply


PRECONDITIONERS = {
    "none": lambda a, **kw: identity(),
    "jacobi": lambda a, **kw: jacobi(a),
    "block_jacobi": lambda a, block=64, **kw: block_jacobi(a, block),
    "neumann": lambda a, order=2, **kw: neumann(a, order=order),
}
