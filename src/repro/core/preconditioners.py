"""The preconditioning subsystem: right preconditioners for every solver path.

The paper runs unpreconditioned GMRES (pracma's default).  On a pod, a good
preconditioner is the cheapest way to cut collective rounds: fewer Arnoldi
steps = fewer all-gathers — it deletes steps where every other layer of this
repo merely accelerates one.

Every member implements the ``Preconditioner`` protocol:

  apply      ``pc(v) -> M^{-1} v`` — a jit/vmap-compatible callable.  Setup
             (factorizations, spectral-interval estimation) happens ONCE at
             construction, eagerly, and is closed over.
  batched    ``pc.batched(vs)`` — the (k, n) multi-lane form the blocked
             solver paths use (``gmres_batched`` / the serve layer); the
             default vmaps ``apply``, members with a cheaper vectorized form
             override it.
  cost       ``pc.cost(op)`` -> ``PrecondCost`` — modeled setup/apply flops
             and HBM bytes plus ``matvec_equiv``, the apply cost in units of
             one operator mat-vec.  This is what the strategies table and
             the ``precond_*`` bench rows report: a preconditioner pays off
             when (steps cut) x (step cost) > matvec_equiv x (steps left).
  shard      ``pc.shard_aware`` + ``pc.rebind(op_local)`` — shard-aware
             members rebuild themselves INSIDE the distributed wrapper's
             shard_map body from the local operator shard (banded
             block-Jacobi masks the bands to the local diagonal block;
             Chebyshev re-targets the halo-exchange mat-vec).  Members with
             ``shard_aware=False`` make ``gmres_sharded`` raise instead of
             silently producing a wrong-layout apply.
  identity   ``pc.n`` / ``pc.requires_fmt`` — admission metadata the serve
             layer validates against the handle's operator BEFORE a request
             can reach a lane (``serve.request.validate_precond``).

Members
-------
  identity       no-op (``is_identity=True`` keeps the fused-Arnoldi path).
  jacobi         diagonal scaling, every format, shard-aware.
  block_jacobi   dense block-diagonal LU (batched level-3 apply).
  neumann        truncated Neumann series — mat-vec chain, shard-aware.
  chebyshev      degree-``order`` Chebyshev polynomial for spectra inside
                 ``[lam_min, lam_max]`` (interval auto-estimated via
                 Gershgorin + power iteration, ``estimate_interval``).  On
                 single-shard banded operators the whole recurrence runs
                 FUSED in one matrix-powers-style pallas_call — the band
                 stack is streamed from HBM once for all ``order`` mat-vecs
                 (``kernels/matrix_powers.banded_cheb_apply``).
  banded_ilu0    ILU(0) on the band pattern of a ``BandedOperator`` —
                 O(n * nbands^2) one-pass setup, applied as two banded
                 triangular sweeps (``kernels/trisolve``).  ``line_jacobi``
                 is the same member restricted to the (-1, 0, +1) bands,
                 where ILU(0) is the EXACT tridiagonal factorization.
  banded_block_jacobi  the shard-local composition: each shard drops the
                 band entries that cross its row range and ILU(0)-factors
                 its own diagonal block — ZERO preconditioner communication,
                 composing with the halo-exchange mat-vec path.

Polynomial preconditioning is the TPU-sweet-spot choice: it replaces
latency-bound inner products with MXU-bound extra mat-vecs.  The banded
sweeps are the opposite trade (latency-bound, but ~1 mat-vec equivalent
per apply and strong on stencils); the cost model makes the choice legible.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class PrecondCost:
    """Modeled cost account (floats — structural, not measured)."""
    setup_flops: float          # one-time construction cost
    apply_flops: float          # per apply(v)
    apply_hbm_bytes: float      # per apply(v), modeled operand traffic
    matvec_equiv: float         # apply cost in units of one op mat-vec


def _op_nnz(op) -> float:
    """Structural nonzeros of an explicit operator (dense counts all)."""
    from repro.core import operators as op_mod
    if isinstance(op, op_mod.BandedOperator):
        return float(op.bands.shape[0] * op.bands.shape[1])
    if isinstance(op, op_mod.SparseOperator):
        return float(op.values.shape[0] * op.values.shape[1])
    if isinstance(op, op_mod.SlicedEllOperator):
        return float(op.storage_entries)
    if isinstance(op, op_mod.DenseOperator):
        return float(op.a.shape[0] * op.a.shape[1])
    n = _op_dim(op) or 0
    return float(n) * 8.0       # matrix-free: stencil-like guess


def _op_dim(op):
    """Row dimension of an operator (None when it cannot be told)."""
    shape = getattr(op, "shape", None)
    if shape is not None and len(shape):
        return int(shape[0])
    n = getattr(op, "n", None)
    return int(n) if n else None


class Preconditioner:
    """Base protocol: a callable ``v -> M^{-1} v`` with metadata.

    Subclasses set ``name``/``shard_aware``/``requires_fmt`` and implement
    ``__call__`` (single-vector apply) and ``cost``.  ``n`` is the operator
    dimension the apply is bound to (``None`` = shape-agnostic).
    """

    name: str = "preconditioner"
    shard_aware: bool = False
    is_identity: bool = False
    requires_fmt: Optional[str] = None   # "dense" | "banded" | None (any)
    n: Optional[int] = None

    def __call__(self, v: jax.Array) -> jax.Array:
        raise NotImplementedError

    def batched(self, vs: jax.Array) -> jax.Array:
        """(k, n) -> (k, n) multi-lane apply; default vmaps the single form."""
        return jax.vmap(self.__call__)(vs)

    def rebind(self, op_local) -> "Preconditioner":
        """Rebuild against a LOCAL operator shard (inside shard_map).

        Only meaningful when ``shard_aware``; the distributed wrappers call
        it per shard so setup happens in local coordinates.
        """
        raise ValueError(
            f"preconditioner {self.name!r} is not shard-aware; "
            f"gmres_sharded supports identity/jacobi/chebyshev/"
            f"banded_block_jacobi (or the 'block_jacobi' dense string)")

    def cost(self) -> PrecondCost:
        return PrecondCost(0.0, 0.0, 0.0, 0.0)

    def __repr__(self):
        nn = "" if self.n is None else f", n={self.n}"
        return f"<{type(self).__name__} {self.name}{nn}>"


class IdentityPreconditioner(Preconditioner):
    name = "identity"
    shard_aware = True
    is_identity = True

    def __call__(self, v):
        return v

    def batched(self, vs):
        return vs

    def rebind(self, op_local):
        return self


def _diag_of(op) -> jax.Array:
    """Main diagonal of an explicit operator, any storage format."""
    from repro.core import operators as op_mod
    if isinstance(op, op_mod.DenseOperator):
        return jnp.diagonal(op.a)
    if isinstance(op, op_mod.BandedOperator):
        if 0 not in op.offsets:
            raise ValueError("jacobi needs the main diagonal; this banded "
                             "operator has no offset-0 band")
        return op.bands[op.offsets.index(0)]
    if isinstance(op, op_mod.SparseOperator):
        n = op.values.shape[0]
        hit = op.cols == jnp.arange(n)[:, None]
        return jnp.sum(jnp.where(hit, op.values, 0), axis=1)
    if isinstance(op, op_mod.SlicedEllOperator):
        # Per bin, a row's diagonal hit is where a stored GLOBAL column
        # equals the row's ORIGINAL index; scatter the sorted-frame result
        # back through perm.  (Padding slots: value 0, so a spurious
        # col-0 match on original row 0 adds exactly 0.)
        return _sell_rowreduce(
            op, lambda vals, cols, orig:
                jnp.sum(jnp.where(cols == orig[:, None], vals, 0), axis=1))
    raise ValueError(f"jacobi needs explicit storage to read diag(A); got "
                     f"{type(op).__name__}")


def _sell_rowreduce(op, fn) -> jax.Array:
    """Apply ``fn(vals, cols, orig_rows) -> (rows_b,)`` per sliced-ELL bin
    and scatter the concatenated result back to original row order."""
    parts, start = [], 0
    for vals, cols in zip(op.bin_values, op.bin_cols):
        rb = vals.shape[0]
        parts.append(fn(vals, cols, op.perm[start:start + rb]))
        start += rb
    out = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    if op.identity_perm:
        return out
    return jnp.zeros_like(out).at[op.perm].set(out)


class JacobiPreconditioner(Preconditioner):
    """Diagonal scaling M = diag(A) — every format, shard-aware for free
    (the diagonal is row-sharded exactly like v)."""

    name = "jacobi"
    shard_aware = True

    def __init__(self, a):
        from repro.core.operators import as_operator
        op = as_operator(a)
        d = _diag_of(op)
        guard = jnp.asarray(jnp.finfo(d.dtype).tiny ** 0.5, d.dtype)
        mag = jnp.maximum(jnp.abs(d), guard)
        self.inv_d = jnp.sign(jnp.where(d == 0, 1, d)) / mag
        self.n = int(d.shape[0])

    def __call__(self, v):
        return self.inv_d * v

    def batched(self, vs):
        return self.inv_d[None, :] * vs

    def rebind(self, op_local):
        # Under shard_map the local operator's storage IS the local rows,
        # so setup in local coordinates is just construction again — except
        # dense, whose local block is (rows, n); slice the diagonal block.
        from repro.core import operators as op_mod
        from repro.kernels import tuning
        if isinstance(op_local, op_mod.DenseOperator) and (
                op_local.a.shape[0] != op_local.a.shape[1]):
            # Dense shards are (rows, n): the local diagonal entries live
            # in the shard's own diagonal block.
            rows = op_local.a.shape[0]
            p = lax.axis_index(tuning.shard_axis())
            block = lax.dynamic_slice(op_local.a, (0, p * rows),
                                      (rows, rows))
            return JacobiPreconditioner(block)
        return JacobiPreconditioner(op_local)

    def cost(self):
        return PrecondCost(setup_flops=float(self.n or 0),
                           apply_flops=float(self.n or 0),
                           apply_hbm_bytes=12.0 * float(self.n or 0),
                           matvec_equiv=0.1)


class BlockJacobiPreconditioner(Preconditioner):
    """Dense block-diagonal M: invert ``block``-sized diagonal blocks.

    n must be divisible by ``block``; blocks are factorized once (host-side
    cost amortized across the solve) and applied as a batched triangular
    solve pair — a batched level-3 op, MXU-friendly.  Dense single-shard
    only; the sharded dense equivalent is ``gmres_sharded``'s shard-local
    ``precond="block_jacobi"`` and the stencil equivalent is
    ``banded_block_jacobi``.
    """

    name = "block_jacobi"
    requires_fmt = "dense"

    def __init__(self, a: jax.Array, block: int):
        from repro.core import operators as op_mod
        if isinstance(a, op_mod.DenseOperator):
            a = a.a
        n = a.shape[0]
        assert n % block == 0, (n, block)
        nb = n // block
        blocks = jnp.stack([
            a[i * block:(i + 1) * block, i * block:(i + 1) * block]
            for i in range(nb)])
        self.lu, self.piv = jax.vmap(jax.scipy.linalg.lu_factor)(blocks)
        self.n = int(n)
        self.block = int(block)

    def __call__(self, v):
        nb = self.n // self.block
        vb = v.reshape(nb, self.block)
        out = jax.vmap(jax.scipy.linalg.lu_solve)((self.lu, self.piv), vb)
        return out.reshape(self.n)

    def cost(self):
        b = float(self.block)
        n = float(self.n)
        return PrecondCost(setup_flops=n * b * b * (2.0 / 3.0),
                           apply_flops=2.0 * n * b,
                           apply_hbm_bytes=4.0 * (n * b + 2 * n),
                           matvec_equiv=b / n)


class NeumannPreconditioner(Preconditioner):
    """Truncated Neumann series M^{-1} ~= sum_k (I - w D^{-1} A)^k w D^{-1}.

    Pure mat-vec chain — converts preconditioning work into level-2/3 ops
    with zero extra collectives beyond the mat-vecs themselves.
    """

    name = "neumann"
    shard_aware = True

    def __init__(self, a, *, order: int = 2, omega: float | None = None):
        from repro.core.operators import as_operator
        self.op = as_operator(a)
        self.inv_d = JacobiPreconditioner(self.op).inv_d
        self.order = int(order)
        self.omega = 1.0 if omega is None else float(omega)
        self.n = int(self.inv_d.shape[0])

    def __call__(self, v):
        z = self.omega * self.inv_d * v
        acc = z
        for _ in range(self.order):
            z = z - self.omega * self.inv_d * self.op(z)
            acc = acc + z
        return acc

    def rebind(self, op_local):
        pc = object.__new__(NeumannPreconditioner)
        pc.op = op_local
        pc.inv_d = JacobiPreconditioner(op_local).inv_d
        pc.order = self.order
        pc.omega = self.omega
        pc.n = self.n
        return pc

    def cost(self):
        nnz = _op_nnz(self.op)
        return PrecondCost(setup_flops=float(self.n),
                           apply_flops=self.order * 2.0 * nnz,
                           apply_hbm_bytes=self.order * 4.0 * nnz,
                           matvec_equiv=float(self.order))


# --------------------------------------------------------------------------
# Spectral-interval estimation (Chebyshev setup)
# --------------------------------------------------------------------------
def _row_sums_and_diag(op) -> Tuple[jax.Array, jax.Array]:
    """(sum_j |a_ij|, a_ii) per row for any explicit operator."""
    from repro.core import operators as op_mod
    if isinstance(op, op_mod.BandedOperator):
        nbands, n = op.bands.shape
        i = jnp.arange(n)
        sums = jnp.zeros((n,), jnp.float32)
        for d, off in enumerate(op.offsets):
            valid = (i + off >= 0) & (i + off < n)
            sums = sums + jnp.where(valid,
                                    jnp.abs(op.bands[d].astype(jnp.float32)),
                                    0.0)
        return sums, _diag_of(op).astype(jnp.float32)
    if isinstance(op, op_mod.SparseOperator):
        return (jnp.sum(jnp.abs(op.values.astype(jnp.float32)), axis=1),
                _diag_of(op).astype(jnp.float32))
    if isinstance(op, op_mod.SlicedEllOperator):
        sums = _sell_rowreduce(
            op, lambda vals, cols, orig:
                jnp.sum(jnp.abs(vals.astype(jnp.float32)), axis=1))
        return sums, _diag_of(op).astype(jnp.float32)
    if isinstance(op, op_mod.DenseOperator):
        a = op.a.astype(jnp.float32)
        return jnp.sum(jnp.abs(a), axis=1), jnp.diagonal(a)
    raise ValueError(f"spectral bounds need explicit storage; got "
                     f"{type(op).__name__}")


def spectral_bounds(op) -> Tuple[jax.Array, jax.Array]:
    """Traced Gershgorin bounds (lam_lo, lam_hi) — usable under jit.

    ``lam_lo`` may be <= 0 for non-strictly-dominant systems (2-D Poisson
    touches 0 at the boundary rows); callers clamp with a relative floor.
    """
    sums, diag = _row_sums_and_diag(op)
    radius = sums - jnp.abs(diag)
    return jnp.min(diag - radius), jnp.max(diag + radius)


def estimate_interval(a, *, iters: int = 8, floor: float = 1.0 / 30.0,
                      slack: float = 3.0) -> Tuple[float, float]:
    """Cheap eager spectral-interval estimate for Chebyshev setup.

    ``lam_max`` must BOUND the spectrum from above: the Chebyshev
    polynomial oscillates inside [lam_min, lam_max] but grows without
    sign control beyond lam_max, so any eigenvalue above it can flip the
    preconditioned operator indefinite and STALL the outer solve (an
    overestimate merely costs a little polynomial efficiency — the risk is
    one-sided).  Gershgorin IS such a bound and is tight for the
    diagonally-dominant stencils this preconditioner targets, so it wins
    by default; a few power iterations supply a Rayleigh estimate of the
    spectral radius, used only to detect a PATHOLOGICALLY loose Gershgorin
    bound (> ``slack`` x Rayleigh — e.g. one extreme outlier row), where
    we fall back to ``slack/2 x`` the measured radius instead.

    ``lam_min``: the Gershgorin lower bound clamped to ``floor *
    lam_max`` — stencil spectra reach ~0 and Chebyshev on
    [lam_max/30, lam_max] remains an excellent smoother-style
    preconditioner (modes below lam_min stay positive, just less damped;
    GMRES mops them up).

    Everything is a RATIO of A's entries, so the estimate scales linearly
    with A and preconditioned solves stay scale-invariant (the PR 3
    contract).  Eager (returns Python floats); under an enclosing jit
    trace the whole estimate runs at COMPILE time against the operator's
    concrete storage (``ensure_compile_time_eval``) — the interval is
    static metadata that parameterizes the compiled recurrence, never a
    traced value.
    """
    from repro.core.operators import as_operator
    op = as_operator(a)
    with jax.ensure_compile_time_eval():
        lam_lo, lam_hi = spectral_bounds(op)
        gersh_max = float(lam_hi)
        n = int(_row_sums_and_diag(op)[0].shape[0])
        # Deterministic, spread-spectrum probe (no PRNG: setup must be
        # cheap and reproducible; the cosine ramp overlaps every smooth
        # mode).
        v = jnp.cos(jnp.arange(n, dtype=jnp.float32) * 0.7) + 0.5
        v = v / jnp.linalg.norm(v)
        rayleigh = gersh_max
        for _ in range(max(iters, 1)):
            w = op(v.astype(op_dtype(op))).astype(jnp.float32)
            rayleigh = float(jnp.vdot(v, w))
            nrm = float(jnp.linalg.norm(w))
            if nrm <= 0.0:
                break
            v = w / nrm
    lam_max = gersh_max
    if abs(rayleigh) > 0.0 and gersh_max > slack * abs(rayleigh):
        lam_max = (slack / 2.0) * abs(rayleigh)
    if lam_max <= 0.0:
        lam_max = max(gersh_max, 1.0)
    lam_min = max(float(lam_lo), floor * lam_max)
    return lam_min, lam_max


def op_dtype(op):
    from repro.core import operators as op_mod
    if isinstance(op, op_mod.BandedOperator):
        return op.bands.dtype
    if isinstance(op, op_mod.SparseOperator):
        return op.values.dtype
    if isinstance(op, op_mod.DenseOperator):
        return op.a.dtype
    return jnp.float32


def cheb_coeffs(order: int, lam_min: float, lam_max: float
                ) -> Tuple[float, float, Tuple[float, ...]]:
    """Static scalars of the degree-``order`` Chebyshev recurrence.

    Returns (theta, delta, rhos): the interval center/half-width and the
    ``order - 1`` rho values of the classic three-term iteration — all
    Python floats, so kernel implementations can bake them in statically.
    """
    theta = 0.5 * (lam_max + lam_min)
    delta = max(0.5 * (lam_max - lam_min), 1e-12 * abs(theta) or 1e-30)
    sigma1 = theta / delta
    rhos = []
    rho_old = 1.0 / sigma1
    for _ in range(order - 1):
        rho = 1.0 / (2.0 * sigma1 - rho_old)
        rhos.append((rho, rho_old))
        rho_old = rho
    return theta, delta, tuple(rhos)


class ChebyshevPreconditioner(Preconditioner):
    """Chebyshev polynomial preconditioner for spectra in [lam_min, lam_max].

    Classic three-term recurrence; like Neumann, trades inner products for
    mat-vecs, but with the optimal polynomial for a known spectral interval
    (auto-estimated when not given — ``estimate_interval``).

    Dispatch: on a single-shard ``BandedOperator`` with a kernel-capable
    backend the WHOLE recurrence is one fused pallas_call — the band stack
    is read from HBM once for all ``order`` mat-vecs, mirroring the
    matrix-powers kernel's one-pass contract
    (``kernels/matrix_powers.banded_cheb_apply``, gated by
    ``tuning.cheb_fits``).  Everywhere else (dense/ELL/matrix-free, the
    multi-lane ``batched`` form, rebound shards) the recurrence runs
    through the operator's own mat-vec — which under a shard_context is the
    halo-exchange path, so the sharded apply costs ``order`` ppermutes and
    ZERO psums (the interval is static; nothing else reduces).
    """

    name = "chebyshev"
    shard_aware = True

    def __init__(self, a, *, order: int = 4,
                 lam_min: Optional[float] = None,
                 lam_max: Optional[float] = None):
        from repro.core.operators import as_operator
        self.op = as_operator(a)
        if lam_min is None or lam_max is None:
            lam_min, lam_max = estimate_interval(self.op)
        self.order = int(order)
        self.lam_min = float(lam_min)
        self.lam_max = float(lam_max)
        self.theta, self.delta, self.rhos = cheb_coeffs(
            self.order, self.lam_min, self.lam_max)
        self.n = _op_dim(self.op)

    # -- plain (psum-safe, format-agnostic) recurrence ---------------------
    def _apply_ref(self, v, matvec):
        theta, delta = self.theta, self.delta
        z = v / theta
        z_old = jnp.zeros_like(v)
        for rho, rho_old in self.rhos:
            z_new = (rho * (2.0 / delta * (v - matvec(z))
                            + rho_old * (z - z_old)) + z)
            z_old, z = z, z_new
        return z

    def __call__(self, v):
        from repro.core import operators as op_mod
        from repro.kernels import matrix_powers, tuning
        op = self.op
        mode = tuning.kernel_mode()
        if (mode != "ref" and tuning.shard_axis() is None
                and isinstance(op, op_mod.BandedOperator)
                and v.ndim == 1):
            halo = max(abs(int(o)) for o in op.offsets)
            if tuning.cheb_fits(v.shape[0], op.bands.shape[0],
                                op.bands.dtype, halo=halo):
                return matrix_powers.banded_cheb_apply(
                    op.bands, v, op.offsets, theta=self.theta,
                    delta=self.delta, rhos=self.rhos,
                    interpret=mode == "interpret")
        return self._apply_ref(v, op)

    def batched(self, vs):
        # One shared operator stream per recurrence step: the (k, n) block
        # hits A through the same block mat-vec the batched solver uses.
        from repro.core.gmres import _block_matvec
        blockmv = _block_matvec(self.op)
        return self._apply_ref(vs, blockmv)

    def rebind(self, op_local):
        pc = object.__new__(ChebyshevPreconditioner)
        pc.op = op_local
        pc.order = self.order
        pc.lam_min, pc.lam_max = self.lam_min, self.lam_max
        pc.theta, pc.delta, pc.rhos = self.theta, self.delta, self.rhos
        pc.n = self.n
        return pc

    def cost(self):
        nnz = _op_nnz(self.op)
        matvecs = float(self.order)
        return PrecondCost(
            setup_flops=10.0 * nnz,                  # interval estimation
            apply_flops=matvecs * 2.0 * nnz + matvecs * 6.0 * float(self.n or 0),
            # fused banded path streams the band stack ONCE for all
            # `order` mat-vecs; vectors stay VMEM-resident.
            apply_hbm_bytes=4.0 * (nnz + 2.0 * float(self.n or 0)),
            matvec_equiv=matvecs)


class BandedILU0Preconditioner(Preconditioner):
    """ILU(0) on the band pattern of a ``BandedOperator``.

    Setup is ONE pass over the rows (``kernels/trisolve.banded_ilu0``,
    a lax.scan carrying the last ``halo`` factored rows — O(n * nbands^2)
    flops, O(bands) live state).  Apply is two banded triangular sweeps
    (unit-lower forward, upper backward) through the
    ``kernels/trisolve.banded_trisweep`` kernel on the standard
    compiled/interpret/ref dispatch (``tuning.trisweep_fits``).

    ``pattern`` restricts the factorization to a subset of the operator's
    offsets: ``pattern=(-1, 0, 1)`` is LINE-JACOBI — ILU(0) of the
    tridiagonal part, which is its EXACT factorization — see
    ``line_jacobi``.  Not shard-aware (the sweeps recur across the whole
    row range); the sharded composition is ``banded_block_jacobi``.
    """

    name = "banded_ilu0"
    requires_fmt = "banded"

    def __init__(self, op, *, pattern: Optional[Tuple[int, ...]] = None):
        from repro.core import operators as op_mod
        from repro.kernels import trisolve
        if not isinstance(op, op_mod.BandedOperator):
            raise ValueError(
                f"banded_ilu0 needs a BandedOperator (its setup walks the "
                f"band pattern); got {type(op).__name__} — use jacobi/"
                f"chebyshev for dense or ELL operators")
        self.op = op
        bands, offsets = op.bands, tuple(int(o) for o in op.offsets)
        if pattern is not None:
            keep = [d for d, off in enumerate(offsets) if off in pattern]
            if not any(offsets[d] == 0 for d in keep):
                raise ValueError("ilu0 pattern must include the diagonal")
            bands = bands[jnp.asarray(keep)]
            offsets = tuple(offsets[d] for d in keep)
        self.pattern = pattern
        (self.l_bands, self.l_offsets,
         self.u_bands, self.u_offsets) = trisolve.banded_ilu0(bands, offsets)
        self.n = int(bands.shape[1])

    def _sweeps(self, v):
        from repro.kernels import trisolve
        z = trisolve.banded_trisweep(self.l_bands, v, self.l_offsets,
                                     unit_diag=True, lower=True)
        return trisolve.banded_trisweep(self.u_bands, z, self.u_offsets,
                                        unit_diag=False, lower=False)

    def __call__(self, v):
        return self._sweeps(v)

    def batched(self, vs):
        # The scan-based reference sweeps vectorize over lanes directly.
        from repro.kernels import trisolve
        sweep = jax.vmap(lambda v: trisolve.banded_trisweep_ref(
            self.l_bands, v, self.l_offsets, unit_diag=True, lower=True))
        back = jax.vmap(lambda v: trisolve.banded_trisweep_ref(
            self.u_bands, v, self.u_offsets, unit_diag=False, lower=False))
        return back(sweep(vs))

    def cost(self):
        nbands = float(self.l_bands.shape[0] + self.u_bands.shape[0])
        n = float(self.n)
        nnz = max(_op_nnz(self.op), 1.0)
        return PrecondCost(setup_flops=n * nbands * nbands,
                           apply_flops=2.0 * n * nbands,
                           apply_hbm_bytes=4.0 * (n * nbands + 3.0 * n),
                           matvec_equiv=(n * nbands) / nnz)


class BandedBlockJacobiPreconditioner(BandedILU0Preconditioner):
    """Shard-local banded block-Jacobi: ILU(0) of each shard's own block.

    Single-shard it IS ``banded_ilu0``.  Rebinding inside the distributed
    wrapper's shard_map body masks the band entries whose column index
    leaves the local row range (in local coordinates: ``i + off`` outside
    ``[0, n_local)`` — identical on every shard, so no shard-id dependence)
    and factors the remaining LOCAL diagonal block.  The apply is then
    shard-local with ZERO communication, composing with the halo-exchange
    mat-vec exactly as the dense ``_local_block_jacobi`` composes with the
    all-gather one.
    """

    name = "banded_block_jacobi"
    shard_aware = True

    def rebind(self, op_local):
        # Bands arrive row-sharded: op_local.bands is the (nbands, n_local)
        # slice.  BandedOperator's banded storage already zeroes nothing —
        # out-of-range reads are zero via the matvec's halo — so the mask
        # below is what truncates couplings to the local block.
        return BandedBlockJacobiPreconditioner(op_local,
                                               pattern=self.pattern)


def make_preconditioner(name: str, op, **kw) -> Preconditioner:
    """Factory by registry name (see ``PRECONDITIONERS``)."""
    try:
        factory = PRECONDITIONERS[name]
    except KeyError:
        raise ValueError(f"unknown preconditioner {name!r}; options: "
                         f"{sorted(PRECONDITIONERS)}") from None
    return factory(op, **kw)


# --------------------------------------------------------------------------
# Callable-style factories (the original module API, kept stable — each now
# returns a Preconditioner instance, which is still a plain callable).
# --------------------------------------------------------------------------
def identity() -> Preconditioner:
    return IdentityPreconditioner()


def jacobi(a) -> Preconditioner:
    """Diagonal scaling M = diag(A)."""
    return JacobiPreconditioner(a)


def block_jacobi(a, block: int) -> Preconditioner:
    return BlockJacobiPreconditioner(a, block)


def neumann(a, *, order: int = 2,
            omega: float | None = None) -> Preconditioner:
    return NeumannPreconditioner(a, order=order, omega=omega)


def chebyshev(a, *, order: int = 4, lam_min: Optional[float] = None,
              lam_max: Optional[float] = None) -> Preconditioner:
    return ChebyshevPreconditioner(a, order=order, lam_min=lam_min,
                                   lam_max=lam_max)


def banded_ilu0(op) -> Preconditioner:
    return BandedILU0Preconditioner(op)


def line_jacobi(op) -> Preconditioner:
    """ILU(0) restricted to the (-1, 0, +1) bands — exact tridiagonal
    (Thomas) factorization of the operator's line coupling."""
    return BandedILU0Preconditioner(op, pattern=(-1, 0, 1))


def banded_block_jacobi(op) -> Preconditioner:
    return BandedBlockJacobiPreconditioner(op)


PRECONDITIONERS = {
    "none": lambda a, **kw: identity(),
    "jacobi": lambda a, **kw: jacobi(a),
    "block_jacobi": lambda a, block=64, **kw: block_jacobi(a, block),
    "neumann": lambda a, order=2, **kw: neumann(a, order=order),
    "chebyshev": lambda a, order=4, lam_min=None, lam_max=None, **kw:
        chebyshev(a, order=order, lam_min=lam_min, lam_max=lam_max),
    "banded_ilu0": lambda a, **kw: banded_ilu0(a),
    "line_jacobi": lambda a, **kw: line_jacobi(a),
    "banded_block_jacobi": lambda a, **kw: banded_block_jacobi(a),
}
