"""Self-healing GMRES: degradation ladder + restart-boundary checkpoints.

The restart boundary of GMRES(m) is a FREE checkpoint: each cycle is a
pure function of the iterate x (the Krylov basis is rebuilt from the
residual at entry), so any cycle can be re-run, re-run on a different
scheme/kernel stack, or resumed after a kill, and the trajectory from a
committed x is bit-identical to an uninterrupted solve.  This module
exploits that three ways:

  detect    every committed cycle's TRUE residual feeds the bounded ring
            from ``core.gmres.Diagnostics``; ``classify_residuals`` flags
            NAN_INF / BREAKDOWN / STAGNATED against scale-relative
            thresholds (ratios only — c·A, c·b classifies identically).
  degrade   on a fault, re-run the failed cycle FROM THE LAST GOOD x one
            rung down the ladder: orthogonalization schemes step
            cgs2_pipelined -> cgs2_fused -> cgs2 -> mgs within a kernel
            mode, then the mode itself steps compiled -> interpret -> ref
            (``tuning.force_kernel_mode``) and the scheme ladder restarts.
            Transient kernel faults (exceptions) get bounded retries with
            exponential backoff BEFORE costing a rung.
  resume    with ``checkpoint_dir`` set, every committed cycle (or every
            ``checkpoint_every``-th) serializes (x, residual ring, cycle,
            rung) through ``checkpoint/checkpoint.py`` — atomic rename +
            crc32 — so a killed solve resumes from the last completed
            cycle, bit-identically.

Fault-free solves take the FUSED fast path — one plain ``gmres`` call,
zero per-cycle host round-trips — unless a fault schedule is armed for
the core sites (``runtime/faultinject.armed``) or a checkpoint/resume was
requested; only then does the solve run cycle-stepped.  The stepped loop
commits exactly the cycles the fused while_loop would, so even its
restart count matches the fast path.

``CircuitBreaker`` lives here too (the serving layer wires it around the
solver handle): closed -> open after ``threshold`` consecutive failures,
half-open trial after ``cooldown`` ticks, dead after ``max_trips`` opens
without an intervening success.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core.gmres import (BREAKDOWN, Diagnostics, GmresResult, HEALTHY,
                              NAN_INF, STAGNATED, STATUS_NAMES,
                              classify_residuals, gmres)
from repro.core.operators import as_operator
from repro.kernels import tuning
from repro.runtime import faultinject

# Scheme half of the degradation ladder, most aggressive first.  Every
# entry is mathematically GMRES — stepping down trades collective fusion
# and kernel reliance for simplicity, never convergence semantics.
DEGRADATION_SCHEMES = ("cgs2_pipelined", "cgs2_fused", "cgs2", "mgs")


def build_ladder(gs: str = "cgs2_pipelined",
                 mode: Optional[str] = None) -> Tuple[Tuple[str, str], ...]:
    """The (scheme, kernel_mode) rung table, starting at the caller's ask.

    Schemes step down within the current kernel mode first (cheap — same
    executables family, one retrace); when they are exhausted the kernel
    mode drops one level (compiled -> interpret -> ref) and the scheme
    ladder restarts from the top.  The final rung is always ("mgs", "ref")
    — plain jnp modified Gram-Schmidt, no kernels anywhere.
    """
    mode = tuning.kernel_mode() if mode is None else mode
    if mode not in tuning.KERNEL_MODE_LADDER:
        raise ValueError(f"unknown kernel mode {mode!r}")
    rungs: List[Tuple[str, str]] = []
    for j, md in enumerate(
            tuning.KERNEL_MODE_LADDER[
                tuning.KERNEL_MODE_LADDER.index(mode):]):
        if j == 0 and gs in DEGRADATION_SCHEMES:
            schemes = DEGRADATION_SCHEMES[DEGRADATION_SCHEMES.index(gs):]
        elif j == 0:
            # A scheme outside the ladder ("fused", "cgs", ...) is rung 0
            # as requested, then the standard ladder takes over.
            schemes = (gs,) + DEGRADATION_SCHEMES
        else:
            schemes = DEGRADATION_SCHEMES
        rungs.extend((s, md) for s in schemes)
    return tuple(rungs)


@dataclasses.dataclass
class RecoveryEvent:
    cycle: int       # committed-cycle count when the event happened
    kind: str        # "fault" | "retry" | "stepdown" | "checkpoint" | "resume"
    rung: int        # ladder index at the time
    detail: str = ""


@dataclasses.dataclass
class RecoveryReport:
    """What the self-healing loop did — attached next to the GmresResult."""
    ladder: Tuple[Tuple[str, str], ...]
    rung: int = 0                 # final ladder position
    fast_path: bool = False       # True: fused solve, nothing below applies
    cycles: int = 0               # committed restart cycles
    faults: int = 0               # detected faults (exceptions + numerical)
    retries: int = 0              # same-rung re-runs after exceptions
    stepdowns: int = 0            # rungs consumed
    checkpoints: int = 0          # checkpoint writes
    resumed_from: Optional[int] = None   # cycle a resume started from
    gave_up: bool = False         # ladder exhausted mid-fault
    events: List[RecoveryEvent] = dataclasses.field(default_factory=list)

    def log(self, cycle, kind, rung, detail=""):
        self.events.append(RecoveryEvent(cycle, kind, rung, detail))


class CircuitBreaker:
    """Tick-deterministic breaker around a repeatedly-failing callee.

    closed --threshold consecutive failures--> open (``allow`` False)
    open --cooldown ticks--> half-open (ONE trial allowed)
    half-open --success--> closed, fully reset; --failure--> open again
    More than ``max_trips`` opens without an intervening success -> dead
    (permanently open; the server fails its backlog rather than spin).
    """

    def __init__(self, threshold: int = 3, cooldown: int = 5,
                 max_trips: int = 2):
        self.threshold = threshold
        self.cooldown = cooldown
        self.max_trips = max_trips
        self.state = "closed"
        self.failures = 0          # consecutive, in closed state
        self.trips = 0             # opens since the last success
        self.open_until = 0

    @property
    def dead(self) -> bool:
        return self.state == "dead"

    def allow(self, tick: int) -> bool:
        if self.state == "open" and tick >= self.open_until:
            self.state = "half_open"
        return self.state in ("closed", "half_open")

    def record_success(self) -> None:
        if self.state != "dead":
            self.state = "closed"
            self.failures = 0
            self.trips = 0

    def record_failure(self, tick: int) -> None:
        if self.state == "dead":
            return
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.trips += 1
            self.failures = 0
            if self.trips > self.max_trips:
                self.state = "dead"
            else:
                self.state = "open"
                self.open_until = tick + self.cooldown


def _checkpoint_tree(x, hist):
    return {"hist": np.asarray(hist), "x": np.asarray(x)}


def gmres_self_healing(
    a,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    m: int = 30,
    tol: float = 1e-5,
    max_restarts: int = 50,
    gs: str = "cgs2_pipelined",
    precond: Optional[Callable] = None,
    compute_dtype=None,
    window: int = 8,
    max_retries: int = 2,
    backoff_base: float = 0.0,
    sleep: Callable[[float], None] = time.sleep,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    resume: bool = True,
) -> Tuple[GmresResult, RecoveryReport]:
    """Restarted GMRES that survives kernel faults, NaNs and stagnation.

    Same solve contract as ``core.gmres.gmres`` (right-preconditioned
    GMRES(m), TRUE residual, scale-relative guards) plus the recovery
    semantics from the module docstring.  Returns ``(result, report)``;
    ``result.diagnostics`` carries the residual ring and final health
    status, ``report`` the ladder/fault/checkpoint account.

    Recovery knobs:
      window: residual-history ring length == stagnation window.
      max_retries: same-rung re-runs of a cycle whose execution RAISED
        (transient kernel fault) before the fault costs a rung.
      backoff_base: seconds for the exponential backoff between those
        retries (``backoff_base * 2**attempt`` via ``sleep`` — injectable
        for tests; 0.0 disables).
      checkpoint_dir / checkpoint_every / resume: restart-boundary
        checkpointing through ``checkpoint/checkpoint.py``; ``resume=True``
        picks up the latest complete cycle under ``checkpoint_dir``.
    """
    op = as_operator(a)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    ladder = build_ladder(gs)
    report = RecoveryReport(ladder=ladder)

    stepped = (checkpoint_dir is not None
               or faultinject.armed("core.cycle", "core.cycle_nan"))
    if not stepped:
        # Fused fast path: ONE plain gmres program, the zero-overhead
        # common case.  A post-hoc HEALTHY (or converged) diagnosis means
        # nothing to recover; anything else falls through to the stepped
        # loop, re-solving from scratch one rung down — the fused solve's
        # x may be poisoned, x0 is the last x known to be good.
        res = gmres(op, b, x0, m=m, tol=tol, max_restarts=max_restarts,
                    gs=gs, precond=precond, compute_dtype=compute_dtype,
                    history=window)
        status = int(res.diagnostics.status)
        if bool(res.converged) or status in (HEALTHY, STAGNATED):
            report.fast_path = True
            report.cycles = int(res.restarts)
            return res, report
        report.faults += 1
        report.log(0, "fault", 0,
                   f"fast path diagnosed {STATUS_NAMES[status]}")
        if len(ladder) > 1:
            report.rung = 1
            report.stepdowns = 1
            report.log(0, "stepdown", 1, "->".join(ladder[1]))

    dtype = b.dtype
    bnorm = float(np.linalg.norm(np.asarray(b, np.float64)))
    tol_abs = max(tol * bnorm, 0.0)

    def true_residual(x):
        return float(jnp.linalg.norm(b - op(x)))

    x = jnp.asarray(x0)
    hist = np.full((window,), np.inf, np.float64)
    hist[-1] = true_residual(x)
    cycle = 0
    rung = report.rung
    retries = 0

    if checkpoint_dir is not None and resume:
        step = ckpt.latest_step(checkpoint_dir)
        if step is not None:
            tree, manifest = ckpt.restore(
                checkpoint_dir, _checkpoint_tree(x, hist), step=step)
            extra = manifest["extra"]
            x = jnp.asarray(tree["x"], dtype)
            hist = np.asarray(tree["hist"], np.float64)
            cycle = int(extra["cycle"])
            rung = int(extra["rung"])
            report.resumed_from = cycle
            report.log(cycle, "resume", rung, f"step {step}")

    # One jitted single-cycle solver per visited rung, traced under that
    # rung's forced kernel mode.  Each call IS one restart cycle of the
    # fused solver (pure in x), so committed trajectories are identical.
    cycle_fns = {}

    def run_cycle(r, xc):
        scheme, mode = ladder[r]
        if r not in cycle_fns:
            cycle_fns[r] = jax.jit(lambda xx: gmres(
                op, b, xx, m=m, tol=tol, max_restarts=1, gs=scheme,
                precond=precond, compute_dtype=compute_dtype))
        with tuning.force_kernel_mode(mode):
            return cycle_fns[r](xc)

    def step_down() -> bool:
        nonlocal rung
        if rung + 1 >= len(ladder):
            return False
        rung += 1
        report.stepdowns += 1
        report.log(cycle, "stepdown", rung, "->".join(ladder[rung]))
        # Fresh stagnation window: the new rung should not be blamed for
        # (or diagnosed by) the old rung's plateau.
        hist[:-1] = np.inf
        return True

    inner_steps = 0
    beta = hist[-1]
    while beta > tol_abs and cycle < max_restarts and not report.gave_up:
        try:
            faultinject.check("core.cycle", index=cycle)
            res = run_cycle(rung, x)
            x_new = res.x
            beta_new = float(res.residual)
            if faultinject.fire("core.cycle_nan", index=cycle):
                beta_new = float("nan")
        except Exception as e:  # noqa: BLE001 — every kernel fault lands here
            report.faults += 1
            report.log(cycle, "fault", rung, f"{type(e).__name__}: {e}")
            if retries < max_retries:
                retries += 1
                report.retries += 1
                if backoff_base > 0.0:
                    sleep(backoff_base * 2 ** (retries - 1))
                report.log(cycle, "retry", rung, f"attempt {retries}")
                continue
            retries = 0
            if not step_down():
                report.gave_up = True
            continue
        retries = 0

        cand = np.roll(hist, -1)
        cand[-1] = beta_new
        status = int(classify_residuals(jnp.asarray(cand),
                                        converged=beta_new <= tol_abs))
        if status in (NAN_INF, BREAKDOWN):
            # Poisoned or diverging cycle: DISCARD it (x stays the last
            # good iterate — the restart boundary checkpoint) and step
            # down.  No retry: the same rung would deterministically
            # reproduce a numerical fault.
            report.faults += 1
            report.log(cycle, "fault", rung, STATUS_NAMES[status])
            if not step_down():
                report.gave_up = True
            continue
        # HEALTHY or STAGNATED: the cycle is finite — commit it.
        x = x_new
        beta = beta_new
        hist = cand
        cycle += 1
        inner_steps += int(res.inner_steps)
        if status == STAGNATED:
            # Keep the (slow) progress but change the algorithm.
            report.faults += 1
            report.log(cycle, "fault", rung, "STAGNATED")
            if not step_down():
                report.log(cycle, "fault", rung, "ladder exhausted; "
                           "continuing at the final rung")
        if checkpoint_dir is not None and cycle % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, cycle, _checkpoint_tree(x, hist),
                      extra={"cycle": cycle, "rung": rung,
                             "scheme": ladder[rung][0],
                             "mode": ladder[rung][1], "m": m, "tol": tol,
                             "residual": beta})
            report.checkpoints += 1
            report.log(cycle, "checkpoint", rung, f"step {cycle}")

    report.rung = rung
    report.cycles = cycle
    converged = beta <= tol_abs
    hist_j = jnp.asarray(hist, dtype)
    diags = Diagnostics(
        status=classify_residuals(hist_j, converged=converged),
        residual_history=hist_j,
        history_len=jnp.asarray(min(cycle + 1, window), jnp.int32),
    )
    result = GmresResult(
        x=x, residual=jnp.asarray(beta, dtype),
        restarts=jnp.asarray(cycle, jnp.int32),
        converged=jnp.asarray(converged),
        inner_steps=jnp.asarray(inner_steps, jnp.int32),
        done=jnp.asarray(converged | (cycle >= max_restarts)
                         | report.gave_up),
        diagnostics=diags,
    )
    return result, report
