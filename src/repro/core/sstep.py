"""s-step (communication-avoiding) GMRES — the paper's own citation trail.

The paper cites Chronopoulos' s-step Krylov line (Chronopoulos 1986;
Chronopoulos & Kim 1992; Chronopoulos & Swanson 1996).  The idea: build s
Krylov directions with s mat-vecs and NO per-step inner products, then
orthogonalize the whole block in a CONSTANT number of collective rounds:

    round 1:  C1 = V W^T       (block Gram-Schmidt vs old basis, one psum)
    round 2:  G1 = W'W'^T      (Gram matrix -> CholQR, one psum)
    rounds 3-4: one reorthogonalization pass (CGS2-equivalent stability)

vs. classical Arnoldi's ~4 collective rounds PER STEP (CGS2) or j+2 (MGS).
On a pod where a psum costs axis-latency x log P, collective ROUNDS — not
bytes — bound small-m solves; s-step trades rounds for local (s x s) and
(m x s) matmuls, the MXU's favorite trade.  Round ratio per s steps:
4s -> s + 4 (the s mat-vec all-gathers remain; a matrix-powers kernel
would remove those too for stencil operators, not for dense A).

Hessenberg reconstruction (exact, from the power recurrence):
  u_0 = v_k;  A u_{j-1} = sigma_j u_j  (sigma_j = normalization scale)
  orthogonalization gives  u_j = V c[:, j-1] + Q r[:, j-1]
  Let X_j = coefficient vector of u_j in the final basis.  Then
      H X_{j-1} = sigma_j X_j ,   j = 1..s
  i.e. H S1 = S2 with S1 = [X_0..X_{s-1}], S2 = [sigma_j X_j].  Splitting
  H into known columns (< k) and the s new ones and using that S1's rows
  k..k+s-1 form an invertible triangular block S1r:
      H_new = (S2 - H_known S1_masked) @ inv(S1r)
  — all replicated (m x s)-sized algebra, collective-free.

Caveat (inherent to the method, documented since Chronopoulos 1986): the
monomial basis conditions like kappa(A)^s, so practical s is 2..8 in f32;
convergence checks are per-cycle (true residual), not per-step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import arnoldi
from repro.core.gmres import GmresResult
from repro.core.operators import as_operator


def _psum(x, axis_name):
    return x if axis_name is None else lax.psum(x, axis_name)


def _block_step(matvec, v_basis, h, k_start: int, s: int, axis_name, eps):
    """One s-step block at STATIC offset k_start.

    v_basis: (m+1, n_local), rows 0..k_start valid orthonormal basis.
    h: (m+1, m) Hessenberg built so far (columns >= k_start are zero).
    Returns (v_basis with rows k_start+1..k_start+s written,
             h with columns k_start..k_start+s-1 written).
    """
    m1 = v_basis.shape[0]
    dtype = v_basis.dtype

    # ---- s mat-vecs, no inner products (communication: matvec only) -----
    def power(u, _):
        w = matvec(u)
        nrm = jnp.sqrt(_psum(jnp.vdot(w, w).real, axis_name))
        u_next = w / jnp.maximum(nrm, eps)
        return u_next, (u_next, nrm)

    _, (u_cols, sigma) = lax.scan(power, v_basis[k_start], None, length=s)
    # u_cols: (s, n_local) unit-ish power basis; A u_{j-1} = sigma[j] u_j

    # ---- block orthogonalization: CGS2 on the whole block ----------------
    row_mask = (jnp.arange(m1) <= k_start)[:, None].astype(dtype)

    def gs_pass(w):
        c = _psum(v_basis @ w.T, axis_name) * row_mask    # (m1, s)
        return c, w - c.T @ v_basis

    def cholqr(w):
        g = _psum(w @ w.T, axis_name)                     # (s, s)
        # ridge scaled to the Gram's magnitude: keeps Cholesky PSD even
        # when the block is (near-)degenerate — e.g. the solve converged
        # mid-cycle and the power basis collapsed.
        ridge = jnp.maximum(jnp.max(jnp.diagonal(g)), 1.0) * eps
        g = g + ridge * jnp.eye(s, dtype=dtype)
        r = jnp.linalg.cholesky(g).mT                     # upper
        q = jax.scipy.linalg.solve_triangular(r.mT, w, lower=True)
        return q, r

    c1, w1 = gs_pass(u_cols)
    q1, r1 = cholqr(w1)
    c2, w2 = gs_pass(q1)          # reorthogonalization (CGS2 stability)
    q, r2 = cholqr(w2)
    c_tot = c1 + c2 @ r1          # (m1, s):  U = V^T c_tot + Q^T r_tot
    r_tot = r2 @ r1               # (s, s) upper

    # ---- exact Hessenberg columns from the power recurrence --------------
    # X_j in the (m+1)-row global frame; q_l lives at basis row k_start+1+l.
    xs = [jnp.zeros((m1,), dtype).at[k_start].set(1.0)]   # X_0 = e_k
    for j in range(1, s + 1):
        xj = c_tot[:, j - 1]
        xj = lax.dynamic_update_slice(xj, r_tot[:, j - 1], (k_start + 1,))
        xs.append(xj)
    s1 = jnp.stack(xs[:s], axis=1)                        # (m1, s)
    s2 = jnp.stack([sigma[j - 1] * xs[j] for j in range(1, s + 1)], axis=1)

    s1r = lax.dynamic_slice(s1, (k_start, 0), (s, s))     # invertible tri
    s1_masked = s1 * row_mask * (jnp.arange(m1) < k_start)[:, None]
    corr = h @ s1_masked[: h.shape[1]]                    # (m1, s)
    h_new = jnp.linalg.solve(s1r.T, (s2 - corr).T).T      # (m1, s)

    v_basis = lax.dynamic_update_slice(v_basis, q, (k_start + 1, 0))
    h = lax.dynamic_update_slice(h, h_new, (0, k_start))
    return v_basis, h


def gmres_sstep(a, b, x0=None, *, s: int = 4, blocks: int = 5,
                tol: float = 1e-5, max_restarts: int = 30,
                axis_name: Optional[str] = None) -> GmresResult:
    """Restarted s-step GMRES(m = s * blocks).

    The per-cycle least-squares solve runs once on the replicated
    (m+1, m) Hessenberg — tiny next to the mat-vecs and collective-free.
    """
    matvec = as_operator(a)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    dtype = b.dtype
    eps = jnp.asarray(jnp.finfo(dtype).eps * 100, dtype)
    m = s * blocks
    bnorm = arnoldi.norm(b, axis_name)
    tol_abs = tol * bnorm

    def cycle(x):
        r = b - matvec(x)
        beta = arnoldi.norm(r, axis_name)
        v = jnp.zeros((m + 1, b.shape[0]), dtype).at[0].set(
            r / jnp.maximum(beta, eps))
        h = jnp.zeros((m + 1, m), dtype)
        for blk in range(blocks):                  # static offsets
            v, h = _block_step(matvec, v, h, blk * s, s, axis_name, eps)
        e1 = jnp.zeros((m + 1,), dtype).at[0].set(beta)
        y = jnp.linalg.lstsq(h, e1)[0]
        return x + y @ v[:m]

    def cond(carry):
        _, beta, it = carry
        return (beta > tol_abs) & (it < max_restarts)

    def body(carry):
        x, _, it = carry
        x = cycle(x)
        beta = arnoldi.norm(b - matvec(x), axis_name)
        return x, beta, it + 1

    beta0 = arnoldi.norm(b - matvec(x0), axis_name)
    x, beta, it = lax.while_loop(
        cond, body, (x0, beta0, jnp.zeros((), jnp.int32)))
    return GmresResult(x=x, residual=beta, restarts=it,
                       converged=beta <= tol_abs, inner_steps=it * m)
