"""s-step (communication-avoiding) GMRES — the paper's own citation trail.

The paper cites Chronopoulos' s-step Krylov line (Chronopoulos 1986;
Chronopoulos & Kim 1992; Chronopoulos & Swanson 1996).  The idea: build s
Krylov directions with s mat-vecs and NO per-step inner products, then
orthogonalize the whole block in a CONSTANT number of collective rounds:

    round 1:  C1 = V W^T       (block Gram-Schmidt vs old basis, one psum)
    round 2:  G1 = W'W'^T      (Gram matrix -> CholQR, one psum)
    rounds 3-4: one reorthogonalization pass (CGS2-equivalent stability)

vs. classical Arnoldi's ~4 collective rounds PER STEP (CGS2) or j+2 (MGS).
On a pod where a psum costs axis-latency x log P, collective ROUNDS — not
bytes — bound small-m solves; s-step trades rounds for local (s x s) and
(m x s) matmuls, the MXU's favorite trade.  Round ratio per s steps:
4s -> s + 4 (the s mat-vec all-gathers remain; the matrix-powers kernel
removes their HBM passes too for stencil operators, not for dense A).

Since PR 3 the whole block step is kernel-backed on single-shard solves
(same dispatch contract as the standard cycle got in PR 1):

  powers   kernels/matrix_powers.py — all s normalized powers in ONE
           pallas_call.  Banded/stencil operators keep the band stack
           VMEM-resident (one HBM pass over A for the whole block); dense
           A streams once per power with the normalization reductions
           fused in-register.  Gated by ``tuning.powers_fits``.
  block GS kernels/block_gs.py — each CGS2 pass is one pallas_call with
           the basis VMEM-resident: projection, update and the CholQR
           Gram matrix in-register (V streamed twice per block step
           instead of four times).  Gated by ``tuning.block_gs_fits``.
           The (s, s) Cholesky between passes is replicated algebra and
           stays out here, at the collective boundary.

Row-sharded solves (``axis_name`` under the distributed wrapper's
``tuning.shard_context``) are kernel-backed too since PR 5:

  powers   banded operators run the COMMUNICATION-AVOIDING matrix-powers
           kernel (``matrix_powers.banded_powers_halo``): one ppermute
           halo exchange of width s*halo, all s raw powers per-shard in
           one pallas_call, one psum completing every norm — 2 collective
           rounds per block where the reference pays s all-gathers +
           s psums.  Dense A keeps the per-power all-gather reference
           (dense rows touch every column; nothing to halo).
  block GS the split-phase pair (``block_gs.block_gs_pass_sharded``):
           per-shard project kernel, C psum, per-shard update kernel,
           G psum — the collectives sit exactly where
           ``block_gs_pass_ref`` puts them, so the cycle code is shared.

``kernel_mode() == "ref"``, VMEM-overflowing shapes, and sharded solves
without a shard_context still run the psum-safe jnp references
(``matrix_powers_ref`` / ``block_gs_pass_ref``) — identical arithmetic,
collectives where the kernel outputs sit.

Hessenberg reconstruction (exact, from the power recurrence):
  u_0 = v_k;  A u_{j-1} = sigma_j u_j  (sigma_j = normalization scale)
  orthogonalization gives  u_j = V c[:, j-1] + Q r[:, j-1]
  Let X_j = coefficient vector of u_j in the final basis.  Then
      H X_{j-1} = sigma_j X_j ,   j = 1..s
  i.e. H S1 = S2 with S1 = [X_0..X_{s-1}], S2 = [sigma_j X_j].  Splitting
  H into known columns (< k) and the s new ones and using that S1's rows
  k..k+s-1 form an invertible triangular block S1r:
      H_new = (S2 - H_known S1_masked) @ inv(S1r)
  — all replicated (m x s)-sized algebra, collective-free.

The per-cycle least-squares solve folds the (m+1, m) Hessenberg through
the same incremental Givens QR the standard solver uses (core/givens.py)
— O(m^2) rotations instead of the dense ``lstsq`` SVD path, and the same
replicated, collective-free footprint.

Caveat (inherent to the method, documented since Chronopoulos 1986): the
monomial basis conditions like kappa(A)^s, so practical s is 2..8 in f32;
convergence checks are per-cycle (true residual), not per-step.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import arnoldi, givens
from repro.core.gmres import (Diagnostics, GmresResult, check_precond,
                              classify_residuals)
from repro.core.operators import (EXPLICIT_OPERATORS, BandedOperator,
                                  DenseOperator, SparseOperator, as_operator,
                                  with_dtype)


def _leja_perm(s: int) -> tuple:
    """Static Leja-style ordering of the s Chebyshev points.

    Greedy max-product-of-distances on the REFERENCE points
    cos((2k+1) pi / 2s) — pure Python (the point POSITIONS are static even
    though the mapped shift values are traced), so the permutation bakes
    into the trace.  Leja ordering keeps every Newton-basis prefix well
    spread over the interval; consecutive nearby shifts would reintroduce
    the monomial basis's conditioning growth.
    """
    import math
    pts = [math.cos(math.pi * (2 * k + 1) / (2 * s)) for k in range(s)]
    perm = [0]
    remaining = set(range(1, s))
    while remaining:
        nxt = max(remaining, key=lambda j: (
            math.prod(abs(pts[j] - pts[i]) for i in perm), -j))
        perm.append(nxt)
        remaining.discard(nxt)
    return tuple(perm)


def _newton_shifts(op, s: int) -> jax.Array:
    """Newton-basis shifts: Leja-ordered Chebyshev points of A's interval.

    The interval is the Gershgorin bound (one pass over the rows, traced
    — no eigensolve); shifts at the Chebyshev points of [lo, hi] bound
    |prod (A - shift_j)| growth the way the monomial basis (all shifts 0)
    cannot, keeping the power block conditioned far past the kappa^s wall.
    """
    from repro.core.preconditioners import spectral_bounds
    lo, hi = spectral_bounds(op)
    k = jnp.arange(s)
    pts = ((lo + hi) / 2
           + (hi - lo) / 2 * jnp.cos(jnp.pi * (2 * k + 1) / (2 * s)))
    return pts[jnp.asarray(_leja_perm(s))].astype(jnp.float32)


def _make_block_fns(op, n: int, s: int, m1: int, dtype, axis_name,
                    gs: str = "cgs2", precond=None, shifts=None):
    """Trace-time dispatch: (powers_fn, gs_pass_fn, basis_shape, single_reduce).

    Kernel paths need a kernel-capable backend (``tuning.kernel_mode()
    != "ref"``) and a working set that fits VMEM; row-sharded solves
    additionally pick the PER-SHARD variants — the communication-avoiding
    halo powers kernel (banded operators, when the ambient
    ``tuning.shard_context`` supplies the ppermute geometry) and the
    split-phase block-GS pair.  Anything else gets the psum-safe jnp
    references.  Mirrors the ``gs="fused"`` dispatch in core/gmres.py —
    including the pre-padded loop carry: when a block-GS kernel is
    engaged, ``basis_shape`` is the tile-aligned (m1_pad, n_pad) the
    cycle allocates directly, so the basis is never re-padded (a full HBM
    copy) inside the block step.

    Note ``n`` is the LOCAL vector length under sharding, so the VMEM
    fits-checks divide by the shard count — sharding ADMITS kernel-path
    systems the single device could not hold.
    """
    from repro.kernels import block_gs, matrix_powers, spmv, tuning

    mode = tuning.kernel_mode()
    interp = mode == "interpret"
    guard = float(jnp.finfo(dtype).tiny) ** 0.5   # breakdown guard
    # The halo-exchange powers path builds static ppermute permutations,
    # which needs the shard count — only the ambient shard_context (set by
    # core/distributed.py) carries it.
    ctx_sharded = axis_name is not None and tuning.shard_axis() == axis_name
    # Right preconditioning powers B = A M^{-1}: the fused kernels stream
    # A's own storage, so a non-identity M^{-1} takes the reference powers
    # over the composed mat-vec (M^{-1} itself may still be kernel-backed,
    # e.g. the fused Chebyshev apply inside each power).
    identity_pc = precond is None or getattr(precond, "is_identity", False)

    powers_fn = None
    if mode != "ref" and axis_name is None and identity_pc:
        if isinstance(op, BandedOperator):
            halo = max(abs(int(o)) for o in op.offsets)
            if tuning.powers_fits(n, op.bands.dtype, s,
                                  nbands=op.bands.shape[0], halo=halo):
                powers_fn = lambda u0: matrix_powers.banded_powers(
                    op.bands, u0, op.offsets, s, shifts=shifts,
                    interpret=interp)
        elif isinstance(op, SparseOperator):
            width = op.values.shape[1]
            if tuning.ell_powers_fits(n, width, op.values.dtype, s):
                powers_fn = lambda u0: matrix_powers.ell_powers(
                    op.values, op.cols, u0, s, shifts=shifts,
                    interpret=interp)
        elif isinstance(op, DenseOperator) and shifts is None:
            if tuning.powers_fits(n, op.a.dtype, s):
                block = tuning.choose_powers_block(
                    n, jnp.dtype(op.a.dtype).name, s=s)
                powers_fn = lambda u0: matrix_powers.dense_powers(
                    op.a, u0, s, block=block, interpret=interp)
    elif (mode != "ref" and ctx_sharded and isinstance(op, BandedOperator)
          and identity_pc and shifts is None):
        halo = max(abs(int(o)) for o in op.offsets)
        nshards = tuning.shard_size()
        if (s * halo <= n
                and tuning.powers_fits(n + 2 * s * halo, op.bands.dtype, s,
                                       nbands=op.bands.shape[0], halo=halo)):
            # Bands are loop-invariant: exchange the (s-1)*halo neighbor
            # columns ONCE here (trace top level, outside the restart
            # loop) and zero-pad the outer halo margin.
            bands_ex = spmv.halo_exchange(op.bands.T, (s - 1) * halo,
                                          axis_name, nshards).T
            bands_pad = jnp.pad(bands_ex, ((0, 0), (halo, halo)))
            # Deferred normalization computes RAW powers, whose magnitude
            # grows like ||A||^s — enough to overflow f32 for moderately
            # scaled systems.  Pre-scale by theta >= ||A||_inf (row sums,
            # pmax-completed): the kernel then powers B = A/theta with
            # ||B||_inf <= 1, so no system scale can overflow, and the
            # recurrence is recovered EXACTLY via
            # sigma_j = theta * ||z'_j|| / ||z'_{j-1}|| — this also makes
            # the sharded path scale-invariant by construction (solving
            # c*A, c*b pre-scales c away entirely).
            row_sums = jnp.sum(jnp.abs(op.bands.astype(jnp.float32)),
                               axis=0)
            theta = lax.pmax(jnp.max(row_sums), axis_name)
            theta = jnp.maximum(theta, jnp.asarray(guard, theta.dtype))
            bands_pad = (bands_pad.astype(jnp.float32)
                         / theta).astype(bands_pad.dtype)

            def powers_fn(u0):
                # One neighbor exchange + one psum for ALL s powers: the
                # kernel computes z'_j = (A/theta)^j u0 per-shard, the
                # batched psum completes every ||z'_j||, and u_j / sigma_j
                # follow exactly (see kernels/matrix_powers.py).
                x_halo = spmv.halo_exchange(u0, s * halo, axis_name,
                                            nshards)
                z, nrm_part = matrix_powers.banded_powers_halo(
                    bands_pad, x_halo, op.offsets, s, interpret=interp)
                znorm = jnp.sqrt(lax.psum(nrm_part, axis_name))
                g = jnp.asarray(guard, znorm.dtype)
                prev = jnp.concatenate(
                    [jnp.ones((1,), znorm.dtype), znorm[:-1]])
                sigma = theta.astype(znorm.dtype) * znorm / jnp.maximum(
                    prev, g)
                u = z / jnp.maximum(znorm, g)[:, None]
                return u, sigma
    if powers_fn is None:
        pmatvec = op if identity_pc else (lambda v: op(precond(v)))
        powers_fn = lambda u0: matrix_powers.matrix_powers_ref(
            pmatvec, u0, s, guard, axis_name, shifts=shifts)

    if gs not in ("cgs2", "cgs2_pipelined"):
        raise ValueError(f"gmres_sstep: unknown gs {gs!r}; options: "
                         f"['cgs2', 'cgs2_pipelined']")
    single_reduce = gs == "cgs2_pipelined"
    kernel_gs = mode != "ref" and tuning.block_gs_fits(m1, n, dtype, s=s)
    if single_reduce:
        # ONE stacked psum per pass ([C_hat; M] payload, CholQR Gram
        # recovered against the maintained basis Gram matrix) — 2 rounds
        # per block instead of 4.  The matrix-powers exchange/psum above
        # stays separate: its operand is the RAW power block, whose row
        # scaling would destabilize CholQR if folded into this payload.
        if kernel_gs:
            gs_pass = (lambda v, w, tin, mask, gram:
                       block_gs.block_gs_pass_single_reduce(
                           v, w, tin, mask, gram, axis_name,
                           interpret=interp))
        else:
            gs_pass = (lambda v, w, tin, mask, gram:
                       block_gs.block_gs_pass_single_reduce_ref(
                           v, w, tin, mask, gram, axis_name))
    elif kernel_gs:
        if axis_name is None:
            gs_pass = lambda v, w, tin, mask: block_gs.block_gs_pass(
                v, w, tin, mask, interpret=interp)
        else:
            gs_pass = lambda v, w, tin, mask: block_gs.block_gs_pass_sharded(
                v, w, tin, mask, axis_name, interpret=interp)
    else:
        gs_pass = lambda v, w, tin, mask: block_gs.block_gs_pass_ref(
            v, w, tin, mask, axis_name)
    if kernel_gs:
        m1p, n_pad, _ = tuning.choose_block_gs(m1, n, s,
                                               jnp.dtype(dtype).name)
        basis_shape = (m1p, n_pad)
    else:
        basis_shape = (m1, n)
    return powers_fn, gs_pass, basis_shape, single_reduce


def _block_step(powers_fn, gs_pass, v_basis, h, k_start: int, s: int, eps,
                n: int, gram=None, shifts=None):
    """One s-step block at STATIC offset k_start.

    v_basis: (m1_pad, n_pad) basis carry — live rows/cols are (m+1, n),
    any padding rows/cols are zero (see ``_make_block_fns``).  h: (m+1, m)
    Hessenberg built so far (columns >= k_start are zero).  Returns
    (v_basis with rows k_start+1..k_start+s written,
     h with columns k_start..k_start+s-1 written, gram).

    ``gram`` (single-reduce mode): the maintained (m1_pad, m1_pad) basis
    Gram matrix.  Each pass then pays ONE stacked psum and the CholQR Gram
    is recovered from it; after CholQR the s new basis rows' measured
    inner products extend ``gram`` via

        Gamma_cross = V Q_new^T = (C_hat_2 - Gamma C_2) R_2^{-1}
        Gamma_diag  = Q_new Q_new^T = R_2^{-T} G_2 R_2^{-1}

    — replicated (m x s) algebra, no collective.
    """
    m1p, n_pad = v_basis.shape
    m1 = h.shape[0]                      # live rows: m + 1
    # Two precisions, same split as the standard cycle's compute_dtype
    # path: the STREAMS (basis rows, power block — the O(n) traffic) live
    # in the basis dtype, while the replicated (s x s)/(m x s) algebra —
    # CholQR, Hessenberg recurrence — runs in h's dtype (b.dtype).  The
    # block-GS passes already accumulate in promote(stream, f32), so a
    # bf16 basis halves the streamed bytes without bf16 dot products.
    dtype = v_basis.dtype
    hdt = h.dtype

    # ---- s mat-vecs, no inner products (communication: matvec only) -----
    # One fused launch on the kernel path: A is streamed once for the whole
    # block (banded) or once per power (dense), u_j never round-trips.
    u_cols, sigma = powers_fn(v_basis[k_start, :n])
    u_cols = u_cols.astype(dtype)        # (s, n) power basis; A u_{j-1} =
    sigma = sigma.astype(hdt)            # sigma[j] u_j
    if n_pad != n:                       # cheap (s, n_pad) copy; the BASIS
        u_cols = jnp.pad(u_cols, ((0, 0), (0, n_pad - n)))  # is never re-padded

    # ---- block orthogonalization: CGS2 + CholQR on the whole block ------
    row_mask = (jnp.arange(m1p) <= k_start).astype(dtype)

    def cholqr_factor(g):
        # ridge scaled to the Gram's magnitude: keeps Cholesky PSD even
        # when the block is (near-)degenerate — e.g. the solve converged
        # mid-cycle and the power basis collapsed.  The floor is the
        # scale-free breakdown guard, NOT an absolute 1.0: a system scaled
        # by c must produce the same solve (only a true zero Gram hits it).
        g = g.astype(hdt)
        guard = jnp.asarray(jnp.finfo(hdt).tiny ** 0.5, hdt)
        ridge = jnp.maximum(jnp.max(jnp.diagonal(g)), guard) * eps
        g = g + ridge * jnp.eye(s, dtype=hdt)
        return jnp.linalg.cholesky(g).mT                  # upper

    eye_s = jnp.eye(s, dtype=hdt)
    if gram is None:
        c1, w1, g1 = gs_pass(v_basis, u_cols, eye_s, row_mask)
    else:
        c1, w1, g1, _ = gs_pass(v_basis, u_cols, eye_s, row_mask, gram)
    r1 = cholqr_factor(g1)
    # T = inv(R1^T): folds the CholQR back-substitution (Q1 = R1^{-T} W1)
    # into the second pass's stream instead of a separate (s, n) solve.
    t1 = jax.scipy.linalg.solve_triangular(r1.mT, eye_s, lower=True)
    if gram is None:
        c2, w2, g2 = gs_pass(v_basis, w1.astype(dtype), t1, row_mask)
    else:
        c2, w2, g2, c_hat2 = gs_pass(v_basis, w1.astype(dtype), t1,
                                     row_mask, gram)
    r2 = cholqr_factor(g2)
    # Back-substitute in the algebra dtype (w2 arrives in the passes' f32
    # accumulator); the result is quantized ONCE, where it joins the
    # stored basis stream.
    q = jax.scipy.linalg.solve_triangular(r2.mT, w2.astype(hdt),
                                          lower=True)
    if gram is not None:
        # Extend the maintained Gram matrix by the s rows just built.
        gacc = gram.dtype
        t2 = jax.scipy.linalg.solve_triangular(
            r2.mT.astype(gacc), jnp.eye(s, dtype=gacc), lower=True)
        cross = (c_hat2.astype(gacc)
                 - gram @ c2.astype(gacc)) @ t2.mT       # (m1p, s) X R2^{-1}
        diag = t2 @ g2.astype(gacc) @ t2.mT              # (s, s)
        gram = lax.dynamic_update_slice(gram, cross, (0, k_start + 1))
        gram = lax.dynamic_update_slice(gram, cross.mT, (k_start + 1, 0))
        gram = lax.dynamic_update_slice(gram, diag, (k_start + 1, k_start + 1))
    # Padded basis rows are masked to zero in C, so the Hessenberg algebra
    # below runs at the live (m+1) row count.
    c_tot = (c1[:m1].astype(hdt) + c2[:m1].astype(hdt) @ r1)  # (m1, s)
    r_tot = r2 @ r1                                 # (s, s) upper

    # ---- exact Hessenberg columns from the power recurrence --------------
    # X_j in the (m+1)-row global frame; q_l lives at basis row k_start+1+l.
    xs = [jnp.zeros((m1,), hdt).at[k_start].set(1.0)]     # X_0 = e_k
    for j in range(1, s + 1):
        xj = c_tot[:, j - 1]
        xj = lax.dynamic_update_slice(xj, r_tot[:, j - 1], (k_start + 1,))
        xs.append(xj)
    s1 = jnp.stack(xs[:s], axis=1)                        # (m1, s)
    # Newton basis: A u_{j-1} = sigma_j u_j + shift_j u_{j-1}, so the
    # shifted term rides along in S2 (monomial: shifts identically zero).
    if shifts is None:
        s2_cols = [sigma[j - 1] * xs[j] for j in range(1, s + 1)]
    else:
        sh = shifts.astype(sigma.dtype)
        s2_cols = [sigma[j - 1] * xs[j] + sh[j - 1] * xs[j - 1]
                   for j in range(1, s + 1)]
    s2 = jnp.stack(s2_cols, axis=1)

    s1r = lax.dynamic_slice(s1, (k_start, 0), (s, s))     # invertible tri
    s1_masked = s1 * (jnp.arange(m1) < k_start)[:, None]
    corr = h @ s1_masked[: h.shape[1]]                    # (m1, s)
    h_new = jnp.linalg.solve(s1r.T, (s2 - corr).T).T      # (m1, s)

    v_basis = lax.dynamic_update_slice(v_basis, q.astype(dtype),
                                       (k_start + 1, 0))
    h = lax.dynamic_update_slice(h, h_new, (0, k_start))
    return v_basis, h, gram


def gmres_sstep(a, b, x0=None, *, s: int = 4, blocks: int = 5,
                tol: float = 1e-5, max_restarts: int = 30,
                axis_name: Optional[str] = None,
                gs: str = "cgs2", history: int = 8,
                precond: Optional[Callable] = None,
                basis: str = "monomial",
                compute_dtype=None) -> GmresResult:
    """Restarted s-step GMRES(m = s * blocks).

    ``a`` may be any operator ``gmres`` accepts; ``BandedOperator`` /
    ``DenseOperator`` systems run the block step through the Pallas
    matrix-powers + block-GS kernels when single-shard and VMEM-sized
    (see module docstring), degrading to the jnp reference otherwise.
    The per-cycle least-squares solve folds the replicated (m+1, m)
    Hessenberg through incremental Givens QR — tiny next to the mat-vecs
    and collective-free.

    ``gs``: "cgs2" (the split-phase block passes — 4 psums per block when
    sharded) | "cgs2_pipelined" (single-reduce passes: each pass's C and
    Gram reductions cross shards as ONE stacked payload, with the CholQR
    Gram recovered against a maintained basis Gram matrix — 2 psums per
    block; with the banded CA powers path that is 4 collective rounds per
    s steps total).  There is no cross-block mat-vec pipelining here: the
    power basis of block k+1 starts from the LAST orthonormal vector of
    block k, a true dependency the standard cycle's depth-1 trick cannot
    break.

    ``precond``: right preconditioner ``v -> M^{-1} v`` (None = identity).
    The power block is built over ``B = A M^{-1}`` through the reference
    powers (the apply itself may be kernel-backed, e.g. the fused
    Chebyshev recurrence) and the cycle update un-preconditions:
    ``x += M^{-1} (y V)``.  ``basis``: "monomial" | "newton" — Newton uses
    Leja-ordered Chebyshev-point shifts of A's Gershgorin interval in the
    SAME one-pass powers kernels (``shifts=``), keeping the block
    conditioned past the monomial kappa^s wall (sharded banded solves keep
    the monomial CA halo kernel; newton there runs the per-power psum
    reference).

    ``compute_dtype``: storage dtype for the STREAMED arrays — the basis
    carry and the power block — mirroring the standard cycle's option
    (PR 3's fused path).  ``bf16`` halves basis traffic AND, for explicit
    operators, downcasts the operand stream of A inside the power block
    (the matrix-powers / SpMV kernels accumulate in f32 in-register); the
    replicated CholQR/Hessenberg/Givens algebra and the restart-boundary
    residual recompute stay in ``b.dtype``, so tolerance checks are
    honest.  None keeps everything in ``b.dtype``.
    """
    matvec = as_operator(a)
    if x0 is None:
        x0 = jnp.zeros_like(b)
    n = b.shape[0]
    dtype = b.dtype
    basis_dtype = dtype if compute_dtype is None else jnp.dtype(compute_dtype)
    eps = jnp.asarray(jnp.finfo(dtype).eps * 100, dtype)   # relative factor
    guard = jnp.asarray(jnp.finfo(dtype).tiny ** 0.5, dtype)
    m = s * blocks
    bnorm = arnoldi.norm(b, axis_name)
    tol_abs = tol * bnorm
    if basis not in ("monomial", "newton"):
        raise ValueError(f"gmres_sstep: unknown basis {basis!r}; options: "
                         f"['monomial', 'newton']")
    check_precond(precond)
    shifts = _newton_shifts(matvec, s) if basis == "newton" else None
    identity_pc = precond is None or getattr(precond, "is_identity", False)
    # A compute dtype narrower than A's storage also downcasts the A
    # stream inside the power block — the original operator is kept for
    # the restart-boundary residual (full-precision convergence checks).
    power_op = matvec
    if (isinstance(matvec, EXPLICIT_OPERATORS)
            and jnp.dtype(basis_dtype).itemsize
            < jnp.dtype(matvec.dtype).itemsize):
        power_op = with_dtype(matvec, basis_dtype)
    powers_fn, gs_pass, basis_shape, single_reduce = _make_block_fns(
        power_op, n, s, m + 1, basis_dtype, axis_name, gs, precond=precond,
        shifts=shifts)
    gacc = jnp.promote_types(dtype, jnp.float32)

    def cycle(x):
        r = b - matvec(x)
        beta = arnoldi.norm(r, axis_name)
        v = jnp.zeros(basis_shape, basis_dtype).at[0, :n].set(
            (r / jnp.maximum(beta, guard)).astype(basis_dtype))
        h = jnp.zeros((m + 1, m), dtype)
        # Identity init is exact where it matters: rows beyond the current
        # block are only ever touched against zero (masked) columns.
        gram = jnp.eye(basis_shape[0], dtype=gacc) if single_reduce else None
        for blk in range(blocks):                  # static offsets
            v, h, gram = _block_step(powers_fn, gs_pass, v, h, blk * s, s,
                                     eps, n, gram, shifts=shifts)

        # Fold the m Hessenberg columns through incremental Givens QR.  The
        # ``done`` latch mirrors the standard solver's cycle masking: once
        # the LS residual meets tol or a subdiagonal collapses (the Krylov
        # space is exhausted — e.g. b an eigenvector), remaining columns
        # fold as identity with y_j = 0, keeping R nonsingular where the
        # old dense ``lstsq`` relied on the SVD's min-norm behavior.
        def fold(j, carry):
            st, done = carry
            col = lax.dynamic_slice(h, (0, j), (m + 1, 1))[:, 0]
            st = givens.update(st, col, j, active=jnp.logical_not(done))
            # Relative breakdown probe: a subdiagonal that has collapsed
            # against its own column (an all-zero column included) marks
            # the Krylov space exhausted, at ANY system scale.
            happy = jnp.abs(col[j + 1]) <= eps * jnp.max(jnp.abs(col))
            done = done | (givens.residual_norm(st, j) <= tol_abs) | happy
            return st, done

        giv, _ = lax.fori_loop(
            0, m, fold, (givens.init(m, beta, dtype), beta <= tol_abs))
        y = givens.solve(giv)
        dx = y @ v[:m, :n].astype(dtype)
        # Right preconditioning: the basis spans the M^{-1}-Krylov space,
        # so the update un-preconditions (x solves A x = b, untransformed).
        return x + (dx if identity_pc else precond(dx))

    def cond(carry):
        _, beta, it, _ = carry
        return (beta > tol_abs) & (it < max_restarts)

    def body(carry):
        x, _, it, hist = carry
        x = cycle(x)
        beta = arnoldi.norm(b - matvec(x), axis_name)
        hist = jnp.roll(hist, -1).at[-1].set(beta)
        return x, beta, it + 1, hist

    beta0 = arnoldi.norm(b - matvec(x0), axis_name)
    # Same bounded residual ring as ``gmres`` (see core/gmres.Diagnostics):
    # chronological, inf left-padding, seeded with the entry residual.
    hist0 = jnp.full((history,), jnp.inf, beta0.dtype).at[-1].set(beta0)
    x, beta, it, hist = lax.while_loop(
        cond, body, (x0, beta0, jnp.zeros((), jnp.int32), hist0))
    converged = beta <= tol_abs
    diags = Diagnostics(
        status=classify_residuals(hist, converged=converged),
        residual_history=hist,
        history_len=jnp.minimum(it + 1, history).astype(jnp.int32),
    )
    return GmresResult(x=x, residual=beta, restarts=it, converged=converged,
                       inner_steps=it * m,
                       done=converged | (it >= max_restarts),
                       diagnostics=diags)
