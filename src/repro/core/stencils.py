"""Classic sparse GMRES test problems as structured operators.

The paper benchmarks dense random systems only; the workloads Krylov
methods were built for are discretized PDEs — Poisson and
convection-diffusion on regular grids — whose matrices are five/seven-point
stencils: a handful of diagonals, O(n) nonzeros.  These constructors build
them directly in the band layout ``core.operators.BandedOperator`` uses
(no dense intermediate, so a 1024x1024 grid — a 10^6-row system — costs
5 band vectors, not a 10^12-entry matrix).

Conventions (unit grid spacing, Dirichlet boundaries):

  poisson_2d / poisson_3d     -Laplace, SPD: 4 (resp. 6) on the main
                              diagonal, -1 on each neighbor coupling.
  convection_diffusion_2d     Poisson plus a central-difference convection
                              term with velocity ``beta = (bx, by)`` —
                              NONSYMMETRIC, the canonical GMRES target.
                              |b| < 2 keeps the mesh Peclet number below
                              the oscillation threshold.

Every constructor takes ``fmt`` to pick the operator class the same system
comes back as — "banded" (native), "ell" (exercises the gather SpMV
kernel), "sell" (sliced ELL; on these near-uniform rows it degenerates to
identity order — the never-worse-than-ELL baseline the bench gate holds
it to), or "dense" (``DenseOperator``; small grids only) — and
``backend`` ("jnp" | "pallas") which is forwarded to the operator.
Grid points are ordered x-fastest: site (ix, iy, iz) is row
``ix + nx * (iy + ny * iz)``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.operators import (BandedOperator, DenseOperator,
                                  SlicedEllOperator)


def _assemble(bands, offsets, fmt: str, backend: str):
    op = BandedOperator(bands, tuple(int(o) for o in offsets), backend)
    if fmt == "banded":
        return op
    if fmt == "ell":
        return op.to_ell()
    if fmt == "sell":
        return SlicedEllOperator.from_ell(op.to_ell())
    if fmt == "dense":
        return DenseOperator(op.todense(), backend)
    raise ValueError(f"unknown fmt {fmt!r}; options: banded, ell, sell, "
                     f"dense")


def poisson_2d(nx: int, ny: int | None = None, *, dtype=jnp.float32,
               fmt: str = "banded", backend: str = "jnp"):
    """2-D Poisson five-point stencil on an nx-by-ny grid (SPD, n = nx*ny)."""
    ny = nx if ny is None else ny
    n = nx * ny
    i = jnp.arange(n)
    one = jnp.ones((n,), dtype)
    west = jnp.where(i % nx != 0, -one, 0)           # couples x[i - 1]
    east = jnp.where(i % nx != nx - 1, -one, 0)      # couples x[i + 1]
    south = jnp.where(i >= nx, -one, 0)              # couples x[i - nx]
    north = jnp.where(i < n - nx, -one, 0)           # couples x[i + nx]
    bands = jnp.stack([south, west, 4 * one, east, north])
    return _assemble(bands, (-nx, -1, 0, 1, nx), fmt, backend)


def poisson_3d(nx: int, ny: int | None = None, nz: int | None = None, *,
               dtype=jnp.float32, fmt: str = "banded", backend: str = "jnp"):
    """3-D Poisson seven-point stencil on nx-by-ny-by-nz (SPD, n = nx*ny*nz)."""
    ny = nx if ny is None else ny
    nz = nx if nz is None else nz
    n = nx * ny * nz
    nxy = nx * ny
    i = jnp.arange(n)
    one = jnp.ones((n,), dtype)
    west = jnp.where(i % nx != 0, -one, 0)
    east = jnp.where(i % nx != nx - 1, -one, 0)
    south = jnp.where((i // nx) % ny != 0, -one, 0)
    north = jnp.where((i // nx) % ny != ny - 1, -one, 0)
    down = jnp.where(i >= nxy, -one, 0)
    up = jnp.where(i < n - nxy, -one, 0)
    bands = jnp.stack([down, south, west, 6 * one, east, north, up])
    return _assemble(bands, (-nxy, -nx, -1, 0, 1, nx, nxy), fmt, backend)


def convection_diffusion_2d(nx: int, ny: int | None = None, *,
                            beta=(0.5, 0.25), dtype=jnp.float32,
                            fmt: str = "banded", backend: str = "jnp"):
    """2-D convection-diffusion five-point stencil (nonsymmetric).

    Central-difference discretization of ``-Laplace(u) + beta . grad(u)``:
    the x-coupling becomes ``-1 +- bx/2`` and the y-coupling ``-1 +- by/2``
    on top of the Poisson diagonal of 4.  ``beta = (0, 0)`` recovers
    ``poisson_2d`` exactly.
    """
    ny = nx if ny is None else ny
    bx, by = (jnp.asarray(b, dtype) / 2 for b in beta)
    n = nx * ny
    i = jnp.arange(n)
    one = jnp.ones((n,), dtype)
    west = jnp.where(i % nx != 0, (-1 - bx) * one, 0)
    east = jnp.where(i % nx != nx - 1, (-1 + bx) * one, 0)
    south = jnp.where(i >= nx, (-1 - by) * one, 0)
    north = jnp.where(i < n - nx, (-1 + by) * one, 0)
    bands = jnp.stack([south, west, 4 * one, east, north])
    return _assemble(bands, (-nx, -1, 0, 1, nx), fmt, backend)
