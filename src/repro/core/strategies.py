"""The paper's package comparison, recast as accelerator-offload strategies.

The paper benchmarks four implementations of the SAME restarted GMRES(m):

  =================  ==========================================================
  paper              this module
  =================  ==========================================================
  pracma::gmres      ``serial_numpy``    — pure host NumPy, single-threaded
                       control flow, MGS (what pracma does).
  gmatrix            ``offload_matvec``  — ONLY the level-2 mat-vec runs on the
                       device (A resident there, as gmatrix's ``gmatrix()``
                       objects are); every call ships v across the boundary
                       and the result back.  Level-1 ops stay on the host,
                       below the device-profitability threshold (Morris 2016:
                       N > 5e5).
  gputools           ``transfer_per_call`` — the mat-vec runs on the device
                       but operands live on the host (gputools semantics):
                       every call pays the FULL H2D transfer of A.  This is
                       why Table 1 shows speedup < 1 at small N.
  gpuR (vcl)         ``device_resident`` — everything device-side and
                       asynchronous.  Our realization is strictly stronger
                       than gpuR's: the WHOLE restarted solve is one XLA
                       program (core.gmres), so there is no per-op dispatch
                       at all, not merely no per-op transfer.
  =================  ==========================================================

  Kernel-backed paths (beyond the paper's strategy space): the
  ``device_resident`` solver's hot loop can execute through the Pallas
  kernel layer instead of XLA-lowered jnp —

    gs="cgs2_fused"                  streaming fused Gram-Schmidt kernel
                                     (kernels/cgs2.py): projection+update
                                     share one grid, h never leaves VMEM.
    gs="fused"                       the ENTIRE Arnoldi step (mat-vec +
                                     both CGS2 passes) as one pallas_call
                                     (kernels/arnoldi_fused.py) with the
                                     basis VMEM-resident.
    DenseOperator(backend="pallas")  every mat-vec through the tiled GEMV /
                                     block multi-RHS GEMM kernel
                                     (kernels/matvec.py); gmres_batched
                                     streams A ONCE for all k RHS.
    device_resident_sstep            the communication-avoiding s-step
                                     cycle (core/sstep.py): s powers per
                                     matrix-powers kernel launch + block
                                     Gram-Schmidt (kernels/block_gs.py) —
                                     constant collective rounds per block
                                     instead of ~4 per Arnoldi step.

  All are compiled on TPU, interpreted on CPU (what CI exercises), and
  degrade to the jnp reference elsewhere (kernels/tuning.kernel_mode).

  Steps vs cost (core/preconditioners.py; docs/preconditioning.md): every
  row above makes a step cheaper — a preconditioner DELETES steps, which
  also deletes the step's collectives.  ``precond=`` composes with every
  strategy; per-step overhead is the price of the restart-count cut:

    precond=None                     baseline: restart count set purely by
                                     κ(A); every Arnoldi step pays its
                                     full collective round(s).
    precond="jacobi"/"neumann"       +O(n) elementwise per step — nearly
                                     free; helps only when the diagonal
                                     carries the conditioning.
    precond="chebyshev" (order s)    +s mat-vecs per step (one fused
                                     matrix-powers-shaped kernel pass, or
                                     s halo exchanges sharded — ZERO extra
                                     psums); cuts Poisson/convection-
                                     diffusion restarts >= 2x at s >= 4.
    precond="banded_ilu0"            O(n*bands^2) one-off setup, two O(n*
                                     bands) triangular sweeps per step
                                     (kernels/trisolve.py); strongest
                                     restart cut on stencils, but sweeps
                                     recur across rows — single-device
                                     only (shard via banded_block_jacobi).
    precond="banded_block_jacobi"    shard-local banded ILU(0): same sweep
                                     cost, no cross-shard recurrence, so
                                     it composes with the halo-exchange
                                     path and keeps one-psum-per-step.

The host solver below is deliberately plain NumPy with Python loops — it is
the measurement baseline, not a strawman: it mirrors pracma::gmres
(MGS + dense Givens LS) operation for operation.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.gmres import gmres, GmresResult
from repro.core.operators import DenseOperator
from repro.core.sstep import gmres_sstep


# --------------------------------------------------------------------------
# Host (NumPy) restarted GMRES, parameterized by the mat-vec callable.
# --------------------------------------------------------------------------
def _host_gmres(matvec: Callable[[np.ndarray], np.ndarray], b, x0, m, tol,
                max_restarts):
    n = b.shape[0]
    dtype = b.dtype
    x = np.array(x0, dtype=dtype, copy=True)
    bnorm = np.linalg.norm(b)
    tol_abs = tol * bnorm if bnorm > 0 else tol
    restarts = 0
    inner = 0

    for restarts in range(1, max_restarts + 1):
        r = b - matvec(x)
        beta = np.linalg.norm(r)
        if beta <= tol_abs:
            restarts -= 1
            break
        v = np.zeros((m + 1, n), dtype=dtype)
        v[0] = r / beta
        h = np.zeros((m + 1, m), dtype=dtype)
        cs = np.ones(m, dtype=dtype)
        sn = np.zeros(m, dtype=dtype)
        g = np.zeros(m + 1, dtype=dtype)
        g[0] = beta
        k = m
        for j in range(m):
            inner += 1
            w = matvec(v[j])
            for i in range(j + 1):            # MGS — pracma's scheme
                h[i, j] = np.dot(v[i], w)
                w = w - h[i, j] * v[i]
            h[j + 1, j] = np.linalg.norm(w)
            if h[j + 1, j] > 1e-30:
                v[j + 1] = w / h[j + 1, j]
            for i in range(j):                 # apply old rotations
                t = cs[i] * h[i, j] + sn[i] * h[i + 1, j]
                h[i + 1, j] = -sn[i] * h[i, j] + cs[i] * h[i + 1, j]
                h[i, j] = t
            denom = np.hypot(h[j, j], h[j + 1, j])
            if denom > 1e-30:
                cs[j], sn[j] = h[j, j] / denom, h[j + 1, j] / denom
            else:
                cs[j], sn[j] = 1.0, 0.0
            h[j, j], h[j + 1, j] = denom, 0.0
            g[j + 1] = -sn[j] * g[j]
            g[j] = cs[j] * g[j]
            if abs(g[j + 1]) <= tol_abs:
                k = j + 1
                break
        y = np.zeros(k, dtype=dtype)
        for i in range(k - 1, -1, -1):         # back-substitution
            y[i] = (g[i] - h[i, i + 1:k] @ y[i + 1:k]) / h[i, i]
        x = x + y @ v[:k]
    r = b - matvec(x)
    beta = float(np.linalg.norm(r))
    return x, beta, restarts, beta <= tol_abs, inner


def serial_numpy(a: np.ndarray, b: np.ndarray, x0=None, *, m=30, tol=1e-5,
                 max_restarts=50):
    """pracma::gmres analogue — everything on the host."""
    a = np.asarray(a)
    b = np.asarray(b)
    x0 = np.zeros_like(b) if x0 is None else np.asarray(x0)
    return _host_gmres(lambda v: a @ v, b, x0, m, tol, max_restarts)


@jax.jit
def _device_gemv(a_dev, v):
    return a_dev @ v


def offload_matvec(a: np.ndarray, b: np.ndarray, x0=None, *, m=30, tol=1e-5,
                   max_restarts=50):
    """gmatrix analogue: A device-resident, per-call v H2D + result D2H."""
    a_dev = jax.device_put(jnp.asarray(a))

    def matvec(v):
        out = _device_gemv(a_dev, jax.device_put(jnp.asarray(v)))
        return np.asarray(out)            # D2H sync — the offload boundary

    b = np.asarray(b)
    x0 = np.zeros_like(b) if x0 is None else np.asarray(x0)
    return _host_gmres(matvec, b, x0, m, tol, max_restarts)


def transfer_per_call(a: np.ndarray, b: np.ndarray, x0=None, *, m=30, tol=1e-5,
                      max_restarts=50):
    """gputools analogue: operands host-resident; EVERY call re-ships A."""
    a_host = np.asarray(a)

    def matvec(v):
        a_dev = jax.device_put(jnp.asarray(a_host))   # the H2D wall
        out = _device_gemv(a_dev, jax.device_put(jnp.asarray(v)))
        return np.asarray(out)

    b = np.asarray(b)
    x0 = np.zeros_like(b) if x0 is None else np.asarray(x0)
    return _host_gmres(matvec, b, x0, m, tol, max_restarts)


@functools.lru_cache(maxsize=32)
def _resident_solver(m, tol, max_restarts, gs):
    return jax.jit(functools.partial(gmres, m=m, tol=tol,
                                     max_restarts=max_restarts, gs=gs))


def device_resident(a, b, x0=None, *, m=30, tol=1e-5, max_restarts=50,
                    gs="cgs2", backend="jnp") -> GmresResult:
    """gpuR/vcl analogue: one fused XLA program, nothing leaves the device.

    The solver is jit-cached across calls (steady-state timing, matching
    the paper's warm-GPU measurements).  ``gs="fused"``/``"cgs2_fused"``
    and ``backend="pallas"`` run the hot loop through the Pallas kernel
    layer (see the kernel-backed paths note in the module docstring).
    """
    b = jnp.asarray(b)
    op = DenseOperator(jnp.asarray(a), backend=backend)
    return _resident_solver(m, tol, max_restarts, gs)(op, b, x0)


@functools.lru_cache(maxsize=32)
def _resident_sstep_solver(s, blocks, tol, max_restarts):
    return jax.jit(functools.partial(gmres_sstep, s=s, blocks=blocks,
                                     tol=tol, max_restarts=max_restarts))


def device_resident_sstep(a, b, x0=None, *, m=30, tol=1e-5, max_restarts=50,
                          s=4, backend="jnp") -> GmresResult:
    """Communication-avoiding s-step GMRES, device-resident.

    Beyond the paper's strategy space: the restart length is quantized to
    ``s * (m // s)`` blocks and the whole cycle runs the s-step block
    algebra — on kernel-capable backends through the matrix-powers and
    block Gram-Schmidt Pallas kernels (see core/sstep.py).  Comparable to
    ``device_resident`` at the same effective m on well-conditioned
    systems; the monomial-basis caveat applies (practical s is 2..8).
    """
    b = jnp.asarray(b)
    op = DenseOperator(jnp.asarray(a), backend=backend)
    blocks = max(m // s, 1)
    return _resident_sstep_solver(s, blocks, tol, max_restarts)(op, b, x0)


STRATEGIES = {
    "serial_numpy": serial_numpy,
    "offload_matvec": offload_matvec,
    "transfer_per_call": transfer_per_call,
    "device_resident": device_resident,
    "device_resident_sstep": device_resident_sstep,
}
