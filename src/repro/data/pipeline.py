"""Deterministic, host-sharded synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host) — the property that
makes elastic restarts exact: after a failure the surviving hosts reshard
the SAME token stream at the SAME step with a different host count and no
sample is lost or duplicated (tests/test_data.py proves it).

A real deployment swaps `_tokens_for_slots` for a tokenized corpus reader
with identical slot semantics; everything above (sharding math, packing,
prefetch) is production-shaped.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Zipfian token stream with EOS-delimited documents + packing."""

    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 num_hosts: int = 1, host_id: int = 0, seed: int = 0,
                 eos_id: int = 1, zipf_a: float = 1.2):
        assert global_batch % num_hosts == 0, (global_batch, num_hosts)
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.num_hosts = num_hosts
        self.host_id = host_id
        self.seed = seed
        self.eos = eos_id
        self.zipf_a = zipf_a

    # -- deterministic slot -> tokens -------------------------------------
    def _tokens_for_slots(self, step: int, slots: np.ndarray) -> np.ndarray:
        """slots: (local_batch,) GLOBAL sample indices for this step."""
        out = np.empty((len(slots), self.seq + 1), np.int32)
        for i, slot in enumerate(slots):
            rng = np.random.Generator(np.random.Philox(
                key=self.seed, counter=[step, int(slot), 0, 0]))
            # zipf-ish distribution clipped to vocab, 2.. (0=pad, 1=eos)
            toks = rng.zipf(self.zipf_a, size=self.seq + 1)
            toks = (toks % (self.vocab - 2)) + 2
            # sprinkle document boundaries (packing)
            doc_lens = rng.geometric(1.0 / 512.0, size=8)
            pos = np.cumsum(doc_lens)
            pos = pos[pos < self.seq]
            toks[pos] = self.eos
            out[i] = toks
        return out

    def batch(self, step: int) -> dict:
        """Local shard of the global batch at ``step`` (numpy, host-side)."""
        base = np.arange(self.local_batch, dtype=np.int64)
        slots = base * self.num_hosts + self.host_id   # strided global slots
        toks = self._tokens_for_slots(step, slots)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
            "mask": (toks[:, 1:] != 0).astype(np.float32),
        }

    def global_batch_at(self, step: int) -> dict:
        """All-host batch (for single-process dry-runs and tests)."""
        shards = []
        for h in range(self.num_hosts):
            other = SyntheticLM(
                vocab_size=self.vocab, seq_len=self.seq,
                global_batch=self.global_batch, num_hosts=self.num_hosts,
                host_id=h, seed=self.seed, eos_id=self.eos,
                zipf_a=self.zipf_a)
            shards.append(other.batch(step))
        # interleave back to global order (slot = b * H + h)
        out = {}
        for k in shards[0]:
            stacked = np.stack([s[k] for s in shards], axis=1)
            out[k] = stacked.reshape(self.global_batch,
                                     *shards[0][k].shape[1:])
        return out


class Prefetcher:
    """Background-thread prefetch (double buffering off the host loop)."""

    def __init__(self, pipeline: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.pipeline = pipeline
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.pipeline.batch(step)
            self.q.put((step, batch))
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
