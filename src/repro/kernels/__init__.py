"""Pallas TPU kernels for the perf-critical compute layers.

  matvec.py        tiled dense GEMV + block multi-RHS GEMM (one A stream)
  spmv.py          sparse mat-vec: ELL gather kernel + banded/stencil
                   kernel (operand VMEM-resident, bands/values streamed)
                   + row-sharded halo variants (ppermute halo_exchange
                   outside, halo-padded local shard resident inside)
  cgs2.py          fused Gram-Schmidt projection (Arnoldi orthogonalization)
                   + the split-phase project/update pair the row-sharded
                   solve runs with the h psum between them
  arnoldi_fused.py ONE-pallas_call Arnoldi step: mat-vec + CGS2, basis
                   VMEM-resident, w/h never round-trip to HBM
  matrix_powers.py s-step matrix powers: all s Krylov directions in ONE
                   launch (banded A resident; dense streamed once/power)
                   + the communication-avoiding row-sharded banded variant
                   (one s*halo exchange, deferred normalization, one psum)
  block_gs.py      block Gram-Schmidt: fused CGS2+CholQR pass for the
                   s-step cycle (+ its split-phase sharded pair) and
                   batched per-lane CGS2 for gmres_batched
  tuning.py        VMEM block-size autotuner + backend dispatch policy
                   (+ the shard_context that makes dispatch axis-aware)
  attention.py     blockwise flash attention w/ GQA + sliding window
  ssd.py           Mamba2 SSD chunk scan, state carried in VMEM (zamba2 lever)
  gated_norm.py    fused SiLU-gate + RMSNorm (the SSD elementwise floor)
  ref.py           pure-jnp oracles (ground truth for the allclose sweeps)
  ops.py           mode dispatch (ref | pallas | interpret)

These are wired into the solver: ``gmres(gs="fused"|"cgs2_fused")`` and the
``backend="pallas"`` operators (``DenseOperator``, ``SparseOperator``,
``BandedOperator``) execute through this layer — compiled on TPU, interpret
mode on CPU, jnp reference elsewhere; see ``tuning.kernel_mode``.
"""
from repro.kernels import ops, ref, tuning
from repro.kernels.arnoldi_fused import arnoldi_step as arnoldi_step_fused
from repro.kernels.attention import attention as flash_attention
from repro.kernels.block_gs import (batched_cgs2, block_gs_pass,
                                    block_gs_pass_ref, block_gs_pass_sharded,
                                    block_gs_project, block_gs_update)
from repro.kernels.cgs2 import (cgs2 as cgs2_fused, cgs2_split,
                                gs_project as gs_project_fused,
                                gs_project_partial, gs_update)
from repro.kernels.gated_norm import gated_rmsnorm, gated_rmsnorm_ref
from repro.kernels.matrix_powers import (banded_powers, banded_powers_halo,
                                         dense_powers, matrix_powers_ref)
from repro.kernels.matvec import block_matvec, matvec as matvec_tiled
from repro.kernels.spmv import (banded_matvec, banded_matvec_halo,
                                banded_matvec_halo_ref, banded_matvec_ref,
                                ell_matvec, ell_matvec_halo, ell_matvec_ref,
                                halo_exchange)
from repro.kernels.ssd import ssd_scan, ssd_scan_ref

__all__ = [
    "ops", "ref", "tuning", "flash_attention", "cgs2_fused", "cgs2_split",
    "gs_project_fused", "gs_project_partial", "gs_update", "matvec_tiled",
    "block_matvec", "ell_matvec", "ell_matvec_halo", "ell_matvec_ref",
    "banded_matvec", "banded_matvec_halo", "banded_matvec_halo_ref",
    "banded_matvec_ref", "halo_exchange", "arnoldi_step_fused",
    "banded_powers", "banded_powers_halo", "dense_powers",
    "matrix_powers_ref", "block_gs_pass", "block_gs_pass_ref",
    "block_gs_pass_sharded", "block_gs_project", "block_gs_update",
    "batched_cgs2", "ssd_scan", "ssd_scan_ref", "gated_rmsnorm",
    "gated_rmsnorm_ref",
]
