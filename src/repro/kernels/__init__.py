"""Pallas TPU kernels for the perf-critical compute layers.

  matvec.py     tiled dense GEMV — the paper's offloaded hot spot
  cgs2.py       fused Gram-Schmidt projection (Arnoldi orthogonalization)
  attention.py  blockwise flash attention w/ GQA + sliding window
  ssd.py        Mamba2 SSD chunk scan, state carried in VMEM (zamba2 lever)
  gated_norm.py fused SiLU-gate + RMSNorm (the SSD elementwise floor)
  ref.py        pure-jnp oracles (ground truth for the allclose sweeps)
  ops.py        mode dispatch (ref | pallas | interpret)
"""
from repro.kernels import ops, ref
from repro.kernels.attention import attention as flash_attention
from repro.kernels.cgs2 import cgs2 as cgs2_fused, gs_project as gs_project_fused
from repro.kernels.gated_norm import gated_rmsnorm, gated_rmsnorm_ref
from repro.kernels.matvec import matvec as matvec_tiled
from repro.kernels.ssd import ssd_scan, ssd_scan_ref

__all__ = [
    "ops", "ref", "flash_attention", "cgs2_fused", "gs_project_fused",
    "matvec_tiled", "ssd_scan", "ssd_scan_ref", "gated_rmsnorm",
    "gated_rmsnorm_ref",
]
