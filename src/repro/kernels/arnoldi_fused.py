"""Pallas TPU kernel: one FUSED Arnoldi step (mat-vec + CGS2) per launch.

An Arnoldi step is the whole hot loop of GMRES (Ioannidis et al. 1906.04051
measure mat-vec + orthogonalization at >90% of parallel GMRES wall-time):

    w  = A @ v_j                     level-2, streams A          (matvec.py)
    h  = mask * (V @ w)   } x2       level-2, streams V           (cgs2.py)
    w' = w - h @ V        } (CGS2)

Run as separate kernels, ``w`` is written to HBM by the mat-vec and
re-read (twice) by each Gram-Schmidt pass, and ``h`` round-trips between
the projection and the update.  This kernel runs the ENTIRE step in one
``pallas_call`` with a two-phase grid:

    phase 0 — grid (nbi, nbj): w[i] += A[i,j] @ v_j[j].  The f32 ``w``
              accumulator is an output block with a CONSTANT index map, so
              it lives in VMEM for the whole kernel and is flushed to HBM
              exactly once, at the end.
    phase 1 — one grid step: both CGS2 passes against the basis V held
              ENTIRELY in VMEM (a (m+1, n) f32 basis is ~m*n*4 bytes —
              128 KiB per 1k of n at m=30 — far under the ~16 MiB core
              budget for every problem the tuner admits).  ``h`` and the
              intermediate ``w'`` never exist in HBM at all.

HBM traffic per step: A once, V once, v_j once in; h + w'' once out.  The
unfused kernel pair streams V four times and round-trips w three times —
``benchmarks/kernel_bench.py`` carries the model.  A streams in whatever
dtype it arrives in and upcasts in-register: the solver exploits this for
``compute_dtype=bf16`` by downcasting the padded A ONCE per solve
(core/gmres.py), halving the dominant HBM term while the dot_generals
still accumulate at f32/f64.

Feasibility (V must fit in VMEM) is decided by ``tuning.fused_step_fits``;
``core/gmres.py`` falls back to the streaming cgs2 kernel, then to the jnp
reference, when it doesn't hold.  The kernel is single-shard by
construction — the distributed solver keeps its psum boundary outside and
uses the unfused path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning


def _dot(a, b, dims, acc):
    return jax.lax.dot_general(a, b, dimension_numbers=(dims, ((), ())),
                               preferred_element_type=acc)


def _fused_kernel(a_ref, vj_ref, vb_ref, mask_ref, h_ref, w_ref, *, bm, nbi):
    i = pl.program_id(0)
    j = pl.program_id(1)
    acc = w_ref.dtype  # f32 accumulation; f64 for x64 solves

    @pl.when((i == 0) & (j == 0))
    def _init():
        w_ref[...] = jnp.zeros_like(w_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(i < nbi)
    def _matvec():
        # (bm, bn) @ (bn, 1) -> (bm, 1) partial of w, accumulated into the
        # VMEM-resident slice of the full w buffer.
        w_ref[pl.ds(i * bm, bm), :] += _dot(a_ref[...], vj_ref[...],
                                            (((1,), (0,))), acc)

    @pl.when((i == nbi) & (j == 0))
    def _orthogonalize():
        # Both CGS2 passes on the VMEM-resident basis; pure MXU work, no
        # HBM traffic.  The basis is upcast in-register so bf16 storage
        # still accumulates in full precision.
        v = vb_ref[...].astype(acc)               # (m1, n)
        mask = mask_ref[...]                      # (m1, 1)
        w = w_ref[...]                            # (n, 1) acc dtype
        h1 = mask * _dot(v, w, (((1,), (0,))), acc)    # project
        w1 = w - _dot(v, h1, (((0,), (0,))), acc)      # update: w - V^T h1
        h2 = mask * _dot(v, w1, (((1,), (0,))), acc)   # reorthogonalize
        w2 = w1 - _dot(v, h2, (((0,), (0,))), acc)
        h_ref[...] = h1 + h2
        w_ref[...] = w2                           # overwrite the accumulator


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def arnoldi_step(a: jax.Array, v_basis: jax.Array, j, *,
                 block: int | None = None, interpret: bool = False):
    """One fused Arnoldi step: ``w'' , h = cgs2(V, A @ V[j])``.

    a: (n, n) in its storage dtype; v_basis: (m+1, n) row-major basis
    (rows > j are zero); j: traced step index.  Returns
    ``(h, w)`` with h (m+1,) f32 (entries > j zero) and w (n,) f32, the
    UNNORMALIZED reorthogonalized vector — normalization (and the h[j+1]
    breakdown probe) stay outside with the caller, where the distributed
    psum boundary also lives.
    """
    n = a.shape[0]
    m1 = v_basis.shape[0]
    if block is None:
        block = tuning.choose_fused_block(n, a.dtype)
    b = min(block, tuning._round_up(n, tuning.LANE))
    n_pad = tuning._round_up(n, b)
    m1_pad = tuning._round_up(m1, tuning.sublane(v_basis.dtype))

    vj = v_basis[j].astype(a.dtype)
    # mask[i] = 1 for valid basis rows i <= j (padded rows stay masked)
    mask = ((jnp.arange(m1_pad) <= j) & (jnp.arange(m1_pad) < m1)
            ).astype(jnp.float32)

    if n_pad != n or m1_pad != m1:
        a = jnp.pad(a, ((0, n_pad - n), (0, n_pad - n)))
        vj = jnp.pad(vj, (0, n_pad - n))
        v_basis = jnp.pad(v_basis, ((0, m1_pad - m1), (0, n_pad - n)))

    nbi = n_pad // b
    # f32 accumulation for f32/bf16 storage; full f64 for x64 solves (the
    # unfused matvec kernel makes the same choice).
    acc_dtype = jnp.promote_types(a.dtype, jnp.float32)
    kernel = functools.partial(_fused_kernel, bm=b, nbi=nbi)
    h, w = pl.pallas_call(
        kernel,
        grid=(nbi + 1, nbi),
        in_specs=[
            # A tiles stream during phase 0 only; the index map parks on
            # the LAST phase-0 block afterwards so phase 1 triggers no A
            # traffic (parking anywhere else would re-fetch one tile).
            pl.BlockSpec((b, b), lambda i, j: (jnp.minimum(i, nbi - 1),
                                               jnp.where(i < nbi, j,
                                                         nbi - 1))),
            pl.BlockSpec((b, 1), lambda i, j: (jnp.where(i < nbi, j, 0), 0)),
            # The whole basis is ONE block: fetched once, VMEM-resident.
            pl.BlockSpec((m1_pad, n_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((m1_pad, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m1_pad, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((n_pad, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m1_pad, 1), acc_dtype),
            jax.ShapeDtypeStruct((n_pad, 1), acc_dtype),
        ],
        interpret=interpret,
        name="gmres_arnoldi_fused",
    )(a, vj[:, None], v_basis, mask[:, None].astype(acc_dtype))
    return h[:m1, 0], w[:n, 0]


def arnoldi_step_ref(a: jax.Array, v_basis: jax.Array, j):
    """jnp oracle for the fused kernel (matvec + masked CGS2, unnormalized)."""
    from repro.kernels import ref
    m1 = v_basis.shape[0]
    mask = (jnp.arange(m1) <= j).astype(jnp.float32)
    w = ref.matvec(a.astype(jnp.float32), v_basis[j].astype(jnp.float32))
    return ref.cgs2(v_basis.astype(jnp.float32), w, mask)
