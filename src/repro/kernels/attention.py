"""Pallas TPU kernel: blockwise (flash) attention with GQA + sliding window.

The model stack's compute hot spot.  Online-softmax blockwise attention
(Dao 2022 adapted to TPU): for each query tile, stream key/value tiles
HBM->VMEM, maintain running max ``m``, normalizer ``l`` and accumulator
``acc`` in VMEM scratch, rescaling on the fly.  Never materializes the
(sq, skv) score matrix — the whole point on a 16 MiB-VMEM chip at 32k
context.

TPU adaptation vs. the CUDA original:
  - tiles are MXU-aligned (bq, bk multiples of 128 on the lane dim);
  - no warp-level reductions — the VPU reduces across lanes natively;
  - causal + sliding-window out-of-horizon tiles are skipped with
    @pl.when block-level guards, the TPU analogue of CUDA's per-CTA early
    return (the DMA still issues; a grid-pruning variant is a §Perf item).

GQA: query head h reads kv head h // (hq // hkv) — done in the index maps,
so no K/V replication ever hits HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, q_offset, bq, bk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Query tile qi covers absolute positions [q_offset + qi*bq, ... + bq).
    q_lo = q_offset + qi * bq
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    need = True
    if causal:
        need = need & (k_lo <= q_hi)
    if window is not None:
        need = need & (k_hi > q_lo - window)

    @pl.when(need)
    def _block():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (bq, bk)
        correction = jnp.exp(m_prev - m_new)       # (bq, 1)
        l_ref[...] = correction * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        # Fully-masked rows (front-padded queries) have l == 0; guard the
        # divide — those rows are sliced off by the wrapper anyway.
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"),
)
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, block_q: int = 128,
              block_k: int = 128, interpret: bool = False):
    """Flash attention.  q: (b, hq, sq, d), k/v: (b, hkv, skv, d).

    Queries are aligned at the END of the key axis (prefill: sq == skv;
    decode: sq < skv).  GQA via hq % hkv == 0.  Matches ref.attention.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sqp = (sq + bq - 1) // bq * bq
    skvp = (skv + bk - 1) // bk * bk

    # Front-pad queries (their positions fall before the context start and
    # mask to zero rows), back-pad keys (their positions fall beyond every
    # real query's causal horizon).
    qp = jnp.pad(q, ((0, 0), (0, 0), (sqp - sq, 0), (0, 0))) if sqp != sq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skvp - skv), (0, 0))) if skvp != skv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skvp - skv), (0, 0))) if skvp != skv else v
    if not causal and (sqp != sq or skvp != skv):
        raise NotImplementedError("non-causal attention needs tile-aligned shapes")

    # Absolute position of the first (possibly padded) query row.
    q_offset = (skv - sq) - (sqp - sq)

    qf = qp.reshape(b * hq, sqp, d)
    kf = kp.reshape(b * hkv, skvp, d)
    vf = vp.reshape(b * hkv, skvp, d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sqp // bq, skvp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    out = out.reshape(b, hq, sqp, d)
    return out[:, :, sqp - sq:, :]
