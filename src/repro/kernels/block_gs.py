"""Pallas TPU kernels: one-pass BLOCK Gram-Schmidt (CGS2 + CholQR support).

Two block orthogonalization workloads share the same structural problem:
the basis V is the big operand, and the jnp reference streams it from HBM
once per level-2 product —

s-step GMRES (core/sstep.py) orthogonalizes an (s, n) power block W
against the (m1, n) basis with block-CGS2 + CholQR.  Per CGS2 pass the
reference makes TWO V streams (projection ``C = V W^T``, update
``W' = W - C^T V``) and the CholQR that follows re-streams W' for the
Gram matrix and again for the triangular solve: 4 passes over V plus
three W round-trips per block step.

``gmres_batched`` orthogonalizes k lanes, each against its OWN (m1, n)
basis; the vmapped jnp CGS2 streams every lane's basis four times per
Arnoldi step (2 passes x projection + update).

Both kernels here hold the basis block ENTIRELY in VMEM for the duration
of one grid step — the same residency bet ``arnoldi_fused`` makes, gated
by ``tuning.block_gs_fits`` — so V is read from HBM exactly once per pass
and the intermediates never leave the chip:

``block_gs_pass`` — one fused s-step pass.  Inputs V, W, a small (s, s)
  transform T and the row mask; ONE grid step computes

      Q   = T @ W                (CholQR back-substitution of the PREVIOUS
                                  pass, fused into this one's stream)
      C   = mask * (V Q^T)       block projection
      W'  = Q - C^T V            block update
      G   = W' W'^T              Gram matrix for the NEXT CholQR

  in-register.  The (s, s) Cholesky between passes is replicated
  collective-boundary algebra and stays OUTSIDE with the caller (exactly
  like the norm in ``arnoldi.finalize``): pass 1 runs with T = I, the
  caller Cholesky-factors G, and pass 2 receives T = inv(R1^T).  Per
  block step V is streamed twice (once per pass) instead of four times,
  and the 3 W round-trips disappear — the ``block_gs_*`` rows in
  benchmarks/kernel_bench.py model the ratio at ~0.48.

``batched_cgs2`` — the (k, m1, n) Gram-Schmidt for ``gmres_batched``.
  Grid (k,): each step holds ONE lane's basis in VMEM and runs BOTH CGS2
  passes against it (no CholQR — each lane orthogonalizes a single
  vector; normalization stays outside, at the psum boundary).  Each
  lane's V is streamed once per Arnoldi step instead of four times.

Both accumulate in f32 (f64 under x64) and upcast a bf16-stored basis
in-register, matching the other kernels in this package.

``block_gs_pass_ref`` is the psum-safe jnp fallback: with ``axis_name``
set, the C and G reductions complete across the row-sharded mesh — the
collective boundaries sit exactly where the kernel's outputs do, which is
why the sharded solve can fall back with identical semantics.

ROW-SHARDED kernel path (PR 5): the fused ``block_gs_pass`` cannot run
per-shard because the projection C must psum across shards BEFORE the
update consumes it.  ``block_gs_project`` / ``block_gs_update`` are the
same arithmetic split at exactly that boundary (the split-phase shape
``kernels/cgs2.py`` uses for the standard cycle):

    project kernel:  Q = T W;  C_partial = mask * (V_local Q^T)
    psum(C)          OUTSIDE, at the shard_map level
    update kernel:   W' = Q - C^T V_local;  G_partial = W' W'^T
    psum(G)          OUTSIDE — feeds the replicated CholQR

``block_gs_pass_sharded`` strings them together; per shard V streams once
per phase (twice per pass — the jnp reference's count) but the CholQR
Gram accumulates in-register with the update and W never round-trips
within a phase, and above all the sharded s-step cycle stays on the
kernel path instead of bailing to the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels import tuning


def _dot(a, b, dims, acc):
    return lax.dot_general(a, b, dimension_numbers=(dims, ((), ())),
                           preferred_element_type=acc)


# --------------------------------------------------------------------------
# s-step block pass: Q = T W;  C = mask * (V Q^T);  W' = Q - C^T V;  G = W'W'^T
# --------------------------------------------------------------------------
def _block_gs_kernel(v_ref, w_ref, t_ref, mask_ref, c_ref, wout_ref, g_ref):
    acc = g_ref.dtype
    v = v_ref[...].astype(acc)                        # (m1p, np) upcast
    q = _dot(t_ref[...], w_ref[...], ((1,), (0,)), acc)      # (sp, np)
    c = mask_ref[...] * _dot(v, q, ((1,), (1,)), acc)        # (m1p, sp)
    w2 = q - _dot(c, v, ((0,), (0,)), acc)                   # (sp, np)
    g = _dot(w2, w2, ((1,), (1,)), acc)                      # (sp, sp)
    c_ref[...] = c
    wout_ref[...] = w2
    g_ref[...] = g


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gs_pass(v: jax.Array, w: jax.Array, tin: jax.Array,
                  mask: jax.Array, *, interpret: bool = False):
    """One fused block-GS pass.  v: (m1, n); w: (s, n); tin: (s, s);
    mask: (m1,).  Returns ``(c, w', g)`` — see the module docstring."""
    m1, n = v.shape
    s = w.shape[0]
    if w.shape[1] != n:
        raise TypeError(f"block_gs_pass: v {v.shape} and w {w.shape} must "
                        f"share the vector length")
    if tin.shape != (s, s) or mask.shape != (m1,):
        raise TypeError(f"block_gs_pass: tin {tin.shape} must be ({s}, {s}) "
                        f"and mask {mask.shape} ({m1},)")
    acc = jnp.promote_types(w.dtype, jnp.float32)
    m1p, np_, sp = tuning.choose_block_gs(m1, n, s, jnp.dtype(v.dtype).name)
    v = jnp.pad(v, ((0, m1p - m1), (0, np_ - n)))
    # Padded W rows / T rows are zero, so Q's padded rows — and with them
    # C's padded columns and G's padded block — stay exactly zero.
    w = jnp.pad(w.astype(acc), ((0, sp - s), (0, np_ - n)))
    tin = jnp.pad(tin.astype(acc), ((0, sp - s), (0, sp - s)))
    mask = jnp.pad(mask.astype(acc), (0, m1p - m1))

    c, w2, g = pl.pallas_call(
        _block_gs_kernel,
        grid=(1,),
        in_specs=[
            # Everything is ONE block: V fetched once, VMEM-resident for
            # projection AND update within this pass.
            pl.BlockSpec((m1p, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
            pl.BlockSpec((m1p, 1), lambda _: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m1p, sp), lambda _: (0, 0)),
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m1p, sp), acc),
            jax.ShapeDtypeStruct((sp, np_), acc),
            jax.ShapeDtypeStruct((sp, sp), acc),
        ],
        interpret=interpret,
        name="gmres_block_gs",
    )(v, w, tin, mask[:, None])
    return c[:m1, :s], w2[:s, :n], g[:s, :s]


def block_gs_pass_ref(v: jax.Array, w: jax.Array, tin: jax.Array,
                      mask: jax.Array, axis_name=None):
    """jnp oracle / psum-safe fallback for ``block_gs_pass``.

    The two reductions (C and G) complete over ``axis_name`` when set —
    the collective rounds of the s-step method, one per reduction.
    """
    acc = jnp.promote_types(w.dtype, jnp.float32)
    q = tin.astype(acc) @ w.astype(acc)
    c = v.astype(acc) @ q.T
    if axis_name is not None:
        c = lax.psum(c, axis_name)
    c = c * mask.astype(acc)[:, None]
    w2 = q - c.T @ v.astype(acc)
    g = w2 @ w2.T
    if axis_name is not None:
        g = lax.psum(g, axis_name)
    return c, w2, g


# --------------------------------------------------------------------------
# Split-phase s-step pass for the row-sharded solve
# --------------------------------------------------------------------------
def _block_gs_project_kernel(v_ref, w_ref, t_ref, mask_ref, q_ref, c_ref):
    acc = c_ref.dtype
    v = v_ref[...].astype(acc)                               # (m1p, np)
    q = _dot(t_ref[...], w_ref[...], ((1,), (0,)), acc)      # (sp, np)
    c_ref[...] = mask_ref[...] * _dot(v, q, ((1,), (1,)), acc)
    q_ref[...] = q


def _block_gs_update_kernel(v_ref, q_ref, c_ref, wout_ref, g_ref):
    acc = g_ref.dtype
    v = v_ref[...].astype(acc)
    w2 = q_ref[...] - _dot(c_ref[...], v, ((0,), (0,)), acc)  # (sp, np)
    g_ref[...] = _dot(w2, w2, ((1,), (1,)), acc)              # (sp, sp)
    wout_ref[...] = w2


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gs_project(v: jax.Array, w: jax.Array, tin: jax.Array,
                     mask: jax.Array, *, interpret: bool = False):
    """Projection phase: Q = T W and the PRE-psum C_partial = mask*(V Q^T).

    All arrays are local shards along the vector dim: v (m1, n_local), w
    (s, n_local), tin (s, s), mask (m1,).  Returns ``(q, c_partial)`` with
    q (s, n_local) — the transformed block the update phase consumes — and
    c_partial (m1, s), to be psum-completed by the caller.
    """
    m1, n = v.shape
    s = w.shape[0]
    if w.shape[1] != n:
        raise TypeError(f"block_gs_project: v {v.shape} and w {w.shape} "
                        f"must share the vector length")
    if tin.shape != (s, s) or mask.shape != (m1,):
        raise TypeError(f"block_gs_project: tin {tin.shape} must be "
                        f"({s}, {s}) and mask {mask.shape} ({m1},)")
    acc = jnp.promote_types(w.dtype, jnp.float32)
    m1p, np_, sp = tuning.choose_block_gs(m1, n, s, jnp.dtype(v.dtype).name)
    v = jnp.pad(v, ((0, m1p - m1), (0, np_ - n)))
    w = jnp.pad(w.astype(acc), ((0, sp - s), (0, np_ - n)))
    tin = jnp.pad(tin.astype(acc), ((0, sp - s), (0, sp - s)))
    mask = jnp.pad(mask.astype(acc), (0, m1p - m1))

    q, c = pl.pallas_call(
        _block_gs_project_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m1p, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
            pl.BlockSpec((m1p, 1), lambda _: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((m1p, sp), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sp, np_), acc),
            jax.ShapeDtypeStruct((m1p, sp), acc),
        ],
        interpret=interpret,
        name="gmres_block_gs_project",
    )(v, w, tin, mask[:, None])
    return q[:s, :n], c[:m1, :s]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gs_update(v: jax.Array, q: jax.Array, c: jax.Array, *,
                    interpret: bool = False):
    """Update phase: W' = Q - C^T V and the PRE-psum Gram G_partial = W'W'^T.

    ``c`` is the psum-COMPLETED (global) projection; v/q are local shards.
    Returns ``(w2, g_partial)`` — w2 (s, n_local), g_partial (s, s) to be
    psum-completed for the replicated CholQR outside.
    """
    m1, n = v.shape
    s = q.shape[0]
    if q.shape[1] != n or c.shape != (m1, s):
        raise TypeError(f"block_gs_update: v {v.shape} needs q ({s}, {n}) "
                        f"and c ({m1}, {s}); got {q.shape}, {c.shape}")
    acc = jnp.promote_types(q.dtype, jnp.float32)
    m1p, np_, sp = tuning.choose_block_gs(m1, n, s, jnp.dtype(v.dtype).name)
    v = jnp.pad(v, ((0, m1p - m1), (0, np_ - n)))
    q = jnp.pad(q.astype(acc), ((0, sp - s), (0, np_ - n)))
    c = jnp.pad(c.astype(acc), ((0, m1p - m1), (0, sp - s)))

    w2, g = pl.pallas_call(
        _block_gs_update_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m1p, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((m1p, sp), lambda _: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sp, np_), acc),
            jax.ShapeDtypeStruct((sp, sp), acc),
        ],
        interpret=interpret,
        name="gmres_block_gs_update",
    )(v, q, c)
    return w2[:s, :n], g[:s, :s]


def block_gs_pass_sharded(v: jax.Array, w: jax.Array, tin: jax.Array,
                          mask: jax.Array, axis_name: str, *,
                          interpret: bool = False):
    """One row-sharded block-GS pass: split-phase kernels, psums between.

    Same (c, w', g) contract as ``block_gs_pass`` / ``block_gs_pass_ref``
    with all vector-dim arrays local shards; c and g return GLOBAL
    (psum-completed), matching where ``block_gs_pass_ref`` places its
    collectives — the s-step cycle cannot tell the implementations apart.
    """
    q, c = block_gs_project(v, w, tin, mask, interpret=interpret)
    c = lax.psum(c, axis_name)
    w2, g = block_gs_update(v, q, c, interpret=interpret)
    g = lax.psum(g, axis_name)
    return c, w2, g


# --------------------------------------------------------------------------
# Single-reduce s-step pass (gs="cgs2_pipelined"): ONE psum per pass
# --------------------------------------------------------------------------
def _block_gs_project_gram_kernel(v_ref, w_ref, t_ref, q_ref, c_ref, m_ref):
    acc = c_ref.dtype
    v = v_ref[...].astype(acc)                               # (m1p, np)
    q = _dot(t_ref[...], w_ref[...], ((1,), (0,)), acc)      # (sp, np)
    c_ref[...] = _dot(v, q, ((1,), (1,)), acc)   # UNMASKED C_hat = V Q^T
    m_ref[...] = _dot(q, q, ((1,), (1,)), acc)   # M = Q Q^T
    q_ref[...] = q


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_gs_project_gram(v: jax.Array, w: jax.Array, tin: jax.Array, *,
                          interpret: bool = False):
    """Single-reduce projection phase: Q = T W plus the PRE-psum payload
    halves ``C_hat_partial = V Q^T`` (UNMASKED — the Gram recurrence needs
    the full column) and ``M_partial = Q Q^T``, all from ONE stream of V/W.

    Returns ``(q, c_hat_partial, m_partial)``; the caller stacks the last
    two into one psum payload (``block_gs_pass_single_reduce``).
    """
    m1, n = v.shape
    s = w.shape[0]
    if w.shape[1] != n:
        raise TypeError(f"block_gs_project_gram: v {v.shape} and w "
                        f"{w.shape} must share the vector length")
    if tin.shape != (s, s):
        raise TypeError(f"block_gs_project_gram: tin {tin.shape} must be "
                        f"({s}, {s})")
    acc = jnp.promote_types(w.dtype, jnp.float32)
    m1p, np_, sp = tuning.choose_block_gs(m1, n, s, jnp.dtype(v.dtype).name)
    v = jnp.pad(v, ((0, m1p - m1), (0, np_ - n)))
    w = jnp.pad(w.astype(acc), ((0, sp - s), (0, np_ - n)))
    tin = jnp.pad(tin.astype(acc), ((0, sp - s), (0, sp - s)))

    q, c, mm = pl.pallas_call(
        _block_gs_project_gram_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m1p, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((sp, np_), lambda _: (0, 0)),
            pl.BlockSpec((m1p, sp), lambda _: (0, 0)),
            pl.BlockSpec((sp, sp), lambda _: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((sp, np_), acc),
            jax.ShapeDtypeStruct((m1p, sp), acc),
            jax.ShapeDtypeStruct((sp, sp), acc),
        ],
        interpret=interpret,
        name="gmres_block_gs_project_gram",
    )(v, w, tin)
    return q[:s, :n], c[:m1, :s], mm[:s, :s]


def _sr_recover_block(payload, mask, gram, m1):
    """Replicated recovery of (c, g, c_hat) from the stacked psum payload.

    With Gamma = ``gram`` the maintained basis Gram matrix (~= V V^T), the
    CholQR Gram of the updated block W' = Q - C^T V is exactly

        G = M - C_hat^T C - C^T C_hat + C^T Gamma C

    — no second reduction: the W'W'^T psum of the split-phase pass is
    replaced by collective-free (m x s) algebra.
    """
    c_hat = payload[:m1]
    mm = payload[m1:]
    c = c_hat * mask[:, None]
    gc = gram @ c
    g = mm - c_hat.T @ c - c.T @ c_hat + c.T @ gc
    return c, g, c_hat


def block_gs_pass_single_reduce(v: jax.Array, w: jax.Array, tin: jax.Array,
                                mask: jax.Array, gram: jax.Array,
                                axis_name=None, *, interpret: bool = False):
    """One single-reduce block-GS pass: ONE stacked psum instead of two.

    Same ``(c, w', g)`` contract as ``block_gs_pass_sharded`` plus the raw
    ``c_hat`` column (the caller maintains the basis Gram matrix ``gram``
    with it).  The projection kernel emits the unmasked C_hat = V Q^T and
    M = Q Q^T from one stream; both cross shards as ONE stacked
    (m1 + s, s) payload, and the CholQR Gram is recovered from it against
    ``gram`` (see ``_sr_recover_block``).  The update kernel's own Gram
    output is discarded — its psum is the round being saved.
    """
    m1 = v.shape[0]
    q, c_hat, mm = block_gs_project_gram(v, w, tin, interpret=interpret)
    payload = jnp.concatenate([c_hat, mm], axis=0)
    if axis_name is not None:
        payload = lax.psum(payload, axis_name)           # the ONE collective
    c, g, c_hat = _sr_recover_block(payload, mask.astype(payload.dtype),
                                    gram, m1)
    w2, _ = block_gs_update(v, q, c, interpret=interpret)
    return c, w2, g, c_hat


def block_gs_pass_single_reduce_ref(v: jax.Array, w: jax.Array,
                                    tin: jax.Array, mask: jax.Array,
                                    gram: jax.Array, axis_name=None):
    """jnp oracle / psum-safe fallback for ``block_gs_pass_single_reduce``
    — identical payload stacking and the same single psum placement."""
    acc = jnp.promote_types(w.dtype, jnp.float32)
    m1 = v.shape[0]
    va = v.astype(acc)
    q = tin.astype(acc) @ w.astype(acc)
    payload = jnp.concatenate([va @ q.T, q @ q.T], axis=0)
    if axis_name is not None:
        payload = lax.psum(payload, axis_name)
    c, g, c_hat = _sr_recover_block(payload, mask.astype(acc), gram, m1)
    w2 = q - c.T @ va
    return c, w2, g, c_hat


# --------------------------------------------------------------------------
# batched per-lane CGS2 for gmres_batched
# --------------------------------------------------------------------------
def _batched_cgs2_kernel(v_ref, w_ref, mask_ref, h_ref, wout_ref):
    acc = h_ref.dtype
    v = v_ref[0].astype(acc)                          # (m1p, np) this lane
    w = w_ref[...]                                    # (1, np)
    mask = mask_ref[...]                              # (1, m1p)
    # Both CGS2 passes against the VMEM-resident lane basis; h and the
    # intermediate w' never exist in HBM.
    h1 = mask * _dot(w, v, ((1,), (1,)), acc)         # (1, m1p) project
    w1 = w - _dot(h1, v, ((1,), (0,)), acc)           # (1, np)   update
    h2 = mask * _dot(w1, v, ((1,), (1,)), acc)        # reorthogonalize
    w2 = w1 - _dot(h2, v, ((1,), (0,)), acc)
    h_ref[...] = h1 + h2
    wout_ref[...] = w2


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_cgs2(v: jax.Array, w: jax.Array, mask: jax.Array, *,
                 interpret: bool = False):
    """Per-lane CGS2, one lane's basis VMEM-resident per grid step.

    v: (k, m1, n) per-lane bases; w: (k, n) fresh mat-vec outputs; mask:
    (k, m1) per-lane valid-row masks (lanes sit at different step counts).
    Returns ``(h, w'')`` with h (k, m1) and w'' (k, n) — unnormalized, the
    per-lane norm/breakdown probe stays outside (``arnoldi.finalize``).
    """
    k, m1, n = v.shape
    if w.shape != (k, n) or mask.shape != (k, m1):
        raise TypeError(f"batched_cgs2: v {v.shape} needs w ({k}, {n}) and "
                        f"mask ({k}, {m1}); got {w.shape}, {mask.shape}")
    acc = jnp.promote_types(w.dtype, jnp.float32)
    m1p, np_, _ = tuning.choose_block_gs(m1, n, 1, jnp.dtype(v.dtype).name)
    v = jnp.pad(v, ((0, 0), (0, m1p - m1), (0, np_ - n)))
    w = jnp.pad(w.astype(acc), ((0, 0), (0, np_ - n)))
    mask = jnp.pad(mask.astype(acc), ((0, 0), (0, m1p - m1)))

    h, w2 = pl.pallas_call(
        _batched_cgs2_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, m1p, np_), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, m1p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m1p), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m1p), acc),
            jax.ShapeDtypeStruct((k, np_), acc),
        ],
        interpret=interpret,
        name="gmres_block_gs_batched",
    )(v, w, mask)
    return h[:, :m1], w2[:, :n]
