"""Pallas TPU kernel: fused Gram-Schmidt projection pass.

One Arnoldi orthogonalization pass is two level-2 ops over the SAME basis
matrix V (m1, n):

    h = mask * (V @ w)        (project)
    w' = w - h @ V            (update)

Done naively (the jnp reference) V is streamed from HBM twice per pass.
This kernel fuses both into a single ``pallas_call`` with a two-phase grid:

    phase 0: accumulate h tile-by-tile, h lives in the OUTPUT VMEM block
             (revisited every step -> never leaves VMEM);
    phase 1: re-stream V and write w' = w - h @ V per tile.

V is still read twice from HBM (the dependency h <- all of w is fundamental)
BUT w is read once, h/partials never round-trip to HBM, and there is no
intermediate (m1, n_tiles) partial array — vs. the XLA lowering of the
reference which materializes partial reductions and re-loads h.

For the ROW-SHARDED distributed solver the phase boundary is also where the
psum of h must sit — the projection's partial sums have to cross shards
before the update may run — so the fused two-phase grid above cannot be
used per-shard.  The SPLIT-PHASE pair below is the same arithmetic cut at
that boundary:

    ``gs_project_partial``  one pallas_call: the per-shard h contribution
                            (phase 0 of the fused grid, alone);
    ``lax.psum``            OUTSIDE, at the shard_map level;
    ``gs_update``           one pallas_call: w' = w - h V with the now
                            GLOBAL h (phase 1 of the fused grid, alone).

``cgs2_split`` strings two such pass pairs together with the two psums of
the CGS2 scheme between them — per shard the basis is still streamed
exactly as often as the fused kernel streams it (twice per pass), w/h
round-trips stay off HBM within each phase, and the collective rounds are
the 2-per-pass minimum the scheme admits.  This is what keeps the
row-sharded solve on the kernel path (pre-PR-5 it bailed to the jnp
reference whenever ``axis_name`` was set).

SINGLE-REDUCE payload (PR 6): ``gs_project_norm_partial`` is the project
kernel extended by one row and generalized to a small column block — the
same tile loop projects V against W = [z, v_j] (the fresh mat-vec output
AND the basis row built last step) while accumulating the local column
norms, so the per-shard output is the stacked (m1 + 1, 2) payload

    [ mask * (V_local @ [z, v_j]) ;  z.z, v_j.v_j ]

that the ``gs="cgs2_pipelined"`` scheme completes with ONE psum per
Arnoldi step (vs the split-phase pair's two h psums plus the norm psum).
Column 0 carries the projection coefficients and norm; column 1 is the
MEASURED row j of the basis Gram matrix — it captures the rounding of
the previous step's update and normalization, which a predicted Gram
recurrence cannot (that prediction error compounds ~two digits per step
on fast-converging systems).  The second-pass CGS2 correction and
||w''|| are recovered from the payload by replicated O(m^2) algebra
(core/arnoldi.py ``sr_recover``); the update half reuses ``gs_update``
unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _gs_kernel(v_ref, w_ref, mask_ref, h_ref, wout_ref):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(phase == 0)
    def _project():
        # (m1, bn) @ (bn, 1) -> (m1, 1), f32 accumulate; V is upcast
        # in-register so a bf16-stored basis never quantizes w.
        h_ref[...] += jax.lax.dot_general(
            v_ref[...].astype(h_ref.dtype), w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=h_ref.dtype,
        ) * mask_ref[...]

    @pl.when(phase == 1)
    def _update():
        # w' = w - h^T V : (1, m1) @ (m1, bn) -> (1, bn) -> (bn, 1)
        hv = jax.lax.dot_general(
            h_ref[...] * mask_ref[...], v_ref[...].astype(h_ref.dtype),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=h_ref.dtype,
        )  # (1, bn)
        wout_ref[...] = w_ref[...] - hv.T.astype(wout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gs_project(v: jax.Array, w: jax.Array, mask: jax.Array, *,
               block_n: int = 1024, interpret: bool = False):
    """Fused h = mask*(V@w); w' = w - h@V.  v: (m1, n), w: (n,), mask: (m1,)."""
    m1, n = v.shape
    bn = min(block_n, n)
    if n % bn:
        np_ = (n + bn - 1) // bn * bn
        h, wout = gs_project(
            jnp.pad(v, ((0, 0), (0, np_ - n))), jnp.pad(w, (0, np_ - n)),
            mask, block_n=bn, interpret=interpret)
        return h, wout[:n]

    # w streams in f32 (it is the fresh mat-vec output); only the basis V is
    # read in its storage dtype — bf16 V halves its HBM stream while every
    # product still accumulates in f32.
    acc_dtype = jnp.promote_types(w.dtype, jnp.float32)
    h, wout = pl.pallas_call(
        _gs_kernel,
        grid=(2, n // bn),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda p, j: (0, j)),
            pl.BlockSpec((bn, 1), lambda p, j: (j, 0)),
            pl.BlockSpec((m1, 1), lambda p, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m1, 1), lambda p, j: (0, 0)),
            pl.BlockSpec((bn, 1), lambda p, j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m1, 1), acc_dtype),
            jax.ShapeDtypeStruct((n, 1), acc_dtype),
        ],
        interpret=interpret,
        name="gmres_gs_fused",
    )(v, w[:, None].astype(acc_dtype), mask[:, None].astype(acc_dtype))
    return h[:, 0], wout[:, 0].astype(w.dtype)


def cgs2(v: jax.Array, w: jax.Array, mask: jax.Array, *,
         block_n: int = 1024, interpret: bool = False):
    """Reorthogonalized (two-pass) fused Gram-Schmidt; returns (h, w'')."""
    h1, w1 = gs_project(v, w, mask, block_n=block_n, interpret=interpret)
    h2, w2 = gs_project(v, w1, mask, block_n=block_n, interpret=interpret)
    return h1 + h2, w2


# --------------------------------------------------------------------------
# Split-phase pair for the row-sharded solve (psum between the phases)
# --------------------------------------------------------------------------
def _project_kernel(v_ref, w_ref, mask_ref, h_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    # (m1, bn) @ (bn, 1) -> (m1, 1): the h accumulator lives in the output
    # VMEM block (revisited every grid step — partials never touch HBM).
    h_ref[...] += jax.lax.dot_general(
        v_ref[...].astype(h_ref.dtype), w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=h_ref.dtype,
    ) * mask_ref[...]


def _update_kernel(v_ref, w_ref, h_ref, wout_ref):
    # w' = w - h^T V per column tile; h arrives already masked AND already
    # psum-completed (global), so the update is pure per-shard work.
    hv = jax.lax.dot_general(
        h_ref[...], v_ref[...].astype(h_ref.dtype),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=h_ref.dtype,
    )  # (1, bn)
    wout_ref[...] = w_ref[...] - hv.T.astype(wout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gs_project_partial(v: jax.Array, w: jax.Array, mask: jax.Array, *,
                       block_n: int = 1024, interpret: bool = False):
    """Per-shard projection half: h_partial = mask * (V_local @ w_local).

    v: (m1, n_local), w: (n_local,), mask: (m1,).  Returns the (m1,)
    PRE-psum contribution — the caller completes it over the mesh axis
    before handing it to ``gs_update``.
    """
    m1, n = v.shape
    bn = min(block_n, n)
    if n % bn:
        np_ = (n + bn - 1) // bn * bn
        return gs_project_partial(
            jnp.pad(v, ((0, 0), (0, np_ - n))), jnp.pad(w, (0, np_ - n)),
            mask, block_n=bn, interpret=interpret)

    acc_dtype = jnp.promote_types(w.dtype, jnp.float32)
    h = pl.pallas_call(
        _project_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda j: (0, j)),
            pl.BlockSpec((bn, 1), lambda j: (j, 0)),
            pl.BlockSpec((m1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m1, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1, 1), acc_dtype),
        interpret=interpret,
        name="gmres_gs_project",
    )(v, w[:, None].astype(acc_dtype), mask[:, None].astype(acc_dtype))
    return h[:, 0]


def _project_norm_kernel(v_ref, w_ref, mask_ref, p_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)

    # Rows 0..m1-1 accumulate mask * (V @ W) for a small column block W;
    # the extra last row accumulates the local column norms — ONE streaming
    # pass over the tile produces the whole single-reduce payload
    # in-register.  The mask broadcasts across columns.
    w = w_ref[...]  # (bn, k), already acc dtype
    h = jax.lax.dot_general(
        v_ref[...].astype(p_ref.dtype), w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=p_ref.dtype,
    ) * mask_ref[...]  # (m1, k)
    nrm = jnp.sum(w * w, axis=0, keepdims=True)  # (1, k)
    p_ref[...] += jnp.concatenate([h, nrm], axis=0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gs_project_norm_partial(v: jax.Array, w: jax.Array, mask: jax.Array, *,
                            block_n: int = 1024, interpret: bool = False):
    """Per-shard single-reduce payload: [mask * (V_local @ W); colnorms(W)].

    v: (m1, n_local), w: (n_local,) or (n_local, k), mask: (m1,).  Returns
    the (m1 + 1,) / (m1 + 1, k) PRE-psum stacked payload — one ``lax.psum``
    of this block at the shard_map level is the ONLY collective a pipelined
    Arnoldi step pays (``core/arnoldi.py::sr_recover`` turns the k=2
    payload [z, v_j] into both CGS2 coefficient sets, the norm and the
    measured Gram row).  Padding contributes zeros to both halves.
    """
    squeeze = w.ndim == 1
    wk = w[:, None] if squeeze else w
    m1, n = v.shape
    k = wk.shape[1]
    bn = min(block_n, n)
    if n % bn:
        np_ = (n + bn - 1) // bn * bn
        p = gs_project_norm_partial(
            jnp.pad(v, ((0, 0), (0, np_ - n))),
            jnp.pad(wk, ((0, np_ - n), (0, 0))),
            mask, block_n=bn, interpret=interpret)
        return p[:, 0] if squeeze else p

    acc_dtype = jnp.promote_types(w.dtype, jnp.float32)
    p = pl.pallas_call(
        _project_norm_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda j: (0, j)),
            pl.BlockSpec((bn, k), lambda j: (j, 0)),
            pl.BlockSpec((m1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m1 + 1, k), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m1 + 1, k), acc_dtype),
        interpret=interpret,
        name="gmres_gs_project_norm",
    )(v, wk.astype(acc_dtype), mask[:, None].astype(acc_dtype))
    return p[:, 0] if squeeze else p


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gs_update(v: jax.Array, w: jax.Array, h: jax.Array, *,
              block_n: int = 1024, interpret: bool = False):
    """Per-shard update half: w' = w - h @ V_local with a GLOBAL h.

    v: (m1, n_local), w: (n_local,), h: (m1,) — already masked and
    psum-completed.  Returns w' (n_local,) in w's dtype.
    """
    m1, n = v.shape
    bn = min(block_n, n)
    if n % bn:
        np_ = (n + bn - 1) // bn * bn
        wout = gs_update(
            jnp.pad(v, ((0, 0), (0, np_ - n))), jnp.pad(w, (0, np_ - n)),
            h, block_n=bn, interpret=interpret)
        return wout[:n]

    acc_dtype = jnp.promote_types(w.dtype, jnp.float32)
    wout = pl.pallas_call(
        _update_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m1, bn), lambda j: (0, j)),
            pl.BlockSpec((bn, 1), lambda j: (j, 0)),
            pl.BlockSpec((m1, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), acc_dtype),
        interpret=interpret,
        name="gmres_gs_update",
    )(v, w[:, None].astype(acc_dtype), h[:, None].astype(acc_dtype))
    return wout[:, 0].astype(w.dtype)


def cgs2_split(v: jax.Array, w: jax.Array, mask: jax.Array, axis_name: str,
               *, block_n: int = 1024, interpret: bool = False):
    """Row-sharded CGS2 through the split-phase kernel pair.

    Two project/psum/update rounds — the collective-round minimum for the
    reorthogonalized scheme — with every level-2 product a per-shard
    ``pallas_call``.  All arrays are LOCAL shards; returns (h, w'') with h
    the GLOBAL Hessenberg column contribution and w'' the local shard of
    the orthogonalized vector.
    """
    h1 = lax.psum(gs_project_partial(v, w, mask, block_n=block_n,
                                     interpret=interpret), axis_name)
    w1 = gs_update(v, w, h1, block_n=block_n, interpret=interpret)
    h2 = lax.psum(gs_project_partial(v, w1, mask, block_n=block_n,
                                     interpret=interpret), axis_name)
    w2 = gs_update(v, w1, h2, block_n=block_n, interpret=interpret)
    return (h1 + h2).astype(w.dtype), w2
