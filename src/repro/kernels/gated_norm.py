"""Pallas TPU kernel: fused SiLU-gate + RMSNorm (the Mamba2 block tail).

The zamba2 chunk-size sweep (EXPERIMENTS.md SSPerf) REFUTED the
(Q,Q)-scores hypothesis and located the SSD memory floor in the
d_inner-wide elementwise chains: ``y * silu(z)`` then RMSNorm is, to XLA's
per-op accounting, four full passes over a (tokens, 2*d_model) activation
(mul+silu, square, mean-reduce, scale) plus their intermediates.

This kernel does the whole tail in ONE HBM pass per operand: a (bt, d)
tile is loaded once, gated, row-reduced and normalized entirely in VMEM.

    out = rmsnorm(y * silu(z)) * w

Tiling: rows = tokens (any blocking), d kept whole per tile (d_inner <=
16k fits VMEM: 256 x 14336 x 4 B = 14.7 MiB for two operands at bt=128 —
choose bt accordingly; default bt=128, f32 in/out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, z_ref, w_ref, o_ref, *, eps):
    y = y_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    g = y * (z * jax.nn.sigmoid(z))                 # y * silu(z)
    ms = jnp.mean(g * g, axis=-1, keepdims=True)
    out = g * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "eps", "interpret"))
def gated_rmsnorm(y: jax.Array, z: jax.Array, w: jax.Array, *,
                  eps: float = 1e-5, block_t: int = 128,
                  interpret: bool = False) -> jax.Array:
    """out = rmsnorm(y * silu(z), w).  y/z: (..., t, d), w: (d,)."""
    shape = y.shape
    d = shape[-1]
    yf = y.reshape(-1, d)
    zf = z.reshape(-1, d)
    t = yf.shape[0]
    bt = min(block_t, t)
    if t % bt:
        pad = (t + bt - 1) // bt * bt - t
        yf = jnp.pad(yf, ((0, pad), (0, 0)))
        zf = jnp.pad(zf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(yf.shape[0] // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(yf.shape, y.dtype),
        interpret=interpret,
        name="gated_rmsnorm",
    )(yf, zf, w[None, :])
    return out[:t].reshape(shape)


def gated_rmsnorm_ref(y, z, w, *, eps: float = 1e-5):
    """Pure-jnp oracle (matches models/ssm.py's unfused tail)."""
    g = (y.astype(jnp.float32)
         * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps)
            * w.astype(jnp.float32)).astype(y.dtype)
