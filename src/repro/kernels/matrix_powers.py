"""Pallas TPU kernels: the s-step matrix-powers computation in ONE launch.

The s-step (communication-avoiding) GMRES cycle (core/sstep.py) opens each
block with s normalized mat-vec powers

    u_0 = v_k;   w = A u_{j-1};  sigma_j = ||w||;  u_j = w / sigma_j

and NO per-step inner products.  Run through the operator layer that is s
separate SpMV/GEMV launches: each power streams A from HBM, writes w back,
re-reads it for the norm, and writes the normalized u — the intermediate
vectors round-trip s times even though every u_j is consumed exactly once,
by the very next power.

These kernels run the WHOLE power sequence in one ``pallas_call``:

``banded_powers`` — banded/stencil operators.  The band stack (nbands, n)
  is tiny next to a dense matrix (5 vectors for the five-point Poisson
  stencil), so it sits ENTIRELY in VMEM together with the operand and the
  (s, n) output block: A is read from HBM exactly ONCE for all s powers
  (s HBM passes collapse to 1) and no u_j ever exists in HBM before the
  final block write.  The grid is (s,) — one step per power — with the
  current operand carried in a halo-padded VMEM scratch between steps, so
  each power is pure VPU work over statically shifted windows (the same
  gather-free structure as ``spmv.banded_matvec``).

``dense_powers`` — explicit dense A.  The (n, n) matrix cannot be
  VMEM-resident, so A streams once PER POWER in MXU-aligned (b, b) tiles
  (grid (s, nbi, nbj), tile index maps ignore the power index) — that
  stream is irreducible for dense A (see core/sstep.py's round-count
  analysis).  What fusion removes is everything else: the w accumulator
  and the current operand live in VMEM scratch across the whole grid, the
  normalization reductions run in-register at each power boundary, and
  only the final (s, n) block + sigmas are written out.

Both kernels accumulate in f32 (f64 under x64) whatever the storage dtype
— bf16 bands/tiles halve the matrix stream without quantizing the power
recurrence — and both bake the breakdown guard ``u = w / max(|w|, guard)``
with ``guard = tiny**0.5`` (the standard solver's normalization guard:
small enough that any representable system scale keeps the recurrence
``A u_{j-1} = sigma_j u_j`` exact, only a true zero block is clamped), so
a collapsed basis (solve converged mid-block) degrades exactly like the
jnp reference.

``banded_powers_halo`` (PR 5) — the ROW-SHARDED banded variant, i.e. the
  classic communication-avoiding matrix-powers kernel (Demmel/Hoemmen
  line, which Chronopoulos' s-step method anticipates): ONE ``ppermute``
  halo exchange of width s*halo brings in every remote operand value the
  whole s-power sequence will touch, the per-shard kernel then computes
  the s UNNORMALIZED powers z_j = A^j u_0 over the shrinking-validity
  halo-padded shard (wrongness creeps inward one halo per power and never
  reaches the center rows), and ONE psum afterwards completes all s
  squared norms at once — from which u_j = z_j/||z_j|| and
  sigma_j = ||z_j||/||z_{j-1}|| are recovered exactly.  Collective
  rounds per block: 2 (one neighbor exchange + one psum) vs the
  reference's s all-gathers + s psums.  The deferred normalization costs
  dynamic range — |z_s| grows like ||A||^s — so the CALLER must pre-scale
  the band stack by theta >= ||A|| and multiply theta back into the
  sigmas (core/sstep.py does exactly this with the pmax-completed
  ||A||_inf row-sum bound, making the path overflow-proof and
  scale-invariant at any system scale; the residual conditioning left is
  the monomial basis's own kappa^s, which bounds practical s at ~8
  regardless of implementation).

``matrix_powers_ref`` is the jnp oracle and the ``kernel_mode() == "ref"``
fallback (also the dense row-sharded path: dense A needs the whole
operand per power, so an all-gather per power is irreducible there): the
per-power norm psums over ``axis_name``.

HBM traffic per s-step block (f32, five-point stencil, modeled in
``benchmarks/kernel_bench.py`` as the ``sstep_powers_*`` rows):

    fused banded:  (nbands + s + 1) * 4n         bands + x in, U out
    s SpMV launches: s * (nbands + 4) * 4n       bands re-streamed + w/u trips

— ratio (nbands + s + 1) / (s * (nbands + 4)) ~= 0.28 at s = 4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning


def _acc_dtype(mat_dtype, x_dtype):
    return jnp.promote_types(jnp.promote_types(mat_dtype, x_dtype),
                             jnp.float32)


# --------------------------------------------------------------------------
# Banded / stencil matrix powers
# --------------------------------------------------------------------------
def _banded_powers_kernel(bands_ref, x_ref, sh_ref, u_ref, sig_ref,
                          pad_ref, *, offsets, halo, eps, shifted):
    p = pl.program_id(0)
    n_pad = u_ref.shape[1]
    acc = sig_ref.dtype

    @pl.when(p == 0)
    def _seed():
        # Zero the halo once; the operand for power 0 is x itself.
        pad_ref[...] = jnp.zeros_like(pad_ref)
        pad_ref[:, pl.ds(halo, n_pad)] = x_ref[...].astype(acc)

    # One banded mat-vec over the VMEM-carried operand: static unroll over
    # the diagonals, each band an elementwise product with a shifted window
    # of the halo-padded current vector.  Padded columns (>= n) carry zero
    # bands, so they contribute nothing to w or the norm.
    w = jnp.zeros((1, n_pad), acc)
    for d, off in enumerate(offsets):
        band = bands_ref[d:d + 1, :].astype(acc)              # (1, n_pad)
        w += band * pad_ref[:, pl.ds(halo + off, n_pad)]
    if shifted:
        # Newton basis: w = (A - shift_j I) u_{j-1} — same one-pass stream,
        # the shift is a per-power scalar from the tiny shifts block.
        sh = pl.load(sh_ref, (pl.ds(0, 1), pl.ds(p, 1)))
        w -= sh * pad_ref[:, pl.ds(halo, n_pad)]

    sigma = jnp.sqrt(jnp.sum(w * w))
    u = w / jnp.maximum(sigma, eps)
    sig_ref[0, p] = sigma
    u_ref[pl.ds(p, 1), :] = u
    pad_ref[:, pl.ds(halo, n_pad)] = u     # operand for the next power


@functools.partial(jax.jit,
                   static_argnames=("offsets", "s", "interpret"))
def banded_powers(bands: jax.Array, x: jax.Array, offsets: tuple, s: int, *,
                  shifts: jax.Array | None = None,
                  interpret: bool = False):
    """All s normalized powers of a banded operator in one launch.

    bands: (nbands, n); offsets: static diagonal shifts (see
    ``spmv.banded_matvec``); x: (n,) starting vector (u_0).  Returns
    ``(u, sigma)`` with u (s, n) — row j-1 is u_j — and sigma (s,), the
    pre-normalization norms.  With ``shifts`` (s,) the recurrence is the
    NEWTON basis ``w = (A - shifts[j] I) u_{j-1}`` (shifts at Chebyshev
    points of the spectral interval keep the basis conditioned far past
    the monomial kappa^s wall — see core/sstep.py); the Hessenberg
    relation becomes ``A u_{j-1} = sigma_j u_j + shifts[j] u_{j-1}``.
    """
    nbands, n = bands.shape
    if len(offsets) != nbands:
        raise TypeError(f"banded_powers: {nbands} bands but {len(offsets)} "
                        f"offsets")
    if x.shape != (n,):
        raise TypeError(f"banded_powers: bands {bands.shape} need x of "
                        f"shape ({n},), got {x.shape}")
    halo = max(abs(int(o)) for o in offsets)
    n_pad = tuning._round_up(n, tuning.LANE)
    acc = _acc_dtype(bands.dtype, x.dtype)
    eps = float(jnp.finfo(acc).tiny) ** 0.5   # breakdown guard, scale-free
    if n_pad != n:
        bands = jnp.pad(bands, ((0, 0), (0, n_pad - n)))
        x = jnp.pad(x, (0, n_pad - n))
    s_pad = tuning._round_up(s, tuning.sublane(acc))
    shifted = shifts is not None
    sh = (jnp.zeros(s, acc) if shifts is None
          else jnp.asarray(shifts, acc).reshape(s))
    sh = jnp.pad(sh, (0, s_pad - s))[None, :]

    u, sig = pl.pallas_call(
        functools.partial(_banded_powers_kernel, offsets=offsets,
                          halo=halo, eps=eps, shifted=shifted),
        grid=(s,),
        in_specs=[
            # Both operands are ONE block each: fetched once, VMEM-resident
            # across all s powers.
            pl.BlockSpec((nbands, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_pad, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, n_pad), acc),
            jax.ShapeDtypeStruct((1, s_pad), acc),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_pad + 2 * halo), acc)],
        interpret=interpret,
        name="gmres_sstep_powers_banded",
    )(bands, x[None, :], sh)
    return u[:s, :n], sig[0, :s]


# --------------------------------------------------------------------------
# Row-sharded banded matrix powers (communication-avoiding)
# --------------------------------------------------------------------------
def _banded_powers_halo_kernel(bands_ref, x_ref, z_ref, nrm_ref, pad_ref, *,
                               offsets, halo, center, ln):
    p = pl.program_id(0)
    w_width = x_ref.shape[1]                 # n_local + 2*s*halo
    acc = nrm_ref.dtype

    @pl.when(p == 0)
    def _seed():
        pad_ref[...] = jnp.zeros_like(pad_ref)
        pad_ref[:, pl.ds(halo, w_width)] = x_ref[...].astype(acc)

    # One UNNORMALIZED banded mat-vec over the whole halo-padded width.
    # Positions closer than p*halo to either edge go stale (their true
    # neighbors were not exchanged) — by construction the center slice
    # stays exact through all s powers (see module docstring).
    w = jnp.zeros((1, w_width), acc)
    for d, off in enumerate(offsets):
        band = bands_ref[d:d + 1, :].astype(acc)
        w += band * pad_ref[:, pl.ds(halo + off, w_width)]

    zc = w[:, center:center + ln]            # this shard's rows of z_{p+1}
    nrm_ref[0, p] = jnp.sum(zc * zc)         # PER-SHARD partial sq-norm
    z_ref[pl.ds(p, 1), :] = zc
    pad_ref[:, pl.ds(halo, w_width)] = w     # raw carry — no division here


@functools.partial(jax.jit, static_argnames=("offsets", "s", "interpret"))
def banded_powers_halo(bands_pad: jax.Array, x_halo: jax.Array,
                       offsets: tuple, s: int, *, interpret: bool = False):
    """All s RAW powers of a row-sharded banded operator in one launch.

    bands_pad: (nbands, n_local + 2*s*halo) — the local band-stack shard
    extended with (s-1)*halo exchanged neighbor columns each side and then
    halo zeros each side (the caller builds this ONCE per solve; bands are
    loop-invariant).  x_halo: (n_local + 2*s*halo,) — ``halo_exchange`` of
    the unit-norm starting vector with width s*halo.  Returns
    ``(z, nrm_partial)``: z (s, n_local) holds the LOCAL rows of the raw
    powers z_j = A^j u_0, and nrm_partial (s,) their per-shard squared
    norms — one psum of nrm_partial recovers every ||z_j||, from which
    u_j = z_j / ||z_j|| and sigma_j = ||z_j|| / ||z_{j-1}|| follow with
    NO collective between powers.
    """
    nbands, w_width = bands_pad.shape
    if len(offsets) != nbands:
        raise TypeError(f"banded_powers_halo: {nbands} bands but "
                        f"{len(offsets)} offsets")
    halo = max(abs(int(o)) for o in offsets)
    ln = w_width - 2 * s * halo
    if ln <= 0:
        raise TypeError(f"banded_powers_halo: padded width {w_width} too "
                        f"small for s={s} powers of halo={halo}")
    if x_halo.shape != (w_width,):
        raise TypeError(f"banded_powers_halo: bands_pad {bands_pad.shape} "
                        f"needs x_halo of shape ({w_width},), got "
                        f"{x_halo.shape}")
    acc = _acc_dtype(bands_pad.dtype, x_halo.dtype)
    s_pad = tuning._round_up(s, tuning.sublane(acc))

    z, nrm = pl.pallas_call(
        functools.partial(_banded_powers_halo_kernel, offsets=offsets,
                          halo=halo, center=s * halo, ln=ln),
        grid=(s,),
        in_specs=[
            # Band stack and operand are ONE VMEM-resident block each; per
            # shard that is 1/P of the global residency, which is how the
            # sharded fits-check admits systems the single-device kernel
            # cannot hold.
            pl.BlockSpec((nbands, w_width), lambda p: (0, 0)),
            pl.BlockSpec((1, w_width), lambda p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_pad, ln), lambda p: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, ln), acc),
            jax.ShapeDtypeStruct((1, s_pad), acc),
        ],
        scratch_shapes=[pltpu.VMEM((1, w_width + 2 * halo), acc)],
        interpret=interpret,
        name="gmres_sstep_powers_banded_halo",
    )(bands_pad, x_halo[None, :])
    return z[:s, :], nrm[0, :s]


# --------------------------------------------------------------------------
# Dense matrix powers
# --------------------------------------------------------------------------
def _dense_powers_kernel(a_ref, x_ref, u_ref, sig_ref, cur_ref, w_ref, *,
                         bm, s, nb, eps):
    p = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    acc = sig_ref.dtype
    first_tile = (i == 0) & (j == 0)

    def _normalize(power):
        w = w_ref[...]
        sigma = jnp.sqrt(jnp.sum(w * w))
        sig_ref[0, power] = sigma
        u = w / jnp.maximum(sigma, eps)
        u_ref[pl.ds(power, 1), :] = u
        return u

    @pl.when(first_tile & (p == 0))
    def _seed():
        cur_ref[...] = x_ref[...].astype(acc)

    @pl.when(first_tile & (p > 0))
    def _advance():
        # Fused normalization: the finished power's norm and scale run
        # in-register at the power boundary — w never visits HBM.
        cur_ref[...] = _normalize(p - 1)

    @pl.when(first_tile)
    def _reset():
        w_ref[...] = jnp.zeros_like(w_ref)

    # w[i-block] += cur[j-block] @ A[i, j]^T — row-major throughout so the
    # per-tile partial lands directly in the (1, n) accumulator.
    w_ref[:, pl.ds(i * bm, bm)] += jax.lax.dot_general(
        cur_ref[:, pl.ds(j * bm, bm)], a_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc,
    )

    @pl.when((p == s - 1) & (i == nb - 1) & (j == nb - 1))
    def _finish():
        _normalize(s - 1)


@functools.partial(jax.jit, static_argnames=("s", "block", "interpret"))
def dense_powers(a: jax.Array, x: jax.Array, s: int, *,
                 block: int | None = None, interpret: bool = False):
    """All s normalized powers of a dense A in one launch.

    a: (n, n); x: (n,).  A streams once per power (irreducible for dense
    storage); the w accumulator, current operand, and all s normalization
    reductions stay in VMEM.  Returns ``(u, sigma)`` as ``banded_powers``.
    """
    n = a.shape[0]
    if a.shape != (n, n) or x.shape != (n,):
        raise TypeError(f"dense_powers: a {a.shape} must be square and x "
                        f"{x.shape} of length {n}")
    if block is None:
        block = tuning.choose_powers_block(n, jnp.dtype(a.dtype).name, s=s)
    b = min(block, tuning._round_up(n, tuning.LANE))
    n_pad = tuning._round_up(n, b)
    acc = _acc_dtype(a.dtype, x.dtype)
    eps = float(jnp.finfo(acc).tiny) ** 0.5   # breakdown guard, scale-free
    if n_pad != n:
        a = jnp.pad(a, ((0, n_pad - n), (0, n_pad - n)))
        x = jnp.pad(x, (0, n_pad - n))
    nb = n_pad // b
    s_pad = tuning._round_up(s, tuning.sublane(acc))

    u, sig = pl.pallas_call(
        functools.partial(_dense_powers_kernel, bm=b, s=s, nb=nb, eps=eps),
        grid=(s, nb, nb),
        in_specs=[
            # A tiles ignore the power index: the same (i, j) sweep streams
            # the matrix once per power.
            pl.BlockSpec((b, b), lambda p, i, j: (i, j)),
            pl.BlockSpec((1, n_pad), lambda p, i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_pad, n_pad), lambda p, i, j: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p, i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, n_pad), acc),
            jax.ShapeDtypeStruct((1, s_pad), acc),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, n_pad), acc),      # current operand u_{j-1}
            pltpu.VMEM((1, n_pad), acc),      # w accumulator
        ],
        interpret=interpret,
        name="gmres_sstep_powers_dense",
    )(a, x[None, :])
    return u[:s, :n], sig[0, :s]


# --------------------------------------------------------------------------
# ELL matrix powers (general sparsity)
# --------------------------------------------------------------------------
def _ell_powers_kernel(vals_ref, cols_ref, x_ref, sh_ref, u_ref, sig_ref,
                       cur_ref, *, eps, shifted):
    p = pl.program_id(0)
    acc = sig_ref.dtype

    @pl.when(p == 0)
    def _seed():
        cur_ref[...] = x_ref[...].astype(acc)

    # One gather-style SpMV over the VMEM-carried operand (same structure
    # as ``spmv._ell_kernel``, minus the row tiling: values/cols stay whole
    # so the sparse column pattern can reach any operand row).  Padding
    # slots carry value 0 at column 0, contributing nothing.
    g = jnp.take(cur_ref[0, :], cols_ref[...], axis=0).astype(acc)
    w = jnp.sum(vals_ref[...].astype(acc) * g, axis=1)[None, :]
    if shifted:
        sh = pl.load(sh_ref, (pl.ds(0, 1), pl.ds(p, 1)))
        w -= sh * cur_ref[...]

    sigma = jnp.sqrt(jnp.sum(w * w))
    u = w / jnp.maximum(sigma, eps)
    sig_ref[0, p] = sigma
    u_ref[pl.ds(p, 1), :] = u
    cur_ref[...] = u                         # operand for the next power


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def ell_powers(values: jax.Array, cols: jax.Array, x: jax.Array, s: int, *,
               shifts: jax.Array | None = None, interpret: bool = False):
    """All s normalized powers of an ELL-format operator in one launch.

    values/cols: (n, width) as in ``spmv.ell_matvec``; x: (n,).  The
    values+cols pair is fetched ONCE and stays VMEM-resident across all s
    powers (gated by ``tuning.ell_powers_fits``), closing the general-
    sparsity gap in the s-step cycle: previously only banded operators
    took the fused-powers path.  ``shifts`` selects the Newton basis as in
    ``banded_powers``.  Returns ``(u, sigma)``.
    """
    n, width = values.shape
    if cols.shape != (n, width):
        raise TypeError(f"ell_powers: cols {cols.shape} must match values "
                        f"{values.shape}")
    if x.shape != (n,):
        raise TypeError(f"ell_powers: values {values.shape} need x of "
                        f"shape ({n},), got {x.shape}")
    n_pad = tuning._round_up(n, tuning.LANE)
    acc = _acc_dtype(values.dtype, x.dtype)
    eps = float(jnp.finfo(acc).tiny) ** 0.5
    if n_pad != n:
        values = jnp.pad(values, ((0, n_pad - n), (0, 0)))
        cols = jnp.pad(cols, ((0, n_pad - n), (0, 0)))
        x = jnp.pad(x, (0, n_pad - n))
    s_pad = tuning._round_up(s, tuning.sublane(acc))
    shifted = shifts is not None
    sh = (jnp.zeros(s, acc) if shifts is None
          else jnp.asarray(shifts, acc).reshape(s))
    sh = jnp.pad(sh, (0, s_pad - s))[None, :]

    u, sig = pl.pallas_call(
        functools.partial(_ell_powers_kernel, eps=eps, shifted=shifted),
        grid=(s,),
        in_specs=[
            pl.BlockSpec((n_pad, width), lambda p: (0, 0)),
            pl.BlockSpec((n_pad, width), lambda p: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_pad, n_pad), lambda p: (0, 0)),
            pl.BlockSpec((1, s_pad), lambda p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, n_pad), acc),
            jax.ShapeDtypeStruct((1, s_pad), acc),
        ],
        scratch_shapes=[pltpu.VMEM((1, n_pad), acc)],
        interpret=interpret,
        name="gmres_sstep_powers_ell",
    )(values, cols, x[None, :], sh)
    return u[:s, :n], sig[0, :s]


# --------------------------------------------------------------------------
# Fused Chebyshev preconditioner apply
# --------------------------------------------------------------------------
def _banded_cheb_kernel(bands_ref, v_ref, o_ref, zp_ref, *,
                        offsets, halo, theta, delta, rhos):
    acc = o_ref.dtype
    n_pad = o_ref.shape[1]
    zp_ref[...] = jnp.zeros_like(zp_ref)     # zero the halo once
    v = v_ref[...].astype(acc)
    z = v / theta
    z_old = jnp.zeros_like(v)
    # The whole three-term recurrence unrolls STATICALLY — theta/delta/rhos
    # are Python floats baked at trace time — so the band stack is read
    # from HBM exactly once for all `order` mat-vecs and no intermediate z
    # ever exists outside VMEM.
    for rho, rho_old in rhos:
        zp_ref[:, pl.ds(halo, n_pad)] = z
        w = jnp.zeros((1, n_pad), acc)
        for d, off in enumerate(offsets):
            band = bands_ref[d:d + 1, :].astype(acc)
            w += band * zp_ref[:, pl.ds(halo + off, n_pad)]
        z_new = rho * (2.0 / delta * (v - w) + rho_old * (z - z_old)) + z
        z_old, z = z, z_new
    o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("offsets", "theta", "delta",
                                             "rhos", "interpret"))
def banded_cheb_apply(bands: jax.Array, v: jax.Array, offsets: tuple, *,
                      theta: float, delta: float, rhos: tuple,
                      interpret: bool = False) -> jax.Array:
    """z ~= A^{-1} v by the fused Chebyshev recurrence (one launch).

    bands/offsets as in ``spmv.banded_matvec``; theta/delta/rhos from
    ``core/preconditioners.cheb_coeffs`` (static Python floats — the
    spectral interval is estimated once at setup).  This is the kernel
    behind ``ChebyshevPreconditioner`` on single-shard banded operators:
    len(rhos) mat-vecs for ONE HBM pass over the band stack, gated by
    ``tuning.cheb_fits``.
    """
    nbands, n = bands.shape
    if len(offsets) != nbands:
        raise TypeError(f"banded_cheb_apply: {nbands} bands but "
                        f"{len(offsets)} offsets")
    if v.shape != (n,):
        raise TypeError(f"banded_cheb_apply: bands {bands.shape} need v of "
                        f"shape ({n},), got {v.shape}")
    halo = max(abs(int(o)) for o in offsets)
    n_pad = tuning._round_up(n, tuning.LANE)
    acc = _acc_dtype(bands.dtype, v.dtype)
    out_dtype = jnp.promote_types(bands.dtype, v.dtype)
    if n_pad != n:
        bands = jnp.pad(bands, ((0, 0), (0, n_pad - n)))
        v = jnp.pad(v, (0, n_pad - n))

    z = pl.pallas_call(
        functools.partial(_banded_cheb_kernel, offsets=offsets, halo=halo,
                          theta=float(theta), delta=float(delta),
                          rhos=tuple(rhos)),
        in_specs=[
            pl.BlockSpec((nbands, n_pad), lambda: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_pad), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), acc),
        scratch_shapes=[pltpu.VMEM((1, n_pad + 2 * halo), acc)],
        interpret=interpret,
        name="gmres_precond_cheb_fused",
    )(bands, v[None, :])
    return z[0, :n].astype(out_dtype)


# --------------------------------------------------------------------------
# jnp oracle / fallback
# --------------------------------------------------------------------------
def matrix_powers_ref(matvec, x: jax.Array, s: int, eps, axis_name=None,
                      shifts: jax.Array | None = None):
    """s normalized powers via s sequential mat-vecs (the jnp reference).

    ``matvec`` is any operator/callable; under ``axis_name`` the per-power
    norm psums over the mesh axis — the reason the row-sharded s-step solve
    stays on this path (the reduction must cross shards between powers).
    ``shifts`` (s,) selects the Newton basis as in ``banded_powers``.
    """
    from jax import lax

    def power(u, shift):
        w = matvec(u)
        if shift is not None:
            w = w - shift * u
        nrm2 = jnp.vdot(w, w).real
        if axis_name is not None:
            nrm2 = lax.psum(nrm2, axis_name)
        sigma = jnp.sqrt(nrm2)
        u_next = w / jnp.maximum(sigma, jnp.asarray(eps, w.dtype))
        return u_next, (u_next, sigma)

    xs = None if shifts is None else jnp.asarray(shifts).reshape(s)
    _, (u, sigma) = lax.scan(power, x, xs, length=s)
    return u, sigma
