"""Pallas TPU kernel: tiled dense mat-vec / block multi-RHS mat-mat.

The paper ships ``A %*% v`` to the GPU through gmatrix/gputools/gpuR; the
TPU-native version streams A once HBM->VMEM in MXU-aligned (bm, bn) tiles
and keeps the running partial sum for each output tile resident in VMEM
across the reduction dimension of the grid.

Arithmetic intensity of GEMV is ~2 FLOP per 4 bytes (f32) — firmly
memory-bound (roofline: 819 GB/s -> ~0.4 TFLOP/s f32 ceiling per chip), so
the ONLY thing that matters is streaming A at full HBM bandwidth: big
contiguous tiles, no re-reads.  Block defaults (256, 512) give
256*512*4 B = 512 KiB per A tile — comfortably inside the ~16 MiB/core VMEM
with double-buffering headroom; ``kernels.tuning.choose_matvec_blocks``
picks sizes per (shape, dtype) instead of these static defaults.

``block_matvec`` is the multi-RHS form: ``Y = A @ X`` with X of shape
(n, k).  The SAME single stream of A now feeds k GEMV lanes as one GEMM —
a k-fold arithmetic-intensity win over k separate kernel launches (which
is exactly what ``jax.vmap`` of a ``pallas_call`` GEMV degenerates to:
the batch axis becomes an outer grid dimension and A is re-streamed per
lane).  ``core/gmres.py``'s batched solver rides this.

Grid layout: (rows/bm, cols/bn), column index innermost so each output tile
o[i] accumulates over j with A streamed row-block by row-block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bm, bn) @ (bn, k) -> (bm, k): an MXU matmul (k = 1 for plain GEMV is
    # a degenerate N dim); f32 accumulation regardless of input dtype.  A
    # tiles stream in storage dtype and upcast IN-REGISTER when x is wider
    # (bf16-stored A keeps its halved HBM stream without quantizing x).
    o_ref[...] += jax.lax.dot_general(
        a_ref[...].astype(x_ref.dtype), x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def block_matvec(a: jax.Array, x: jax.Array, *, block_m: int = 256,
                 block_n: int = 512, interpret: bool = False) -> jax.Array:
    """Y = A @ X with one shared stream of A.  a: (m, n), x: (n, k)."""
    m, n = a.shape
    if x.shape[0] != n:
        # Pallas pads blocks, so a length mismatch would otherwise read
        # garbage instead of raising the way ``a @ x`` does.
        raise TypeError(f"block_matvec: a {a.shape} @ x {x.shape} — "
                        f"x must have {n} rows")
    k = x.shape[1]
    bm = min(block_m, m)
    bn = min(block_n, n)
    if m % bm or n % bn:
        # Pad to tile multiples; zero columns contribute nothing.
        mp = (m + bm - 1) // bm * bm
        np_ = (n + bn - 1) // bn * bn
        a = jnp.pad(a, ((0, mp - m), (0, np_ - n)))
        x = jnp.pad(x, ((0, np_ - n), (0, 0)))
        return block_matvec(a, x, block_m=bm, block_n=bn,
                            interpret=interpret)[:m]

    # Compute at the promoted dtype (what ``a @ x`` would use): a narrow x
    # is upcast here (a vector — cheap); a narrow A stays narrow in HBM and
    # upcasts per-tile inside the kernel.
    compute_dtype = jnp.promote_types(a.dtype, x.dtype)
    acc_dtype = jnp.promote_types(compute_dtype, jnp.float32)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), acc_dtype),
        interpret=interpret,
        name="gmres_matvec",
    )(a, x.astype(compute_dtype))
    return out.astype(compute_dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def matvec(a: jax.Array, x: jax.Array, *, block_m: int = 256,
           block_n: int = 512, interpret: bool = False) -> jax.Array:
    """y = A @ x with explicit VMEM tiling.  a: (m, n), x: (n,)."""
    return block_matvec(a, x[:, None], block_m=block_m, block_n=block_n,
                        interpret=interpret)[:, 0]
