"""Pallas TPU kernel: tiled dense mat-vec (the paper's offloaded hot spot).

The paper ships ``A %*% v`` to the GPU through gmatrix/gputools/gpuR; the
TPU-native version streams A once HBM->VMEM in MXU-aligned (bm, bn) tiles
and keeps the running partial sum for each output tile resident in VMEM
across the reduction dimension of the grid.

Arithmetic intensity of GEMV is ~2 FLOP per 4 bytes (f32) — firmly
memory-bound (roofline: 819 GB/s -> ~0.4 TFLOP/s f32 ceiling per chip), so
the ONLY thing that matters is streaming A at full HBM bandwidth: big
contiguous tiles, no re-reads.  Block defaults (256, 512) give
256*512*4 B = 512 KiB per A tile — comfortably inside the ~16 MiB/core VMEM
with double-buffering headroom.

Grid layout: (rows/bm, cols/bn), column index innermost so each output tile
o[i] accumulates over j with A streamed row-block by row-block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bm, bn) @ (bn, 1) -> (bm, 1): an MXU matmul with a degenerate N dim;
    # f32 accumulation regardless of input dtype.
    o_ref[...] += jax.lax.dot_general(
        a_ref[...], x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def matvec(a: jax.Array, x: jax.Array, *, block_m: int = 256,
           block_n: int = 512, interpret: bool = False) -> jax.Array:
    """y = A @ x with explicit VMEM tiling.  a: (m, n), x: (n,)."""
    m, n = a.shape
    bm = min(block_m, m)
    bn = min(block_n, n)
    if m % bm or n % bn:
        # Pad to tile multiples; zero columns contribute nothing.
        mp = (m + bm - 1) // bm * bm
        np_ = (n + bn - 1) // bn * bn
        a = jnp.pad(a, ((0, mp - m), (0, np_ - n)))
        x = jnp.pad(x, (0, np_ - n))
        return matvec(a, x, block_m=bm, block_n=bn, interpret=interpret)[:m]

    acc_dtype = jnp.float32 if a.dtype != jnp.float64 else jnp.float64
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), acc_dtype),
        interpret=interpret,
        name="gmres_matvec",
    )(a, x[:, None].astype(a.dtype))
    return out[:, 0].astype(x.dtype)
