"""Dispatch layer for the MODEL stack: Pallas kernels on TPU, jnp elsewhere.

``use_kernels(True/False/"interpret")`` flips every model-stack call site
(attention, SSD, gated-norm) at once.  On this CPU container the kernels
are exercised through interpret mode (tests/benchmarks); the model/dry-run
path lowers the jnp references, which XLA fuses for the roofline analysis —
the Pallas kernels are the TPU-target artifacts.

The SOLVER's kernel paths (``gmres(gs="fused"|"cgs2_fused")``,
``DenseOperator(backend="pallas")``) do not consult this switch: their
dispatch is ``kernels.tuning.kernel_mode()`` (backend sniffing + the
``REPRO_KERNELS`` env override), chosen per call site at trace time.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

from repro.kernels import attention as _attention_k
from repro.kernels import cgs2 as _cgs2_k
from repro.kernels import matvec as _matvec_k
from repro.kernels import ref as _ref

_MODE = "ref"  # "ref" | "pallas" | "interpret"


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in ("ref", "pallas", "interpret"), mode
    _MODE = mode


def get_mode() -> str:
    return _MODE


@contextlib.contextmanager
def use_kernels(mode: str = "interpret"):
    prev = _MODE
    set_mode(mode)
    try:
        yield
    finally:
        set_mode(prev)


def _kernel_kw():
    return {"interpret": _MODE == "interpret"}


def matvec(a, x, **kw):
    if _MODE == "ref":
        return _ref.matvec(a, x)
    return _matvec_k.matvec(a, x, **_kernel_kw(), **kw)


def gs_project(v, w, mask, **kw):
    if _MODE == "ref":
        return _ref.gs_project(v, w, mask)
    return _cgs2_k.gs_project(v, w, mask, **_kernel_kw(), **kw)


def cgs2(v, w, mask, **kw):
    if _MODE == "ref":
        return _ref.cgs2(v, w, mask)
    return _cgs2_k.cgs2(v, w, mask, **_kernel_kw(), **kw)


def attention(q, k, v, *, causal=True, window=None, scale=None,
              q_chunk=None, **kw):
    if _MODE == "ref":
        return _ref.attention(q, k, v, causal=causal, scale=scale,
                              window=window, q_chunk=q_chunk)
    # the Pallas kernel is natively blocked; q_chunk is a ref-path knob
    return _attention_k.attention(q, k, v, causal=causal, window=window,
                                  scale=scale, **_kernel_kw(), **kw)


def ssd_scan(x, dt, lg, b, c, *, heads, chunk, **kw):
    """x: (BH, S, P); dt/lg: (BH, S); b/c: (B, S, N) -> y (BH, S, P)."""
    from repro.kernels import ssd as _ssd
    if _MODE == "ref":
        return _ssd.ssd_scan_ref(x, dt, lg, b, c, heads=heads, chunk=chunk)
    return _ssd.ssd_scan(x, dt, lg, b, c, heads=heads, chunk=chunk,
                         **_kernel_kw(), **kw)


def gated_rmsnorm(y, z, w, *, eps=1e-5, **kw):
    from repro.kernels import gated_norm as _gn
    if _MODE == "ref":
        return _gn.gated_rmsnorm_ref(y, z, w, eps=eps)
    return _gn.gated_rmsnorm(y, z, w, eps=eps, **_kernel_kw(), **kw)
