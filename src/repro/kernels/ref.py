"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against (allclose sweeps
over shapes/dtypes, interpret=True on CPU).  They are also the fallback
implementation the model/solver stacks use when kernels are disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matvec(a: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x.  a: (m, n), x: (n,) -> (m,)."""
    return (a @ x[:, None])[:, 0] if x.ndim == 1 else a @ x


def gs_project(v: jax.Array, w: jax.Array, mask: jax.Array):
    """One classical Gram-Schmidt pass: h = mask*(V w); w' = w - h V.

    v: (m1, n) row-major basis, w: (n,), mask: (m1,) 0/1 rows valid.
    Returns (h, w').
    """
    h = (v @ w) * mask
    return h, w - h @ v


def cgs2(v: jax.Array, w: jax.Array, mask: jax.Array):
    """Two GS passes (reorthogonalization); returns (h1+h2, w'')."""
    h1, w1 = gs_project(v, w, mask)
    h2, w2 = gs_project(v, w1, mask)
    return h1 + h2, w2


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              window: int | None = None, q_chunk: int | None = None):
    """Reference multi-head attention.

    q: (b, hq, sq, d), k/v: (b, hkv, skv, d); GQA when hq > hkv.
    ``window`` = sliding-window size (Mistral-style, counts the diagonal).
    Positions are aligned at the END (decode: sq last queries of skv keys).

    ``q_chunk``: scan over query chunks so the f32 score tensor peaks at
    (b, h, q_chunk, skv) instead of (b, h, sq, skv) — the XLA-level
    flash-attention memory shape (SSPerf hillclimb 1 iter 2).  Numerics are
    identical (softmax is complete over skv within each chunk).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    skv = k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5

    def chunk_out(q_c, qpos_c):
        qr = q_c.reshape(b, hkv, group, q_c.shape[2], d)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qr.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        kpos = jnp.arange(skv)
        mask = jnp.ones((q_c.shape[2], skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos_c[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos_c[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
        return out.reshape(b, hq, q_c.shape[2], d).astype(q.dtype)

    qpos = jnp.arange(sq) + (skv - sq)
    if not q_chunk or sq % q_chunk or sq <= q_chunk:
        return chunk_out(q, qpos)

    nc = sq // q_chunk
    qs = q.reshape(b, hq, nc, q_chunk, d).transpose(2, 0, 1, 3, 4)
    ps = qpos.reshape(nc, q_chunk)

    def body(_, args):
        q_c, qpos_c = args
        return None, chunk_out(q_c, qpos_c)

    _, outs = jax.lax.scan(body, None, (qs, ps))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)
