"""Pallas TPU kernels: sparse / structured mat-vec (the SpMV layer).

The paper benchmarks dense ``A %*% v`` only, but the production home of
GMRES is sparse systems (Ioannidis et al. 1906.04051): discretized PDEs
where A has O(n) nonzeros and dense GEMV would waste n/nnz of the HBM
stream on zeros.  Two storage formats, chosen for TPU-style tiling:

ELL (``ell_matvec``) — general sparsity.  A is (values, cols), both
  (n, width): row i holds its nonzeros in ``values[i, :]`` with their
  column indices in ``cols[i, :]``, zero-padded to the fixed per-row
  ``width`` (padding slots point at column 0 with value 0 so the gather
  stays in-bounds).  The rectangular layout is exactly what a row-blocked
  grid wants — every (bm, width) tile is dense in VMEM — at the price of
  padding rows to the widest row (the classic ELL trade; keep ``width``
  tight or slice the matrix).  The operand x stays WHOLE in VMEM: sparse
  column patterns touch arbitrary rows of x, so tiling x would re-stream
  it once per row block, and for the O(n)-nonzero regime x is the small
  array anyway (``tuning.spmv_fits`` gates the residency).

Sliced ELL (``sell_matvec``) — irregular sparsity (SELL-C-sigma style).
  Plain ELL's pad-to-widest is pathological when row lengths span orders
  of magnitude (power-law graphs: one hub row inflates every row's
  storage).  Here rows are sorted by nonzero count, cut into fixed-height
  slices each padded only to its own widest row, and same-width slices
  are merged into a handful of rectangular width BINS — the matvec is one
  ``_ell_kernel`` launch per bin over the shared VMEM-resident operand
  (column indices stay GLOBAL, so x needs no permutation), producing the
  output in the sorted-row frame.  The caller (``SlicedEllOperator``)
  owns the row permutation and scatters the result back; traffic is
  proportional to sum_b rows_b*width_b instead of n*max_width.

Banded / stencil (``banded_matvec``) — structured grids.  A is a DIA-style
  band stack (nbands, n) plus a static tuple of diagonal ``offsets``:
  ``y[i] = sum_d bands[d, i] * x[i + offsets[d]]`` with out-of-range reads
  contributing zero.  No gather at all: each band is an elementwise product
  with a SHIFTED window of x, so the kernel is pure VPU work over dynamic
  slices of a halo-padded VMEM-resident x — the five/seven-point Poisson
  and convection-diffusion stencils hit this path.

Both kernels accept (n,) vectors or (n, k) multi-RHS blocks — one stream
of the matrix feeds all k lanes, same as ``matvec.block_matvec`` — and
both accumulate in f32 (f64 under x64) regardless of storage dtype, so a
bf16 band/values stream halves matrix traffic without quantizing x.

HBM traffic per matvec (f32, vs dense GEMV's 4*(n*n + 2n) bytes):

    ELL:    n*width*(s + 4) + 8n      (values + int32 cols + x + y)
    banded: nbands*n*s + 8n           (bands + x + y; offsets are static)

For a five-point stencil on a 256x256 grid that is ~650x less traffic than
the dense stream — the reason sparse GMRES iterations are matvec-cheap and
orthogonalization-dominated (see benchmarks/kernel_bench.py spmv rows).

ROW-SHARDED variants (PR 5).  When the matrix rows are sharded over a mesh
axis, a shard's matvec needs operand values at most ``halo`` rows beyond
its own block (halo = the matrix bandwidth, max |col - row|) — NOT the
whole vector.  ``halo_exchange`` moves exactly those boundary rows with
two ``ppermute`` rounds (neighbors only; edge shards read zeros, matching
the out-of-range-is-zero convention of the kernels), and the
``*_matvec_halo`` entry points run the SAME kernels as above over the
halo-padded LOCAL operand — VMEM-resident per shard, so the residency
fits-checks divide by the shard count while the exchanged bytes stay
O(halo), independent of n.  That is the communication picture Ioannidis
et al. (1906.04051) identify as the multi-GPU GMRES bottleneck: an
all-gather per matvec becomes a fixed-width neighbor exchange.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _acc_dtypes(mat_dtype, x_dtype):
    """(compute, accumulate) dtypes matching dense ``a @ x`` promotion."""
    compute = jnp.promote_types(mat_dtype, x_dtype)
    return compute, jnp.promote_types(compute, jnp.float32)


# --------------------------------------------------------------------------
# ELL gather kernel
# --------------------------------------------------------------------------
def _ell_kernel(vals_ref, cols_ref, x_ref, o_ref):
    vals = vals_ref[...]                     # (bm, width), storage dtype
    cols = cols_ref[...]                     # (bm, width) int32
    x = x_ref[...]                           # (n, k) — whole, VMEM-resident
    # Gather the operand rows each slot references: (bm, width, k).  The
    # matrix tile upcasts in-register so bf16 values keep their halved HBM
    # stream without quantizing x; products accumulate in o_ref's dtype.
    g = jnp.take(x, cols, axis=0).astype(o_ref.dtype)
    o_ref[...] = jnp.sum(vals[:, :, None].astype(o_ref.dtype) * g, axis=1)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_matvec(values: jax.Array, cols: jax.Array, x: jax.Array, *,
               block_m: int = 512, interpret: bool = False) -> jax.Array:
    """y = A @ x for ELL-format A.  values/cols: (n, width); x: (n,) or (n, k)."""
    n, width = values.shape
    if cols.shape != (n, width):
        raise TypeError(f"ell_matvec: cols {cols.shape} must match values "
                        f"{values.shape}")
    if x.shape[0] != n:
        # Pallas pads blocks, so a length mismatch would otherwise read
        # garbage instead of raising the way ``a @ x`` does.
        raise TypeError(f"ell_matvec: values {values.shape} @ x {x.shape} — "
                        f"x must have {n} rows")
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    k = x.shape[1]
    bm = min(block_m, n)
    if n % bm:
        # Pad rows to the tile grid; padding slots carry value 0 at column 0
        # (same convention as real padding slots), so they contribute nothing.
        np_ = (n + bm - 1) // bm * bm
        out = ell_matvec(
            jnp.pad(values, ((0, np_ - n), (0, 0))),
            jnp.pad(cols, ((0, np_ - n), (0, 0))),
            jnp.pad(x, ((0, np_ - n), (0, 0))),
            block_m=bm, interpret=interpret)[:n]
        return out[:, 0] if squeeze else out

    compute_dtype, acc_dtype = _acc_dtypes(values.dtype, x.dtype)
    out = _ell_pallas(values, cols, x.astype(compute_dtype), bm, interpret,
                      acc_dtype, "gmres_spmv_ell").astype(compute_dtype)
    return out[:, 0] if squeeze else out


def _ell_pallas(values, cols, x, bm, interpret, acc_dtype, name):
    """Shared pallas_call: (n, width) values/cols row tiles, operand x
    WHOLE in VMEM — x has n rows single-device, n + 2*halo rows for the
    row-sharded variant (``cols`` then index the halo-local frame)."""
    n, width = values.shape
    k = x.shape[1]
    return pl.pallas_call(
        _ell_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, width), lambda i: (i, 0)),
            pl.BlockSpec((bm, width), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0], k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), acc_dtype),
        interpret=interpret,
        name=name,
    )(values, cols, x)


def ell_matvec_ref(values: jax.Array, cols: jax.Array,
                   x: jax.Array) -> jax.Array:
    """Pure-jnp ELL SpMV oracle (and the ``kernel_mode() == "ref"`` path)."""
    compute_dtype, acc_dtype = _acc_dtypes(values.dtype, x.dtype)
    g = x[cols].astype(acc_dtype)            # (n, width) or (n, width, k)
    vals = values.astype(acc_dtype)
    if x.ndim == 2:
        vals = vals[:, :, None]
    return jnp.sum(vals * g, axis=1).astype(compute_dtype)


# --------------------------------------------------------------------------
# Sliced-ELL (SELL-C-sigma) row-binned kernel entry points
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block_ms", "interpret"))
def sell_matvec(bin_values: tuple, bin_cols: tuple, x: jax.Array, *,
                block_ms: tuple | None = None,
                interpret: bool = False) -> jax.Array:
    """Sliced-ELL SpMV in the SORTED-row frame: one launch per width bin.

    ``bin_values[b]`` / ``bin_cols[b]`` are (rows_b, width_b) rectangles —
    contiguous runs of nnz-sorted rows padded to the bin's width, with
    int32 GLOBAL column indices (padding slots: value 0 at column 0).
    ``x`` is the full (n,) or (n, k) operand, resident in VMEM for every
    launch.  Returns the (sum_b rows_b,) or (sum_b rows_b, k) output in
    bin order — the caller scatters it back through its row permutation.

    ``block_ms``: optional per-bin row-block tuple (``choose_sell_block``
    per bin); each bin's row count is padded up to its block like
    ``ell_matvec`` pads the grid — but only the bin's rows, never x.
    """
    bin_values = tuple(bin_values)
    bin_cols = tuple(bin_cols)
    if not bin_values or len(bin_values) != len(bin_cols):
        raise TypeError(f"sell_matvec: {len(bin_values)} value bins vs "
                        f"{len(bin_cols)} cols bins (need >= 1, matching)")
    if block_ms is not None and len(block_ms) != len(bin_values):
        raise TypeError(f"sell_matvec: {len(block_ms)} block_ms for "
                        f"{len(bin_values)} bins")
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    outs = []
    for i, (vals, cols) in enumerate(zip(bin_values, bin_cols)):
        rb, wb = vals.shape
        if cols.shape != (rb, wb):
            raise TypeError(f"sell_matvec: bin {i} cols {cols.shape} must "
                            f"match values {vals.shape}")
        bm = min(block_ms[i] if block_ms is not None else 512, rb)
        rp = (rb + bm - 1) // bm * bm
        if rp != rb:
            # Pad ONLY the bin's rows to the tile grid (value 0 at column
            # 0, in-bounds in x) — unlike ``ell_matvec``'s recursive pad,
            # x must stay untouched: its length is n, not rows_b.
            vals = jnp.pad(vals, ((0, rp - rb), (0, 0)))
            cols = jnp.pad(cols, ((0, rp - rb), (0, 0)))
        compute_dtype, acc_dtype = _acc_dtypes(vals.dtype, x.dtype)
        out = _ell_pallas(vals, cols, x.astype(compute_dtype), bm, interpret,
                          acc_dtype, "gmres_spmv_sell")
        outs.append(out[:rb].astype(compute_dtype))
    y = jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]
    return y[:, 0] if squeeze else y


def sell_matvec_ref(bin_values: tuple, bin_cols: tuple,
                    x: jax.Array) -> jax.Array:
    """Pure-jnp sliced-ELL SpMV oracle, sorted-row frame (see sell_matvec)."""
    if not bin_values or len(bin_values) != len(bin_cols):
        raise TypeError(f"sell_matvec_ref: {len(bin_values)} value bins vs "
                        f"{len(bin_cols)} cols bins (need >= 1, matching)")
    outs = [ell_matvec_ref(v, c, x) for v, c in zip(bin_values, bin_cols)]
    return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]


# --------------------------------------------------------------------------
# Banded / stencil kernel
# --------------------------------------------------------------------------
def _banded_kernel(bt_ref, x_ref, o_ref, *, offsets, halo, bm):
    i = pl.program_id(0)
    base = i * bm + halo                     # row 0 of this tile, in x_pad
    acc = jnp.zeros(o_ref.shape, o_ref.dtype)
    for d, off in enumerate(offsets):        # static unroll over the bands
        seg = x_ref[pl.ds(base + off, bm), :]            # (bm, k) window
        band = bt_ref[:, d:d + 1]                        # (bm, 1)
        acc += band.astype(o_ref.dtype) * seg.astype(o_ref.dtype)
    o_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("offsets", "block_m", "interpret"))
def banded_matvec(bands: jax.Array, x: jax.Array, offsets: tuple, *,
                  block_m: int = 1024, interpret: bool = False) -> jax.Array:
    """y[i] = sum_d bands[d, i] * x[i + offsets[d]], out-of-range -> 0.

    bands: (nbands, n); offsets: static tuple of diagonal shifts (one per
    band, e.g. (-nx, -1, 0, 1, nx) for the five-point stencil); x: (n,) or
    (n, k).  x is halo-padded with zeros so every shifted window is a plain
    dynamic slice — no gather, no per-band bounds check.
    """
    nbands, n = bands.shape
    if len(offsets) != nbands:
        raise TypeError(f"banded_matvec: {nbands} bands but {len(offsets)} "
                        f"offsets")
    if x.shape[0] != n:
        raise TypeError(f"banded_matvec: bands {bands.shape} @ x {x.shape} — "
                        f"x must have {n} rows")
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    k = x.shape[1]
    bm = min(block_m, n)
    if n % bm:
        np_ = (n + bm - 1) // bm * bm
        out = banded_matvec(
            jnp.pad(bands, ((0, 0), (0, np_ - n))),
            jnp.pad(x, ((0, np_ - n), (0, 0))),
            offsets, block_m=bm, interpret=interpret)[:n]
        return out[:, 0] if squeeze else out

    halo = max(abs(int(o)) for o in offsets)
    compute_dtype, acc_dtype = _acc_dtypes(bands.dtype, x.dtype)
    x_pad = jnp.pad(x.astype(compute_dtype), ((halo, halo), (0, 0)))
    out = _banded_pallas(bands, x_pad, offsets, halo, bm, interpret,
                         acc_dtype).astype(compute_dtype)
    return out[:, 0] if squeeze else out


def _banded_pallas(bands, x_pad, offsets, halo, bm, interpret, acc_dtype):
    """Shared pallas_call: bands (nbands, n), x_pad (n + 2*halo, k) — the
    operand arrives halo-padded (zeros single-device, neighbor rows when
    row-sharded) and stays WHOLE in VMEM."""
    nbands, n = bands.shape
    k = x_pad.shape[1]
    return pl.pallas_call(
        functools.partial(_banded_kernel, offsets=offsets, halo=halo, bm=bm),
        grid=(n // bm,),
        in_specs=[
            # bands transposed to (n, nbands): the per-tile read is then a
            # contiguous (bm, nbands) block and each band is a column slice.
            pl.BlockSpec((bm, nbands), lambda i: (i, 0)),
            pl.BlockSpec((n + 2 * halo, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), acc_dtype),
        interpret=interpret,
        name="gmres_spmv_banded",
    )(bands.T, x_pad)


def banded_matvec_ref(bands: jax.Array, x: jax.Array,
                      offsets: tuple) -> jax.Array:
    """Pure-jnp banded SpMV oracle (and the ``kernel_mode() == "ref"`` path)."""
    halo = max(abs(int(o)) for o in offsets)
    squeeze = x.ndim == 1
    xp = x[:, None] if squeeze else x
    xp = jnp.pad(xp, ((halo, halo), (0, 0)))
    out = banded_matvec_halo_ref(bands, xp, offsets)
    return out[:, 0] if squeeze else out


# --------------------------------------------------------------------------
# Row-sharded halo variants
# --------------------------------------------------------------------------
def halo_exchange(x: jax.Array, halo: int, axis_name: str,
                  num_shards: int) -> jax.Array:
    """Fetch ``halo`` boundary rows from each mesh neighbor.

    x: the LOCAL (n_local,) or (n_local, k) shard of a row-partitioned
    vector.  Returns (n_local + 2*halo, ...) with rows [0, halo) holding
    the PREVIOUS shard's last rows and rows [halo + n_local, ...) the NEXT
    shard's first rows.  Edge shards receive zeros (``ppermute`` leaves
    non-receiving parties zeroed), which matches the kernels'
    out-of-range-reads-are-zero convention, so Dirichlet boundaries stay
    free.  Communication: 2 neighbor ppermutes of halo*k values —
    independent of the global n, vs. the (n - n_local)*k values an
    all-gather would move.

    ``num_shards`` must be the static size of ``axis_name`` (the
    permutation is built at trace time); requires halo <= n_local.
    """
    if halo == 0:
        return x
    if halo > x.shape[0]:
        raise ValueError(f"halo_exchange: halo={halo} exceeds the local "
                         f"shard length {x.shape[0]} — neighbors' neighbors "
                         f"would be needed; use an all-gather fallback")
    squeeze = x.ndim == 1
    xp = x[:, None] if squeeze else x
    down = [(p, p + 1) for p in range(num_shards - 1)]   # shard p -> p+1
    up = [(p + 1, p) for p in range(num_shards - 1)]     # shard p+1 -> p
    top = lax.ppermute(xp[-halo:], axis_name, perm=down)
    bot = lax.ppermute(xp[:halo], axis_name, perm=up)
    out = jnp.concatenate([top, xp, bot], axis=0)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("offsets", "block_m", "interpret"))
def banded_matvec_halo(bands: jax.Array, x_halo: jax.Array, offsets: tuple,
                       *, block_m: int = 1024,
                       interpret: bool = False) -> jax.Array:
    """Per-shard banded SpMV over an ALREADY halo-padded operand.

    bands: the (nbands, n_local) shard of the band stack; x_halo: the
    (n_local + 2*halo, ...) output of ``halo_exchange`` (halo =
    max |offsets|).  Same kernel as ``banded_matvec`` — the only
    difference is that the halo rows hold neighbor values instead of
    zeros.  Returns the (n_local, ...) local output shard.
    """
    nbands, n = bands.shape
    if len(offsets) != nbands:
        raise TypeError(f"banded_matvec_halo: {nbands} bands but "
                        f"{len(offsets)} offsets")
    halo = max(abs(int(o)) for o in offsets)
    if x_halo.shape[0] != n + 2 * halo:
        raise TypeError(f"banded_matvec_halo: bands {bands.shape} with "
                        f"halo={halo} need x_halo of {n + 2 * halo} rows, "
                        f"got {x_halo.shape}")
    squeeze = x_halo.ndim == 1
    if squeeze:
        x_halo = x_halo[:, None]
    bm = min(block_m, n)
    if n % bm:
        # Pad the row grid; appended zero-band rows read (real) trailing
        # halo values times zero, so they contribute nothing, and every
        # live row's read indices are unchanged.
        np_ = (n + bm - 1) // bm * bm
        out = banded_matvec_halo(
            jnp.pad(bands, ((0, 0), (0, np_ - n))),
            jnp.pad(x_halo, ((0, np_ - n), (0, 0))),
            offsets, block_m=bm, interpret=interpret)[:n]
        return out[:, 0] if squeeze else out

    compute_dtype, acc_dtype = _acc_dtypes(bands.dtype, x_halo.dtype)
    out = _banded_pallas(bands, x_halo.astype(compute_dtype), offsets, halo,
                         bm, interpret, acc_dtype).astype(compute_dtype)
    return out[:, 0] if squeeze else out


def banded_matvec_halo_ref(bands: jax.Array, x_halo: jax.Array,
                           offsets: tuple) -> jax.Array:
    """jnp oracle / fallback for ``banded_matvec_halo`` (prepadded x)."""
    nbands, n = bands.shape
    compute_dtype, acc_dtype = _acc_dtypes(bands.dtype, x_halo.dtype)
    squeeze = x_halo.ndim == 1
    xp = x_halo[:, None] if squeeze else x_halo
    halo = max(abs(int(o)) for o in offsets)
    xp = xp.astype(acc_dtype)
    acc = jnp.zeros((n, xp.shape[1]), acc_dtype)
    for d, off in enumerate(offsets):
        seg = jax.lax.slice_in_dim(xp, halo + off, halo + off + n, axis=0)
        acc = acc + bands[d][:, None].astype(acc_dtype) * seg
    out = acc.astype(compute_dtype)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def ell_matvec_halo(values: jax.Array, cols: jax.Array, x_halo: jax.Array,
                    *, block_m: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Per-shard ELL SpMV over an ALREADY halo-padded operand.

    values/cols: the (n_local, width) shard, with ``cols`` REMAPPED to
    halo-local coordinates (global col - shard offset + halo; see
    ``SparseOperator.__call__``); x_halo: the output of ``halo_exchange``.
    The gather kernel is identical to ``ell_matvec``'s — the resident
    operand is just (n_local + 2*halo, k) instead of (n, k), which is the
    whole point: residency divides by the shard count.
    """
    n, width = values.shape
    if cols.shape != (n, width):
        raise TypeError(f"ell_matvec_halo: cols {cols.shape} must match "
                        f"values {values.shape}")
    squeeze = x_halo.ndim == 1
    if squeeze:
        x_halo = x_halo[:, None]
    bm = min(block_m, n)
    if n % bm:
        # Padding rows carry value 0 at column 0 — in-bounds in x_halo.
        np_ = (n + bm - 1) // bm * bm
        out = ell_matvec_halo(
            jnp.pad(values, ((0, np_ - n), (0, 0))),
            jnp.pad(cols, ((0, np_ - n), (0, 0))),
            x_halo, block_m=bm, interpret=interpret)[:n]
        return out[:, 0] if squeeze else out

    compute_dtype, acc_dtype = _acc_dtypes(values.dtype, x_halo.dtype)
    out = _ell_pallas(values, cols, x_halo.astype(compute_dtype), bm,
                      interpret, acc_dtype,
                      "gmres_spmv_ell_halo").astype(compute_dtype)
    return out[:, 0] if squeeze else out
