"""Pallas TPU kernel: Mamba2 SSD chunked scan (the zamba2 lever).

The chunk-size sweep (EXPERIMENTS.md SSPerf) showed the XLA lowering of the
SSD scan is floor-bound by materialized intermediates.  This kernel runs
one chunk per grid step ENTIRELY in VMEM:

  - the (Q, Q) masked-decay score matrix never exists in HBM,
  - the inter-chunk state H (N, P) lives in VMEM scratch, carried across
    the sequential chunk dimension of the grid (never round-trips),
  - per chunk, HBM traffic is exactly the inputs x/dt/lg/B/C and output y.

Grid: (batch*heads, n_chunks), chunks innermost/sequential.  B/C are
shared across heads (n_groups=1, Mamba2's default) — the index map reads
head bh from the (b, ...) B/C arrays with bh // heads, so no replication
hits HBM.

Layout: x (BH, S, P); dt/lg (BH, S); B/C (B, S, N); out y (BH, S, P).
VMEM/step at Q=256, P=64, N=64: x/y 128 KiB, scores 256 KiB, H 16 KiB —
comfortable with double buffering.  Matches ref ``ssd_chunk_ref`` (the
jnp oracle distilled from models/ssm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, lg_ref, b_ref, c_ref, o_ref, h_ref, *, q):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    lg = lg_ref[0].astype(jnp.float32)        # (Q,) log-decay (negative)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(lg)                      # (Q,) inclusive
    total = cum[-1]

    # intra-chunk: scores[t, u] = (C_t . B_u) exp(cum_t - cum_u) dt_u, u<=t
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    decay = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    decay = jnp.where(tri, decay, -jnp.inf)
    scores = cb * jnp.exp(decay) * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C * exp(cum)) @ H
    y = y + jax.lax.dot_general(c * jnp.exp(cum)[:, None], h_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: H' = exp(total) H + B^T diag(exp(total - cum) dt) x
    su = (jnp.exp(total - cum) * dt)[:, None]             # (Q, 1)
    s_new = jax.lax.dot_general(b, su * x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(total) * h_ref[...] + s_new
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("heads", "chunk", "interpret"))
def ssd_scan(x, dt, lg, b, c, *, heads: int, chunk: int = 256,
             interpret: bool = False):
    """Chunked SSD.  x: (BH, S, P); dt/lg: (BH, S); b/c: (B, S, N).

    BH = batch * heads (head-major within batch).  Returns y (BH, S, P).
    """
    bh, s, p_dim = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    return pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, q, n), lambda i, j, h=heads: (i // h, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j, h=heads: (i // h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p_dim), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p_dim), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p_dim), jnp.float32)],
        interpret=interpret,
        name="ssd_chunk_scan",
    )(x, dt, lg, b, c)


def ssd_scan_ref(x, dt, lg, b, c, *, heads: int, chunk: int = 256):
    """jnp oracle — the models/ssm.py chunk recurrence, head-flattened."""
    bh, s, p_dim = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    nc = s // q
    batch = bh // heads
    bb = jnp.repeat(b, heads, axis=0)                    # (BH, S, N)
    cc = jnp.repeat(c, heads, axis=0)

    def per_row(x_r, dt_r, lg_r, b_r, c_r):
        def body(h, args):
            xc, dtc, lgc, bc, ccx = args                 # (q, .)
            cum = jnp.cumsum(lgc)
            total = cum[-1]
            cb = ccx @ bc.T
            decay = cum[:, None] - cum[None, :]
            tri = jnp.tril(jnp.ones((q, q), bool))
            w = jnp.where(tri, jnp.exp(decay), 0.0)
            y = (cb * w * dtc[None, :]) @ xc
            y = y + (ccx * jnp.exp(cum)[:, None]) @ h
            su = (jnp.exp(total - cum) * dtc)[:, None]
            h = jnp.exp(total) * h + bc.T @ (su * xc)
            return h, y

        rc = lambda t: t.reshape((nc, q) + t.shape[1:])
        _, ys = jax.lax.scan(body, jnp.zeros((n, p_dim), jnp.float32),
                             (rc(x_r.astype(jnp.float32)), rc(dt_r),
                              rc(lg_r), rc(b_r.astype(jnp.float32)),
                              rc(c_r.astype(jnp.float32))))
        return ys.reshape(s, p_dim)

    y = jax.vmap(per_row)(x, dt.astype(jnp.float32), lg.astype(jnp.float32),
                          bb, cc)
    return y.astype(x.dtype)
