"""Pallas TPU kernels: banded ILU(0) factorization + triangular sweeps.

This is the kernel layer behind ``core/preconditioners.BandedILU0`` (and
its ``line_jacobi`` / ``banded_block_jacobi`` restrictions).  Two pieces:

``banded_ilu0(bands, offsets)`` — the SETUP.  Incomplete LU restricted to
  the band pattern: a single ``lax.scan`` over rows carrying a ring buffer
  of the last K factored rows (K = number of subdiagonals = -min(offsets)),
  so setup is one streaming pass, O(n * nbands^2) flops and O(K * nbands)
  live state — the "O(bands) setup" a stencil operator deserves, vs the
  O(n^3) dense LU that ``block_jacobi`` pays.  All inter-row offset
  combinatorics are resolved in PYTHON (the offsets tuple is static), so
  the scan body is pure static-indexed arithmetic; band entries whose
  column falls outside [0, n) are masked to zero first (BandedOperator
  storage does not guarantee zeros there), and the pivot gets a
  scale-relative safe replacement AT FACTOR TIME so the sweeps below never
  need an in-kernel guard.

``banded_trisweep(bands, v, offsets, unit_diag=, lower=)`` — the APPLY.
  Solves the banded triangular system (unit-lower forward substitution or
  upper backward substitution).  Dispatch follows the standard kernel
  policy (``tuning.kernel_mode`` x ``tuning.trisweep_fits``): the Pallas
  kernel walks sequential row blocks on a (nb,) grid with the trailing K
  solved entries carried in a VMEM scratch ring — one HBM read of bands/v
  and one write of z — and ``banded_trisweep_ref`` is the psum-safe
  ``lax.scan`` oracle (also the vmapped multi-RHS path: substitution is
  sequential in rows but embarrassingly parallel across lanes).

  An UPPER solve is a lower solve read back-to-front: flip bands/v along
  the row axis, negate the offsets, forward-substitute, flip the result.
  Both directions therefore share one kernel and one reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning
from repro.kernels.tuning import LANE, _round_up


def _mask_oob(bands, offsets):
    """Zero band entries whose column i + off falls outside [0, n)."""
    n = bands.shape[1]
    rows = jnp.arange(n)
    masked = []
    for d, off in enumerate(offsets):
        cols = rows + off
        masked.append(jnp.where((cols >= 0) & (cols < n), bands[d], 0))
    return jnp.stack(masked)


def banded_ilu0(bands: jax.Array, offsets: tuple):
    """ILU(0) of a banded matrix, restricted to its own band pattern.

    bands: (nbands, n) with ``a[i, i+off_d] = bands[d, i]``; offsets must
    include 0.  Returns ``(l_bands, l_offsets, u_bands, u_offsets)``:
    the strictly-lower factor (unit diagonal implied) and the upper factor
    (diagonal included), both in the same DIA layout, ready for
    ``banded_trisweep``.
    """
    offsets = tuple(int(o) for o in offsets)
    nbands = bands.shape[0]
    if len(offsets) != nbands:
        raise TypeError(f"banded_ilu0: {nbands} bands but {len(offsets)} "
                        f"offsets")
    if 0 not in offsets:
        raise ValueError("banded_ilu0: offsets must include the diagonal "
                         "(offset 0)")
    l_offsets = tuple(sorted(o for o in offsets if o < 0))
    u_offsets = tuple([0] + sorted(o for o in offsets if o > 0))
    l_bands, u_bands = _ilu0_factor(bands, offsets)
    return l_bands, l_offsets, u_bands, u_offsets


@functools.partial(jax.jit, static_argnames=("offsets",))
def _ilu0_factor(bands: jax.Array, offsets: tuple):
    n = bands.shape[1]
    idx = {off: d for d, off in enumerate(offsets)}
    lower = sorted(o for o in offsets if o < 0)    # most negative first
    upper = sorted(o for o in offsets if o > 0)
    k_ring = -lower[0] if lower else 1

    acc = jnp.promote_types(bands.dtype, jnp.float32)
    a = _mask_oob(bands.astype(acc), offsets)
    eps = jnp.finfo(acc).eps
    tiny = jnp.finfo(acc).tiny

    def step(ring, a_row):
        # ring: (k_ring, nbands) — ring[k_ring + l] is factored row i + l.
        row = a_row
        for l in lower:
            krow = ring[k_ring + l]
            lik = row[idx[l]] / krow[idx[0]]
            row = row.at[idx[l]].set(lik)
            # Row k's U entries sit at columns k + off_u; in row i's frame
            # that is offset off_u + l — update only where the pattern has
            # a slot (that IS the ILU(0) restriction).
            for off_u in upper:
                tgt = off_u + l
                if tgt in idx:
                    row = row.at[idx[tgt]].add(-lik * krow[idx[off_u]])
        # Scale-relative safe pivot: a (near-)zero diagonal after
        # elimination would poison every later row through the ring, so
        # replace it HERE — the sweeps then divide unconditionally.
        piv = row[idx[0]]
        floor = jnp.maximum(jnp.max(jnp.abs(row)) * eps, tiny ** 0.5)
        sgn = jnp.where(piv < 0, -1.0, 1.0).astype(acc)
        row = row.at[idx[0]].set(
            jnp.where(jnp.abs(piv) >= floor, piv, sgn * floor))
        ring = jnp.concatenate([ring[1:], row[None]])
        return ring, row

    # Seed with unit diagonals so the first rows' (masked-to-zero) lower
    # entries divide by 1 instead of garbage.
    nbands = len(offsets)
    seed = jnp.zeros((k_ring, nbands), acc).at[:, idx[0]].set(1.0)
    _, fact = lax.scan(step, seed, a.T)            # fact: (n, nbands)
    fact = fact.T

    l_bands = (jnp.stack([fact[idx[o]] for o in lower])
               if lower else jnp.zeros((0, n), acc))
    u_bands = jnp.stack([fact[idx[o]] for o in [0] + upper])
    return l_bands, u_bands


# --------------------------------------------------------------------------
# Triangular sweep: lax.scan reference
# --------------------------------------------------------------------------
def _forward_ref(bands, v, offsets, unit_diag):
    """Forward substitution; offsets all <= 0 (0 present iff not unit)."""
    acc = jnp.promote_types(bands.dtype if bands.size else v.dtype,
                            jnp.promote_types(v.dtype, jnp.float32))
    k_ring = max((-o for o in offsets), default=0) or 1
    idx0 = offsets.index(0) if 0 in offsets else None

    def step(ring, inp):
        row, rhs = inp
        z = rhs
        for d, off in enumerate(offsets):
            if off < 0:
                z = z - row[d] * ring[k_ring + off]
        if not unit_diag:
            z = z / row[idx0]
        ring = jnp.concatenate([ring[1:], z[None]])
        return ring, z

    seed = jnp.zeros((k_ring,), acc)
    _, z = lax.scan(step, seed, (bands.T.astype(acc), v.astype(acc)))
    return z.astype(jnp.promote_types(bands.dtype, v.dtype))


def banded_trisweep_ref(bands: jax.Array, v: jax.Array, offsets: tuple, *,
                        unit_diag: bool, lower: bool) -> jax.Array:
    """Pure-jnp triangular sweep oracle (and the ``"ref"``-mode path)."""
    offsets = tuple(int(o) for o in offsets)
    _check_tri(bands, v, offsets, unit_diag, lower)
    if lower:
        return _forward_ref(bands, v, offsets, unit_diag)
    # Upper solve == lower solve of the row-reversed system.
    flip = _forward_ref(bands[:, ::-1] if bands.size else bands, v[::-1],
                        tuple(-o for o in offsets), unit_diag)
    return flip[::-1]


def _check_tri(bands, v, offsets, unit_diag, lower):
    if bands.shape[0] != len(offsets):
        raise TypeError(f"banded_trisweep: {bands.shape[0]} bands but "
                        f"{len(offsets)} offsets")
    if bands.size and bands.shape[1] != v.shape[0]:
        raise TypeError(f"banded_trisweep: bands {bands.shape} vs "
                        f"v {v.shape}")
    bad = [o for o in offsets if (o > 0 if lower else o < 0)]
    if bad:
        side = "lower" if lower else "upper"
        raise ValueError(f"banded_trisweep: offsets {bad} on the wrong "
                         f"side for a {side} sweep")
    if not unit_diag and 0 not in offsets:
        raise ValueError("banded_trisweep: unit_diag=False needs the "
                         "diagonal band (offset 0)")


# --------------------------------------------------------------------------
# Triangular sweep: Pallas kernel
# --------------------------------------------------------------------------
def _trisweep_kernel(b_ref, v_ref, o_ref, zp_ref, *,
                     offsets, unit_diag, k_ring, bm):
    """Sequential row blocks; zp_ref (1, k_ring + bm) carries the trailing
    k_ring solved entries across blocks (requires bm >= k_ring)."""
    i = pl.program_id(0)
    acc = o_ref.dtype
    idx0 = offsets.index(0) if 0 in offsets else None

    @pl.when(i == 0)
    def _seed():
        zp_ref[...] = jnp.zeros_like(zp_ref)

    def row(r, carry):
        z = pl.load(v_ref, (pl.ds(0, 1), pl.ds(r, 1))).astype(acc)
        for d, off in enumerate(offsets):
            if off < 0:
                coef = pl.load(b_ref, (pl.ds(d, 1), pl.ds(r, 1))).astype(acc)
                z = z - coef * pl.load(
                    zp_ref, (pl.ds(0, 1), pl.ds(r + k_ring + off, 1)))
        if not unit_diag:
            z = z / pl.load(b_ref,
                            (pl.ds(idx0, 1), pl.ds(r, 1))).astype(acc)
        pl.store(zp_ref, (pl.ds(0, 1), pl.ds(k_ring + r, 1)), z)
        pl.store(o_ref, (pl.ds(0, 1), pl.ds(r, 1)), z)
        return carry

    lax.fori_loop(0, bm, row, 0, unroll=False)
    # Shift the trailing solved entries to the front for the next block.
    zp_ref[0, :k_ring] = zp_ref[0, bm:bm + k_ring]


@functools.partial(
    jax.jit, static_argnames=("offsets", "unit_diag", "lower", "block_m",
                              "interpret"))
def banded_trisweep_kernel(bands: jax.Array, v: jax.Array, offsets: tuple, *,
                           unit_diag: bool, lower: bool,
                           block_m: int = 0,
                           interpret: bool = False) -> jax.Array:
    """One-pass Pallas triangular sweep (see module docstring)."""
    offsets = tuple(int(o) for o in offsets)
    _check_tri(bands, v, offsets, unit_diag, lower)
    if not lower:
        # Same back-to-front reduction as the reference: one kernel serves
        # both sweep directions.
        z = banded_trisweep_kernel(
            bands[:, ::-1] if bands.size else bands, v[::-1],
            tuple(-o for o in offsets), unit_diag=unit_diag, lower=True,
            block_m=block_m, interpret=interpret)
        return z[::-1]

    n = v.shape[0]
    out_dtype = jnp.promote_types(bands.dtype, v.dtype)
    acc = jnp.promote_types(out_dtype, jnp.float32)
    k_ring = max((-o for o in offsets), default=0) or 1
    bm = block_m or tuning.choose_trisweep_block(n, len(offsets), k_ring)
    bm = max(bm, _round_up(k_ring, LANE))          # carry shift needs bm>=K
    n_pad = _round_up(n, bm)

    # Padded tail rows solve to v = 0: identity diagonal, zero off-diags.
    bands_p = jnp.pad(bands.astype(acc), ((0, 0), (0, n_pad - n)))
    if not unit_diag:
        pad_diag = jnp.arange(n_pad) >= n
        d0 = offsets.index(0)
        bands_p = bands_p.at[d0].set(jnp.where(pad_diag, 1.0, bands_p[d0]))
    v_p = jnp.pad(v.astype(acc), (0, n_pad - n))[None, :]

    z = pl.pallas_call(
        functools.partial(_trisweep_kernel, offsets=offsets,
                          unit_diag=unit_diag, k_ring=k_ring, bm=bm),
        grid=(n_pad // bm,),
        in_specs=[
            pl.BlockSpec((max(len(offsets), 1), bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), acc),
        scratch_shapes=[pltpu.VMEM((1, k_ring + bm), acc)],
        interpret=interpret,
        name="gmres_precond_trisweep",
    )(bands_p if bands.size else jnp.zeros((1, n_pad), acc), v_p)
    return z[0, :n].astype(out_dtype)


def banded_trisweep(bands: jax.Array, v: jax.Array, offsets: tuple, *,
                    unit_diag: bool, lower: bool) -> jax.Array:
    """Dispatching entry point: kernel when the mode and VMEM footprint
    allow it, ``banded_trisweep_ref`` otherwise (identical results — the
    sweep is the same sequential recurrence either way)."""
    offsets = tuple(int(o) for o in offsets)
    mode = tuning.kernel_mode()
    k_ring = max((-o if lower else o for o in offsets), default=0) or 1
    if (mode == "ref" or v.ndim != 1
            or not tuning.trisweep_fits(v.shape[0], max(bands.shape[0], 1),
                                        bands.dtype, k=k_ring)):
        return banded_trisweep_ref(bands, v, offsets,
                                   unit_diag=unit_diag, lower=lower)
    return banded_trisweep_kernel(bands, v, offsets, unit_diag=unit_diag,
                                  lower=lower, interpret=mode == "interpret")
