"""Block-size autotuner + backend dispatch policy for the solver kernels.

Every Pallas kernel in this package is parameterized by VMEM tile sizes.
The right sizes depend on (shape, dtype) and the per-core VMEM budget:
bigger tiles amortize DMA setup and keep the MXU fed, but the working set
(with double-buffering) must stay inside ~16 MiB/core.  This module is the
single place that arithmetic lives, so the solver layer never hard-codes a
block shape.

Also here: the kernel execution mode policy.  The solver asks
``kernel_mode()`` once per trace and gets

    "compiled"   on TPU — real Pallas lowering,
    "interpret"  on CPU — the Pallas interpreter (slow, bit-accurate; what
                 CI exercises),
    "ref"        anywhere else (or via REPRO_KERNELS=ref) — pure-jnp
                 reference, no Pallas at all.

so ``gmres(gs="cgs2_fused")`` is safe to call on any backend.

Since PR 5 the policy is ALSO axis-aware: a row-sharded solve enters a
``shard_context(axis_name, num_shards)`` (core/distributed.py does this
around the shard_map body) and every dispatch site combines
``kernel_mode()`` with ``shard_axis()``/``shard_size()`` to pick the
per-shard kernels — the split-phase CGS2 pair, the halo-exchange SpMV
variants, the communication-avoiding matrix powers — instead of bailing
to the jnp reference the way pre-PR-5 code did.  The context is
trace-time static (same contract as ``kernel_mode``): shard_map traces
the per-shard program once, with the context set, and the resulting jaxpr
carries the kernel calls with the collectives between them.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Per-core VMEM budget the tuner plans against.  Real cores have ~16 MiB;
# we plan to ~3/4 of it so the compiler keeps double-buffering headroom.
VMEM_BUDGET = 12 * 1024 * 1024

# MXU/VPU native tile: the lane (last) dim is always 128; the sublane dim
# is 8 for f32 and 16 for bf16.
LANE = 128


def sublane(dtype) -> int:
    return 16 if jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16) else 8


def itemsize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


# Kernel execution modes, fastest first.  ``KERNEL_MODE_LADDER`` is the
# degradation order the recovery layer (core/recovery.py) steps down when a
# kernel-backed cycle faults: compiled Pallas -> the (slow, bit-accurate)
# interpreter -> the pure-jnp reference.
KERNEL_MODE_LADDER = ("compiled", "interpret", "ref")

_FORCED_MODE: list = []   # stack; trace-time static, like shard_context


@contextlib.contextmanager
def force_kernel_mode(mode: str):
    """Pin ``kernel_mode()`` for code traced inside (trace-time static).

    This is the recovery ladder's kernel-stack rung control: re-tracing a
    cycle under ``force_kernel_mode("interpret")`` / ``("ref")`` steps the
    solve down to a slower-but-safer execution mode WITHOUT touching the
    ``REPRO_KERNELS`` environment (which stays the process-wide default).
    Takes precedence over the env override; nests like ``shard_context``.
    """
    if mode not in KERNEL_MODE_LADDER:
        raise ValueError(f"unknown kernel mode {mode!r}; "
                         f"options: {list(KERNEL_MODE_LADDER)}")
    _FORCED_MODE.append(mode)
    try:
        yield
    finally:
        _FORCED_MODE.pop()


def kernel_mode() -> str:
    """Execution mode for kernel-backed solver paths (trace-time static).

    Shard-agnostic on purpose: a row-sharded trace keeps its "compiled" /
    "interpret" mode and dispatch sites consult ``shard_axis()`` to pick
    the per-shard (split-phase / halo) kernel variants — sharding changes
    WHICH kernel runs, not WHETHER kernels run.  An ambient
    ``force_kernel_mode`` context (the recovery ladder) outranks the
    ``REPRO_KERNELS`` env override.
    """
    if _FORCED_MODE:
        return _FORCED_MODE[-1]
    forced = os.environ.get("REPRO_KERNELS")
    if forced in ("ref", "interpret", "compiled"):
        return forced
    backend = jax.default_backend()
    if backend == "tpu":
        return "compiled"
    if backend == "cpu":
        return "interpret"
    return "ref"  # GPU etc.: these kernels are TPU-shaped; use the reference


class _ShardCtx(NamedTuple):
    axis_name: str
    num_shards: int


_SHARD_CTX: list = []   # stack; trace-time static, like kernel_mode()


@contextlib.contextmanager
def shard_context(axis_name: str, num_shards: int):
    """Declare that code traced inside operates on ROW-LOCAL shards.

    The distributed solvers wrap their shard_map bodies in this context;
    operators and orthogonalization schemes read it back via
    ``shard_axis()`` / ``shard_size()`` to dispatch the per-shard kernels
    (halo-exchange SpMV, split-phase CGS2, CA matrix powers).  The
    ``num_shards`` is needed wherever a static ``ppermute`` permutation is
    built — jax < 0.5 has no ``lax.axis_size``.
    """
    _SHARD_CTX.append(_ShardCtx(str(axis_name), int(num_shards)))
    try:
        yield
    finally:
        _SHARD_CTX.pop()


def shard_axis() -> Optional[str]:
    """Mesh axis of the ambient ``shard_context`` (None = single-shard)."""
    return _SHARD_CTX[-1].axis_name if _SHARD_CTX else None


def shard_size() -> int:
    """Shard count of the ambient ``shard_context`` (1 = single-shard)."""
    return _SHARD_CTX[-1].num_shards if _SHARD_CTX else 1


# --------------------------------------------------------------------------
# Persistent autotune cache
# --------------------------------------------------------------------------
# Every ``choose_*`` decision below is deterministic arithmetic today, but
# serving processes re-derive them on every restart and future measured
# tuning (ROADMAP) needs somewhere durable to live.  Tuned choices are
# cached to an on-disk JSON keyed by (function, args, dtype, topology):
#
#     REPRO_TUNE_CACHE=<path>   override the cache file location
#     REPRO_TUNE_CACHE=off      disable persistence (in-memory lru only)
#
# Default: ~/.cache/repro/tuning.json.  All I/O is best-effort — an
# unreadable/unwritable cache silently degrades to the computed value —
# and writes are atomic (tmp + rename) so concurrent processes never see
# a torn file.

_TUNE_CACHE_ENV = "REPRO_TUNE_CACHE"
_DISK_CACHE: Optional[dict] = None   # lazily-loaded {key: value} mirror
_PERSISTENT_FNS: list = []           # for clear_tune_cache()


def tune_cache_path() -> Optional[str]:
    """Resolved cache file path, or None when persistence is disabled."""
    p = os.environ.get(_TUNE_CACHE_ENV)
    if p is not None:
        return None if p.lower() in ("", "0", "off", "none") else p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tuning.json")


def _disk_load() -> dict:
    global _DISK_CACHE
    if _DISK_CACHE is None:
        _DISK_CACHE = {}
        path = tune_cache_path()
        if path is not None:
            try:
                import json
                with open(path) as f:
                    data = json.load(f)
                if isinstance(data, dict):
                    _DISK_CACHE.update(data)
            except (OSError, ValueError):
                pass  # missing/corrupt cache: start fresh
    return _DISK_CACHE


def _disk_store(cache: dict) -> None:
    path = tune_cache_path()
    if path is None:
        return
    try:
        import json
        import tempfile
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tune.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc.: persistence is best-effort


def _decode(v):
    return tuple(v) if isinstance(v, list) else v


def persistent_choice(fn):
    """lru_cache + on-disk JSON persistence for a ``choose_*`` function.

    Disk keys include the ambient topology (``shard_size()``): choices are
    deterministic in their arguments today, so entries recorded under
    different topologies agree — but measured tuning won't, and the key
    schema is what survives restarts.
    """

    @functools.lru_cache(maxsize=256)
    def _lookup(key, args, kwargs):
        disk = _disk_load()
        if key in disk:
            return _decode(disk[key])
        val = fn(*args, **dict(kwargs))
        disk[key] = val
        _disk_store(disk)
        return val

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        kw = tuple(sorted(kwargs.items()))
        key = f"{fn.__name__}|{args}|{kw}|p{shard_size()}"
        return _lookup(key, args, kw)

    wrapper.cache_clear = _lookup.cache_clear
    wrapper.__wrapped__ = fn
    _PERSISTENT_FNS.append(wrapper)
    return wrapper


class LruCache:
    """Bounded LRU with hit/miss/eviction counters.

    ``persistent_choice`` above persists tile CHOICES (cheap arithmetic,
    keyed for restarts); this holds things that cannot go to disk —
    pre-lowered solver handles, jitted callables — and therefore needs an
    eviction bound and observable stats (the serve layer reports them as
    ``solver_serve_*`` metrics).  Not thread-safe by design: the serving
    scheduler is a single tick loop, and dict/OrderedDict mutation under
    the GIL covers the host-ingress read path.
    """

    def __init__(self, maxsize: int = 8):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        from collections import OrderedDict
        self.maxsize = int(maxsize)
        self._d = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get_or_create(self, key, factory):
        """Return the cached value, building (and possibly evicting) on miss."""
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        self.misses += 1
        val = factory()
        self._d[key] = val
        if len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.evictions += 1
        return val

    def stats(self) -> dict:
        return {"size": len(self._d), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}

    def clear(self) -> None:
        self._d.clear()


def clear_tune_cache(disk: bool = False) -> None:
    """Drop the in-memory tuning caches (and the disk file when ``disk``)."""
    global _DISK_CACHE
    for fn in _PERSISTENT_FNS:
        fn.cache_clear()
    _DISK_CACHE = None
    if disk:
        path = tune_cache_path()
        if path is not None:
            try:
                os.remove(path)
            except OSError:
                pass


def record_tuned(choice_fn, value, *args, **kwargs) -> str:
    """Overwrite the persistent cache entry for ``choice_fn(*args, **kwargs)``.

    This is the WRITE path of autotune-by-measurement (``kernel_bench
    --measure``): ``persistent_choice`` lookups give a disk entry
    precedence over recomputing the VMEM model, so recording a measured
    winner here re-tunes every later call with the same key — in this
    process (the lru shadow is dropped) and in every future one (the JSON
    survives restarts).  The key is built exactly like the read path's,
    including the ambient topology, so record under the same
    ``shard_context`` the kernel will run under.  Returns the key.
    """
    if not hasattr(choice_fn, "__wrapped__"):
        raise TypeError(f"record_tuned wants a @persistent_choice function, "
                        f"got {choice_fn!r}")
    kw = tuple(sorted(kwargs.items()))
    key = f"{choice_fn.__name__}|{args}|{kw}|p{shard_size()}"
    disk = _disk_load()
    # JSON round-trips tuples as lists; store the list form so the entry
    # is identical whether it was written here or by a model lookup that
    # got persisted and re-read (``_decode`` restores tuples either way).
    disk[key] = list(value) if isinstance(value, tuple) else value
    _disk_store(disk)
    choice_fn.cache_clear()
    return key


@persistent_choice
def choose_matvec_blocks(m: int, n: int, dtype_name: str = "float32",
                         k: int = 1, budget: int = VMEM_BUDGET):
    """Pick (block_m, block_n) for the tiled GEMV/GEMM kernel.

    Working set per grid step (double-buffered A tile + operand/output
    columns):  2*bm*bn*s + bn*k*s + bm*k*4  bytes.  We maximize the A tile
    under the budget, preferring a wide ``block_n`` (contiguous HBM stream
    along the reduction dim) over a tall ``block_m``.
    """
    s = itemsize(dtype_name)
    sub = sublane(dtype_name)
    best = (sub, LANE)
    for bm in (128, 256, 512):
        for bn in (128, 256, 512, 1024, 2048):
            bytes_ = 2 * bm * bn * s + bn * k * s + bm * k * 4
            if bytes_ > budget:
                continue
            cur_bm, cur_bn = best
            if (bn, bm * bn) > (cur_bn, cur_bm * cur_bn):
                best = (bm, bn)
    bm, bn = best
    # Clamp to the (sublane/lane-aligned) problem size — a block larger
    # than the array just pads the whole array into one tile.
    bm = min(bm, _round_up(m, sub))
    bn = min(bn, _round_up(n, LANE))
    return bm, bn


@persistent_choice
def choose_spmv_block(n: int, width: int, dtype_name: str = "float32",
                      k: int = 1, halo: int = 0,
                      budget: int = VMEM_BUDGET) -> int:
    """Pick ``block_m`` (rows per grid step) for the ELL SpMV kernel.

    The gather kernel keeps the WHOLE operand x (n, k) resident in VMEM
    (sparse column patterns touch arbitrary rows of x, so tiling x would
    re-stream it once per row block); per grid step it adds a
    double-buffered (bm, width) values tile + int32 cols tile and the
    (bm, k) f32 output tile.  We maximize the row block under the budget —
    bigger blocks amortize the gather setup and the grid overhead.

    ``halo``: extra resident operand rows on EACH side — the row-sharded
    halo variant gathers from a (n + 2*halo, k) exchanged operand.
    """
    s = itemsize(dtype_name)
    sub = sublane(dtype_name)
    resident = _round_up(n + 2 * halo, LANE) * k * 4   # x, promoted to f32
    best = sub
    for bm in (128, 256, 512, 1024, 2048):
        need = 2 * bm * width * (s + 4) + resident + bm * k * 4
        if need <= budget:
            best = bm
    return min(best, _round_up(n, sub))


def spmv_fits(n: int, width: int, dtype, k: int = 1, halo: int = 0,
              budget: int = VMEM_BUDGET) -> bool:
    """Can the gather SpMV kernel keep the full operand x in VMEM?

    This is the kernel's hard requirement (see ``choose_spmv_block``); when
    it fails — n in the several-millions for f32 — the operator degrades to
    the jnp gather reference, which XLA streams from HBM.  ``halo`` prices
    the row-sharded variant's exchanged (n + 2*halo, k) operand; note the
    sharded check runs on the LOCAL n, so sharding P-fold also divides the
    residency requirement P-fold — the halo path FITS systems the
    single-device kernel cannot hold.
    """
    s = itemsize(dtype)
    sub = sublane(dtype)
    need = (2 * sub * width * (s + 4)                  # min values+cols tiles
            + _round_up(n + 2 * halo, LANE) * k * 4    # resident x (+ halo)
            + sub * k * 4)                             # output tile
    return need <= budget


@persistent_choice
def choose_sell_block(n: int, rows: int, width: int,
                      dtype_name: str = "float32", k: int = 1,
                      slice_height: int = 64,
                      budget: int = VMEM_BUDGET) -> int:
    """Pick ``block_m`` for ONE width bin of the sliced-ELL SpMV kernel.

    A bin is just an ELL rectangle — (rows, width) values + int32 cols in
    the sorted-row frame gathering from the GLOBAL (n, k) operand resident
    in VMEM — so the working-set model matches ``choose_spmv_block``.  Two
    differences: the resident-operand term uses the global ``n`` (column
    indices are global; the operand is shared by every bin's launch, not
    sliced per bin), and candidates step in multiples of ``slice_height``
    so a grid step covers whole slices (a block boundary inside a slice
    would split the one rectangle the format guarantees is dense).
    """
    s = itemsize(dtype_name)
    sub = sublane(dtype_name)
    resident = _round_up(n, LANE) * k * 4   # x, promoted to f32
    c = max(int(slice_height), sub)
    best = c
    bm = c
    while bm <= 4096:
        need = 2 * bm * width * (s + 4) + resident + bm * k * 4
        if need <= budget:
            best = bm
        bm *= 2
    return min(best, _round_up(rows, sub))


def sell_fits(n: int, width: int, dtype, k: int = 1,
              budget: int = VMEM_BUDGET) -> bool:
    """Can the sliced-ELL kernel keep the full operand x in VMEM?

    ``width`` is the WIDEST bin's padded width: the per-bin launches share
    one resident (n, k) operand (column indices are global), so the
    binding residency constraint is plain ELL's at the widest bin — which
    is at most plain ELL's own, since bin widths never exceed the global
    max row width.
    """
    return spmv_fits(n, width, dtype, k=k, halo=0, budget=budget)


@persistent_choice
def choose_banded_block(n: int, nbands: int, dtype_name: str = "float32",
                        halo: int = 0, k: int = 1,
                        budget: int = VMEM_BUDGET) -> int:
    """Pick ``block_m`` for the banded/stencil SpMV kernel.

    The kernel holds the halo-padded operand (n + 2*halo, k) resident in
    VMEM (each band reads a shifted window of it) plus a double-buffered
    (bm, nbands) bands tile and the (bm, k) output tile.
    """
    s = itemsize(dtype_name)
    sub = sublane(dtype_name)
    resident = _round_up(n + 2 * halo, LANE) * k * 4
    best = sub
    for bm in (128, 256, 512, 1024, 2048, 4096):
        need = 2 * bm * nbands * s + resident + bm * k * 4
        if need <= budget:
            best = bm
    return min(best, _round_up(n, sub))


def banded_fits(n: int, nbands: int, dtype, halo: int = 0, k: int = 1,
                budget: int = VMEM_BUDGET) -> bool:
    """Can the banded kernel keep the halo-padded operand in VMEM?"""
    s = itemsize(dtype)
    sub = sublane(dtype)
    need = (2 * sub * nbands * s
            + _round_up(n + 2 * halo, LANE) * k * 4
            + sub * k * 4)
    return need <= budget


@persistent_choice
def choose_powers_block(n: int, dtype_name: str = "float32", s: int = 4,
                        budget: int = VMEM_BUDGET) -> int:
    """Square A-tile size for the dense s-step matrix-powers kernel.

    The kernel's resident set is the (s, n) power block plus the current
    operand and the w accumulator (all f32); what's left of the budget goes
    to the double-buffered A tile, biggest MXU-aligned candidate first.
    """
    resident = _round_up(n, LANE) * 4 * (s + 2)
    best = LANE
    for b in (256, 512):
        if b > _round_up(n, LANE):
            break
        if (_round_up(n, b) - n) * 8 > n:
            continue  # same padding-overhead rule as choose_fused_block
        if 2 * b * b * itemsize(dtype_name) + resident <= budget:
            best = b
    return best


def powers_fits(n: int, dtype, s: int, *, nbands: int | None = None,
                halo: int = 0, budget: int = VMEM_BUDGET) -> bool:
    """Can the matrix-powers kernel keep its working set in VMEM?

    Both variants carry the (s, n) power block, the current operand and the
    w/halo scratch in f32; the banded variant (``nbands`` set) additionally
    holds the whole band stack resident (the point of the kernel: ONE HBM
    pass over A for all s powers), the dense variant one double-buffered
    A tile.  Failing the check sends the block step to the jnp reference.
    """
    s_mat = itemsize(dtype)
    np_ = _round_up(n, LANE)
    vecs = np_ * 4 * (_round_up(s, sublane("float32")) + 2)
    if nbands is None:
        b = choose_powers_block(n, jnp.dtype(dtype).name, s=s, budget=budget)
        need = vecs + 2 * b * b * s_mat
    else:
        need = vecs + nbands * np_ * s_mat + (np_ + 2 * halo) * 4
    return need <= budget


@persistent_choice
def choose_block_gs(m1: int, n: int, s: int = 1,
                    dtype_name: str = "float32"):
    """Padded residency plan ``(m1_pad, n_pad, s_pad)`` for the block-GS kernel.

    The kernel holds the whole basis as ONE VMEM block (that is its HBM
    win: V streamed once per pass instead of twice), so the only tiling
    decision is the hardware-aligned padding the operands are brought to.
    """
    return (_round_up(m1, sublane(dtype_name)), _round_up(n, LANE),
            _round_up(s, sublane("float32")))


def block_gs_fits(m1: int, n: int, dtype, s: int = 1,
                  budget: int = VMEM_BUDGET) -> bool:
    """Can the block-GS kernel keep the (m1, n) basis block in VMEM?

    Peak working set: the basis in storage ``dtype`` plus its f32 (f64
    under x64) in-register upcast, the (s, n) operand block and its
    orthogonalized copy, and the small C/G outputs.  Per grid step only
    ONE basis block is resident — the batched (k, m1, n) form visits one
    lane per step, so k does not enter the bound.
    """
    sb = itemsize(dtype)
    acc = max(4, sb)
    m1p, np_, sp = choose_block_gs(m1, n, s, jnp.dtype(dtype).name)
    need = (m1p * np_ * (sb + acc)      # resident V + in-kernel upcast
            + 2 * sp * np_ * acc        # W block in + W' out
            + m1p * sp * acc            # C output
            + 2 * sp * sp * acc)        # T in, G out
    return need <= budget


@persistent_choice
def choose_gs_block(m1: int, n: int, dtype_name: str = "float32",
                    budget: int = VMEM_BUDGET):
    """Pick ``block_n`` for the streaming fused Gram-Schmidt kernel.

    Per grid step the kernel holds a (m1, bn) V tile (double-buffered), the
    (bn, 1) w tile, and the (m1, 1) h accumulator.
    """
    s = 4  # the GS kernel accumulates f32
    best = LANE
    for bn in (128, 256, 512, 1024, 2048, 4096):
        if 2 * m1 * bn * s + bn * s + m1 * s <= budget:
            best = bn
    return min(best, _round_up(n, LANE))


def gs_payload_fits(m1: int, n: int, dtype, budget: int = VMEM_BUDGET) -> bool:
    """Can the single-reduce payload/update kernel pair run at (m1, n)?

    The streaming payload kernel tiles V, so the bound is the minimum tile
    working set — a (m1, LANE) V tile double-buffered, the (LANE, 2) W tile
    ([z, v_j]) and the (m1 + 1, 2) payload accumulator, all f32-accumulated.
    This effectively always holds; it exists as the EXPLICIT dispatch gate
    of the ``gs="cgs2_pipelined"`` scheme so overflow (and tests forcing it)
    degrade to the psum-safe jnp reference rather than a kernel failure.
    """
    del dtype  # accumulation is f32 regardless of storage dtype
    s = 4
    need = 2 * m1 * LANE * s + 2 * LANE * s + 2 * (m1 + 1) * s
    return n > 0 and need <= budget


@persistent_choice
def _choose_fused_block(n: int, dtype_name: str, budget: int):
    best = LANE
    for b in (256, 512):
        if b > _round_up(n, LANE):
            break
        if (_round_up(n, b) - n) * 8 > n:
            continue  # >12.5% padded rows/cols — padding traffic beats DMA win
        if 2 * b * b * itemsize(dtype_name) <= budget // 4:
            best = b
    return best


def choose_fused_block(n: int, dtype, budget: int = VMEM_BUDGET) -> int:
    """Square A-tile size for the fused Arnoldi-step kernel.

    One block size for rows and columns (so row/col padding agree on the
    square A), biggest MXU-aligned candidate whose padding overhead and
    double-buffered tile stay sane — the resident basis is the real VMEM
    consumer and is budgeted by ``fused_step_fits``.
    """
    return _choose_fused_block(n, jnp.dtype(dtype).name, budget)


def fused_step_fits(m1: int, n: int, dtype, budget: int = VMEM_BUDGET,
                    a_dtype=None) -> bool:
    """Can the fused Arnoldi-step kernel keep the whole basis V in VMEM?

    The fused kernel's peak working set is the Gram-Schmidt grid step: the
    full (m1, n) basis in storage ``dtype`` PLUS its accumulator-dtype
    upcast, the w accumulator, and one double-buffered A tile — priced in
    ``a_dtype`` (the matrix may be stored wider than the basis, e.g. f32 A
    with a bf16 ``compute_dtype`` basis).
    """
    if a_dtype is None:
        a_dtype = dtype
    s = itemsize(dtype)
    sa = itemsize(a_dtype)
    acc = max(4, sa)                 # f32 accumulation; f64 under x64
    b = choose_fused_block(n, a_dtype, budget)
    m1p = _round_up(m1, sublane(dtype))
    np_ = _round_up(n, b)
    need = (m1p * np_ * (s + acc)    # resident V + in-kernel upcast
            + np_ * acc * 2          # w accumulator + orthogonalized copy
            + 2 * b * b * sa)        # double-buffered A tile
    return need <= budget


def cheb_fits(n: int, nbands: int, dtype, *, halo: int = 0,
              budget: int = VMEM_BUDGET) -> bool:
    """Can the fused Chebyshev-apply kernel keep its working set in VMEM?

    The kernel is grid-free: the whole band stack, the input v and the
    three recurrence vectors (z, z_old, the stencil accumulator) stay
    resident for all ``order`` matvecs, plus the halo-padded z scratch.
    This is the EXPLICIT dispatch gate for ``banded_cheb_apply`` — on
    overflow (and in tests forcing it) the preconditioner degrades to the
    psum-safe per-matvec recurrence through the operator.
    """
    s = itemsize(dtype)
    np_ = _round_up(n, LANE)
    need = (nbands * np_ * s            # resident band stack
            + np_ * 4 * 4               # v, z, z_old, w (f32)
            + (np_ + 2 * halo) * 4)     # halo-padded z scratch
    return need <= budget


@persistent_choice
def choose_trisweep_block(n: int, nbands: int, k: int = 1,
                          budget: int = VMEM_BUDGET) -> int:
    """Row-block size for the banded triangular-sweep kernel.

    The sweep is sequential in rows, so the block only sizes the VMEM
    tiles (bands, v, z, and the (1, k + bm) carry ring) — bigger blocks
    amortize grid overhead; the floor is the carry depth k (the shift
    ``zp[:k] = zp[bm:bm+k]`` needs bm >= k).
    """
    best = LANE
    for bm in (128, 256, 512, 1024, 2048, 4096):
        need = (2 * bm * nbands * 4 + 3 * bm * 4 + (k + bm) * 4)
        if need <= budget:
            best = bm
    return max(best, _round_up(k, LANE))


def trisweep_fits(n: int, nbands: int, dtype, *, k: int = 1,
                  budget: int = VMEM_BUDGET) -> bool:
    """Can the triangular-sweep kernel hold a row block + carry ring?

    The EXPLICIT dispatch gate for ``kernels/trisolve.banded_trisweep`` —
    overflow (and tests forcing it) degrades to the lax.scan reference,
    which computes the identical recurrence.
    """
    bm = _round_up(max(k, LANE), LANE)
    need = 2 * bm * nbands * itemsize(dtype) + 3 * bm * 4 + (k + bm) * 4
    return need <= budget


def ell_powers_fits(n: int, width: int, dtype, s: int,
                    budget: int = VMEM_BUDGET) -> bool:
    """Can the ELL matrix-powers kernel keep values+cols+powers in VMEM?

    Mirrors ``powers_fits``: the (s, n) normalized power block, current
    operand and w accumulator in f32, plus the WHOLE (n, width)
    values/cols pair resident (the sparse gather may touch any row, and
    one residency pays for all s powers).  Failing the check sends the
    s-step block to the jnp reference powers.
    """
    np_ = _round_up(n, LANE)
    vecs = np_ * 4 * (_round_up(s, sublane("float32")) + 2)
    need = vecs + np_ * width * (itemsize(dtype) + 4)
    return need <= budget
