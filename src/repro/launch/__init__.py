"""Launch layer: meshes, sharded step factories, drivers, multi-pod dry-run."""
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.launch.steps import (TrainState, make_train_step,
                                make_prefill_step, make_serve_step,
                                make_optimizer, state_shardings,
                                abstract_state)

__all__ = ["make_production_mesh", "make_host_mesh", "TrainState",
           "make_train_step", "make_prefill_step", "make_serve_step",
           "make_optimizer", "state_shardings", "abstract_state"]
