import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# This flag lives ONLY here — smoke tests and benches see the real 1 device.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract params / optimizer state / batch / cache
     (ShapeDtypeStruct only — nothing is allocated),
  3. jits the train/prefill/serve step with explicit NamedShardings,
  4. ``.lower().compile()`` — a sharding mismatch, an unsupported
     collective, or an at-compile OOM is a FAILURE of the framework,
  5. records memory_analysis / cost_analysis / the collective schedule and
     the three roofline terms as a JSON line.

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun.jsonl
    python -m repro.launch.dryrun --arch mixtral-8x22b --shape decode_32k \
        --multi-pod
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro import compat

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_state, make_optimizer,
                                make_prefill_step, make_serve_step,
                                make_train_step, state_shardings)
from repro.models import (SHAPES, active_param_count, build, cache_specs,
                          input_specs, shape_applicable)
from repro.roofline import analyze, model_flops_for


def _depth_variant(cfg, k: int):
    """Same arch with a k-unit-deep scan (unit = one scan iteration)."""
    import dataclasses as dc
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return dc.replace(cfg, num_layers=k)
    if fam == "moe":
        return dc.replace(cfg, num_layers=k * cfg.moe_every)
    if fam == "encdec":
        return dc.replace(cfg, num_layers=k, encoder_layers=k)
    if fam == "hybrid":
        return dc.replace(cfg, num_layers=k * cfg.attn_every)
    if fam == "ssm":
        return dc.replace(cfg, num_layers=k * cfg.slstm_every)
    raise ValueError(fam)


def _depth_units(cfg) -> float:
    fam = cfg.family
    if fam in ("dense", "vlm", "encdec"):
        return float(cfg.num_layers)
    if fam == "moe":
        return cfg.num_layers / cfg.moe_every
    if fam == "hybrid":
        return cfg.num_layers / cfg.attn_every
    if fam == "ssm":
        return cfg.num_layers / cfg.slstm_every
    raise ValueError(fam)


def _lower_one(cfg, shape, mesh):
    """Lower + compile a single program for (cfg, shape) on mesh."""
    if shape.kind == "train":
        fn, _, _ = make_train_step(cfg, mesh, shape)
        opt = make_optimizer(cfg)
        return fn.lower(abstract_state(cfg, opt), input_specs(cfg, shape))
    if shape.kind == "prefill":
        fn, _, _ = make_prefill_step(cfg, mesh, shape)
        ab_params = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))
        return fn.lower(ab_params, input_specs(cfg, shape))
    fn, _, _ = make_serve_step(cfg, mesh, shape)
    ab_params = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))
    ab_cache = cache_specs(cfg, shape)
    ab_tok = jax.ShapeDtypeStruct((shape.global_batch,), "int32")
    ab_pos = jax.ShapeDtypeStruct((), "int32")
    return fn.lower(ab_params, ab_cache, ab_tok, ab_pos)


def _stats_of(compiled):
    from repro.roofline import parse_collectives
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(c.result_bytes * c.count for c in colls)),
        "coll_s": float(sum(c.ring_seconds() * c.count for c in colls)),
    }


def depth_corrected_stats(cfg, shape, mesh, full_stats):
    """XLA's cost analysis attributes ~ZERO cost to while/scan BODIES
    (verified: granite-3-8b train FLOPs are depth-invariant at 1/2/4
    layers — EXPERIMENTS.md SSPerf iteration 0).  So the full program's
    numbers cover only the non-scanned base (embeddings, lm head, loss,
    optimizer), and each scan unit is compiled STANDALONE with identical
    shardings and added in: total = base + sum units x unit (unitcost.py).
    """
    from repro.launch.unitcost import composed_stats
    total, detail = composed_stats(cfg, shape, mesh, full_stats)
    return total, {"base": full_stats, "units": detail}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None, correct_depth: bool = True):
    """Lower + compile one cell; returns (record dict, compiled)."""
    cfg = cfg_override or configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    with mesh:
        lowered = _lower_one(cfg, shape, mesh)
        compiled = lowered.compile()
        compile_s = time.time() - t0

        raw = _stats_of(compiled)
        if correct_depth:
            corrected, depth_info = depth_corrected_stats(cfg, shape, mesh,
                                                          raw)
        else:
            corrected, depth_info = raw, {}

    cost = compat.cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        bytes_per_device = getattr(mem, "output_size_in_bytes", None)
        mem_record = {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception:
        bytes_per_device = None
        mem_record = {}
    hlo = compiled.as_text()

    roof = analyze(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost={"flops": corrected["flops"],
              "bytes accessed": corrected["bytes"]},
        hlo_text=hlo,
        model_flops=model_flops_for(cfg, shape, active_param_count(cfg)),
        bytes_per_device=bytes_per_device)
    # override HLO-text collective stats with the depth-corrected ones
    roof = dataclasses.replace(roof,
                               collective_bytes=corrected["coll_bytes"],
                               collective_s=corrected["coll_s"])
    terms = {"compute": roof.compute_s, "memory": roof.memory_s,
             "collective": roof.collective_s}
    roof = dataclasses.replace(roof, bottleneck=max(terms, key=terms.get))

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips, "compile_s": round(compile_s, 1),
        "memory_analysis": mem_record,
        "cost_flops_raw": cost.get("flops"),
        "cost_bytes_raw": cost.get("bytes accessed"),
        "depth_correction": depth_info,
        "roofline": dataclasses.asdict(roof),
        "hlo_bytes": len(hlo),
    }
    return record, compiled


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) cells on the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in configs.ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        try:
            record, _ = lower_cell(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:
            record = {"arch": arch, "shape": shape,
                      "mesh": "2x16x16" if args.multi_pod else "16x16",
                      "status": "error", "error": repr(e),
                      "trace": traceback.format_exc()[-2000:]}
            failures += 1
        line = json.dumps(record)
        print(line if record["status"] != "ok" else
              f"OK {arch} {shape} {record['mesh']} "
              f"compile={record['compile_s']}s "
              f"bottleneck={record['roofline']['bottleneck']}")
        sys.stdout.flush()
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
