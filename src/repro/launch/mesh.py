"""Production mesh construction (pure function — importing this module

never touches jax device state; the dry-run sets the 512-device XLA flag
before its first jax import).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))
