"""Serving driver: batched prefill + decode loop with device-resident cache.

The decode loop is the paper's gpuR lesson applied to serving: the cache
never leaves the device (donated buffers), the host only feeds tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step
from repro.models import build
from repro.models.config import ShapeConfig

log = logging.getLogger("repro.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("cli", max_len, args.batch, "decode")
    model = build(cfg)

    params = model.init(jax.random.PRNGKey(0))
    serve_step, _, _ = make_serve_step(cfg, mesh, shape)
    cache = model.init_cache(args.batch, max_len)

    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)

    # prefill by stepping the decode program over the prompt (exercises the
    # same cache path serving uses; a fused prefill is the prefill_* lowering)
    tok = jnp.asarray(prompt[:, 0])
    t0 = time.perf_counter()
    with mesh:
        for i in range(args.prompt_len):
            nxt, cache = serve_step(params, cache, jnp.asarray(prompt[:, i]),
                                    jnp.int32(i))
        generated = []
        tok = nxt
        for i in range(args.gen):
            tok, cache = serve_step(params, cache, tok,
                                    jnp.int32(args.prompt_len + i))
            generated.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    log.info("generated %d tokens in %.2fs (%.1f tok/s)",
             args.batch * args.gen, dt, total_tokens / dt)
    gen = np.stack(generated, axis=1)
    log.info("sample row: %s", gen[0][:16])
    return gen


if __name__ == "__main__":
    main()
