"""Step factories: sharded train / prefill / decode programs.

Everything the launcher and the dry-run lower comes from here, so the
jitted programs benchmarks measure and the programs production runs are the
same objects.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import build, cache_specs, input_specs, param_specs
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw, schedules
from repro.sharding import (batch_shardings, cache_shardings,
                            param_shardings, replicated)


class TrainState(NamedTuple):
    params: Any
    opt: Any


def make_optimizer(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                   warmup: int = 100, total: int = 10_000):
    return adamw(schedules.cosine_warmup(peak_lr, warmup_steps=warmup,
                                         total_steps=total),
                 moment_dtype=cfg.moment_dtype)


def state_shardings(cfg: ModelConfig, mesh: Mesh, opt) -> TrainState:
    ap = param_specs(cfg)
    ps = param_shardings(mesh, ap)
    ao = jax.eval_shape(opt.init, ap)
    # moments mirror the param tree; scalars replicate
    mo = param_shardings(mesh, ao.m)
    vo = param_shardings(mesh, ao.v)
    so = NamedSharding(mesh, P())
    return TrainState(params=ps, opt=type(ao)(step=so, m=mo, v=vo))


def abstract_state(cfg: ModelConfig, opt) -> TrainState:
    ap = param_specs(cfg)
    return TrainState(params=ap, opt=jax.eval_shape(opt.init, ap))


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    opt=None, jit: bool = True):
    """Returns (step_fn, state_shardings, batch_shardings_tree)."""
    model = build(cfg)
    opt = opt or make_optimizer(cfg)

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        params, opt_state, opt_metrics = opt.update(grads, state.opt,
                                                    state.params)
        return TrainState(params=params, opt=opt_state), {**metrics,
                                                          **opt_metrics}

    st_sh = state_shardings(cfg, mesh, opt)
    ab = input_specs(cfg, shape)
    b_sh = batch_shardings(mesh, ab, shape.global_batch)
    if not jit:
        return train_step, st_sh, b_sh
    fn = jax.jit(train_step,
                 in_shardings=(st_sh, b_sh),
                 out_shardings=(st_sh, replicated(mesh, {"_": 0})["_"]),
                 donate_argnums=(0,))
    return fn, st_sh, b_sh


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                      jit: bool = True):
    model = build(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    p_sh = param_shardings(mesh, param_specs(cfg))
    ab = input_specs(cfg, shape)
    b_sh = batch_shardings(mesh, ab, shape.global_batch)
    if not jit:
        return prefill_step, p_sh, b_sh
    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                 out_shardings=NamedSharding(mesh, P()))
    return fn, p_sh, b_sh


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    jit: bool = True, greedy: bool = True):
    """Single-token decode step: (params, cache, token, pos) ->

    (next_token, logits?, new_cache).  Cache is donated — decode is a
    steady-state loop over device-resident state (the gpuR lesson, again).
    """
    model = build(cfg)

    def serve_step(params, cache, token, pos):
        logits, new_cache = model.decode(params, cache, token, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_cache

    p_sh = param_shardings(mesh, param_specs(cfg))
    c_ab = cache_specs(cfg, shape)
    c_sh = cache_shardings(mesh, c_ab, shape.global_batch)
    tok_sh = batch_shardings(mesh, {"t": jax.ShapeDtypeStruct(
        (shape.global_batch,), jnp.int32)}, shape.global_batch)["t"]
    pos_sh = NamedSharding(mesh, P())
    if not jit:
        return serve_step, p_sh, c_sh
    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                 out_shardings=(tok_sh, c_sh),
                 donate_argnums=(1,))
    return fn, p_sh, (c_sh, tok_sh, pos_sh)
