"""End-to-end training driver (runs REAL steps on whatever devices exist).

On this CPU container it trains reduced configs (the e2e example); pointed
at a TPU slice it trains the full configs — the step program, sharding
rules, checkpointing, and fault-tolerant runner are identical, only the
mesh differs.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (TrainState, make_optimizer, make_train_step,
                                state_shardings)
from repro.models import build
from repro.models.config import ShapeConfig
from repro.optim import newton_krylov
from repro.runtime import Runner, RunnerConfig

log = logging.getLogger("repro.train")


def build_everything(cfg, shape, mesh, *, peak_lr, total_steps):
    opt = make_optimizer(cfg, peak_lr=peak_lr, total=total_steps)
    step_fn, st_sh, b_sh = make_train_step(cfg, mesh, shape, opt=opt)
    model = build(cfg)

    def init_state(mesh):
        with mesh:
            params = jax.jit(model.init,
                             out_shardings=st_sh.params)(
                                 jax.random.PRNGKey(0))
            opt_state = jax.jit(opt.init, out_shardings=st_sh.opt)(params)
        return TrainState(params=params, opt=opt_state)

    return step_fn, init_state, b_sh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", choices=["adamw", "newton_krylov"],
                    default="adamw")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_host_mesh()

    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)

    if args.optimizer == "newton_krylov":
        return train_nk(cfg, shape, args, pipe)

    step_fn, init_state, b_sh = build_everything(
        cfg, shape, mesh, peak_lr=args.lr, total_steps=args.steps)

    def batch_for(step, mesh):
        host = pipe.global_batch_at(step)
        if cfg.family == "encdec":
            host["frames"] = np.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), np.float32)
        if cfg.family == "vlm":
            from repro.models.transformer import D_VISION
            host["patches"] = np.zeros(
                (args.batch, cfg.num_patches, D_VISION), np.float32)
        return jax.device_put(host, b_sh)

    losses = []

    def on_metrics(step, metrics, dt):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            log.info("step %5d loss %.4f grad_norm %.3f  %.0f ms", step,
                     float(metrics["loss"]), float(metrics["grad_norm"]),
                     dt * 1e3)

    runner = Runner(
        config=RunnerConfig(checkpoint_dir=args.ckpt_dir,
                            checkpoint_every=args.ckpt_every),
        make_mesh=lambda failures: mesh,
        build_step=lambda mesh: step_fn,
        init_state=init_state,
        batch_for=batch_for,
    )
    state, step = runner.run(args.steps, on_metrics=on_metrics)
    if losses:
        log.info("finished at step %d; loss %.4f -> %.4f", step,
                 losses[0], np.mean(losses[-10:]))
    else:
        log.info("nothing to do: checkpoint already at step %d", step)
    return losses


def train_nk(cfg, shape, args, pipe):
    """Newton-Krylov path: GMRES inside the optimizer (paper tie-in)."""
    model = build(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch)[0]

    init, update = newton_krylov(loss_fn, m=8, tol=1e-3, damping=10.0)
    params = model.init(jax.random.PRNGKey(0))
    nk_state = init(params)
    jit_update = jax.jit(update)
    losses = []
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, pipe.global_batch_at(step))
        params, nk_state, metrics = jit_update(params, nk_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            log.info("NK step %4d loss %.4f gmres_steps %d damping %.2f",
                     step, losses[-1], int(metrics["gmres_steps"]),
                     float(metrics["damping"]))
    return losses


if __name__ == "__main__":
    main()
