"""Per-scan-unit cost programs for the roofline analysis.

Why this exists (SSPerf iteration 0, recorded in EXPERIMENTS.md): XLA's
``compiled.cost_analysis()`` on the partitioned module attributes ~ZERO
flops/bytes/collectives to ``while``-loop bodies — scanned layer stacks
disappear from the numbers entirely (verified: granite-3-8b train FLOPs are
depth-invariant for 1/2/4 layers).  Differential-depth extrapolation
therefore measures nothing.

Fix: compile each scan unit (one layer of each kind) as its OWN program
with the SAME shardings the full model uses, cost-analyze that (no loop ->
counted correctly), and compose

    total(term) = base_program(term) + sum_i units_i x unit_i(term)

where base_program is the full lowering (embeddings, lm head, loss,
optimizer — everything outside the scans, which XLA does count).

Adjustments:
  - train units are lowered as value_and_grad(sum(layer(x))) wrt (params, x)
    = 1 fwd + full bwd.  With remat="full" the real program recomputes the
    fwd inside bwd: flops x (4/3) (fwd:bwd ~ 1:2); bytes/collectives are
    left as measured (remat trades bytes DOWN, so this is conservative).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import attention, encdec, layers as L, moe, ssm, \
    transformer, xlstm
from repro.models.config import ModelConfig, ShapeConfig
from repro.sharding import partition

REMAT_FLOPS_FACTOR = 4.0 / 3.0


def _act_sharding(mesh, batch):
    baxes = partition.batch_axes_for(mesh, batch)
    return NamedSharding(mesh, P(baxes))


def _param_shardings_for(mesh, abstract):
    return partition.param_shardings(mesh, abstract)


def _cache_shardings_for(mesh, abstract, batch):
    return partition.cache_shardings(mesh, abstract, batch)


def _stats(compiled, *, flops_factor=1.0):
    from repro.launch.dryrun import _stats_of
    st = _stats_of(compiled)
    st["flops"] *= flops_factor
    return st


def _compile_unit(fn, mesh, args, in_shardings):
    jfn = jax.jit(fn, in_shardings=in_shardings)
    return jfn.lower(*args).compile()


def _train_unit(layer_fn, abstract_params, mesh, cfg, shape, extra=None):
    """value_and_grad of sum(layer(params, x [, extra]))."""
    cdt = L.dtype_of(cfg.compute_dtype)
    b, s = shape.global_batch, shape.seq_len
    x = jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)

    def obj(p, x, *extra_args):
        y = layer_fn(p, x, *extra_args)
        return jnp.sum(y.astype(jnp.float32))

    grad_fn = jax.value_and_grad(obj, argnums=(0, 1))
    p_sh = _param_shardings_for(mesh, abstract_params)
    x_sh = _act_sharding(mesh, b)
    args = [abstract_params, x]
    shardings = [p_sh, x_sh]
    if extra is not None:
        args += [extra[0]]
        shardings += [extra[1]]
    factor = REMAT_FLOPS_FACTOR if cfg.remat != "none" else 1.0
    return _stats(_compile_unit(grad_fn, mesh, args, tuple(shardings)),
                  flops_factor=factor)


def _fwd_unit(layer_fn, abstract_params, mesh, cfg, shape, seq=None,
              extra=None):
    cdt = L.dtype_of(cfg.compute_dtype)
    b = shape.global_batch
    s = seq if seq is not None else shape.seq_len
    x = jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)
    p_sh = _param_shardings_for(mesh, abstract_params)
    x_sh = _act_sharding(mesh, b)
    args = [abstract_params, x]
    shardings = [p_sh, x_sh]
    if extra is not None:
        args += [extra[0]]
        shardings += [extra[1]]
    return _stats(_compile_unit(layer_fn, mesh, args, tuple(shardings)))


def _decode_unit(step_fn, abstract_params, abstract_cache, mesh, cfg, shape):
    cdt = L.dtype_of(cfg.compute_dtype)
    b = shape.global_batch
    x = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cdt)
    p_sh = _param_shardings_for(mesh, abstract_params)
    x_sh = _act_sharding(mesh, b)
    c_sh = _cache_shardings_for(mesh, abstract_cache, b)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    return _stats(_compile_unit(step_fn, mesh,
                                [abstract_params, x, abstract_cache, pos],
                                (p_sh, x_sh, c_sh, pos_sh)))


# --------------------------------------------------------------------------
# family-specific units
# --------------------------------------------------------------------------
def _positions(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def unit_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> list:
    """Returns [(units, stats_dict), ...] for every scan-unit kind."""
    cdt = L.dtype_of(cfg.compute_dtype)
    key = jax.random.PRNGKey(0)
    kind = shape.kind
    fam = cfg.family
    b = shape.global_batch
    out = []

    def dense_layer(p, x):
        pos = _positions(x.shape[0], x.shape[1])
        return transformer.dense_layer_apply(p, x, cfg, pos, cdt)

    def moe_layer(p, x):
        pos = _positions(x.shape[0], x.shape[1])
        return transformer.moe_layer_apply(p, x, cfg, pos, cdt)

    def dense_decode(p, x, c, pos):
        h, c2 = attention.decode(p["attn"],
                                 L.rmsnorm(x, p["ln1"], cfg.norm_eps), c,
                                 pos, cfg, compute_dtype=cdt,
                                 rope=cfg.positions == "rope",
                                 window=cfg.window)
        x = x + h
        return x + L.mlp_apply(p["mlp"],
                               L.rmsnorm(x, p["ln2"], cfg.norm_eps), cdt), c2

    def moe_decode(p, x, c, pos):
        h, c2 = attention.decode(p["attn"],
                                 L.rmsnorm(x, p["ln1"], cfg.norm_eps), c,
                                 pos, cfg, compute_dtype=cdt,
                                 rope=cfg.positions == "rope",
                                 window=cfg.window)
        x = x + h
        return x + moe.apply(p["moe"], L.rmsnorm(x, p["ln2"], cfg.norm_eps),
                             cfg, compute_dtype=cdt), c2

    if fam in ("dense", "vlm", "moe"):
        n_moe = cfg.num_layers // cfg.moe_every if cfg.num_experts else 0
        n_dense = cfg.num_layers - n_moe
        if n_dense:
            ap = jax.eval_shape(
                lambda k: transformer.dense_layer_init(k, cfg, jnp.float32),
                key)
            if kind == "train":
                out.append((n_dense, _train_unit(dense_layer, ap, mesh, cfg,
                                                 shape)))
            elif kind == "prefill":
                out.append((n_dense, _fwd_unit(dense_layer, ap, mesh, cfg,
                                               shape)))
            else:
                ac = jax.eval_shape(functools.partial(
                    attention.init_cache, cfg, b, shape.seq_len))
                out.append((n_dense, _decode_unit(dense_decode, ap, ac, mesh,
                                                  cfg, shape)))
        if n_moe:
            ap = jax.eval_shape(
                lambda k: transformer.moe_layer_init(k, cfg, jnp.float32),
                key)
            if kind == "train":
                out.append((n_moe, _train_unit(moe_layer, ap, mesh, cfg,
                                               shape)))
            elif kind == "prefill":
                out.append((n_moe, _fwd_unit(moe_layer, ap, mesh, cfg,
                                             shape)))
            else:
                ac = jax.eval_shape(functools.partial(
                    attention.init_cache, cfg, b, shape.seq_len))
                out.append((n_moe, _decode_unit(moe_decode, ap, ac, mesh,
                                                cfg, shape)))
        return out

    if fam == "encdec":
        ap_enc = jax.eval_shape(
            lambda k: encdec._enc_layer_init(k, cfg, jnp.float32), key)
        ap_dec = jax.eval_shape(
            lambda k: encdec._dec_layer_init(k, cfg, jnp.float32), key)
        enc_out_spec = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model),
                                            cdt)
        enc_sh = _act_sharding(mesh, b)

        def enc_layer(p, x):
            h = attention.apply(p["attn"], encdec._ln(x, p["ln1"],
                                                      cfg.norm_eps), cfg,
                                causal=False, compute_dtype=cdt, rope=False)
            x = x + h
            return x + encdec._mlp_bias_apply(
                p["mlp"], encdec._ln(x, p["ln2"], cfg.norm_eps), cdt)

        def dec_layer(p, x, enc_out):
            h = attention.apply(p["self_attn"],
                                encdec._ln(x, p["ln1"], cfg.norm_eps), cfg,
                                causal=True, compute_dtype=cdt, rope=False)
            x = x + h
            kv = attention.encoder_kv(p["cross_attn"], enc_out, cfg,
                                      compute_dtype=cdt)
            x = x + attention.cross_apply(
                p["cross_attn"], encdec._ln(x, p["ln_x"], cfg.norm_eps), kv,
                cfg, compute_dtype=cdt)
            return x + encdec._mlp_bias_apply(
                p["mlp"], encdec._ln(x, p["ln2"], cfg.norm_eps), cdt)

        if kind == "train":
            # encoder unit uses encoder_seq, not shape.seq_len
            enc_shape = ShapeConfig("enc", cfg.encoder_seq, b, "train")
            out.append((cfg.encoder_layers,
                        _train_unit(enc_layer, ap_enc, mesh, cfg, enc_shape)))
            out.append((cfg.num_layers,
                        _train_unit(dec_layer, ap_dec, mesh, cfg, shape,
                                    extra=(enc_out_spec, enc_sh))))
        elif kind == "prefill":
            out.append((cfg.encoder_layers,
                        _fwd_unit(enc_layer, ap_enc, mesh, cfg, shape,
                                  seq=cfg.encoder_seq)))
            out.append((cfg.num_layers,
                        _fwd_unit(dec_layer, ap_dec, mesh, cfg, shape,
                                  extra=(enc_out_spec, enc_sh))))
        else:
            ac = jax.eval_shape(functools.partial(
                attention.init_cache, cfg, b, shape.seq_len))
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            cross_kv = (jax.ShapeDtypeStruct((b, hkv, cfg.encoder_seq, hd),
                                             jnp.bfloat16),) * 2

            def dec_decode(p, x, c, pos, ckv):
                h, c2 = attention.decode(
                    p["self_attn"], encdec._ln(x, p["ln1"], cfg.norm_eps), c,
                    pos, cfg, compute_dtype=cdt, rope=False)
                x = x + h
                x = x + attention.cross_apply(
                    p["cross_attn"], encdec._ln(x, p["ln_x"], cfg.norm_eps),
                    ckv, cfg, compute_dtype=cdt)
                return x + encdec._mlp_bias_apply(
                    p["mlp"], encdec._ln(x, p["ln2"], cfg.norm_eps), cdt), c2

            x = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cdt)
            ckv_sh = _cache_shardings_for(
                mesh, {"cross": cross_kv}, b)["cross"]
            st = _stats(_compile_unit(
                dec_decode, mesh,
                [ap_dec, x, ac, jax.ShapeDtypeStruct((), jnp.int32),
                 cross_kv],
                (_param_shardings_for(mesh, ap_dec), _act_sharding(mesh, b),
                 _cache_shardings_for(mesh, ac, b), NamedSharding(mesh, P()),
                 ckv_sh)))
            out.append((cfg.num_layers, st))
        return out

    if fam == "hybrid":
        from repro.models import hybrid as hy
        ap_m = jax.eval_shape(
            lambda k: hy._mamba_layer_init(k, cfg, jnp.float32), key)
        ap_a = jax.eval_shape(
            lambda k: hy._shared_attn_init(k, cfg, jnp.float32), key)
        n_sites = cfg.num_layers // cfg.attn_every

        def mamba_layer(p, x):
            return x + ssm.apply(p["block"],
                                 L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg,
                                 compute_dtype=cdt)

        def attn_block(p, x):
            pos = _positions(x.shape[0], x.shape[1])
            return hy._shared_attn_apply(p, x, cfg, pos, cdt)

        if kind == "train":
            out.append((cfg.num_layers,
                        _train_unit(mamba_layer, ap_m, mesh, cfg, shape)))
            out.append((n_sites,
                        _train_unit(attn_block, ap_a, mesh, cfg, shape)))
        elif kind == "prefill":
            out.append((cfg.num_layers,
                        _fwd_unit(mamba_layer, ap_m, mesh, cfg, shape)))
            out.append((n_sites,
                        _fwd_unit(attn_block, ap_a, mesh, cfg, shape)))
        else:
            a_state = jax.eval_shape(functools.partial(
                ssm.init_state, cfg, b))

            def mamba_decode(p, x, st, pos):
                del pos
                h, st2 = ssm.decode(p["block"],
                                    L.rmsnorm(x, p["ln"], cfg.norm_eps), st,
                                    cfg, compute_dtype=cdt)
                return x + h, st2

            out.append((cfg.num_layers,
                        _decode_unit(mamba_decode, ap_m, a_state, mesh, cfg,
                                     shape)))
            ac = jax.eval_shape(functools.partial(
                attention.init_cache, cfg, b, shape.seq_len))

            def attn_decode(p, x, c, pos):
                h, c2 = attention.decode(
                    p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), c, pos,
                    cfg, compute_dtype=cdt)
                x = x + h
                return x + L.mlp_apply(
                    p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cdt), c2

            out.append((n_sites,
                        _decode_unit(attn_decode, ap_a, ac, mesh, cfg,
                                     shape)))
        return out

    if fam == "ssm":
        ap_m = jax.eval_shape(lambda k: {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "block": xlstm.mlstm_init(k, cfg, jnp.float32)}, key)
        ap_s = jax.eval_shape(lambda k: {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "block": xlstm.slstm_init(k, cfg, jnp.float32)}, key)
        n_s = cfg.num_layers // cfg.slstm_every
        n_m = cfg.num_layers - n_s

        def m_layer(p, x):
            return x + xlstm.mlstm_apply(
                p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg,
                compute_dtype=cdt)

        def s_layer(p, x):
            return x + xlstm.slstm_apply(
                p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg,
                compute_dtype=cdt)

        if kind == "train":
            out.append((n_m, _train_unit(m_layer, ap_m, mesh, cfg, shape)))
            out.append((n_s, _train_unit(s_layer, ap_s, mesh, cfg, shape)))
        elif kind == "prefill":
            out.append((n_m, _fwd_unit(m_layer, ap_m, mesh, cfg, shape)))
            out.append((n_s, _fwd_unit(s_layer, ap_s, mesh, cfg, shape)))
        else:
            m_state = jax.eval_shape(functools.partial(
                xlstm.mlstm_state, cfg, b))
            s_state = jax.eval_shape(functools.partial(
                xlstm.slstm_state, cfg, b))

            def m_decode(p, x, st, pos):
                del pos
                h, st2 = xlstm.mlstm_decode(
                    p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps), st, cfg,
                    compute_dtype=cdt)
                return x + h, st2

            def s_decode(p, x, st, pos):
                del pos
                h, st2 = xlstm.slstm_decode(
                    p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps), st, cfg,
                    compute_dtype=cdt)
                return x + h, st2

            out.append((n_m, _decode_unit(m_decode, ap_m, m_state, mesh, cfg,
                                          shape)))
            out.append((n_s, _decode_unit(s_decode, ap_s, s_state, mesh, cfg,
                                          shape)))
        return out

    raise ValueError(fam)


def composed_stats(cfg, shape, mesh, base_stats: dict) -> tuple:
    """total = base (full program, scans ~invisible) + sum units x unit."""
    units = unit_costs(cfg, shape, mesh)
    total = dict(base_stats)
    detail = []
    for n, st in units:
        for k in total:
            total[k] = total[k] + n * st[k]
        detail.append({"units": n, **st})
    return total, detail
