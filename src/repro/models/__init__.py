"""LM substrate: configs, layers, families, unified Model API."""
from repro.models.config import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from repro.models.model import (Model, build, input_specs, cache_specs,
                                param_specs, param_count, active_param_count)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "Model", "build", "input_specs", "cache_specs", "param_specs",
    "param_count", "active_param_count",
]
