"""GQA multi-head attention: train/prefill path + cached decode step.

Decode caches:
  - full cache: (b, hkv, S, hd) written at slot = position
  - ring cache (sliding window): (b, hkv, W, hd) written at slot = pos % W —
    this is what makes mixtral's long_500k decode O(W) memory.

Keys are cached POST-RoPE (absolute positions), so ring slots need no
re-rotation; masks are built from the stored absolute position of each slot.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L


def init(key, cfg, d_model=None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, hq * hd, dtype),
        "wk": L.dense_init(ks[1], d, hkv * hd, dtype),
        "wv": L.dense_init(ks[2], d, hkv * hd, dtype),
        "wo": L.dense_init(ks[3], hq * hd, d, dtype, scale=1.0 / (hq * hd) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _project_qkv(p, x, cfg, compute_dtype, positions, rope: bool):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    x = x.astype(compute_dtype)
    q = x @ p["wq"].astype(compute_dtype)
    k = x @ p["wk"].astype(compute_dtype)
    v = x @ p["wv"].astype(compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(compute_dtype)
        k = k + p["bk"].astype(compute_dtype)
        v = v + p["bv"].astype(compute_dtype)
    q = q.reshape(b, s, hq, hd).swapaxes(1, 2)    # (b, hq, s, hd)
    k = k.reshape(b, s, hkv, hd).swapaxes(1, 2)
    v = v.reshape(b, s, hkv, hd).swapaxes(1, 2)
    if rope:
        q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


Q_CHUNK = 512   # f32 score peak = (b, h, Q_CHUNK, skv) — flash-in-XLA


def apply(p, x, cfg, *, positions=None, causal=True, window=None,
          compute_dtype=jnp.bfloat16, rope=True):
    """Full-sequence attention (train / prefill).  x: (b, s, d)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, compute_dtype, positions, rope)
    q_chunk = Q_CHUNK if s > 2 * Q_CHUNK else None
    out = ops.attention(q, k, v, causal=causal, window=window,
                        q_chunk=q_chunk)
    out = out.swapaxes(1, 2).reshape(b, s, cfg.num_heads * cfg.head_dim)
    return out @ p["wo"].astype(compute_dtype)


class KVCache(NamedTuple):
    k: jax.Array          # (b, hkv, S_or_W, hd) bf16 — or int8 when quantized
    v: jax.Array
    kpos: jax.Array       # (S_or_W,) absolute position per slot, -1 = empty
    k_scale: Optional[jax.Array] = None   # (b, hkv, S_or_W, 1) absmax/127
    v_scale: Optional[jax.Array] = None


def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16,
               d_model=None) -> KVCache:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    slots = min(seq_len, cfg.window) if cfg.window else seq_len
    if getattr(cfg, "kv_quant", False):
        return KVCache(
            k=jnp.zeros((batch, hkv, slots, hd), jnp.int8),
            v=jnp.zeros((batch, hkv, slots, hd), jnp.int8),
            kpos=jnp.full((slots,), -1, jnp.int32),
            k_scale=jnp.zeros((batch, hkv, slots, 1), jnp.float16),
            v_scale=jnp.zeros((batch, hkv, slots, 1), jnp.float16),
        )
    return KVCache(
        k=jnp.zeros((batch, hkv, slots, hd), dtype),
        v=jnp.zeros((batch, hkv, slots, hd), dtype),
        kpos=jnp.full((slots,), -1, jnp.int32),
    )


def _quantize_kv(x):
    """Per-(slot, head) absmax int8 quantization.  x: (b, hkv, s, hd)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32)
                           / jnp.maximum(scale, 1e-8)), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def _dequantize_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def decode(p, x, cache: KVCache, pos, cfg, *, compute_dtype=jnp.bfloat16,
           rope=True, window=None):
    """Single-token decode.  x: (b, 1, d); pos: scalar absolute position.

    Returns (out (b, 1, d), new_cache).  Works for both full and ring
    caches — the ring is just slot = pos % slots with stored positions.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    group = hq // hkv
    positions = jnp.broadcast_to(pos[None], (b, 1)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, compute_dtype, positions, rope)

    slots = cache.k.shape[2]
    slot = (pos % slots).astype(jnp.int32)
    quant = cache.k_scale is not None          # static (pytree structure)
    if quant:
        k_q, k_s = _quantize_kv(k_new)
        v_q, v_s = _quantize_kv(v_new)
        upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), slot, axis=2)
        k_store, v_store = upd(cache.k, k_q), upd(cache.v, v_q)
        k_scale, v_scale = upd(cache.k_scale, k_s), upd(cache.v_scale, v_s)
        k = _dequantize_kv(k_store, k_scale)
        v = _dequantize_kv(v_store, v_scale)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, axis=2)
        k_store, v_store = k, v
        k_scale = v_scale = None
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache.kpos, pos[None].astype(jnp.int32), slot, axis=0)

    # scores over all slots, masked by stored absolute positions
    qh = q.reshape(b, hkv, group, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bkgd,bksd->bkgs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale    # (b, hkv, g, slots)
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid &= kpos > pos - window
    logits = jnp.where(valid[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(compute_dtype)
    out = out @ p["wo"].astype(compute_dtype)
    return out, KVCache(k=k_store, v=v_store, kpos=kpos,
                        k_scale=k_scale, v_scale=v_scale)


def cross_init(key, cfg, dtype=jnp.float32):
    """Cross-attention projections (whisper decoder)."""
    return init(key, cfg, dtype=dtype)


def cross_apply(p, x, enc_kv, cfg, *, compute_dtype=jnp.bfloat16):
    """Cross-attention: queries from x (b, sq, d), K/V precomputed from the

    encoder output (b, hkv, se, hd) pair ``enc_kv`` — computed once at
    prefill, reused every decode step.
    """
    b, sq, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    x = x.astype(compute_dtype)
    q = (x @ p["wq"].astype(compute_dtype)).reshape(b, sq, hq, hd).swapaxes(1, 2)
    k, v = enc_kv
    out = ops.attention(q, k.astype(compute_dtype), v.astype(compute_dtype),
                        causal=False)
    out = out.swapaxes(1, 2).reshape(b, sq, hq * hd)
    return out @ p["wo"].astype(compute_dtype)


def encoder_kv(p, enc_out, cfg, *, compute_dtype=jnp.bfloat16):
    """Precompute cross-attention K/V from encoder output (b, se, d)."""
    b, se, _ = enc_out.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    e = enc_out.astype(compute_dtype)
    k = (e @ p["wk"].astype(compute_dtype)).reshape(b, se, hkv, hd).swapaxes(1, 2)
    v = (e @ p["wv"].astype(compute_dtype)).reshape(b, se, hkv, hd).swapaxes(1, 2)
    return k, v
