"""Model + shape configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen2
    window: Optional[int] = None            # sliding-window attention (mixtral)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                      # MoE layer frequency (llama4: 2)
    capacity_factor: float = 1.25
    num_shared_experts: int = 0             # llama4: 1 shared expert

    # --- SSM / hybrid ---
    ssm_state: int = 0                      # Mamba2 N
    ssm_expand: int = 2                     # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_conv: int = 4                       # causal conv width
    attn_every: int = 0                     # zamba2: shared attn every k blocks
    ssm_chunk: int = 256                    # SSD chunk length

    # --- xLSTM ---
    slstm_every: int = 0                    # interleave sLSTM every k blocks

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                    # precomputed frame embeddings

    # --- VLM (pixtral) ---
    num_patches: int = 0                    # precomputed patch embeddings

    # --- numerics / memory policy ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"           # bf16 for >=100B configs
    kv_quant: bool = False                  # int8 KV cache (+absmax scales)
    remat: str = "full"                     # none | full | dots
    loss_chunk: int = 1024                  # seq chunk for the vocab matmul

    # positions: "rope" | "sinusoidal"
    positions: str = "rope"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode a 500k context with bounded state?"""
        return (self.family in ("ssm", "hybrid")
                or (self.window is not None))

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads
                                    * 4 // self.num_heads)),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else 256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            num_patches=min(self.num_patches, 16),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            window=None if self.window is None else 32,
            loss_chunk=64,
            param_dtype="float32",
            compute_dtype="float32",
            moment_dtype="float32",
            remat="none",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: dict = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 524288-token decode has "
                       "unbounded KV + quadratic prefill; skipped per "
                       "assignment (see DESIGN.md SS5)")
    return True, ""
