"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Per the assignment the audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (b, encoder_seq, d_model) — the output of
whisper's two conv layers.  Everything downstream is faithful: LayerNorm
(+bias) pre-norm blocks, GELU MLPs with biases, MHA (kv == q heads),
sinusoidal positions, tied decoder embedding / lm head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers as L
from repro.models.config import ModelConfig


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _mlp_bias_init(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": L.dense_init(k1, d, f, dtype), "b1": jnp.zeros((f,), dtype),
        "w2": L.dense_init(k2, f, d, dtype), "b2": jnp.zeros((d,), dtype),
    }


def _mlp_bias_apply(p, x, cdt):
    x = x.astype(cdt)
    h = jax.nn.gelu(x @ p["w1"].astype(cdt) + p["b1"].astype(cdt))
    return h @ p["w2"].astype(cdt) + p["b2"].astype(cdt)


def _ln(x, p, eps):
    return L.layernorm(x, p["w"], p["b"], eps)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": _ln_init(d, dtype),
        "attn": attention.init(k1, cfg, dtype=dtype),
        "ln2": _ln_init(d, dtype),
        "mlp": _mlp_bias_init(k2, d, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": _ln_init(d, dtype),
        "self_attn": attention.init(k1, cfg, dtype=dtype),
        "ln_x": _ln_init(d, dtype),
        "cross_attn": attention.cross_init(k2, cfg, dtype=dtype),
        "ln2": _ln_init(d, dtype),
        "mlp": _mlp_bias_init(k3, d, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    pdt = L.dtype_of(cfg.param_dtype)
    ke, kd, kemb = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, pdt))(enc_keys),
        "enc_final_ln": _ln_init(cfg.d_model, pdt),
        "embed": L.embed_init(kemb, cfg.vocab_size, cfg.d_model, pdt),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, pdt))(dec_keys),
        "dec_final_ln": _ln_init(cfg.d_model, pdt),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (b, se, d) stubbed conv output -> encoder states (b, se, d)."""
    cdt = L.dtype_of(cfg.compute_dtype)
    b, se, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
    x = frames.astype(cdt) + L.sinusoidal(pos, cfg.d_model).astype(cdt)

    def body(x, p):
        h = attention.apply(p["attn"], _ln(x, p["ln1"], cfg.norm_eps), cfg,
                            causal=False, compute_dtype=cdt, rope=False)
        x = x + h
        x = x + _mlp_bias_apply(p["mlp"], _ln(x, p["ln2"], cfg.norm_eps), cdt)
        return x, None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return _ln(x, params["enc_final_ln"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, enc_out):
    """Teacher-forced decoder.  tokens: (b, s) -> hidden (b, s, d)."""
    cdt = L.dtype_of(cfg.compute_dtype)
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = params["embed"][tokens].astype(cdt)
    x = x + L.sinusoidal(pos, cfg.d_model).astype(cdt)

    def body(x, p):
        h = attention.apply(p["self_attn"], _ln(x, p["ln1"], cfg.norm_eps),
                            cfg, causal=True, compute_dtype=cdt, rope=False)
        x = x + h
        kv = attention.encoder_kv(p["cross_attn"], enc_out, cfg,
                                  compute_dtype=cdt)
        h = attention.cross_apply(p["cross_attn"],
                                  _ln(x, p["ln_x"], cfg.norm_eps), kv, cfg,
                                  compute_dtype=cdt)
        x = x + h
        x = x + _mlp_bias_apply(p["mlp"], _ln(x, p["ln2"], cfg.norm_eps), cdt)
        return x, None

    x, _ = lax.scan(body, x, params["dec_layers"])
    return _ln(x, params["dec_final_ln"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["frames"])
    x = decode_train(params, cfg, batch["tokens"], enc_out)
    cdt = L.dtype_of(cfg.compute_dtype)
    loss = L.chunked_softmax_xent(x, params["embed"].T, batch["labels"],
                                  batch["mask"], chunk=cfg.loss_chunk,
                                  compute_dtype=cdt)
    return loss, {"loss": loss}


def prefill(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["frames"])
    x = decode_train(params, cfg, batch["tokens"], enc_out)
    cdt = L.dtype_of(cfg.compute_dtype)
    return L.logits_for(x[:, -1], params["embed"].T, cdt)


# --------------------------------------------------------------------------
# cached decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Self-attn KV stacked over decoder layers + per-layer cross KV."""
    one = attention.init_cache(cfg, batch, max_len, dtype)
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one)
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cross = (jnp.zeros((cfg.num_layers, batch, hkv, cfg.encoder_seq, hd), dtype),
             jnp.zeros((cfg.num_layers, batch, hkv, cfg.encoder_seq, hd), dtype))
    return {"self": self_kv, "cross": cross}


def precompute_cross(params, cfg: ModelConfig, frames, dtype=jnp.bfloat16):
    """Encoder pass + per-layer cross K/V (prefill side of serving)."""
    enc_out = encode(params, cfg, frames)
    cdt = L.dtype_of(cfg.compute_dtype)

    def per_layer(p):
        k, v = attention.encoder_kv(p, enc_out, cfg, compute_dtype=cdt)
        return k.astype(dtype), v.astype(dtype)

    return jax.vmap(per_layer, in_axes=0)(
        params["dec_layers"]["cross_attn"])


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    cdt = L.dtype_of(cfg.compute_dtype)
    pos = jnp.asarray(pos, jnp.int32)
    x = params["embed"][token][:, None, :].astype(cdt)
    x = x + L.sinusoidal(pos[None, None], cfg.d_model).astype(cdt)

    def body(x, args):
        p, c, (ck, cv) = args
        h, c2 = attention.decode(p["self_attn"],
                                 _ln(x, p["ln1"], cfg.norm_eps), c, pos, cfg,
                                 compute_dtype=cdt, rope=False)
        x = x + h
        h = attention.cross_apply(p["cross_attn"],
                                  _ln(x, p["ln_x"], cfg.norm_eps), (ck, cv),
                                  cfg, compute_dtype=cdt)
        x = x + h
        x = x + _mlp_bias_apply(p["mlp"], _ln(x, p["ln2"], cfg.norm_eps), cdt)
        return x, c2

    x, new_self = lax.scan(body, x, (params["dec_layers"], cache["self"],
                                     cache["cross"]))
    x = _ln(x, params["dec_final_ln"], cfg.norm_eps)
    logits = L.logits_for(x[:, 0], params["embed"].T, cdt)
    return logits, {"self": new_self, "cross": cache["cross"]}
