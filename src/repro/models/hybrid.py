"""Hybrid stacks: zamba2 (Mamba2 + shared attention) and xLSTM (mLSTM/sLSTM).

zamba2: ``num_layers`` Mamba2 blocks; after every ``attn_every`` of them one
SHARED-weight full transformer block (attention + MLP) runs — zamba2's
signature trick: one set of attention weights, applied at many depths (13
sites for 81 layers / every 6).  Each site keeps its OWN KV cache.  The
stack is scanned over groups of (attn_every mamba + 1 shared-attn site);
leftover mamba layers form a scanned tail.

xLSTM: groups of ``slstm_every`` blocks, the last of each group an sLSTM
(sequential scan), the rest mLSTM (chunk-parallel).  d_ff == 0: no MLPs —
the xLSTM blocks carry the full capacity, per the paper.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers as L, ssm, xlstm
from repro.models.config import ModelConfig


# ==========================================================================
# zamba2
# ==========================================================================
def _mamba_layer_init(key, cfg, dtype):
    return {"ln": jnp.ones((cfg.d_model,), dtype),
            "block": ssm.init(key, cfg, dtype)}


def _shared_attn_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": attention.init(k1, cfg, dtype=dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": L.mlp_init(k2, d, cfg.d_ff, dtype),
    }


def _zamba_split(cfg):
    g = cfg.attn_every
    ng = cfg.num_layers // g
    tail = cfg.num_layers - ng * g
    return g, ng, tail


def zamba_init(cfg: ModelConfig, key) -> dict:
    pdt = L.dtype_of(cfg.param_dtype)
    g, ng, tail = _zamba_split(cfg)
    ke, kg, kt, ka, kh = jax.random.split(key, 5)
    gkeys = jax.random.split(kg, ng * g).reshape(ng, g, 2)
    params = {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, pdt),
        "groups": jax.vmap(jax.vmap(
            lambda k: _mamba_layer_init(k, cfg, pdt)))(gkeys),
        "shared_attn": _shared_attn_init(ka, cfg, pdt),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab_size, pdt),
    }
    if tail:
        tkeys = jax.random.split(kt, tail)
        params["tail"] = jax.vmap(
            lambda k: _mamba_layer_init(k, cfg, pdt))(tkeys)
    return params


def _shared_attn_apply(p, x, cfg, positions, cdt):
    h = attention.apply(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                        positions=positions, causal=True, compute_dtype=cdt)
    x = x + h
    return x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cdt)


def zamba_forward(params, cfg: ModelConfig, tokens):
    cdt = L.dtype_of(cfg.compute_dtype)
    g, ng, tail = _zamba_split(cfg)
    x = params["embed"][tokens].astype(cdt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def mamba_apply(p, x):
        return x + ssm.apply(p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps),
                             cfg, compute_dtype=cdt)

    def group_body(x, gp):
        def m_body(x, p):
            return mamba_apply(p, x), None
        x, _ = lax.scan(m_body, x, gp)
        x = _shared_attn_apply(params["shared_attn"], x, cfg, positions, cdt)
        return x, None

    x, _ = lax.scan(group_body, x, params["groups"])
    if tail:
        def t_body(x, p):
            return mamba_apply(p, x), None
        x, _ = lax.scan(t_body, x, params["tail"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def zamba_loss(params, cfg, batch):
    x = zamba_forward(params, cfg, batch["tokens"])
    cdt = L.dtype_of(cfg.compute_dtype)
    loss = L.chunked_softmax_xent(x, params["lm_head"], batch["labels"],
                                  batch["mask"], chunk=cfg.loss_chunk,
                                  compute_dtype=cdt)
    return loss, {"loss": loss}


def zamba_prefill(params, cfg, batch):
    x = zamba_forward(params, cfg, batch["tokens"])
    cdt = L.dtype_of(cfg.compute_dtype)
    return L.logits_for(x[:, -1], params["lm_head"], cdt)


class ZambaCache(NamedTuple):
    group_ssm: Any      # SsmState stacked (ng, g, ...)
    tail_ssm: Any       # SsmState stacked (tail, ...) or None
    attn: Any           # KVCache stacked (ng, ...)


def zamba_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> ZambaCache:
    g, ng, tail = _zamba_split(cfg)
    one_ssm = ssm.init_state(cfg, batch)
    one_kv = attention.init_cache(cfg, batch, max_len, dtype)
    stack = lambda t, pre: jax.tree.map(
        lambda a: jnp.broadcast_to(a, pre + a.shape), t)
    return ZambaCache(
        group_ssm=stack(one_ssm, (ng, g)),
        tail_ssm=stack(one_ssm, (tail,)) if tail else None,
        attn=stack(one_kv, (ng,)),
    )


def zamba_decode(params, cfg: ModelConfig, cache: ZambaCache, token, pos):
    cdt = L.dtype_of(cfg.compute_dtype)
    g, ng, tail = _zamba_split(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    x = params["embed"][token][:, None, :].astype(cdt)

    def mamba_step(p, x, st):
        h, st2 = ssm.decode(p["block"], L.rmsnorm(x, p["ln"], cfg.norm_eps),
                            st, cfg, compute_dtype=cdt)
        return x + h, st2

    def group_body(x, args):
        gp, gst, kv = args

        def m_body(x, a):
            p, st = a
            return mamba_step(p, x, st)

        x, gst2 = lax.scan(m_body, x, (gp, gst))
        sa = params["shared_attn"]
        h, kv2 = attention.decode(sa["attn"],
                                  L.rmsnorm(x, sa["ln1"], cfg.norm_eps),
                                  kv, pos, cfg, compute_dtype=cdt)
        x = x + h
        x = x + L.mlp_apply(sa["mlp"], L.rmsnorm(x, sa["ln2"], cfg.norm_eps),
                            cdt)
        return x, (gst2, kv2)

    x, (gss, kvs) = lax.scan(group_body, x,
                             (params["groups"], cache.group_ssm, cache.attn))
    tss = cache.tail_ssm
    if tail:
        def t_body(x, a):
            p, st = a
            return mamba_step(p, x, st)
        x, tss = lax.scan(t_body, x, (params["tail"], cache.tail_ssm))

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_for(x[:, 0], params["lm_head"], cdt)
    return logits, ZambaCache(group_ssm=gss, tail_ssm=tss, attn=kvs)


# ==========================================================================
# xLSTM
# ==========================================================================
def _xlstm_split(cfg):
    se = cfg.slstm_every
    assert cfg.num_layers % se == 0, (cfg.num_layers, se)
    return se, cfg.num_layers // se


def xlstm_init(cfg: ModelConfig, key) -> dict:
    pdt = L.dtype_of(cfg.param_dtype)
    se, ng = _xlstm_split(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)
    mkeys = jax.random.split(km, ng * (se - 1)).reshape(ng, se - 1, 2)
    skeys = jax.random.split(ks, ng)
    return {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, pdt),
        "mlstm": jax.vmap(jax.vmap(lambda k: {
            "ln": jnp.ones((cfg.d_model,), pdt),
            "block": xlstm.mlstm_init(k, cfg, pdt)}))(mkeys),
        "slstm": jax.vmap(lambda k: {
            "ln": jnp.ones((cfg.d_model,), pdt),
            "block": xlstm.slstm_init(k, cfg, pdt)})(skeys),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "lm_head": L.dense_init(kh, cfg.d_model, cfg.vocab_size, pdt),
    }


def xlstm_forward(params, cfg: ModelConfig, tokens):
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)

    def group_body(x, gp):
        def m_body(x, p):
            h = xlstm.mlstm_apply(p["block"],
                                  L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg,
                                  compute_dtype=cdt)
            return x + h, None
        x, _ = lax.scan(m_body, x, gp["m"])
        sp = gp["s"]
        x = x + xlstm.slstm_apply(sp["block"],
                                  L.rmsnorm(x, sp["ln"], cfg.norm_eps), cfg,
                                  compute_dtype=cdt)
        return x, None

    x, _ = lax.scan(group_body, x, {"m": params["mlstm"],
                                    "s": params["slstm"]})
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def xlstm_loss(params, cfg, batch):
    x = xlstm_forward(params, cfg, batch["tokens"])
    cdt = L.dtype_of(cfg.compute_dtype)
    loss = L.chunked_softmax_xent(x, params["lm_head"], batch["labels"],
                                  batch["mask"], chunk=cfg.loss_chunk,
                                  compute_dtype=cdt)
    return loss, {"loss": loss}


def xlstm_prefill(params, cfg, batch):
    x = xlstm_forward(params, cfg, batch["tokens"])
    cdt = L.dtype_of(cfg.compute_dtype)
    return L.logits_for(x[:, -1], params["lm_head"], cdt)


class XlstmCache(NamedTuple):
    m: Any    # MlstmState stacked (ng, se-1, ...)
    s: Any    # SlstmState stacked (ng, ...)


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> XlstmCache:
    se, ng = _xlstm_split(cfg)
    stack = lambda t, pre: jax.tree.map(
        lambda a: jnp.broadcast_to(a, pre + a.shape), t)
    return XlstmCache(
        m=stack(xlstm.mlstm_state(cfg, batch), (ng, se - 1)),
        s=stack(xlstm.slstm_state(cfg, batch), (ng,)),
    )


def xlstm_decode(params, cfg: ModelConfig, cache: XlstmCache, token, pos):
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][token][:, None, :].astype(cdt)

    def group_body(x, args):
        gp, gm, gs = args

        def m_body(x, a):
            p, st = a
            h, st2 = xlstm.mlstm_decode(p["block"],
                                        L.rmsnorm(x, p["ln"], cfg.norm_eps),
                                        st, cfg, compute_dtype=cdt)
            return x + h, st2

        x, gm2 = lax.scan(m_body, x, (gp["m"], gm))
        sp = gp["s"]
        h, gs2 = xlstm.slstm_decode(sp["block"],
                                    L.rmsnorm(x, sp["ln"], cfg.norm_eps),
                                    gs, cfg, compute_dtype=cdt)
        return x + h, (gm2, gs2)

    x, (ms, ss) = lax.scan(group_body, x,
                           ({"m": params["mlstm"], "s": params["slstm"]},
                            cache.m, cache.s))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_for(x[:, 0], params["lm_head"], cdt)
    return logits, XlstmCache(m=ms, s=ss)
