"""Shared neural-net primitives (pure JAX — no flax/optax in this container).

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init
function is shape-deterministic so ``jax.eval_shape`` over it yields the
abstract parameter tree used by the dry-run (no allocation).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, head_dim), positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions, d_model: int):
    """positions (..., s) -> (..., s, d) classic transformer sin/cos table."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# MLP (SwiGLU — llama/granite/qwen/mixtral family)
# --------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p, x, compute_dtype):
    x = x.astype(compute_dtype)
    g = x @ p["w_gate"].astype(compute_dtype)
    u = x @ p["w_up"].astype(compute_dtype)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(compute_dtype)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def chunked_softmax_xent(x, lm_head, labels, mask, *, chunk: int,
                         compute_dtype):
    """Cross-entropy WITHOUT materializing full (b, s, V) logits.

    x: (b, s, d) final hidden states; lm_head: (d, V); labels/mask: (b, s).
    Scans over sequence chunks; inside a chunk the logits exist only as a
    (b, chunk, V) transient (vocab-sharded under pjit).  Returns mean nll.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s = s + pad
    nchunk = s // chunk
    xc = x.reshape(b, nchunk, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nchunk, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, nchunk, chunk).swapaxes(0, 1)
    w = lm_head.astype(compute_dtype)

    def body(carry, args):
        xi, li, mi = args
        logits = (xi.astype(compute_dtype) @ w).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (carry[0] + nll.sum(), carry[1] + mi.sum()), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)),
                             (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def logits_for(x_last, lm_head, compute_dtype):
    """Decode-path logits for the sampled position(s): (b, d) -> (b, V)."""
    return (x_last.astype(compute_dtype)
            @ lm_head.astype(compute_dtype)).astype(jnp.float32)
