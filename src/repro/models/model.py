"""Unified model API: build(config) -> Model, plus abstract input specs.

``Model`` exposes exactly the four entry points the launcher lowers:
  loss        training step objective       (train_* shapes)
  prefill     full-sequence forward         (prefill_* shapes)
  decode      one-token cached step         (decode_* / long_* shapes)
  init_cache  cache constructor (used via eval_shape in the dry-run)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer
from repro.models.config import ModelConfig, ShapeConfig


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], tuple]
    prefill: Callable[[Any, dict], jax.Array]
    decode: Callable[[Any, Any, jax.Array, jax.Array], tuple]
    init_cache: Callable[..., Any]


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=functools.partial(_flip(transformer.init_params), cfg),
            loss=functools.partial(_bind(transformer.loss_fn), cfg),
            prefill=functools.partial(_bind(transformer.prefill), cfg),
            decode=functools.partial(_bind2(transformer.decode_step), cfg),
            init_cache=functools.partial(transformer.init_cache, cfg),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=functools.partial(_flip(encdec.init_params), cfg),
            loss=functools.partial(_bind(encdec.loss_fn), cfg),
            prefill=functools.partial(_bind(encdec.prefill), cfg),
            decode=functools.partial(_bind2(encdec.decode_step), cfg),
            init_cache=functools.partial(encdec.init_cache, cfg),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=functools.partial(_flip(hybrid.zamba_init), cfg),
            loss=functools.partial(_bind(hybrid.zamba_loss), cfg),
            prefill=functools.partial(_bind(hybrid.zamba_prefill), cfg),
            decode=functools.partial(_bind2(hybrid.zamba_decode), cfg),
            init_cache=functools.partial(hybrid.zamba_init_cache, cfg),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=functools.partial(_flip(hybrid.xlstm_init), cfg),
            loss=functools.partial(_bind(hybrid.xlstm_loss), cfg),
            prefill=functools.partial(_bind(hybrid.xlstm_prefill), cfg),
            decode=functools.partial(_bind2(hybrid.xlstm_decode), cfg),
            init_cache=functools.partial(hybrid.xlstm_init_cache, cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


def _flip(init_fn):
    # init(cfg, key) -> init(cfg)(key)
    return lambda cfg, key: init_fn(cfg, key)


def _bind(fn):
    # fn(params, cfg, batch) ordered as (cfg, params, batch)
    return lambda cfg, params, batch: fn(params, cfg, batch)


def _bind2(fn):
    return lambda cfg, params, cache, token, pos: fn(params, cfg, cache,
                                                     token, pos)


# --------------------------------------------------------------------------
# abstract inputs (the dry-run's ShapeDtypeStruct stand-ins)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract batch for (arch x shape) — no device allocation.

    train/prefill: token batch (+ stubbed modality embeddings);
    decode: one new token + position (the KV cache spec comes separately
    from ``cache_specs`` since it is carried state, not an input).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    S = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": S((b, s), i32),
            "labels": S((b, s), i32),
            "mask": S((b, s), f32),
        }
        if cfg.family == "encdec":
            specs["frames"] = S((b, cfg.encoder_seq, cfg.d_model), f32)
        if cfg.family == "vlm" and cfg.num_patches:
            specs["patches"] = S((b, cfg.num_patches, transformer.D_VISION),
                                 f32)
        if shape.kind == "prefill":
            specs.pop("labels")
            specs.pop("mask")
        return specs

    # decode: one token per sequence, scalar position
    return {"token": S((b,), i32), "pos": S((), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    """Abstract KV/state cache for decode shapes via eval_shape."""
    model = build(cfg)
    return jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch,
                          shape.seq_len, dtype))


def param_specs(cfg: ModelConfig) -> Any:
    """Abstract parameter tree (shapes only) via eval_shape."""
    model = build(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig) -> int:
    import math
    specs = param_specs(cfg)
    return sum(math.prod(p.shape) for p in jax.tree.leaves(specs))


def active_param_count(cfg: ModelConfig) -> int:
    """Active-per-token params (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    specs = param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    inactive = 0
    for path, p in flat:
        keys = "/".join(str(k) for k in path)
        if any(w in keys for w in ("w_gate", "w_up", "w_down")) \
                and "moe" in keys and "shared" not in keys:
            n = 1
            for d in p.shape:
                n *= d
            inactive += n * (1 - cfg.top_k / cfg.num_experts)
    return int(total - inactive)
