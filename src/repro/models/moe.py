"""Capacity-based top-k Mixture-of-Experts (GShard-style groups, EP-sharded).

Design notes (napkin math in EXPERIMENTS.md SSPerf):

- Dense one-hot dispatch einsum (the textbook GShard formulation) builds a
  (tokens, E, C) tensor — at llama4 scale (1M tokens x 128 experts) that is
  O(10^13) elements.  Rejected.
- A GLOBAL argsort over tokens x k assignments is O(T log T) memory-lean but
  lowers to a cross-device sort (heavy all-to-all chains under GSPMD).
  Rejected for the baseline.
- Chosen: GROUPED dispatch.  Tokens are grouped by their data-parallel
  shard (group = one sequence; decode: one group per batch row-block), the
  position-in-expert cumsum and gather/scatter stay group-local (no
  cross-device traffic), and only the expert einsum crosses the data/model
  axes — XLA inserts the one unavoidable all-to-all there.

Capacity C = ceil(S * k / E * capacity_factor) per group; overflow tokens
are dropped (standard GShard semantics), underflow slots gather a zero row.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import partition


def init(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": L.dense_init(ks[0], d, e, jnp.float32),   # router in f32
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, cfg.d_ff, dtype)
    return p


def _dispatch_indices(sel, weights, e: int, cap: int):
    """Group-local dispatch bookkeeping.

    sel: (S, k) selected expert ids; weights: (S, k) router weights.
    Returns (disp_idx (e*cap,) token index per slot with sentinel S,
             slot_w (e*cap,) combine weight per slot).
    """
    s, k = sel.shape
    e_flat = sel.reshape(-1)                                   # (S*k,)
    w_flat = weights.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)        # (S*k, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                  # rank+1 where sel
    pos_in_e = pos.sum(axis=1) - 1                             # (S*k,)
    keep = pos_in_e < cap
    dest = jnp.where(keep, e_flat * cap + pos_in_e, e * cap)   # overflow slot
    token_of = jnp.arange(s * k, dtype=jnp.int32) // k
    disp_idx = jnp.full((e * cap + 1,), s, jnp.int32).at[dest].set(token_of)
    slot_w = jnp.zeros((e * cap + 1,), w_flat.dtype).at[dest].set(w_flat)
    return disp_idx[:-1], slot_w[:-1]


def apply(p, x, cfg, *, compute_dtype=jnp.bfloat16):
    """x: (b, s, d) -> (b, s, d).  Groups = batch rows (data-sharded)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(1, math.ceil(s * k / e * cfg.capacity_factor))

    logits = (x.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))               # (b, s, E)
    weights, sel = jax.lax.top_k(logits, k)                    # (b, s, k)
    weights = jax.nn.softmax(weights, axis=-1)                 # over selected

    disp_idx, slot_w = jax.vmap(
        lambda sl, w: _dispatch_indices(sl, w, e, cap))(sel, weights)
    # disp_idx: (b, E*cap); slot_w: (b, E*cap)

    x_pad = jnp.concatenate(
        [x, jnp.zeros((b, 1, d), x.dtype)], axis=1)            # sentinel row
    xe = jnp.take_along_axis(
        x_pad, disp_idx[..., None], axis=1)                    # (b, E*cap, d)
    xe = xe.reshape(b, e, cap, d).astype(compute_dtype)

    # Expert FFN — the cross-axis einsum (tokens: data-sharded groups,
    # experts: model-sharded weights); SwiGLU like the dense MLP.
    # Explicit activation constraints pin GSPMD to the intended pattern:
    # EP (experts on tensor axis) when divisible, else TP-within-expert
    # (hidden f on the tensor axis) — mirroring _moe_in_spec.
    #
    # DECODE (s == 1): replicate the (tiny) token batch across the fsdp
    # axis instead.  With batch data-sharded, GSPMD's only way to contract
    # the fsdp-sharded d dim of the expert weights is to ALL-GATHER the
    # weights (3 x 1.34 GB/layer/step measured on llama4) — replicated
    # activations let it partial-sum locally and all-reduce the ~30 MB
    # outputs instead (SSPerf hillclimb 2 follow-up).
    ep = partition.expert_parallel_ok(e)
    bspec = None if s == 1 else "batch"
    xe = partition.constrain(xe, bspec, "tensor" if ep else None,
                             None, None)
    wg = p["w_gate"].astype(compute_dtype)
    wu = p["w_up"].astype(compute_dtype)
    wd = p["w_down"].astype(compute_dtype)
    g = jnp.einsum("becd,edf->becf", xe, wg)
    u = jnp.einsum("becd,edf->becf", xe, wu)
    g = partition.constrain(g, bspec, "tensor" if ep else None, None,
                            None if ep else "tensor")
    u = partition.constrain(u, bspec, "tensor" if ep else None, None,
                            None if ep else "tensor")
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, wd)   # (b, E, cap, d)
    y = partition.constrain(y, bspec, "tensor" if ep else None, None,
                            None)

    y = (y.reshape(b, e * cap, d)
         * slot_w[..., None].astype(compute_dtype))
    out = jnp.zeros((b, s + 1, d), compute_dtype)
    out = jax.vmap(lambda o, idx, vals: o.at[idx].add(vals))(
        out, disp_idx, y)[:, :s]

    if cfg.num_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, compute_dtype)
    return out
