"""Mamba2 (SSD — state-space duality) block, chunked for TPU.

Recurrence per head (scalar-identity A, Mamba2's choice):

    h_t = a_t * h_{t-1} + dt_t * (B_t (x) x_t)        h: (N, P)
    y_t = C_t . h_t + D * x_t                          a_t = exp(dt_t * A)

Chunked computation (chunk length Q = cfg.ssm_chunk):
  - intra-chunk: attention-like (Q, Q) lower-triangular score matmul
  - inter-chunk: lax.scan carrying the (N, P) state per head

The scan-over-chunks form is deliberate: vectorizing all chunks at once
materializes b*s*Q*h score elements (terabytes at zamba2 train shapes —
napkin math in EXPERIMENTS.md), while the scan keeps one chunk's (Q, Q)
scores live at a time and the HLO compact.  The per-chunk body is also the
natural target for a future Pallas SSD kernel (SSPerf candidate).

Decode: single-step recurrence on (conv_state, ssm_state) — O(1) per token,
which is why zamba2/xlstm run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, h, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n          # conv over [x, B, C] (n_groups = 1)
    ks = jax.random.split(key, 6)
    # in_proj -> [z (d_inner), x (d_inner), B (n), C (n), dt (h)]
    out_w = d_inner * 2 + 2 * n + h
    return {
        "in_proj": L.dense_init(ks[0], d, out_w, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) / math.sqrt(cfg.ssm_conv)
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": L.dense_init(ks[2], d_inner, d, dtype),
    }


class SsmState(NamedTuple):
    conv: jax.Array   # (b, K-1, conv_dim) last inputs for the causal conv
    h: jax.Array      # (b, heads, N, P) ssm state


def init_state(cfg, batch: int, dtype=jnp.float32) -> SsmState:
    d_inner, h, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return SsmState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        h=jnp.zeros((batch, h, n, p_dim), jnp.float32),
    )


def _split_proj(proj, cfg):
    d_inner, h, p_dim, n = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev=None):
    """Depthwise causal conv, width K.  xbc: (b, s, c); prev: (b, K-1, c)."""
    k = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    return jax.nn.silu(out + conv_b), xp[:, -(k - 1):]


def _ssd_chunk_scan(xh, dt, a_log, b_in, c_in, h0, chunk: int):
    """Chunked SSD.  xh: (b, s, h, p); dt: (b, s, h); b_in/c_in: (b, s, n).

    Returns (y (b, s, h, p), h_final (b, h, n, p)).
    """
    b, s, nh, p_dim = xh.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    a = -jnp.exp(a_log)                                  # (h,) negative
    lg = dt * a                                          # (b, s, h) log-decay
    # reshape into chunks, scan over chunk axis
    def rc(t, extra=()):
        return t.reshape((b, nc, q) + t.shape[2:]).swapaxes(0, 1)

    xs = (rc(xh), rc(dt), rc(lg), rc(b_in), rc(c_in))

    def body(h_prev, args):
        xc, dtc, lgc, bc, cc = args                      # xc: (b, q, h, p)
        cum = jnp.cumsum(lgc, axis=1)                    # (b, q, h) inclusive
        total = cum[:, -1]                               # (b, h)
        # --- intra-chunk (lower-triangular attention-like) ---
        # scores[t, u] = C_t.B_u * exp(cum_t - cum_u) * dt_u   for u <= t
        cb = jnp.einsum("btn,bun->btu", cc, bc)          # (b, q, q)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (b, t, u, h)
        tri = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: upper-triangle log-decays are positive and would
        # overflow to inf, poisoning the backward pass through where().
        decay = jnp.where(tri[None, :, :, None], decay, -jnp.inf)
        w = jnp.exp(decay)
        scores = cb[..., None] * w * dtc[:, None, :, :]  # (b, t, u, h)
        y_intra = jnp.einsum("btuh,buhp->bthp", scores, xh_f32(xc))
        # --- inter-chunk: contribution of entering state ---
        y_off = jnp.einsum("btn,bhnp,bth->bthp", cc, h_prev, jnp.exp(cum))
        # --- state update: S = sum_u exp(total - cum_u) dt_u B_u (x) x_u ---
        su = jnp.exp(total[:, None] - cum) * dtc         # (b, q, h)
        s_new = jnp.einsum("bun,buh,buhp->bhnp", bc, su, xh_f32(xc))
        h_new = jnp.exp(total)[:, :, None, None] * h_prev + s_new
        return h_new, y_intra + y_off

    h_fin, ys = lax.scan(body, h0, xs)                   # ys: (nc, b, q, h, p)
    y = ys.swapaxes(0, 1).reshape(b, s, nh, p_dim)
    return y, h_fin


def xh_f32(x):
    return x.astype(jnp.float32)


def apply(p, x, cfg, *, compute_dtype=jnp.bfloat16):
    """Full-sequence Mamba2 block.  x: (b, s, d) -> (b, s, d).

    With ``kernels.ops`` in pallas/interpret mode the SSD scan and the
    gate+norm tail run through the fused Pallas kernels (kernels/ssd.py,
    kernels/gated_norm.py); the default ref mode keeps the pure-jnp path
    the dry-run lowers.
    """
    from repro.kernels import ops
    b, s, d = x.shape
    d_inner, nh, p_dim, n = _dims(cfg)
    proj = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"].astype(compute_dtype),
                          p["conv_b"].astype(compute_dtype))
    xin, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b, s, h)
    xh = xin.reshape(b, s, nh, p_dim)
    if ops.get_mode() == "ref":
        y, _ = _ssd_chunk_scan(xh, dt, p["a_log"], b_in.astype(jnp.float32),
                               c_in.astype(jnp.float32),
                               jnp.zeros((b, nh, n, p_dim), jnp.float32),
                               cfg.ssm_chunk)
    else:
        # head-major flatten for the Pallas kernel: (b*h, s, p)
        a = -jnp.exp(p["a_log"])                              # (h,)
        x_k = xh.transpose(0, 2, 1, 3).reshape(b * nh, s, p_dim)
        dt_k = dt.transpose(0, 2, 1).reshape(b * nh, s)
        lg_k = (dt.transpose(0, 2, 1) * a[None, :, None]).reshape(b * nh, s)
        y_k = ops.ssd_scan(x_k.astype(jnp.float32), dt_k, lg_k,
                           b_in.astype(jnp.float32),
                           c_in.astype(jnp.float32), heads=nh,
                           chunk=min(cfg.ssm_chunk, s))
        y = y_k.reshape(b, nh, s, p_dim).transpose(0, 2, 1, 3)
    y = y + p["d_skip"][None, None, :, None] * xh_f32(xh)
    y = y.reshape(b, s, d_inner)
    if ops.get_mode() == "ref":
        y = y.astype(compute_dtype) * jax.nn.silu(z)          # gate
        y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    else:
        y = ops.gated_rmsnorm(y, z.astype(jnp.float32), p["norm"],
                              eps=cfg.norm_eps).astype(compute_dtype)
    return y.astype(compute_dtype) @ p["out_proj"].astype(compute_dtype)


def decode(p, x, state: SsmState, cfg, *, compute_dtype=jnp.bfloat16):
    """Single-token step.  x: (b, 1, d) -> (b, 1, d), new state."""
    b = x.shape[0]
    d_inner, nh, p_dim, n = _dims(cfg)
    proj = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, conv_prev = _causal_conv(xbc, p["conv_w"].astype(compute_dtype),
                                  p["conv_b"].astype(compute_dtype),
                                  prev=state.conv.astype(compute_dtype))
    xin, b_in, c_in = jnp.split(xbc[:, 0], [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b, h)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                               # (b, h)
    xh = xin.reshape(b, nh, p_dim).astype(jnp.float32)
    dbx = jnp.einsum("bn,bh,bhp->bhnp", b_in.astype(jnp.float32), dt, xh)
    h_new = decay[:, :, None, None] * state.h + dbx
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), h_new)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(compute_dtype), SsmState(
        conv=conv_prev.astype(state.conv.dtype), h=h_new)
