"""Decoder-only transformer stack (dense / MoE / VLM families).

Layers are scanned with stacked parameters (leading L axis) so the HLO is
one layer long regardless of depth — mandatory for 40-80-layer dry-run
compiles and the standard production trick (MaxText does the same).

MoE models stack in "superblocks" of ``moe_every`` layers whose LAST layer
is MoE (llama4: dense/MoE alternation with moe_every=2; mixtral:
moe_every=1, all-MoE).  VLM (pixtral) prepends projected patch embeddings
from the stubbed vision frontend.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers as L, moe
from repro.models.config import ModelConfig

D_VISION = 1024   # stubbed vision-frontend output width (pixtral)


# --------------------------------------------------------------------------
# layer init / apply
# --------------------------------------------------------------------------
def dense_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": attention.init(k1, cfg, dtype=dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": L.mlp_init(k2, d, cfg.d_ff, dtype),
    }


def moe_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": attention.init(k1, cfg, dtype=dtype),
        "ln2": jnp.ones((d,), dtype),
        "moe": moe.init(k2, cfg, dtype=dtype),
    }


def _attn_block(p, x, cfg, positions, cdt):
    h = attention.apply(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                        positions=positions, causal=True, window=cfg.window,
                        compute_dtype=cdt)
    return x + h


def dense_layer_apply(p, x, cfg, positions, cdt):
    x = _attn_block(p, x, cfg, positions, cdt)
    return x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cdt)


def moe_layer_apply(p, x, cfg, positions, cdt):
    x = _attn_block(p, x, cfg, positions, cdt)
    return x + moe.apply(p["moe"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg,
                         compute_dtype=cdt)


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


# --------------------------------------------------------------------------
# parameter tree
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> dict:
    pdt = L.dtype_of(cfg.param_dtype)
    ke, kl, kh, kp = jax.random.split(key, 4)
    params: dict = {"embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, pdt)}

    if cfg.num_experts:
        ns = cfg.num_layers // cfg.moe_every
        nd = cfg.moe_every - 1
        keys = jax.random.split(kl, ns * (nd + 1)).reshape(ns, nd + 1, 2)
        if nd:
            params["dense_layers"] = jax.vmap(jax.vmap(
                lambda k: dense_layer_init(k, cfg, pdt)))(keys[:, :nd])
        params["moe_layers"] = jax.vmap(
            lambda k: moe_layer_init(k, cfg, pdt))(keys[:, nd])
    else:
        keys = jax.random.split(kl, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: dense_layer_init(k, cfg, pdt))(keys)

    params["final_norm"] = jnp.ones((cfg.d_model,), pdt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab_size, pdt)
    if cfg.num_patches:
        params["patch_proj"] = L.dense_init(kp, D_VISION, cfg.d_model, pdt)
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, *, patches=None):
    """tokens: (b, s) -> final hidden states (b, s_total, d)."""
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.num_patches:
        assert patches is not None
        xp = patches.astype(cdt) @ params["patch_proj"].astype(cdt)
        x = jnp.concatenate([xp, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.positions == "sinusoidal":
        x = x + L.sinusoidal(positions, cfg.d_model).astype(cdt)

    if cfg.num_experts:
        nd = cfg.moe_every - 1

        def super_body(x, ps):
            if nd:
                def d_body(x, p):
                    return _maybe_remat(
                        lambda pp, xx: dense_layer_apply(pp, xx, cfg,
                                                         positions, cdt),
                        cfg)(p, x), None
                x, _ = lax.scan(d_body, x, ps["dense"])
            x = _maybe_remat(
                lambda pp, xx: moe_layer_apply(pp, xx, cfg, positions, cdt),
                cfg)(ps["moe"], x)
            return x, None

        stacked = {"moe": params["moe_layers"]}
        if nd:
            stacked["dense"] = params["dense_layers"]
        x, _ = lax.scan(super_body, x, stacked)
    else:
        def body(x, p):
            return _maybe_remat(
                lambda pp, xx: dense_layer_apply(pp, xx, cfg, positions, cdt),
                cfg)(p, x), None
        x, _ = lax.scan(body, x, params["layers"])

    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: tokens (b,s), labels (b,s), mask (b,s) [, patches].

    For VLM the loss covers the TEXT region only (hidden states sliced to
    the last s positions).
    """
    x = forward(params, cfg, batch["tokens"], patches=batch.get("patches"))
    s = batch["tokens"].shape[1]
    x = x[:, -s:]
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    cdt = L.dtype_of(cfg.compute_dtype)
    loss = L.chunked_softmax_xent(x, head, batch["labels"], batch["mask"],
                                  chunk=cfg.loss_chunk, compute_dtype=cdt)
    return loss, {"loss": loss}


def prefill(params, cfg: ModelConfig, batch):
    """Prefill forward; returns last-position logits (b, V)."""
    x = forward(params, cfg, batch["tokens"], patches=batch.get("patches"))
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    cdt = L.dtype_of(cfg.compute_dtype)
    return L.logits_for(x[:, -1], head, cdt)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    one = attention.init_cache(cfg, batch, max_len, dtype)

    def stack(shape_prefix):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, shape_prefix + a.shape), one)

    if cfg.num_experts:
        ns = cfg.num_layers // cfg.moe_every
        nd = cfg.moe_every - 1
        cache = {"moe": stack((ns,))}
        if nd:
            cache["dense"] = stack((ns, nd))
        return cache
    return stack((cfg.num_layers,))


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """One-token decode.  token: (b,) int32; pos: scalar int32 position.

    Returns (logits (b, V), new cache).
    """
    cdt = L.dtype_of(cfg.compute_dtype)
    x = params["embed"][token][:, None, :].astype(cdt)     # (b, 1, d)
    pos = jnp.asarray(pos, jnp.int32)
    if cfg.positions == "sinusoidal":
        x = x + L.sinusoidal(pos[None, None], cfg.d_model).astype(cdt)

    def attn_step(p, x, c):
        h, c2 = attention.decode(p["attn"],
                                 L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                                 c, pos, cfg, compute_dtype=cdt,
                                 rope=cfg.positions == "rope",
                                 window=cfg.window)
        return x + h, c2

    if cfg.num_experts:
        nd = cfg.moe_every - 1

        def super_body(x, args):
            ps, cs = args
            new_c = {}
            if nd:
                def d_body(x, a):
                    p, c = a
                    x, c2 = attn_step(p, x, c)
                    x = x + L.mlp_apply(p["mlp"],
                                        L.rmsnorm(x, p["ln2"], cfg.norm_eps),
                                        cdt)
                    return x, c2
                x, new_c["dense"] = lax.scan(d_body, x,
                                             (ps["dense"], cs["dense"]))
            x, c2 = attn_step(ps["moe"], x, cs["moe"])
            x = x + moe.apply(ps["moe"]["moe"],
                              L.rmsnorm(x, ps["moe"]["ln2"], cfg.norm_eps),
                              cfg, compute_dtype=cdt)
            new_c["moe"] = c2
            return x, new_c

        stacked_p = {"moe": params["moe_layers"]}
        stacked_c = {"moe": cache["moe"]}
        if nd:
            stacked_p["dense"] = params["dense_layers"]
            stacked_c["dense"] = cache["dense"]
        x, new_cache = lax.scan(super_body, x, (stacked_p, stacked_c))
    else:
        def body(x, args):
            p, c = args
            x, c2 = attn_step(p, x, c)
            x = x + L.mlp_apply(p["mlp"],
                                L.rmsnorm(x, p["ln2"], cfg.norm_eps), cdt)
            return x, c2
        x, new_cache = lax.scan(body, x, (params["layers"], cache))

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    return L.logits_for(x[:, 0], head, cdt), new_cache
