"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) + sLSTM (scalar, scan).

mLSTM is a gated linear-attention recurrence with exponential gating and a
log-space stabilizer (Beck et al. 2024).  Training uses the chunkwise
parallel form (same scan-over-chunks pattern as ssm.py — intra-chunk
quadratic, inter-chunk carried state (C, n, m)); decode is the O(1)
stabilized recurrence.

sLSTM has recurrent (hidden-to-gate) weights -> strictly sequential; it runs
as a ``lax.scan`` over time with block-diagonal per-head recurrent matrices.
This is the honest adaptation: sLSTM is *not* parallelizable over time (the
paper says as much), so the framework treats it as a scan layer and the
xlstm-125m config keeps it to every 4th block.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

_EPS = 1e-6


# ==========================================================================
# mLSTM
# ==========================================================================
def mlstm_init(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.num_heads
    p_dim = d // h
    ks = jax.random.split(key, 6)
    return {
        "wqkv": L.dense_init(ks[0], d, 3 * d, dtype),
        "wif": L.dense_init(ks[1], d, 2 * h, jnp.float32, scale=0.01),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.asarray([3.0] * h, jnp.float32),   # open forget gates
        "norm": jnp.ones((d,), dtype),
        "wo": L.dense_init(ks[2], d, d, dtype),
    }


class MlstmState(NamedTuple):
    c: jax.Array   # (b, h, p, p) matrix memory
    n: jax.Array   # (b, h, p) normalizer
    m: jax.Array   # (b, h) stabilizer


def mlstm_state(cfg, batch: int) -> MlstmState:
    h = cfg.num_heads
    p_dim = cfg.d_model // h
    return MlstmState(
        c=jnp.zeros((batch, h, p_dim, p_dim), jnp.float32),
        n=jnp.zeros((batch, h, p_dim), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def _gates(p, x):
    """log input / forget gates.  x: (b, s, d) -> (b, s, h) each."""
    g = x.astype(jnp.float32) @ p["wif"]
    li, lf = jnp.split(g, 2, axis=-1)
    return li + p["b_i"], jax.nn.log_sigmoid(lf + p["b_f"])


def _qkv(p, x, cfg, compute_dtype):
    b, s, d = x.shape
    h = cfg.num_heads
    p_dim = d // h
    qkv = x.astype(compute_dtype) @ p["wqkv"].astype(compute_dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    rs = lambda t: t.reshape(b, s, h, p_dim)
    return rs(q), rs(k) / math.sqrt(p_dim), rs(v)


def mlstm_apply(p, x, cfg, *, compute_dtype=jnp.bfloat16):
    """Chunk-parallel mLSTM.  x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    h = cfg.num_heads
    p_dim = d // h
    q, k, v = _qkv(p, x, cfg, compute_dtype)
    li, lf = _gates(p, x)                                  # (b, s, h)
    qc = min(cfg.ssm_chunk, s)
    assert s % qc == 0, (s, qc)
    nc = s // qc

    rc = lambda t: t.reshape((b, nc, qc) + t.shape[2:]).swapaxes(0, 1)
    xs = (rc(q.astype(jnp.float32)), rc(k.astype(jnp.float32)),
          rc(v.astype(jnp.float32)), rc(li), rc(lf))
    state0 = mlstm_state(cfg, b)

    def body(st: MlstmState, args):
        qx, kx, vx, lix, lfx = args                        # (b, qc, h, .)
        f_cum = jnp.cumsum(lfx, axis=1)                    # inclusive
        total = f_cum[:, -1]                               # (b, h)
        # log-weight of source u at row t: F_t - F_u + li_u
        src = lix - f_cum                                  # (b, qc, h)
        g_cummax = lax.cummax(src, axis=1)                 # row-wise max helper
        m_intra = f_cum + g_cummax
        m_carry = st.m[:, None, :] + f_cum                 # (b, qc, h)
        m_row = jnp.maximum(m_intra, m_carry)
        # intra weights (b, t, u, h), masked lower-tri
        lw = (f_cum[:, :, None, :] - f_cum[:, None, :, :]
              + lix[:, None, :, :] - m_row[:, :, None, :])
        tri = jnp.tril(jnp.ones((qc, qc), bool))
        # mask in LOG space before exp (inf * 0 = nan in the backward pass)
        lw = jnp.where(tri[None, :, :, None], lw, -jnp.inf)
        w = jnp.exp(lw)
        carry_w = jnp.exp(m_carry - m_row)                 # (b, qc, h)
        # numerator and normalizer
        qk = jnp.einsum("bthp,buhp->btuh", qx, kx)
        y_num = jnp.einsum("btuh,buhp->bthp", qk * w, vx)
        y_num = y_num + jnp.einsum("bthp,bhpj,bth->bthj", qx, st.c, carry_w)
        n_row = (jnp.einsum("btuh,buhp->bthp", w, kx)
                 + st.n[:, None] * carry_w[..., None])
        denom = jnp.abs(jnp.einsum("bthp,bthp->bth", qx, n_row))
        denom = jnp.maximum(denom, jnp.exp(-m_row)) + _EPS
        y = y_num / denom[..., None]
        # state update
        m_new = jnp.maximum(st.m + total, total + jnp.max(src, axis=1))
        upd_w = jnp.exp(total[:, None] - f_cum + lix - m_new[:, None])
        c_new = (st.c * jnp.exp(st.m + total - m_new)[..., None, None]
                 + jnp.einsum("buh,buhp,buhj->bhpj", upd_w, kx, vx))
        n_new = (st.n * jnp.exp(st.m + total - m_new)[..., None]
                 + jnp.einsum("buh,buhp->bhp", upd_w, kx))
        return MlstmState(c=c_new, n=n_new, m=m_new), y

    _, ys = lax.scan(body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(compute_dtype)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(compute_dtype)


def mlstm_decode(p, x, st: MlstmState, cfg, *, compute_dtype=jnp.bfloat16):
    """O(1) stabilized step.  x: (b, 1, d)."""
    b, _, d = x.shape
    h = cfg.num_heads
    p_dim = d // h
    q, k, v = _qkv(p, x, cfg, compute_dtype)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (b, h, p)
    li, lf = _gates(p, x)
    li, lf = li[:, 0], lf[:, 0]                            # (b, h)
    m_new = jnp.maximum(lf + st.m, li)
    fp = jnp.exp(lf + st.m - m_new)
    ip = jnp.exp(li - m_new)
    c = fp[..., None, None] * st.c + ip[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = fp[..., None] * st.n + ip[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)),
                        jnp.exp(-m_new)) + _EPS
    y = jnp.einsum("bhp,bhpj->bhj", q, c) / denom[..., None]
    y = y.reshape(b, 1, d).astype(compute_dtype)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(compute_dtype), MlstmState(c=c, n=n, m=m_new)


# ==========================================================================
# sLSTM
# ==========================================================================
def slstm_init(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.num_heads
    p_dim = d // h
    ks = jax.random.split(key, 3)
    return {
        # input projections for gates (z, i, f, o)
        "wx": L.dense_init(ks[0], d, 4 * d, dtype),
        # block-diagonal recurrent weights per head, per gate
        "r": (jax.random.normal(ks[1], (4, h, p_dim, p_dim), jnp.float32)
              / math.sqrt(p_dim)).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "norm": jnp.ones((d,), dtype),
        "wo": L.dense_init(ks[2], d, d, dtype),
    }


class SlstmState(NamedTuple):
    c: jax.Array   # (b, d)
    n: jax.Array   # (b, d)
    h: jax.Array   # (b, d)
    m: jax.Array   # (b, d)


def slstm_state(cfg, batch: int) -> SlstmState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SlstmState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_cell(p, xg, st: SlstmState, cfg):
    """One time step.  xg: (b, 4d) precomputed input projection."""
    b = xg.shape[0]
    d, h = cfg.d_model, cfg.num_heads
    p_dim = d // h
    hh = st.h.reshape(b, h, p_dim)
    rec = jnp.einsum("bhp,ghpj->gbhj", hh, p["r"].astype(jnp.float32))
    rec = rec.reshape(4, b, d)
    zi, ii, fi, oi = jnp.split(xg.astype(jnp.float32) + p["b"], 4, axis=-1)
    z = jnp.tanh(zi + rec[0])
    li = ii + rec[1]                                   # log input gate (exp)
    lf = jax.nn.log_sigmoid(fi + rec[2])               # log forget gate
    o = jax.nn.sigmoid(oi + rec[3])
    m_new = jnp.maximum(lf + st.m, li)
    fp = jnp.exp(lf + st.m - m_new)
    ip = jnp.exp(li - m_new)
    c = fp * st.c + ip * z
    n = fp * st.n + ip
    hout = o * c / jnp.maximum(n, _EPS)
    return SlstmState(c=c, n=n, h=hout, m=m_new)


def slstm_apply(p, x, cfg, *, compute_dtype=jnp.bfloat16):
    """Sequential sLSTM over time.  x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    xg = x.astype(compute_dtype) @ p["wx"].astype(compute_dtype)  # (b, s, 4d)

    def body(st, xg_t):
        st = _slstm_cell(p, xg_t, st, cfg)
        return st, st.h

    _, hs = lax.scan(body, slstm_state(cfg, b), xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(compute_dtype)        # (b, s, d)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(compute_dtype)


def slstm_decode(p, x, st: SlstmState, cfg, *, compute_dtype=jnp.bfloat16):
    xg = (x.astype(compute_dtype) @ p["wx"].astype(compute_dtype))[:, 0]
    st = _slstm_cell(p, xg, st, cfg)
    y = st.h[:, None].astype(compute_dtype)
    y = L.rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(compute_dtype), st
