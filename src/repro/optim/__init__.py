from repro.optim.adamw import adamw, AdamWState, Optimizer, global_norm
from repro.optim import schedules, compression
from repro.optim.newton_krylov import newton_krylov, NKState

__all__ = ["adamw", "AdamWState", "Optimizer", "global_norm", "schedules",
           "compression", "newton_krylov", "NKState"]
