"""AdamW with configurable moment dtype (pure JAX, pytree state).

bf16 moments are the memory lever that lets the 400 B llama4 config fit
256 x 16 GB HBM (see EXPERIMENTS.md SSDry-run napkin math): fp32 master
params + bf16 m/v = 8 bytes/param instead of 12.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of


class AdamWState(NamedTuple):
    step: jax.Array
    m: any
    v: any


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def adamw(lr: Callable | float, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype: str = "float32",
          grad_clip: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)
    mdt = dtype_of(moment_dtype)

    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = jnp.asarray(lr_fn(step), jnp.float32)

        if grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros((), jnp.float32)

        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * delta
            return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v), \
            {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init=init, update=update)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
