"""Gradient compression for the cross-pod hop (distributed-optimization trick).

int8 block-quantized all-reduce: gradients are quantized per 256-value block
(absmax scaling) before the cross-pod reduction and dequantized after —
4x less ICI traffic on the slowest (inter-pod) links at <1% relative error
(verified by tests/test_optim.py).  Error feedback keeps the quantization
residual locally and folds it into the next step, making the scheme
convergence-safe (Seide et al. 2014; Karimireddy et al. 2019).

Usage inside a shard_map'd step:
    g8, scale = quantize(g)
    g8 = lax.psum(g8.astype(f32)...)   # or psum on int32-accumulated blocks
    g  = dequantize(g8, scale) / n_pods

The pjit training path keeps XLA-generated reductions; this module is the
explicit variant for the cross-pod axis where ICI is scarcest, exercised by
the tests and available to the launcher via ``--grad-compression=int8``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Quantized(NamedTuple):
    q: jax.Array        # int8 payload, shape = padded flat
    scale: jax.Array    # f32 per-block absmax / 127
    shape: tuple        # original shape (static)


def quantize(x: jax.Array) -> Quantized:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return Quantized(q=q.astype(jnp.int8), scale=scale[:, 0], shape=shape)


def dequantize(qx: Quantized) -> jax.Array:
    blocks = qx.q.astype(jnp.float32) * qx.scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in qx.shape:
        n *= d
    return flat[:n].reshape(qx.shape)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum: quantize -> sum int32 -> dequantize.

    The int8 payloads are summed in int32 (no overflow for <=2^23 devices
    on an axis) against a max-combined scale; slightly lossier than f32
    psum but 4x cheaper on the link.
    """
    qx = quantize(x)
    # share a common scale (max over the axis) so payloads are summable
    scale = jax.lax.pmax(qx.scale, axis_name)
    requant = jnp.clip(jnp.round(
        qx.q.astype(jnp.float32) * (qx.scale / jnp.maximum(scale, 1e-12)
                                    )[:, None]), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    blocks = total.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in qx.shape:
        n *= d
    return flat[:n].reshape(qx.shape)


class ErrorFeedback(NamedTuple):
    residual: jax.Array


def ef_init(x: jax.Array) -> ErrorFeedback:
    return ErrorFeedback(residual=jnp.zeros_like(x, dtype=jnp.float32))


def ef_compress(x: jax.Array, ef: ErrorFeedback):
    """Error-feedback wrapper: returns (quantized, new_state)."""
    target = x.astype(jnp.float32) + ef.residual
    qx = quantize(target)
    err = target - dequantize(qx)
    return qx, ErrorFeedback(residual=err)
