"""Newton--Krylov optimizer: GMRES as the inner solver of LM training.

This is where the paper's solver becomes a first-class training feature:
each outer step solves the damped Gauss-Newton/Hessian system

    (H + lambda I) p = -g        H v = jvp(grad L)(v)   (matrix-free)

with restarted GMRES (core.gmres) on the FLATTENED parameter vector, then
applies x <- x + p with a trust-region-ish damping update (Levenberg-
Marquardt schedule).  Entirely matrix-free: memory = a few parameter-sized
vectors + the (m+1, n) Krylov basis — choose small m (5-10).

This is the standard deployment shape of Krylov methods in deep learning
(Hessian-free optimization, Martens 2010), and it is architecture-agnostic:
any ``loss(params, batch)`` works, which is how every assigned architecture
exercises the paper's technique (DESIGN.md SS5).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import gmres
from repro.core.operators import FunctionOperator


class NKState(NamedTuple):
    step: jax.Array
    damping: jax.Array


def newton_krylov(loss_fn: Callable, *, m: int = 8, tol: float = 1e-3,
                  max_restarts: int = 1, damping: float = 1.0,
                  lr: float = 1.0):
    """loss_fn(params, batch) -> scalar.  Returns (init, update)."""

    def init(params) -> NKState:
        del params
        return NKState(step=jnp.zeros((), jnp.int32),
                       damping=jnp.asarray(damping, jnp.float32))

    def update(params, state: NKState, batch):
        flat, unravel = ravel_pytree(params)
        n = flat.shape[0]

        def flat_loss(fp):
            return loss_fn(unravel(fp), batch)

        g = jax.grad(flat_loss)(flat)

        def hvp(v, p):
            return (jax.jvp(jax.grad(flat_loss), (p,), (v,))[1]
                    + state.damping * v)

        op = FunctionOperator(hvp, n, captures=(flat,))
        res = gmres(op, -g, m=m, tol=tol, max_restarts=max_restarts,
                    gs="cgs2")
        newton = flat + lr * res.x
        # The damping->inf limit of the LM step, (H + lambda I)^{-1} g ->
        # g / lambda: a short steepest-descent step.  On an indefinite
        # Hessian an inexact small-m Krylov solve can return an ASCENT
        # direction; rather than burn the whole iteration waiting for the
        # damping schedule to catch up, fall back to this step whenever the
        # Newton step is rejected (standard LM behavior: reject-and-retry
        # within the iteration, here jit-staged as a 3-way select).
        grad_step = flat - (lr / state.damping) * g

        # Levenberg-Marquardt damping schedule on actual-vs-predicted
        loss0 = flat_loss(flat)
        loss_newton = flat_loss(newton)
        improved = loss_newton < loss0       # Newton quality drives damping
        new_damping = jnp.where(improved, state.damping * 0.7,
                                state.damping * 2.0)

        def _reject(_):
            # Evaluated only on rejection: the fallback costs its extra
            # forward pass off the hot (accepted-step) path.
            loss_grad = flat_loss(grad_step)
            ok = loss_grad < loss0
            return (jnp.where(ok, grad_step, flat),
                    jnp.where(ok, loss_grad, loss0))

        new_flat, loss1 = jax.lax.cond(
            improved, lambda _: (newton, loss_newton), _reject, None)
        return unravel(new_flat), NKState(step=state.step + 1,
                                          damping=new_damping), {
            "loss": loss0, "loss_after": loss1,
            "gmres_residual": res.residual,
            "gmres_steps": res.inner_steps,
            "damping": state.damping,
        }

    return init, update
