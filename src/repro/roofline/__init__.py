from repro.roofline.analysis import (Roofline, analyze, parse_collectives,
                                     parse_collectives_by_computation,
                                     split_computations,
                                     innermost_loop_collectives,
                                     model_flops_for, PEAK_FLOPS, HBM_BW,
                                     LINK_BW)

__all__ = ["Roofline", "analyze", "parse_collectives",
           "parse_collectives_by_computation", "split_computations",
           "innermost_loop_collectives", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]
