from repro.roofline.analysis import (Roofline, analyze, parse_collectives,
                                     model_flops_for, PEAK_FLOPS, HBM_BW,
                                     LINK_BW)

__all__ = ["Roofline", "analyze", "parse_collectives", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]
