"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), in SECONDS per step:

    compute    = FLOPs_per_chip / peak_FLOPs_per_chip
    memory     = HBM_bytes_per_chip / HBM_bandwidth
    collective = sum over collective ops of ring-model time on the slowest
                 axis the op spans (bytes x (g-1)/g / link_bw, x2 for
                 all-reduce)

FLOPs / bytes come from ``compiled.cost_analysis()`` (per-device: cost
analysis runs on the SPMD-partitioned module).  Collective bytes are NOT in
cost_analysis — we parse the optimized HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
with replica_groups giving each op's group size.

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link
ICI (we model ring collectives at 2 simultaneous link directions per chip:
eff_bw = 2 x 45 GB/s usable).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 45e9               # usable bytes/s per ICI link direction
RING_LINKS = 2               # ring uses both directions of one axis

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes appearing in a result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    count: int = 1

    def ring_seconds(self) -> float:
        g = max(self.group_size, 2)
        eff = (g - 1) / g
        bw = LINK_BW * RING_LINKS
        if self.kind == "all-reduce":
            return 2 * self.result_bytes * eff / bw
        if self.kind == "collective-permute":
            return self.result_bytes / bw
        # all-gather result bytes are the FULL gathered buffer; each chip
        # receives (g-1)/g of it.  reduce-scatter/all-to-all move ~result.
        return self.result_bytes * eff / bw


def _match_collective(line: str) -> Optional[tuple]:
    """(kind, result_bytes, group_size) when the HLO line is a collective."""
    s = line.strip()
    m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\d]+)\s+"
                 r"([\w\-]+)\(", s)
    if not m:
        return None
    result_type, opname = m.group(1), m.group(2)
    kind = None
    for c in _COLLECTIVES:
        if opname == c or opname.startswith(c + "-start") or \
                opname.startswith(c + "."):
            kind = c
            break
    if kind is None:
        return None
    rbytes = _shape_bytes(result_type)
    gm = _GROUPS_RE.search(s)
    if gm:
        gsize = len(gm.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(s)
        gsize = int(gi.group(2)) if gi else 2
    return kind, rbytes, gsize


def _collect(lines) -> list[CollectiveOp]:
    ops: dict[tuple, CollectiveOp] = {}
    for line in lines:
        key = _match_collective(line)
        if key is None:
            continue
        if key in ops:
            ops[key].count += 1
        else:
            ops[key] = CollectiveOp(*key)
    return list(ops.values())


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    return _collect(hlo_text.splitlines())


def split_computations(hlo_text: str) -> dict[str, str]:
    """HLO computation name -> body text (computations are flat in HLO text:
    a ``%name (...) -> ... {`` header at column 0, closed by ``}``)."""
    comps: dict[str, str] = {}
    name, body = None, []
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                name, body = m.group(1), []
                continue
        if name is not None:
            if line.startswith("}"):
                comps[name] = "\n".join(body)
                name, body = None, []
            else:
                body.append(line)
    return comps


def parse_collectives_by_computation(
        hlo_text: str) -> dict[str, list[CollectiveOp]]:
    return {name: _collect(body.splitlines())
            for name, body in split_computations(hlo_text).items()}


def innermost_loop_collectives(hlo_text: str):
    """Collectives of the hot (innermost collective-bearing) while body.

    Whole-program collective counts dilute per-step schedule differences
    with shared prologue/epilogue work (initial residual, final gather, the
    per-restart true-residual recompute), so per-STEP claims — like the
    pipelined scheme's "one psum per Arnoldi step" — must be read off the
    inner loop body.  HLO while ops name their body computation
    (``body=%name``); a body's OWN ``body=`` references give the loop
    nesting (restart cycle -> Arnoldi step -> Givens helper loops).  This
    picks the deepest-nested body that directly issues collectives (ties
    broken toward more collectives — the Arnoldi body; deeper helper loops
    carry none) and returns ``(name, ops)``; ``(None, [])`` when the
    program has no loop collectives.
    """
    comps = split_computations(hlo_text)
    bodies = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    children = {b: set(re.findall(r"body=%?([\w.\-]+)", comps.get(b, "")))
                for b in bodies}

    def depth(b, seen=frozenset()):
        if b in seen:
            return 0
        parents = [p for p, cs in children.items() if b in cs]
        return 1 + max((depth(p, seen | {b}) for p in parents), default=0)

    best = (0, 0)
    best_name, best_ops = None, []
    for name in bodies:
        body = comps.get(name)
        if body is None:
            continue
        ops = _collect(body.splitlines())
        n = sum(o.count for o in ops)
        if n and (depth(name), n) > best:
            best = (depth(name), n)
            best_name, best_ops = name, ops
    return best_name, best_ops


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float          # from cost_analysis (partitioned module)
    hbm_bytes_per_chip: float
    collective_bytes: float        # summed result bytes of collectives
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float             # 6 * N_active * D tokens (global)
    useful_ratio: float            # model_flops / (flops_per_chip * chips)
    bytes_per_device: Optional[float] = None   # memory_analysis if available
    notes: str = ""

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            bytes_per_device: Optional[float] = None,
            notes: str = "") -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    coll_bytes = sum(c.result_bytes * c.count for c in colls)
    coll_s = sum(c.ring_seconds() * c.count for c in colls)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        collective_bytes=coll_bytes, compute_s=compute_s, memory_s=memory_s,
        collective_s=coll_s, bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=useful, bytes_per_device=bytes_per_device, notes=notes)


def model_flops_for(cfg, shape, n_active: int) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference shapes."""
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
