from repro.runtime.fault_tolerance import Runner, RunnerConfig, StragglerMonitor
from repro.runtime.elastic import plan, make_mesh_from_plan, ElasticPlan

__all__ = ["Runner", "RunnerConfig", "StragglerMonitor", "plan",
           "make_mesh_from_plan", "ElasticPlan"]
