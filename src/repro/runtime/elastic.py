"""Elastic mesh policy: rebuild a valid (data, model) mesh from survivors.

Invariants on failure:
  - the MODEL axis degree is preserved (TP/EP change the numerics layout;
    re-sharding a 16-way-TP checkpoint to 12-way mid-run is a migration,
    not a restart)
  - the DATA (and POD) axes shrink to the largest size the surviving
    device count supports; global batch is preserved by increasing the
    per-device batch (grad accumulation hook) or, if configured, scaled
    down with the LR (linear scaling rule)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    global_batch: int
    grad_accum: int
    lr_scale: float


def plan(n_devices: int, *, model_parallel: int, global_batch: int,
         want_pods: int = 1, keep_global_batch: bool = True) -> ElasticPlan:
    """Largest legal mesh for ``n_devices`` with a fixed model axis."""
    if n_devices % model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}")
    rest = n_devices // model_parallel
    pods = want_pods
    while pods > 1 and rest % pods:
        pods -= 1
    data = rest // pods

    # keep the global batch by accumulating when DP shrank
    full_dp = data * pods
    accum = 1
    lr_scale = 1.0
    if keep_global_batch:
        while global_batch % (full_dp * accum) and accum < 64:
            accum += 1
        if global_batch % (full_dp * accum):
            # fall back: shrink batch + linear LR scaling
            new_batch = (global_batch // full_dp) * full_dp
            lr_scale = new_batch / global_batch
            global_batch = new_batch
            accum = 1
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"), global_batch, accum,
                           lr_scale)
    return ElasticPlan((data, model_parallel), ("data", "model"),
                       global_batch, accum, lr_scale)


def make_mesh_from_plan(p: ElasticPlan, devices: Optional[Sequence] = None):
    if devices is None:
        devices = jax.devices()
    n = 1
    for s in p.mesh_shape:
        n *= s
    return jax.make_mesh(p.mesh_shape, p.axis_names,
                         devices=list(devices)[:n])
