"""Fault-tolerant training runner: checkpoint/restart, elastic re-meshing,

straggler detection.  The runner owns the outer loop a 1000-node deployment
needs:

  - periodic async checkpoints (off the step critical path)
  - on ANY step failure: restore the last complete checkpoint, rebuild the
    mesh from the surviving device set (elastic: the data axis shrinks, the
    model axis is preserved — TP degree is a numerics contract, DP is not),
    re-lower the step, resume from the restored step with the SAME data
    stream (the pipeline is a pure function of (seed, step, host))
  - straggler monitor: per-step wall-time z-score; persistent outliers
    raise a hook the cluster layer maps to "demote host / promote spare"

The device-failure path is exercised in tests via an injected fault (a step
function that raises on a chosen step) plus a shrunken fake-device mesh —
the same code path a real XLA `DataLoss`/halt error takes.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import checkpoint as ckpt

log = logging.getLogger("repro.runtime")


class StragglerMonitor:
    """Flags steps (and, across restarts, hosts) with outlier wall-times."""

    def __init__(self, window: int = 50, zscore: float = 3.0,
                 min_samples: int = 10):
        self.window = window
        self.zscore = zscore
        self.min_samples = min_samples
        self.times: list = []
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < self.min_samples:
            return False
        mu = float(np.mean(hist))
        sd = float(np.std(hist)) + 1e-9
        if (dt - mu) / sd > self.zscore:
            self.flagged.append((step, dt, mu))
            return True
        return False


@dataclass
class RunnerConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    max_failures: int = 3
    straggler_window: int = 50


@dataclass
class Runner:
    """Owns the fault-tolerant outer loop.

    build_step(mesh) -> step_fn(state, batch) -> (state, metrics): re-invoked
    after every elastic re-mesh so shardings re-bind to the new topology.
    make_mesh(n_failures) -> mesh: the elasticity policy (see elastic.py).
    """
    config: RunnerConfig
    make_mesh: Callable[[int], Any]
    build_step: Callable[[Any], Callable]
    init_state: Callable[[Any], Any]        # mesh -> train state pytree
    batch_for: Callable[[int, Any], Any]    # (step, mesh) -> device batch

    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    failures: int = 0

    def run(self, num_steps: int, *, state=None,
            on_metrics: Optional[Callable] = None):
        cp = ckpt.AsyncCheckpointer(self.config.checkpoint_dir)
        mesh = self.make_mesh(self.failures)
        step_fn = self.build_step(mesh)
        if state is None:
            state = self.init_state(mesh)
        start = 0
        restored = self._try_restore(state)
        if restored is not None:
            state, start = restored
            log.info("restored checkpoint at step %d", start)

        step = start
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                batch = self.batch_for(step, mesh)
                state, metrics = step_fn(state, batch)
                dt = time.perf_counter() - t0
                if self.monitor.record(step, dt):
                    log.warning("straggler step %d: %.3fs", step, dt)
                if on_metrics:
                    on_metrics(step, metrics, dt)
                step += 1
                if step % self.config.checkpoint_every == 0:
                    cp.save_async(step, state, extra={"step": step})
                    ckpt.cleanup(self.config.checkpoint_dir,
                                 self.config.keep_checkpoints)
            except Exception as e:   # device loss / injected fault
                self.failures += 1
                log.error("step %d failed (%s); failure %d/%d", step, e,
                          self.failures, self.config.max_failures)
                if self.failures > self.config.max_failures:
                    raise
                cp.wait()
                # elastic re-mesh: data axis may shrink; model axis fixed
                mesh = self.make_mesh(self.failures)
                step_fn = self.build_step(mesh)
                state = self.init_state(mesh)
                restored = self._try_restore(state)
                if restored is not None:
                    state, step = restored
                else:
                    step = start   # no checkpoint yet: replay from scratch
                log.info("resumed at step %d on %s", step,
                         dict(mesh.shape) if hasattr(mesh, "shape")
                         else mesh)
        cp.wait()
        cp.save_async(step, state, extra={"step": step})
        cp.wait()
        return state, step

    def _try_restore(self, state_like):
        step = ckpt.latest_step(self.config.checkpoint_dir)
        if step is None:
            return None
        state, manifest = ckpt.restore(self.config.checkpoint_dir,
                                       state_like, step=step)
        return state, manifest["extra"].get("step", step)
