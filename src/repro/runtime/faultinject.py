"""Deterministic fault injection for the recovery stack.

PR 7 proved faults could be *injected* ad hoc (dispatch spies, NaN
admission, booby-trapped kernels); this module generalizes that into one
site-keyed, replayable schedule so every recovery path — the degradation
ladder in ``core/recovery.py``, lane quarantine and the circuit breaker in
``serve/server.py`` — is driven by scripted faults in tests, benches and
CI.

A *site* is a string naming an instrumented point in the stack; the code
at that point calls ``fire(site, index)`` (or ``check``, the raising
variant) with a deterministic index — the scheduler tick or the restart
cycle count.  A fault fires when an active schedule entry matches the
site and index; entries are consumed (``times`` firings, default 1), so a
retry of the same tick/cycle succeeds — exactly the transient-fault shape
the ladder's bounded-retry path is built for.

Two ways to schedule faults, composable:

  env         ``REPRO_FAULT="serve.cycle:3,core.cycle_nan:1:2"`` —
              ``site:index[:times]``; ``index='*'`` matches any index,
              ``times='*'`` never exhausts.  Parsed lazily once per
              process; ``reset()`` re-arms it (tests).
  context     ``with faultinject.inject("core.cycle", at=2): ...`` —
              scoped, stacked, independent of the env schedule.

The registry below names every instrumented site; ``tools/faultinject.py``
is the CLI shim that validates a schedule and execs a command under it.
Everything here is host-side Python — no jax dependency, importable
anywhere.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, List, Optional

# Registered injection sites -> where the index comes from.  Sites live at
# HOST-side seams (around jitted calls, never inside a trace) so firing is
# deterministic and replayable regardless of backend.
SITES = {
    "serve.cycle": "raise in SolverServer.step before the block cycle "
                   "(index = scheduler tick)",
    "serve.lane_nan": "poison the lowest-indexed active lane's iterate "
                      "after the block cycle (index = scheduler tick)",
    "core.cycle": "raise before a self-healing solve's restart cycle "
                  "(index = committed cycle count)",
    "core.cycle_nan": "poison a self-healing solve's cycle output with NaN "
                      "(index = committed cycle count)",
}


class InjectedFault(RuntimeError):
    """The scripted failure raised at raising sites (serve.cycle, ...)."""

    def __init__(self, site: str, index: Optional[int] = None):
        self.site = site
        self.index = index
        super().__init__(f"injected fault at {site}"
                         + ("" if index is None else f" (index {index})"))


# A schedule entry is a mutable [index_or_None, remaining_or_None] pair:
# index None matches any index, remaining None never exhausts.
_Entry = List[Optional[int]]

_env_schedule: Optional[Dict[str, List[_Entry]]] = None   # lazy REPRO_FAULT
_ctx_schedule: List[tuple] = []                           # (site, entry) stack
fired: Dict[str, int] = {}                                # site -> count


def parse_schedule(spec: str) -> Dict[str, List[_Entry]]:
    """Parse ``site:index[:times],...`` into a schedule dict (validated)."""
    sched: Dict[str, List[_Entry]] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        fields = part.split(":")
        if len(fields) not in (2, 3):
            raise ValueError(f"bad REPRO_FAULT entry {part!r}; expected "
                             f"site:index[:times]")
        site, idx = fields[0], fields[1]
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; options: "
                             f"{sorted(SITES)}")
        index = None if idx == "*" else int(idx)
        times: Optional[int] = 1
        if len(fields) == 3:
            times = None if fields[2] == "*" else int(fields[2])
        sched.setdefault(site, []).append([index, times])
    return sched


def _env() -> Dict[str, List[_Entry]]:
    global _env_schedule
    if _env_schedule is None:
        spec = os.environ.get("REPRO_FAULT", "")
        _env_schedule = parse_schedule(spec) if spec else {}
    return _env_schedule


def reset() -> None:
    """Drop all consumed state and re-arm the env schedule (test hook)."""
    global _env_schedule
    _env_schedule = None
    _ctx_schedule.clear()
    fired.clear()


def _try(entries: List[_Entry], index: Optional[int]) -> bool:
    for entry in entries:
        want, remaining = entry
        if remaining is not None and remaining <= 0:
            continue
        if want is not None and index is not None and want != index:
            continue
        if remaining is not None:
            entry[1] = remaining - 1
        return True
    return False


def fire(site: str, index: Optional[int] = None) -> bool:
    """True if a scheduled fault fires at (site, index); consumes the entry.

    Context-manager schedules are consulted innermost-first, then the env
    schedule — so a test's scoped injection wins over an ambient CI
    schedule without disturbing it.
    """
    for ctx_site, entry in reversed(_ctx_schedule):
        if ctx_site == site and _try([entry], index):
            fired[site] = fired.get(site, 0) + 1
            return True
    if _try(_env().get(site, []), index):
        fired[site] = fired.get(site, 0) + 1
        return True
    return False


def armed(*sites: str) -> bool:
    """True if any unexhausted schedule entry targets one of ``sites``.

    Non-consuming.  The self-healing solver's fused fast path checks this:
    a fast-path solve never visits the per-cycle sites, so an armed
    schedule forces the cycle-stepped loop — otherwise
    ``REPRO_FAULT=core.cycle:2`` would silently inject nothing.
    """
    live = lambda e: e[1] is None or e[1] > 0
    for ctx_site, entry in _ctx_schedule:
        if ctx_site in sites and live(entry):
            return True
    return any(live(e) for s in sites for e in _env().get(s, []))


def check(site: str, index: Optional[int] = None) -> None:
    """Raising variant of ``fire`` for sites that model a crashed call."""
    if fire(site, index):
        raise InjectedFault(site, index)


@contextlib.contextmanager
def inject(site: str, at: Optional[int] = None, times: Optional[int] = 1):
    """Scoped schedule entry: fire at ``(site, at)`` up to ``times`` times.

    ``at=None`` matches any index; ``times=None`` never exhausts.  Yields
    the live entry so callers can inspect how much of it was consumed.
    """
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r}; options: "
                         f"{sorted(SITES)}")
    entry: _Entry = [at, times]
    _ctx_schedule.append((site, entry))
    try:
        yield entry
    finally:
        _ctx_schedule.remove((site, entry))
