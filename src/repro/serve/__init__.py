"""GMRES-as-a-service: continuous batching over the block solver's lanes.

``gmres_batched`` runs k right-hand sides in lockstep off ONE A stream;
this package turns that engine into a server: a backpressured request
queue, a pure tick-driven scheduler that packs heterogeneous (b, tol,
budget) solves into lanes and retires/refills them at restart
boundaries, and an LRU of pre-lowered solver handles so admission never
compiles.  See docs/serving.md for the state machine.
"""
from repro.serve.handles import (HandleCache, HandleKey, SolverHandle,
                                 operator_fmt)
from repro.serve.queue import BackpressuredQueue
from repro.serve.request import (DONE, FAILED, PENDING, REJECTED, RUNNING,
                                 TERMINAL, TIMEOUT, AdmissionError,
                                 SolveOutcome, SolveRequest, validate_b,
                                 validate_params)
from repro.serve.server import SolverServer
from repro.serve import scheduler

__all__ = [
    "AdmissionError", "BackpressuredQueue", "DONE", "FAILED", "HandleCache",
    "HandleKey", "PENDING", "REJECTED", "RUNNING", "SolveOutcome",
    "SolveRequest", "SolverHandle", "SolverServer", "TERMINAL", "TIMEOUT",
    "operator_fmt", "scheduler", "validate_b", "validate_params",
]
