"""Solver handles: pre-lowered batched cycles behind an LRU.

Admission must never pay a compile.  A :class:`SolverHandle` wraps ONE
jitted ``gmres_batched_cycle`` for a fixed ``(n, operator fmt, m, k,
dtype)`` bucket; jax compiles it on the handle's FIRST cycle and every
later request in the bucket reuses the executable.  The
:class:`HandleCache` is a bounded LRU (kernels/tuning.LruCache) over
those buckets — the compiled-executable complement of the on-disk
``persistent_choice`` cache, which already makes the tile choices INSIDE
the lowering restart-stable.

The handle's kernel dispatch is the solver core's, untouched: CGS2-family
schemes go through the batched block-GS kernel when ``tuning.kernel_mode``
and ``tuning.block_gs_fits`` allow, and degrade to the vmapped jnp
reference otherwise — which is exactly the VMEM-overflow fallback the
fault-injection tests force.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.gmres import gmres_batched_cycle
from repro.kernels.tuning import LruCache


def operator_fmt(op) -> str:
    """Stable format tag for handle keys ('dense', 'sparse', 'banded'...)."""
    name = type(op).__name__
    if name.endswith("Operator"):
        return name[:-len("Operator")].lower()
    if hasattr(op, "ndim"):          # raw dense array
        return "dense"
    return "function"


def operator_dim(op) -> int:
    shape = getattr(op, "shape", None)
    if shape is None:
        raise ValueError(
            "operator has no .shape; wrap it in a FunctionOperator so the "
            "server can size its lanes")
    return int(shape[0])


class HandleKey(NamedTuple):
    """LRU key: everything that changes the lowered cycle."""

    n: int
    fmt: str
    m: int
    k: int
    dtype: str


class SolverHandle:
    """One operator bucket's jitted lockstep cycle.

    ``jax.jit`` is lazy, so constructing a handle is cheap; the compile
    lands on the first ``cycle`` call and is keyed by the (k, n) block
    shapes, which the handle pins.  The operator itself is a static
    closure — one handle per A, which is the batched engine's contract
    (ONE A stream shared by all k lanes).
    """

    def __init__(self, op, *, m: int = 30, k: int = 8,
                 dtype=jnp.float32, gs: str = "cgs2",
                 precond=None):
        self.op = op
        self.key = HandleKey(n=operator_dim(op), fmt=operator_fmt(op),
                             m=int(m), k=int(k),
                             dtype=jnp.dtype(dtype).name)
        self.gs = gs
        self._cycle = jax.jit(functools.partial(
            gmres_batched_cycle, op, m=int(m), gs=gs, precond=precond,
            compute_dtype=dtype))
        self.cycles_run = 0

    @property
    def n(self) -> int:
        return self.key.n

    @property
    def k(self) -> int:
        return self.key.k

    @property
    def m(self) -> int:
        return self.key.m

    def block_shape(self) -> Tuple[int, int]:
        return (self.key.k, self.key.n)

    def cycle(self, b, x, tol_abs, active):
        """One lockstep restart cycle; returns ``(x', beta', inner_steps)``.

        All arguments are full (k, n) / (k,) blocks — idle lanes ride
        along masked out (their x passes through untouched), which keeps
        one executable valid for every occupancy level.
        """
        dt = jnp.dtype(self.key.dtype)
        b = jnp.asarray(b, dt)
        x = jnp.asarray(x, dt)
        if b.shape != self.block_shape() or x.shape != self.block_shape():
            raise ValueError(
                f"handle {self.key} expects {self.block_shape()} blocks, "
                f"got b{b.shape} x{x.shape}")
        out = self._cycle(b, x, tol_abs=jnp.asarray(tol_abs, dt),
                          active=jnp.asarray(active, bool))
        self.cycles_run += 1
        return out


class HandleCache:
    """LRU of :class:`SolverHandle`, keyed by ``(n, fmt, m, k, dtype)``.

    ``get`` is the only entry point: hit moves the handle to the front,
    miss builds one (cheap — lowering is lazy) and may evict the coldest
    bucket, dropping its compiled executable with it.  Stats surface as
    ``solver_serve_*`` metrics so cache thrash is visible in the bench.
    """

    def __init__(self, maxsize: int = 8):
        self._lru = LruCache(maxsize=maxsize)

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key) -> bool:
        return key in self._lru

    def get(self, op, *, m: int = 30, k: int = 8, dtype=jnp.float32,
            gs: str = "cgs2", precond=None) -> SolverHandle:
        key = HandleKey(n=operator_dim(op), fmt=operator_fmt(op),
                        m=int(m), k=int(k), dtype=jnp.dtype(dtype).name)
        return self._lru.get_or_create(
            key, lambda: SolverHandle(op, m=m, k=k, dtype=dtype, gs=gs,
                                      precond=precond))

    def stats(self) -> dict:
        return self._lru.stats()

    def clear(self) -> None:
        self._lru.clear()
