"""Solver handles: pre-lowered batched cycles behind an LRU.

Admission must never pay a compile.  A :class:`SolverHandle` wraps ONE
jitted ``gmres_batched_cycle`` for a fixed ``(n, operator fmt, m, k,
dtype)`` bucket; jax compiles it on the handle's FIRST cycle and every
later request in the bucket reuses the executable.  The
:class:`HandleCache` is a bounded LRU (kernels/tuning.LruCache) over
those buckets — the compiled-executable complement of the on-disk
``persistent_choice`` cache, which already makes the tile choices INSIDE
the lowering restart-stable.

The handle jit-closes over the CONCRETE operator (and gs scheme and
preconditioner), not just its shape — so the cache key must too.
:class:`HandleKey` therefore carries identity tokens for the operator
and preconditioner alongside the shape bucket: two servers sharing one
cache over same-shaped but different operators get two handles, never
each other's system.  The tokens are ``id()``s, which is sound here
because the cached handle holds strong references to both objects — a
token can only collide with a DEAD operator, and a dead operator cannot
be passed to ``get``.

The handle's kernel dispatch is the solver core's, untouched: CGS2-family
schemes go through the batched block-GS kernel when ``tuning.kernel_mode``
and ``tuning.block_gs_fits`` allow, and degrade to the vmapped jnp
reference otherwise — which is exactly the VMEM-overflow fallback the
fault-injection tests force.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.gmres import gmres_batched_cycle
from repro.kernels.tuning import LruCache


def operator_fmt(op) -> str:
    """Stable format tag for handle keys ('dense', 'sparse', 'banded'...)."""
    name = type(op).__name__
    if name.endswith("Operator"):
        return name[:-len("Operator")].lower()
    if hasattr(op, "ndim"):          # raw dense array
        return "dense"
    return "function"


def operator_dim(op) -> int:
    shape = getattr(op, "shape", None)
    if shape is None:
        raise ValueError(
            "operator has no .shape; wrap it in a FunctionOperator so the "
            "server can size its lanes")
    return int(shape[0])


class HandleKey(NamedTuple):
    """LRU key: everything that changes the lowered cycle.

    The shape bucket ``(n, fmt, m, k, dtype)`` sizes the executable; the
    identity fields pin WHICH system it solves — the handle closes over
    the operator, gs scheme, and preconditioner, so a key that ignored
    them would hand a same-shaped server the wrong compiled solve.
    """

    n: int
    fmt: str
    m: int
    k: int
    dtype: str
    gs: str
    op_token: int                # id(op): live while the handle is cached
    precond_token: int           # id(precond), 0 for None


def _handle_key(op, *, m: int, k: int, dtype, gs: str,
                precond) -> HandleKey:
    return HandleKey(n=operator_dim(op), fmt=operator_fmt(op),
                     m=int(m), k=int(k), dtype=jnp.dtype(dtype).name,
                     gs=str(gs), op_token=id(op),
                     precond_token=0 if precond is None else id(precond))


class SolverHandle:
    """One operator bucket's jitted lockstep cycle.

    ``jax.jit`` is lazy, so constructing a handle is cheap; the compile
    lands on the first ``cycle`` call and is keyed by the (k, n) block
    shapes, which the handle pins.  The operator itself is a static
    closure — one handle per A, which is the batched engine's contract
    (ONE A stream shared by all k lanes).
    """

    def __init__(self, op, *, m: int = 30, k: int = 8,
                 dtype=jnp.float32, gs: str = "cgs2",
                 precond=None):
        self.op = op
        self.precond = precond   # strong ref: keeps the key token valid
        self.key = _handle_key(op, m=m, k=k, dtype=dtype, gs=gs,
                               precond=precond)
        self.gs = gs
        self._cycle = jax.jit(functools.partial(
            gmres_batched_cycle, op, m=int(m), gs=gs, precond=precond,
            compute_dtype=dtype))
        self.cycles_run = 0

    @property
    def n(self) -> int:
        return self.key.n

    @property
    def k(self) -> int:
        return self.key.k

    @property
    def m(self) -> int:
        return self.key.m

    def block_shape(self) -> Tuple[int, int]:
        return (self.key.k, self.key.n)

    def cycle(self, b, x, tol_abs, active):
        """One lockstep restart cycle; returns ``(x', beta', inner_steps)``.

        All arguments are full (k, n) / (k,) blocks — idle lanes ride
        along masked out (their x passes through untouched), which keeps
        one executable valid for every occupancy level.
        """
        dt = jnp.dtype(self.key.dtype)
        b = jnp.asarray(b, dt)
        x = jnp.asarray(x, dt)
        if b.shape != self.block_shape() or x.shape != self.block_shape():
            raise ValueError(
                f"handle {self.key} expects {self.block_shape()} blocks, "
                f"got b{b.shape} x{x.shape}")
        out = self._cycle(b, x, tol_abs=jnp.asarray(tol_abs, dt),
                          active=jnp.asarray(active, bool))
        self.cycles_run += 1
        return out


class HandleCache:
    """LRU of :class:`SolverHandle`, keyed by :class:`HandleKey`.

    ``get`` is the only entry point: hit moves the handle to the front,
    miss builds one (cheap — lowering is lazy) and may evict the coldest
    bucket, dropping its compiled executable with it.  Stats surface as
    ``solver_serve_*`` metrics so cache thrash is visible in the bench.

    Sharing one cache across servers (``SolverServer(handle_cache=...)``)
    is safe because the key carries operator/gs/precond identity, not
    just the shape bucket; a hit is additionally asserted to resolve to
    the SAME operator object before the handle is handed out.
    """

    def __init__(self, maxsize: int = 8):
        self._lru = LruCache(maxsize=maxsize)

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key) -> bool:
        return key in self._lru

    def get(self, op, *, m: int = 30, k: int = 8, dtype=jnp.float32,
            gs: str = "cgs2", precond=None) -> SolverHandle:
        key = _handle_key(op, m=m, k=k, dtype=dtype, gs=gs,
                          precond=precond)
        handle = self._lru.get_or_create(
            key, lambda: SolverHandle(op, m=m, k=k, dtype=dtype, gs=gs,
                                      precond=precond))
        assert handle.op is op and handle.gs == gs, (
            f"handle cache integrity: key {key} resolved to a different "
            f"operator/scheme")
        return handle

    def stats(self) -> dict:
        return self._lru.stats()

    def clear(self) -> None:
        self._lru.clear()
