"""Bounded admission queue with backpressure.

The idiom is ray-ng's ``backpressured_push``/``wait_queue`` pair
(SNIPPETS.md Snippet 2): a producer never lets its in-flight queue grow
past ``max_depth`` — it either polls the queue down before pushing, or
the push is refused outright and the caller sees the backpressure.

Two admission modes map onto that:

* ``push``          — non-blocking; full queue => refused (``False``).
                      This is the PURE path the scheduler/test harness
                      drive: backpressure is a return value, not a wait.
* ``backpressured_push`` — blocking; spins ``wait_queue`` until depth
                      drops or ``max_wait`` elapses.  Clock and sleep are
                      INJECTED so the deterministic harness can script
                      time; the host loop passes the real ones.

The queue itself is deliberately dumb — a FIFO of opaque items with a
depth bound and counters.  Ordering is the packing contract: lanes are
refilled strictly in admission order.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional


class BackpressuredQueue:
    """Bounded FIFO; refusal-on-full is the backpressure signal."""

    def __init__(self, max_depth: int = 64):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = int(max_depth)
        self._q: deque = deque()
        # Counters survive pops: they are the serve metrics' raw material.
        self.pushed = 0
        self.refused = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.max_depth

    def push(self, item) -> bool:
        """Non-blocking admit; ``False`` = backpressure refusal."""
        if self.full:
            self.refused += 1
            return False
        self._q.append(item)
        self.pushed += 1
        return True

    def pop(self):
        """FIFO pop; ``None`` when empty (scheduler's drain probe)."""
        return self._q.popleft() if self._q else None

    def peek(self):
        return self._q[0] if self._q else None

    @property
    def items(self) -> tuple:
        """Non-destructive FIFO-order snapshot (checkpointing)."""
        return tuple(self._q)

    def wait_queue(self, max_depth: int, *, clock: Callable[[], float],
                   sleep: Callable[[float], None], poll: float = 0.01,
                   max_wait: float = 1.0) -> bool:
        """Block until depth <= ``max_depth`` or ``max_wait`` elapses.

        The Snippet-2 shape: re-check, sleep a poll interval, give up
        after a deadline.  Depth only drops when someone else pops —
        in the server that is the scheduler thread/loop; in tests the
        scripted ``sleep`` hook pops items itself, which is exactly why
        the hooks are injected rather than hard-wired to ``time``.
        """
        deadline = clock() + max_wait
        while len(self._q) > max_depth:
            if clock() >= deadline:
                return False
            sleep(poll)
        return True

    def backpressured_push(self, item, *, clock: Callable[[], float],
                           sleep: Callable[[float], None],
                           poll: float = 0.01,
                           max_wait: float = 1.0) -> bool:
        """Blocking admit: wait for headroom, then push.

        Returns ``False`` only if the queue stayed full past
        ``max_wait`` — the caller converts that into a REJECTED outcome
        (or retries; the server's choice, not the queue's).
        """
        if self.wait_queue(self.max_depth - 1, clock=clock, sleep=sleep,
                           poll=poll, max_wait=max_wait):
            return self.push(item)
        self.refused += 1
        return False

    def drain(self) -> list:
        """Pop everything (shutdown path); returns the evicted items."""
        items = list(self._q)
        self._q.clear()
        return items
