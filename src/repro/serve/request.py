"""Solve requests and outcomes: the server's wire types.

A request is one right-hand side plus its OWN stopping contract —
``tol`` (relative) and ``max_restarts`` (budget).  Heterogeneous
contracts are the whole point of the serving layer: the batched engine
runs k lanes in lockstep off ONE A stream, and per-lane stopping
(core/gmres.py) lets a loose-tolerance request retire after one restart
while a tight one keeps its lane.

Validation happens HERE, at admission, not in the solver: a NaN/Inf b
poisons every reduction it is batched with (one bad lane's mat-vec is
still one column of the shared block GEMM), so it must never reach a
lane.  Rejected requests get a terminal ``REJECTED`` outcome and never
enter the queue.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Terminal / lifecycle states.  Strings, not an Enum: outcomes cross the
# host boundary (JSON metrics, logs) and tests script them literally.
PENDING = "pending"      # admitted, waiting in the queue
RUNNING = "running"      # packed into a lane
DONE = "done"            # converged within its own tol
FAILED = "failed"        # restart budget exhausted before convergence
REJECTED = "rejected"    # refused at admission (invalid b or backpressure)

TERMINAL = frozenset({DONE, FAILED, REJECTED})


class AdmissionError(ValueError):
    """Request refused at admission; ``.reason`` says why."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def validate_b(b, n: Optional[int] = None) -> np.ndarray:
    """Admission gate for a right-hand side.

    Raises :class:`AdmissionError` on non-finite entries or a shape that
    cannot occupy a lane of the server's (k, n) block.  Returns the
    validated vector as a host ndarray (the queue is host-side; device
    transfer happens at pack time, once, for the whole lane block).
    """
    arr = np.asarray(b)
    if arr.ndim != 1:
        raise AdmissionError(f"b must be 1-D, got shape {arr.shape}")
    if n is not None and arr.shape[0] != n:
        raise AdmissionError(f"b has n={arr.shape[0]}, server lane n={n}")
    if not np.all(np.isfinite(arr)):
        raise AdmissionError("b contains NaN/Inf")
    return arr


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One admitted solve: rhs + its own stopping contract."""

    rid: int
    b: np.ndarray                 # validated, host-side (n,)
    tol: float = 1e-5             # relative: stop at ||r|| <= tol*||b||
    max_restarts: int = 50        # restart budget before FAILED retirement
    # Retirement threshold quantized to the serving handle's compute
    # dtype (server.submit sets it).  Host retirement and the compiled
    # cycle's lane masking MUST compare against the SAME number: a raw
    # float64 tol_abs that rounds differently under the device's float32
    # cast leaves a converged-on-device lane spinning unretired on the
    # host until its budget expires.
    tol_abs_override: Optional[float] = None

    @property
    def tol_abs(self) -> float:
        if self.tol_abs_override is not None:
            return self.tol_abs_override
        return float(self.tol) * float(np.linalg.norm(self.b))


@dataclasses.dataclass(frozen=True)
class SolveOutcome:
    """Terminal record handed back to the submitter."""

    rid: int
    status: str                   # DONE / FAILED / REJECTED
    x: Optional[np.ndarray] = None
    residual: float = float("inf")
    restarts: int = 0
    inner_steps: int = 0
    reason: str = ""              # REJECTED: why admission refused it
