"""Solve requests and outcomes: the server's wire types.

A request is one right-hand side plus its OWN stopping contract —
``tol`` (relative) and ``max_restarts`` (budget).  Heterogeneous
contracts are the whole point of the serving layer: the batched engine
runs k lanes in lockstep off ONE A stream, and per-lane stopping
(core/gmres.py) lets a loose-tolerance request retire after one restart
while a tight one keeps its lane.

Validation happens HERE, at admission, not in the solver: a NaN/Inf b
poisons every reduction it is batched with (one bad lane's mat-vec is
still one column of the shared block GEMM), so it must never reach a
lane.  Rejected requests get a terminal ``REJECTED`` outcome and never
enter the queue.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Terminal / lifecycle states.  Strings, not an Enum: outcomes cross the
# host boundary (JSON metrics, logs) and tests script them literally.
PENDING = "pending"      # admitted, waiting in the queue
RUNNING = "running"      # packed into a lane
DONE = "done"            # converged within its own tol
FAILED = "failed"        # restart budget exhausted before convergence
REJECTED = "rejected"    # refused at admission (invalid b or backpressure)
TIMEOUT = "timeout"      # deadline_ticks expired before convergence

TERMINAL = frozenset({DONE, FAILED, REJECTED, TIMEOUT})


class AdmissionError(ValueError):
    """Request refused at admission; ``.reason`` says why."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def validate_b(b, n: Optional[int] = None, dtype=None) -> np.ndarray:
    """Admission gate for a right-hand side.

    Raises :class:`AdmissionError` on non-finite entries, a shape that
    cannot occupy a lane of the server's (k, n) block, or a dtype that
    cannot represent a real right-hand side of the lane block (complex,
    strings, objects — anything outside real floats/ints; the silent
    jnp cast at pack time would truncate imaginary parts or crash the
    tick loop).  Returns the validated vector as a host ndarray (the
    queue is host-side; device transfer happens at pack time, once, for
    the whole lane block).
    """
    try:
        arr = np.asarray(b)
    except (ValueError, TypeError) as e:
        raise AdmissionError(f"b is not array-like: {e}")
    if not (np.issubdtype(arr.dtype, np.floating)
            or np.issubdtype(arr.dtype, np.integer)):
        raise AdmissionError(
            f"b dtype {arr.dtype} cannot occupy a "
            f"{np.dtype(dtype).name if dtype is not None else 'real'} lane")
    if arr.ndim != 1:
        raise AdmissionError(f"b must be 1-D, got shape {arr.shape}")
    if n is not None and arr.shape[0] != n:
        raise AdmissionError(f"b has n={arr.shape[0]}, server lane n={n}")
    if not np.all(np.isfinite(arr)):
        raise AdmissionError("b contains NaN/Inf")
    return arr


def validate_precond(precond, op) -> None:
    """Admission gate for the server's preconditioner vs its operator.

    A mismatched preconditioner is the one bad parameter that CANNOT be
    caught per-request: it is baked into the compiled cycle, so a wrong-n
    or wrong-format M⁻¹ fails on the first tick INSIDE a lane, poisoning
    every request batched with it.  Validate the pairing once, up front,
    with the field named — the caller sees ``precond`` in the reason, not
    a shape error from the middle of a jitted block GEMM.

    Checks (all metadata-only; plain callables without the
    :class:`~repro.core.preconditioners.Preconditioner` protocol pass
    through — they advertise nothing to check against):

    - ``precond`` is callable at all;
    - ``precond.n`` (if advertised) matches the operator's row count;
    - ``precond.requires_fmt`` (if advertised) matches the operator's
      format tag — e.g. a dense-only block-Jacobi on a banded or sharded
      operator is refused here, not inside a lane.
    """
    if precond is None:
        return
    if not callable(precond):
        raise AdmissionError(
            f"precond is not callable: {type(precond).__name__}")
    name = getattr(precond, "name", type(precond).__name__)
    shape = getattr(op, "shape", None)
    op_n = int(shape[0]) if shape is not None else None
    pc_n = getattr(precond, "n", None)
    if pc_n is not None and op_n is not None and int(pc_n) != op_n:
        raise AdmissionError(
            f"precond '{name}' has n={int(pc_n)}, operator has n={op_n}")
    fmt = getattr(precond, "requires_fmt", None)
    if fmt is not None:
        op_name = type(op).__name__
        op_fmt = (op_name[:-len("Operator")].lower()
                  if op_name.endswith("Operator") else "dense")
        if op_fmt != fmt:
            raise AdmissionError(
                f"precond '{name}' requires a {fmt} operator, "
                f"server operator is {op_fmt}")


def validate_params(tol: float, max_restarts: int,
                    deadline_ticks: Optional[int] = None,
                    *, precond=None, op=None) -> None:
    """Admission gate for the stopping contract itself.

    A non-finite or non-positive ``tol`` can never be met (or is met
    vacuously by garbage), a non-positive ``max_restarts`` lane would
    retire FAILED before its first cycle, and a non-positive deadline
    would TIMEOUT at admission — all of these used to poison a lane or
    wedge the tick loop; now they are REJECTED before touching the queue.

    When ``precond``/``op`` are supplied, the preconditioner/operator
    pairing is validated too (see :func:`validate_precond`) so a server
    constructed with a mismatched M⁻¹ is refused before a handle — and
    its compiled cycle — ever exists.
    """
    tol = float(tol)
    if not np.isfinite(tol) or tol <= 0.0:
        raise AdmissionError(f"tol must be finite and > 0, got {tol}")
    if int(max_restarts) < 1:
        raise AdmissionError(
            f"max_restarts must be >= 1, got {max_restarts}")
    if deadline_ticks is not None and int(deadline_ticks) < 1:
        raise AdmissionError(
            f"deadline_ticks must be >= 1 (or None), got {deadline_ticks}")
    if precond is not None:
        validate_precond(precond, op)


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One admitted solve: rhs + its own stopping contract."""

    rid: int
    b: np.ndarray                 # validated, host-side (n,)
    tol: float = 1e-5             # relative: stop at ||r|| <= tol*||b||
    max_restarts: int = 50        # restart budget before FAILED retirement
    # Wall-tick budget: TIMEOUT retirement after this many scheduler
    # ticks IN A LANE (None = no deadline).  Counted per occupancy, so a
    # retry-on-fresh-lane gets a fresh deadline like it gets a fresh x.
    deadline_ticks: Optional[int] = None
    # Times this request was requeued after a lane fault (quarantine
    # path); bounded by the server's ``fault_retries``.
    retries: int = 0
    # Retirement threshold quantized to the serving handle's compute
    # dtype (server.submit sets it).  Host retirement and the compiled
    # cycle's lane masking MUST compare against the SAME number: a raw
    # float64 tol_abs that rounds differently under the device's float32
    # cast leaves a converged-on-device lane spinning unretired on the
    # host until its budget expires.
    tol_abs_override: Optional[float] = None

    @property
    def tol_abs(self) -> float:
        if self.tol_abs_override is not None:
            return self.tol_abs_override
        return float(self.tol) * float(np.linalg.norm(self.b))


@dataclasses.dataclass(frozen=True)
class SolveOutcome:
    """Terminal record handed back to the submitter."""

    rid: int
    status: str                   # DONE / FAILED / REJECTED / TIMEOUT
    x: Optional[np.ndarray] = None
    residual: float = float("inf")
    restarts: int = 0
    inner_steps: int = 0
    reason: str = ""              # REJECTED: why admission refused it
