"""Pure, tick-driven lane scheduler: admit -> pack -> cycle -> retire.

This is the continuous-batching state machine, written with NO I/O, no
clock, no jax — every transition is a pure function from an immutable
:class:`SchedulerState` (plus explicit inputs) to a new state.  The host
loop (server.py) owns the device and the wall clock; the deterministic
test harness (tests/test_serve.py) drives the same functions with
scripted residuals and never touches a device at all.

One tick of the server is:

    admit   requests move from the ingress queue into ``pending``
            until the pending bound pushes back (rejection is a
            RETURN VALUE here; the blocking wait lives in queue.py);
    pack    idle lanes are filled from ``pending`` in strict FIFO
            admission order — the packing contract tests pin down;
    cycle   the host runs ONE lockstep restart cycle over the k lanes
            (gmres_batched_cycle: one A stream for all of them) and
            comes back with per-lane residuals;
    retire  each occupied lane is charged one restart; a lane at or
            under its own tol retires DONE, a lane out of budget
            retires FAILED — and either way frees the lane NOW, at the
            restart boundary, not when the slowest lane finishes;
    refill  is just the next tick's pack: a freed lane picks up the
            next pending request mid-solve of its cohort (the decode-
            loop trick applied to Krylov lanes).

Because retirement frees lanes every tick, total device work is
``sum_i restarts_i`` spread over ``~ceil(sum_i restarts_i / k)`` cycles
instead of ``sum_i restarts_i`` sequential cycles — throughput = lanes x
early retirement.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serve.request import DONE, FAILED, TIMEOUT, SolveRequest


@dataclasses.dataclass(frozen=True)
class Lane:
    """One of the k lockstep lanes; ``req is None`` means idle."""

    req: Optional[SolveRequest] = None
    restarts: int = 0            # cycles charged to the current occupant

    @property
    def idle(self) -> bool:
        return self.req is None


@dataclasses.dataclass(frozen=True)
class Retirement:
    """A lane freed this tick: who, why, and with what residual."""

    lane: int
    req: SolveRequest
    status: str                  # DONE / FAILED / TIMEOUT
    residual: float
    restarts: int
    reason: str = ""             # FAILED detail ("budget" / "lane fault" / ...)


@dataclasses.dataclass(frozen=True)
class SchedulerState:
    """Immutable snapshot of lanes + pending backlog + counters."""

    lanes: Tuple[Lane, ...]
    pending: Tuple[SolveRequest, ...] = ()
    max_pending: int = 64
    tick: int = 0                # completed cycle count
    # Per-lane quarantine: a faulted lane sits out this many ticks before
    # pack may refill it (its device rows may be poisoned; the host zeroes
    # them, quarantine adds scheduling distance).  Empty tuple == no
    # quarantine anywhere (init sizes it to k).
    quarantine: Tuple[int, ...] = ()
    # Counters (the solver_serve_* metrics' raw material):
    admitted: int = 0
    rejected: int = 0
    retired_done: int = 0
    retired_failed: int = 0
    retired_timeout: int = 0
    lane_faults: int = 0         # lanes evicted by fault()
    requeued: int = 0            # faulted occupants sent back to pending
    lane_cycles: int = 0         # sum of active lanes over all ticks

    @property
    def k(self) -> int:
        return len(self.lanes)

    @property
    def active(self) -> int:
        return sum(not ln.idle for ln in self.lanes)

    @property
    def idle_lanes(self) -> Tuple[int, ...]:
        return tuple(i for i, ln in enumerate(self.lanes) if ln.idle)

    def quarantined(self, i: int) -> bool:
        return bool(self.quarantine) and self.quarantine[i] > 0

    @property
    def busy(self) -> bool:
        return self.active > 0 or bool(self.pending)

    @property
    def occupancy(self) -> float:
        """Mean fraction of lanes doing useful work per cycle run."""
        if self.tick == 0:
            return 0.0
        return self.lane_cycles / (self.tick * self.k)


def init(k: int, max_pending: int = 64) -> SchedulerState:
    if k < 1:
        raise ValueError(f"need at least one lane, got k={k}")
    return SchedulerState(lanes=tuple(Lane() for _ in range(k)),
                          quarantine=(0,) * k,
                          max_pending=int(max_pending))


def admit(state: SchedulerState,
          req: SolveRequest) -> Tuple[SchedulerState, bool]:
    """Admit one request into ``pending``; full backlog => refusal.

    Pure backpressure: the bool IS the signal.  Blocking/retry policy
    belongs to the host ingress (queue.BackpressuredQueue), never here.
    """
    if len(state.pending) >= state.max_pending:
        return dataclasses.replace(state, rejected=state.rejected + 1), False
    return dataclasses.replace(state, pending=state.pending + (req,),
                               admitted=state.admitted + 1), True


def pack(state: SchedulerState) -> Tuple[SchedulerState,
                                         List[Tuple[int, SolveRequest]]]:
    """Fill idle lanes from ``pending`` in FIFO admission order.

    Returns the placements ``(lane_index, request)`` made this tick so
    the host can load exactly those lanes' b into the device block —
    running lanes are never repacked (their x is mid-solve).
    """
    lanes = list(state.lanes)
    backlog = list(state.pending)
    placed: List[Tuple[int, SolveRequest]] = []
    for i, ln in enumerate(lanes):
        if not backlog:
            break
        if ln.idle and not state.quarantined(i):
            req = backlog.pop(0)
            lanes[i] = Lane(req=req, restarts=0)
            placed.append((i, req))
    if not placed:
        return state, []
    return dataclasses.replace(state, lanes=tuple(lanes),
                               pending=tuple(backlog)), placed


def retire(state: SchedulerState,
           residuals) -> Tuple[SchedulerState, List[Retirement]]:
    """Charge one restart to every occupied lane, free the finished ones.

    ``residuals[i]`` is lane i's post-cycle ||b - A x|| (ignored for
    idle lanes).  A lane retires DONE at or under its own ``tol_abs``,
    TIMEOUT when its ``deadline_ticks`` lane-tick budget expired (DONE
    wins a tie: a request that converges ON its deadline tick converged),
    FAILED when its restart budget is spent — any retirement frees the
    lane NOW, so one hopeless or deadline-bound request can never stall
    its cohort.  Quarantine countdowns decrement here too: one tick of
    sit-out per cycle run.
    """
    if len(residuals) != state.k:
        raise ValueError(
            f"got {len(residuals)} residuals for {state.k} lanes")
    lanes = list(state.lanes)
    retired: List[Retirement] = []
    active = 0
    for i, ln in enumerate(lanes):
        if ln.idle:
            continue
        active += 1
        used = ln.restarts + 1
        beta = float(residuals[i])
        reason = ""
        if beta <= ln.req.tol_abs:
            status = DONE
        elif (ln.req.deadline_ticks is not None
                and used >= ln.req.deadline_ticks):
            status = TIMEOUT
            reason = f"deadline: {used} >= {ln.req.deadline_ticks} ticks"
        elif used >= ln.req.max_restarts:
            status = FAILED
            reason = "budget"
        else:
            lanes[i] = Lane(req=ln.req, restarts=used)
            continue
        retired.append(Retirement(lane=i, req=ln.req, status=status,
                                  residual=beta, restarts=used,
                                  reason=reason))
        lanes[i] = Lane()
    ndone = sum(r.status == DONE for r in retired)
    ntimeout = sum(r.status == TIMEOUT for r in retired)
    quarantine = tuple(max(0, q - 1) for q in state.quarantine)
    return dataclasses.replace(
        state, lanes=tuple(lanes), tick=state.tick + 1,
        quarantine=quarantine,
        lane_cycles=state.lane_cycles + active,
        retired_done=state.retired_done + ndone,
        retired_timeout=state.retired_timeout + ntimeout,
        retired_failed=state.retired_failed + (len(retired) - ndone
                                               - ntimeout),
    ), retired


def fault(state: SchedulerState, lane_indices,
          *, quarantine_ticks: int = 2,
          max_retries: int = 1) -> Tuple[SchedulerState,
                                         List[SolveRequest],
                                         List[Retirement]]:
    """Evict faulted lanes: quarantine the lane, retry-or-fail the occupant.

    ``lane_indices`` are lanes whose post-cycle state is poisoned (NaN/Inf
    residual, injected corruption) as detected by the HOST — this is a
    fault in the lane's execution, not a property of the request, so the
    occupant deserves a retry on a FRESH lane: it goes back to the FRONT
    of ``pending`` (it has waited longest) with ``retries + 1``, starting
    over from x = 0.  An occupant already retried ``max_retries`` times
    retires FAILED instead (reason "lane fault").  The lane itself sits
    out ``quarantine_ticks`` retire-decrements before pack may reuse it.

    Faulted lanes are freed BEFORE retire() runs this tick, so they are
    charged no restart for the poisoned cycle.
    """
    lanes = list(state.lanes)
    quarantine = list(state.quarantine or (0,) * state.k)
    requeue: List[SolveRequest] = []
    failed: List[Retirement] = []
    for i in sorted(set(int(j) for j in lane_indices)):
        ln = lanes[i]
        quarantine[i] = max(quarantine[i], int(quarantine_ticks))
        if ln.idle:
            continue
        lanes[i] = Lane()
        req = ln.req
        if req.retries < max_retries:
            requeue.append(dataclasses.replace(req, retries=req.retries + 1))
        else:
            failed.append(Retirement(
                lane=i, req=req, status=FAILED, residual=float("inf"),
                restarts=ln.restarts,
                reason=f"lane fault after {req.retries} retries"))
    return dataclasses.replace(
        state, lanes=tuple(lanes), quarantine=tuple(quarantine),
        pending=tuple(requeue) + state.pending,
        lane_faults=state.lane_faults + len(requeue) + len(failed),
        requeued=state.requeued + len(requeue),
        retired_failed=state.retired_failed + len(failed),
    ), requeue, failed


def metrics(state: SchedulerState) -> dict:
    """Counters in the shape kernel_bench's solver_serve_* rows consume."""
    return {
        "tick": state.tick,
        "queue_depth": len(state.pending),
        "active_lanes": state.active,
        "occupancy": state.occupancy,
        "admitted": state.admitted,
        "rejected": state.rejected,
        "retired_done": state.retired_done,
        "retired_failed": state.retired_failed,
        "retired_timeout": state.retired_timeout,
        "lane_faults": state.lane_faults,
        "requeued": state.requeued,
        "quarantined_lanes": sum(q > 0 for q in state.quarantine),
        "lane_cycles": state.lane_cycles,
    }
