"""Host I/O loop: the impure shell around the pure scheduler.

Layering (each piece is separately testable, which is the point):

    queue.BackpressuredQueue   host ingress — bounded, blocking option
    scheduler.*                pure tick machine (admit/pack/retire)
    handles.SolverHandle       the jitted device cycle
    SolverServer               glues them: moves requests queue->lanes,
                               runs cycles, collects outcomes, keeps
                               metrics.  The ONLY code here that touches
                               a device is ``handle.cycle``.

One server serves ONE operator (the batched engine shares a single A
stream across its k lanes); the handle comes from a shared
:class:`~repro.serve.handles.HandleCache` so several servers over
different (n, fmt) buckets reuse compiled cycles instead of recompiling.

Device-side lane state is a (k, n) x block plus a (k, n) b block; a
refill overwrites ONE row of each in place (``.at[lane].set``) and
zeroes the lane's x — host work linear in n, not in k·restarts, and no
full-block device round-trip per tick.  Convergence checks read back
only the (k,) residual and inner-step vectors per tick.

Fault handling (see docs/robustness.md for the full taxonomy): the
cycle call is wrapped in bounded retries + a :class:`CircuitBreaker`
(repeatedly-failing handles stop being hammered; a dead breaker fails
the backlog instead of spinning); per-lane non-finite residuals after a
cycle evict the lane through the PURE ``scheduler.fault`` transition —
quarantine the lane, retry the occupant on a fresh lane, scrub the
poisoned device rows; per-request deadlines retire TIMEOUT; per-tick
wall times feed the ``runtime.fault_tolerance.StragglerMonitor``.  All
of it is driven deterministically by ``runtime.faultinject`` sites
(``serve.cycle``, ``serve.lane_nan``).  ``save_checkpoint`` /
``restore_checkpoint`` serialize the lane blocks + scheduler state at a
tick boundary so a killed server resumes bit-identically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.core.recovery import CircuitBreaker
from repro.runtime import faultinject
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.serve import scheduler as sched
from repro.serve.handles import HandleCache, SolverHandle
from repro.serve.queue import BackpressuredQueue
from repro.serve.request import (AdmissionError, FAILED, REJECTED, TIMEOUT,
                                 SolveOutcome, SolveRequest, validate_b,
                                 validate_params, validate_precond)


class SolverServer:
    """Continuous-batching GMRES server over one operator.

    >>> srv = SolverServer(op, m=16, k=8)
    >>> rid = srv.submit(b, tol=1e-5, max_restarts=40)
    >>> srv.run()                        # drain queue + lanes
    >>> out = srv.results[rid]           # SolveOutcome(status='done', ...)
    """

    def __init__(self, op, *, m: int = 30, k: int = 8,
                 dtype=jnp.float32, gs: str = "cgs2", precond=None,
                 max_pending: int = 64, queue_depth: Optional[int] = None,
                 handle_cache: Optional[HandleCache] = None,
                 clock=time.monotonic, sleep=time.sleep,
                 deadline_default: Optional[int] = None,
                 quarantine_ticks: int = 2, fault_retries: int = 1,
                 cycle_retries: int = 2, backoff_base: float = 0.0,
                 breaker_threshold: int = 3, breaker_cooldown: int = 5,
                 breaker_max_trips: int = 2,
                 straggler_window: int = 50, straggler_zscore: float = 3.0):
        # Precond/operator mismatch is rejected HERE, before a handle
        # exists: it is the one parameter a per-request gate cannot
        # catch, and letting it through fails inside a jitted lane.
        validate_precond(precond, op)
        cache = handle_cache if handle_cache is not None else HandleCache()
        self.handle: SolverHandle = cache.get(op, m=m, k=k, dtype=dtype,
                                              gs=gs, precond=precond)
        self.handle_cache = cache
        self.state = sched.init(k, max_pending=max_pending)
        self.ingress = BackpressuredQueue(
            max_depth=queue_depth if queue_depth is not None else max_pending)
        self.results: Dict[int, SolveOutcome] = {}
        self._clock = clock
        self._sleep = sleep
        self._next_rid = 0
        self._t0: Optional[float] = None
        self._wall: float = 0.0
        # --- fault-handling knobs / state ------------------------------
        self._deadline_default = deadline_default
        self._quarantine_ticks = int(quarantine_ticks)
        self._fault_retries = int(fault_retries)
        self._cycle_retries = int(cycle_retries)
        self._backoff_base = float(backoff_base)
        # The breaker is clocked by step() INVOCATIONS, not scheduler
        # ticks: a failed cycle never advances the scheduler tick, so the
        # cooldown would otherwise wait on a clock that stopped.
        self.breaker = CircuitBreaker(threshold=breaker_threshold,
                                      cooldown=breaker_cooldown,
                                      max_trips=breaker_max_trips)
        self.straggler = StragglerMonitor(window=straggler_window,
                                          zscore=straggler_zscore)
        self._steps = 0               # breaker clock
        self.cycle_faults = 0         # cycle attempts that raised
        self.breaker_skips = 0        # steps skipped while cooling down
        self._last_cycle_error = ""
        # Device-side lane blocks (jnp so cycles never re-upload idle rows).
        kk, n = self.handle.block_shape()
        dt = jnp.dtype(self.handle.key.dtype)
        self._b = jnp.zeros((kk, n), dt)
        self._x = jnp.zeros((kk, n), dt)
        self._tol_abs = np.zeros(kk, np.float64)
        self._inner = np.zeros(kk, np.int64)   # Arnoldi steps per occupant

    # ------------------------------------------------------------------
    # Admission (host ingress)
    # ------------------------------------------------------------------
    def submit(self, b, *, tol: float = 1e-5, max_restarts: int = 50,
               deadline_ticks: Optional[int] = None,
               wait: bool = False, max_wait: float = 1.0) -> int:
        """Admit one solve; returns its rid.

        Invalid b (NaN/Inf, wrong n, non-real dtype) and invalid solver
        parameters (non-finite/non-positive tol, max_restarts < 1, a
        non-positive deadline) are REJECTED here — they never enter the
        queue, so they can never poison a lane block or wedge the tick
        loop.  A full queue refuses non-blocking submits the same way;
        ``wait=True`` instead drains the backlog by ticking the scheduler
        (bounded by ``max_wait``): the server is single-threaded, so the
        submitter IS the consumer — sleeping for someone else to pop the
        ingress would wait forever.

        ``deadline_ticks``: retire TIMEOUT after this many lane ticks
        (defaults to the server's ``deadline_default``; None = none).
        """
        rid = self._next_rid
        self._next_rid += 1
        if deadline_ticks is None:
            deadline_ticks = self._deadline_default
        if self.breaker.dead:
            self.results[rid] = SolveOutcome(
                rid=rid, status=REJECTED,
                reason="circuit breaker open: solver handle is failing "
                       f"({self._last_cycle_error})")
            return rid
        try:
            validate_params(tol, max_restarts, deadline_ticks)
            arr = validate_b(b, n=self.handle.n,
                             dtype=self.handle.key.dtype)
        except AdmissionError as e:
            self.results[rid] = SolveOutcome(rid=rid, status=REJECTED,
                                             reason=e.reason)
            return rid
        # Quantize the retirement threshold to the handle's compute
        # dtype: the compiled cycle masks lanes with the downcast
        # tol_abs, and host retirement must agree on "converged" or a
        # lane can wedge between the two thresholds (device says done,
        # host keeps charging restarts until the budget fails it).
        dt = np.dtype(self.handle.key.dtype)
        tol_abs = float(np.asarray(float(tol) * np.linalg.norm(arr), dt))
        req = SolveRequest(rid=rid, b=arr, tol=float(tol),
                           max_restarts=int(max_restarts),
                           deadline_ticks=(None if deadline_ticks is None
                                           else int(deadline_ticks)),
                           tol_abs_override=tol_abs)
        if wait:
            deadline = self._clock() + max_wait
            while self.ingress.full and self._clock() < deadline:
                depth = len(self.ingress)
                self.step()              # we are our own consumer
                if len(self.ingress) >= depth:
                    # Tick freed no headroom (lanes mid-solve, backlog
                    # full): yield real time toward the deadline.
                    self._sleep(0.01)
            ok = self.ingress.push(req)
        else:
            ok = self.ingress.push(req)
        if not ok:
            self.results[rid] = SolveOutcome(
                rid=rid, status=REJECTED,
                reason=f"backpressure: queue depth {len(self.ingress)} "
                       f">= {self.ingress.max_depth}")
        return rid

    # ------------------------------------------------------------------
    # The tick loop
    # ------------------------------------------------------------------
    def _admit_from_ingress(self) -> None:
        while self.ingress.peek() is not None:
            st, ok = sched.admit(self.state, self.ingress.peek())
            if not ok:
                break                    # pending full: leave it queued
            self.state = st
            self.ingress.pop()

    def _pack(self) -> None:
        self.state, placed = sched.pack(self.state)
        if not placed:
            return
        # Row-wise device updates: only the refilled lanes move — the
        # resident lanes' b/x never round-trip through the host.
        dt = self._b.dtype
        for lane, req in placed:
            self._b = self._b.at[lane].set(jnp.asarray(req.b, dt))
            self._x = self._x.at[lane].set(0.0)
            self._tol_abs[lane] = req.tol_abs
            self._inner[lane] = 0

    def _scrub_lane(self, i: int) -> None:
        """Zero a faulted lane's device rows: NaN in a retired lane's x
        row is confined to that lane's GEMM column, but a zeroed row costs
        nothing and removes the poison from every later block readback."""
        self._b = self._b.at[i].set(0.0)
        self._x = self._x.at[i].set(0.0)
        self._tol_abs[i] = 0.0
        self._inner[i] = 0

    def _fail_backlog(self, reason: str) -> List[sched.Retirement]:
        """Terminal breaker path: retire EVERYTHING as FAILED.

        A dead breaker means the handle cannot run cycles at all; without
        this, ``run()`` would spin its max_ticks bound with lanes wedged
        mid-solve.  Every in-flight and queued request gets a FAILED
        outcome carrying the last cycle error."""
        retired: List[sched.Retirement] = []
        self._admit_from_ingress()
        lanes = list(self.state.lanes)
        occupants = [(i, ln) for i, ln in enumerate(lanes) if not ln.idle]
        for i, ln in occupants:
            retired.append(sched.Retirement(
                lane=i, req=ln.req, status=FAILED, residual=float("inf"),
                restarts=ln.restarts, reason=reason))
            lanes[i] = sched.Lane()
            self._scrub_lane(i)
        pending = self.state.pending
        for req in pending:
            retired.append(sched.Retirement(
                lane=-1, req=req, status=FAILED, residual=float("inf"),
                restarts=0, reason=reason))
        self.state = dataclasses.replace(
            self.state, lanes=tuple(lanes), pending=(),
            retired_failed=self.state.retired_failed + len(retired))
        for r in retired:
            self.results[r.req.rid] = SolveOutcome(
                rid=r.req.rid, status=FAILED, residual=float("inf"),
                restarts=r.restarts, reason=reason)
        return retired

    def step(self) -> List[sched.Retirement]:
        """ONE scheduler tick: admit, pack, cycle, detect faults, retire.

        Returns the retirements (fault-FAILED evictions included) so
        callers and tests can watch lanes free up.  The cycle call gets
        ``cycle_retries`` bounded retries with exponential backoff — a
        transient kernel fault costs latency, not state — then a breaker
        failure; while the breaker cools down, steps admit but run no
        cycle; a DEAD breaker fails the whole backlog (once) instead of
        wedging ``run()``.
        """
        if self._t0 is None:
            self._t0 = self._clock()
        t_start = self._clock()
        self._steps += 1
        if self.breaker.dead:
            return self._fail_backlog(
                f"circuit breaker open permanently ({self._last_cycle_error})")
        self._admit_from_ingress()
        if not self.breaker.allow(self._steps):
            self.breaker_skips += 1
            return []
        self._pack()
        active = np.array([not ln.idle for ln in self.state.lanes])
        if not active.any():
            return []

        attempt = 0
        while True:
            try:
                faultinject.check("serve.cycle", index=self.state.tick)
                x, beta, inner = self.handle.cycle(
                    self._b, self._x, np.where(active, self._tol_abs, 0.0),
                    active)
                beta = np.array(beta)       # materialize: surface faults HERE
                break
            except Exception as e:  # noqa: BLE001 — injected + kernel faults
                self.cycle_faults += 1
                self._last_cycle_error = f"{type(e).__name__}: {e}"
                if attempt < self._cycle_retries:
                    attempt += 1
                    if self._backoff_base > 0.0:
                        self._sleep(self._backoff_base * 2 ** (attempt - 1))
                    continue
                # Retries exhausted: this tick is a no-op (device blocks
                # and scheduler state untouched — the restart boundary IS
                # the rollback) and the breaker hears about it.
                self.breaker.record_failure(self._steps)
                return []
        self.breaker.record_success()

        if faultinject.fire("serve.lane_nan", index=self.state.tick):
            i = int(np.argmax(active))      # lowest-indexed active lane
            x = x.at[i].set(jnp.nan)
            beta[i] = np.nan

        self._x = x
        self._inner += np.where(active, np.asarray(inner), 0)

        # Lane-level fault detection: a non-finite post-cycle residual
        # means that lane's arithmetic is poisoned.  Evict through the
        # pure fault transition (quarantine + retry-on-fresh-lane), scrub
        # the device rows, and only then run normal retirement.
        fault_retired: List[sched.Retirement] = []
        bad = active & ~np.isfinite(beta)
        if bad.any():
            idx = [int(i) for i in np.nonzero(bad)[0]]
            self.state, _requeued, failed = sched.fault(
                self.state, idx, quarantine_ticks=self._quarantine_ticks,
                max_retries=self._fault_retries)
            for i in idx:
                self._scrub_lane(i)
            for r in failed:
                self.results[r.req.rid] = SolveOutcome(
                    rid=r.req.rid, status=FAILED, residual=float("inf"),
                    restarts=r.restarts, reason=r.reason)
            fault_retired = failed

        self.state, retired = sched.retire(self.state, beta)
        if retired:
            x_host = np.asarray(self._x)
            for r in retired:
                self.results[r.req.rid] = SolveOutcome(
                    rid=r.req.rid, status=r.status,
                    x=x_host[r.lane].copy(), residual=r.residual,
                    restarts=r.restarts,
                    inner_steps=int(self._inner[r.lane]),
                    reason=r.reason)
        self.straggler.record(self.state.tick, self._clock() - t_start)
        self._wall = self._clock() - self._t0
        return fault_retired + retired

    def run(self, max_ticks: int = 10_000) -> int:
        """Tick until queue, backlog and lanes are all drained.

        Returns the number of ticks run.  ``max_ticks`` is a safety
        bound, not a policy: per-lane budgets guarantee every occupant
        retires in at most its own ``max_restarts`` ticks.
        """
        ticks = 0
        while (self.state.busy or self.ingress.peek() is not None):
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"server did not drain in {max_ticks} ticks "
                    f"({sched.metrics(self.state)})")
            self.step()
            ticks += 1
        return ticks

    # ------------------------------------------------------------------
    # Checkpoint / resume (restart-boundary, tick-aligned)
    # ------------------------------------------------------------------
    @staticmethod
    def _req_meta(req: SolveRequest) -> dict:
        return {"rid": req.rid, "tol": req.tol,
                "max_restarts": req.max_restarts,
                "tol_abs_override": req.tol_abs_override,
                "deadline_ticks": req.deadline_ticks,
                "retries": req.retries}

    @staticmethod
    def _req_from(meta: dict, b: np.ndarray) -> SolveRequest:
        return SolveRequest(
            rid=int(meta["rid"]), b=np.asarray(b), tol=float(meta["tol"]),
            max_restarts=int(meta["max_restarts"]),
            tol_abs_override=(None if meta["tol_abs_override"] is None
                              else float(meta["tol_abs_override"])),
            deadline_ticks=(None if meta["deadline_ticks"] is None
                            else int(meta["deadline_ticks"])),
            retries=int(meta["retries"]))

    def save_checkpoint(self, directory: str) -> str:
        """Serialize lanes + backlog at the current tick boundary.

        Everything a resumed server needs to continue bit-identically:
        the device b/x blocks (the lane iterates ARE the solve state —
        each cycle is a pure function of them), per-lane budgets and
        tol_abs, the full scheduler state including quarantine, queued
        and in-queue request metadata, and the rid counter.  Goes through
        ``checkpoint/checkpoint.py`` (atomic rename + crc32); call it
        between ticks — mid-``step`` there is no consistent boundary.
        Returns the checkpoint path.
        """
        st = self.state
        n = self.handle.n
        stack = (lambda reqs: np.stack([np.asarray(r.b, np.float64)
                                        for r in reqs])
                 if reqs else np.zeros((0, n), np.float64))
        tree = {
            "b": np.asarray(self._b),
            "ingress_b": stack(list(self.ingress.items)),
            "inner": self._inner.copy(),
            "pending_b": stack(list(st.pending)),
            "tol_abs": self._tol_abs.copy(),
            "x": np.asarray(self._x),
        }
        extra = {
            "lanes": [None if ln.idle else self._req_meta(ln.req)
                      for ln in st.lanes],
            "lane_restarts": [ln.restarts for ln in st.lanes],
            "pending": [self._req_meta(r) for r in st.pending],
            "ingress": [self._req_meta(r) for r in self.ingress.items],
            "sched": {
                "tick": st.tick, "admitted": st.admitted,
                "rejected": st.rejected, "retired_done": st.retired_done,
                "retired_failed": st.retired_failed,
                "retired_timeout": st.retired_timeout,
                "lane_faults": st.lane_faults, "requeued": st.requeued,
                "lane_cycles": st.lane_cycles,
                "max_pending": st.max_pending,
                "quarantine": list(st.quarantine),
            },
            "next_rid": self._next_rid,
            "k": st.k, "n": n, "m": self.handle.key.m,
            "dtype": str(self.handle.key.dtype),
        }
        return ckpt.save(directory, st.tick, tree, extra=extra)

    def restore_checkpoint(self, directory: str,
                           step: Optional[int] = None) -> "SolverServer":
        """Rebuild lanes + backlog from ``save_checkpoint`` output.

        The server must have been constructed over the same operator
        geometry (k, n, m, dtype) — the handle itself is re-lowered, not
        serialized (compiled executables don't survive processes; the
        cycle they compile to is deterministic).  In-flight lanes resume
        from their checkpointed x — every subsequent cycle is the pure
        function of (b, x, tol_abs) it always is, so outcomes match an
        uninterrupted run bit-for-bit.  Returns self.
        """
        kk, n = self.handle.block_shape()
        tree_like = {
            "b": np.zeros((kk, n)), "ingress_b": np.zeros((0, n)),
            "inner": np.zeros(kk), "pending_b": np.zeros((0, n)),
            "tol_abs": np.zeros(kk), "x": np.zeros((kk, n)),
        }
        tree, manifest = ckpt.restore(directory, tree_like, step=step)
        extra = manifest["extra"]
        if (extra["k"], extra["n"]) != (kk, n) \
                or extra["m"] != self.handle.key.m \
                or extra["dtype"] != str(self.handle.key.dtype):
            raise ValueError(
                f"checkpoint geometry (k={extra['k']}, n={extra['n']}, "
                f"m={extra['m']}, {extra['dtype']}) does not match this "
                f"server's handle (k={kk}, n={n}, m={self.handle.key.m}, "
                f"{self.handle.key.dtype})")
        dt = jnp.dtype(self.handle.key.dtype)
        self._b = jnp.asarray(tree["b"], dt)
        self._x = jnp.asarray(tree["x"], dt)
        self._tol_abs = np.asarray(tree["tol_abs"], np.float64)
        self._inner = np.asarray(tree["inner"], np.int64)
        b_host = np.asarray(tree["b"])
        lanes = tuple(
            sched.Lane() if meta is None
            else sched.Lane(req=self._req_from(meta, b_host[i]),
                            restarts=int(extra["lane_restarts"][i]))
            for i, meta in enumerate(extra["lanes"]))
        pending = tuple(self._req_from(meta, tree["pending_b"][i])
                        for i, meta in enumerate(extra["pending"]))
        ss = extra["sched"]
        self.state = sched.SchedulerState(
            lanes=lanes, pending=pending,
            max_pending=int(ss["max_pending"]), tick=int(ss["tick"]),
            quarantine=tuple(int(q) for q in ss["quarantine"]),
            admitted=int(ss["admitted"]), rejected=int(ss["rejected"]),
            retired_done=int(ss["retired_done"]),
            retired_failed=int(ss["retired_failed"]),
            retired_timeout=int(ss["retired_timeout"]),
            lane_faults=int(ss["lane_faults"]),
            requeued=int(ss["requeued"]),
            lane_cycles=int(ss["lane_cycles"]))
        self.ingress = BackpressuredQueue(max_depth=self.ingress.max_depth)
        for i, meta in enumerate(extra["ingress"]):
            self.ingress.push(self._req_from(meta, tree["ingress_b"][i]))
        self._next_rid = int(extra["next_rid"])
        return self

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Scheduler counters + ingress + handle-cache + fault state +
        throughput."""
        m = sched.metrics(self.state)
        m.update({
            "ingress_depth": len(self.ingress),
            "ingress_refused": self.ingress.refused,
            "handle_cache": self.handle_cache.stats(),
            "cycles_run": self.handle.cycles_run,
            "cycle_faults": self.cycle_faults,
            "breaker_state": self.breaker.state,
            "breaker_trips": self.breaker.trips,
            "breaker_skips": self.breaker_skips,
            "straggler_ticks": len(self.straggler.flagged),
            "wall_s": self._wall,
            "solves_per_s": ((m["retired_done"] + m["retired_failed"])
                             / self._wall if self._wall > 0 else 0.0),
            "retirement_rate": ((m["retired_done"] + m["retired_failed"])
                                / m["tick"] if m["tick"] else 0.0),
        })
        return m
