"""Host I/O loop: the impure shell around the pure scheduler.

Layering (each piece is separately testable, which is the point):

    queue.BackpressuredQueue   host ingress — bounded, blocking option
    scheduler.*                pure tick machine (admit/pack/retire)
    handles.SolverHandle       the jitted device cycle
    SolverServer               glues them: moves requests queue->lanes,
                               runs cycles, collects outcomes, keeps
                               metrics.  The ONLY code here that touches
                               a device is ``handle.cycle``.

One server serves ONE operator (the batched engine shares a single A
stream across its k lanes); the handle comes from a shared
:class:`~repro.serve.handles.HandleCache` so several servers over
different (n, fmt) buckets reuse compiled cycles instead of recompiling.

Device-side lane state is a (k, n) x block plus a (k, n) b block; a
refill overwrites ONE row of each in place (``.at[lane].set``) and
zeroes the lane's x — host work linear in n, not in k·restarts, and no
full-block device round-trip per tick.  Convergence checks read back
only the (k,) residual and inner-step vectors per tick.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from repro.serve import scheduler as sched
from repro.serve.handles import HandleCache, SolverHandle
from repro.serve.queue import BackpressuredQueue
from repro.serve.request import (AdmissionError, REJECTED, SolveOutcome,
                                 SolveRequest, validate_b)


class SolverServer:
    """Continuous-batching GMRES server over one operator.

    >>> srv = SolverServer(op, m=16, k=8)
    >>> rid = srv.submit(b, tol=1e-5, max_restarts=40)
    >>> srv.run()                        # drain queue + lanes
    >>> out = srv.results[rid]           # SolveOutcome(status='done', ...)
    """

    def __init__(self, op, *, m: int = 30, k: int = 8,
                 dtype=jnp.float32, gs: str = "cgs2", precond=None,
                 max_pending: int = 64, queue_depth: Optional[int] = None,
                 handle_cache: Optional[HandleCache] = None,
                 clock=time.monotonic, sleep=time.sleep):
        cache = handle_cache if handle_cache is not None else HandleCache()
        self.handle: SolverHandle = cache.get(op, m=m, k=k, dtype=dtype,
                                              gs=gs, precond=precond)
        self.handle_cache = cache
        self.state = sched.init(k, max_pending=max_pending)
        self.ingress = BackpressuredQueue(
            max_depth=queue_depth if queue_depth is not None else max_pending)
        self.results: Dict[int, SolveOutcome] = {}
        self._clock = clock
        self._sleep = sleep
        self._next_rid = 0
        self._t0: Optional[float] = None
        self._wall: float = 0.0
        # Device-side lane blocks (jnp so cycles never re-upload idle rows).
        kk, n = self.handle.block_shape()
        dt = jnp.dtype(self.handle.key.dtype)
        self._b = jnp.zeros((kk, n), dt)
        self._x = jnp.zeros((kk, n), dt)
        self._tol_abs = np.zeros(kk, np.float64)
        self._inner = np.zeros(kk, np.int64)   # Arnoldi steps per occupant

    # ------------------------------------------------------------------
    # Admission (host ingress)
    # ------------------------------------------------------------------
    def submit(self, b, *, tol: float = 1e-5, max_restarts: int = 50,
               wait: bool = False, max_wait: float = 1.0) -> int:
        """Admit one solve; returns its rid.

        Invalid b (NaN/Inf, wrong n) is REJECTED here — it never enters
        the queue, so it can never poison a lane block.  A full queue
        refuses non-blocking submits the same way; ``wait=True`` instead
        drains the backlog by ticking the scheduler (bounded by
        ``max_wait``): the server is single-threaded, so the submitter
        IS the consumer — sleeping for someone else to pop the ingress
        would wait forever.
        """
        rid = self._next_rid
        self._next_rid += 1
        try:
            arr = validate_b(b, n=self.handle.n)
        except AdmissionError as e:
            self.results[rid] = SolveOutcome(rid=rid, status=REJECTED,
                                             reason=e.reason)
            return rid
        # Quantize the retirement threshold to the handle's compute
        # dtype: the compiled cycle masks lanes with the downcast
        # tol_abs, and host retirement must agree on "converged" or a
        # lane can wedge between the two thresholds (device says done,
        # host keeps charging restarts until the budget fails it).
        dt = np.dtype(self.handle.key.dtype)
        tol_abs = float(np.asarray(float(tol) * np.linalg.norm(arr), dt))
        req = SolveRequest(rid=rid, b=arr, tol=float(tol),
                           max_restarts=int(max_restarts),
                           tol_abs_override=tol_abs)
        if wait:
            deadline = self._clock() + max_wait
            while self.ingress.full and self._clock() < deadline:
                depth = len(self.ingress)
                self.step()              # we are our own consumer
                if len(self.ingress) >= depth:
                    # Tick freed no headroom (lanes mid-solve, backlog
                    # full): yield real time toward the deadline.
                    self._sleep(0.01)
            ok = self.ingress.push(req)
        else:
            ok = self.ingress.push(req)
        if not ok:
            self.results[rid] = SolveOutcome(
                rid=rid, status=REJECTED,
                reason=f"backpressure: queue depth {len(self.ingress)} "
                       f">= {self.ingress.max_depth}")
        return rid

    # ------------------------------------------------------------------
    # The tick loop
    # ------------------------------------------------------------------
    def _admit_from_ingress(self) -> None:
        while self.ingress.peek() is not None:
            st, ok = sched.admit(self.state, self.ingress.peek())
            if not ok:
                break                    # pending full: leave it queued
            self.state = st
            self.ingress.pop()

    def _pack(self) -> None:
        self.state, placed = sched.pack(self.state)
        if not placed:
            return
        # Row-wise device updates: only the refilled lanes move — the
        # resident lanes' b/x never round-trip through the host.
        dt = self._b.dtype
        for lane, req in placed:
            self._b = self._b.at[lane].set(jnp.asarray(req.b, dt))
            self._x = self._x.at[lane].set(0.0)
            self._tol_abs[lane] = req.tol_abs
            self._inner[lane] = 0

    def step(self) -> List[sched.Retirement]:
        """ONE scheduler tick: admit, pack, cycle, retire.  Returns the
        retirements so callers (and tests) can watch lanes free up."""
        if self._t0 is None:
            self._t0 = self._clock()
        self._admit_from_ingress()
        self._pack()
        active = np.array([not ln.idle for ln in self.state.lanes])
        if not active.any():
            return []
        x, beta, inner = self.handle.cycle(
            self._b, self._x, np.where(active, self._tol_abs, 0.0), active)
        self._x = x
        self._inner += np.where(active, np.asarray(inner), 0)
        self.state, retired = sched.retire(self.state, np.asarray(beta))
        if retired:
            x_host = np.asarray(self._x)
            for r in retired:
                status = r.status
                self.results[r.req.rid] = SolveOutcome(
                    rid=r.req.rid, status=status,
                    x=x_host[r.lane].copy(), residual=r.residual,
                    restarts=r.restarts,
                    inner_steps=int(self._inner[r.lane]))
        self._wall = self._clock() - self._t0
        return retired

    def run(self, max_ticks: int = 10_000) -> int:
        """Tick until queue, backlog and lanes are all drained.

        Returns the number of ticks run.  ``max_ticks`` is a safety
        bound, not a policy: per-lane budgets guarantee every occupant
        retires in at most its own ``max_restarts`` ticks.
        """
        ticks = 0
        while (self.state.busy or self.ingress.peek() is not None):
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"server did not drain in {max_ticks} ticks "
                    f"({sched.metrics(self.state)})")
            self.step()
            ticks += 1
        return ticks

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------
    def metrics(self) -> dict:
        """Scheduler counters + ingress + handle-cache + throughput."""
        m = sched.metrics(self.state)
        m.update({
            "ingress_depth": len(self.ingress),
            "ingress_refused": self.ingress.refused,
            "handle_cache": self.handle_cache.stats(),
            "cycles_run": self.handle.cycles_run,
            "wall_s": self._wall,
            "solves_per_s": ((m["retired_done"] + m["retired_failed"])
                             / self._wall if self._wall > 0 else 0.0),
            "retirement_rate": ((m["retired_done"] + m["retired_failed"])
                                / m["tick"] if m["tick"] else 0.0),
        })
        return m
