from repro.sharding.partition import (param_shardings, cache_shardings,
                                      batch_shardings, batch_axes_for,
                                      replicated)

__all__ = ["param_shardings", "cache_shardings", "batch_shardings",
           "batch_axes_for", "replicated"]
