"""Logical-axis partition rules -> NamedSharding trees (FSDP x TP).

Two logical axes:
  fsdp    -> mesh ('pod', 'data') when present, else ('data',)
  tensor  -> mesh ('model',)

Parameters are matched by the TRAILING dims of a path rule, so the same
rule covers a single layer and its scan-stacked (L, ...) form (leading dims
replicate).  A mesh axis is only applied when it divides the dim — e.g.
whisper's 12 heads over a 16-way model axis shard at the (divisible)
flattened projection dim, never unevenly.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "fsdp"
TENSOR = "tensor"

def _moe_in_spec(shape, mesh):
    """(E, D, F): EP over E when E divides the tensor axis; otherwise fall
    back to TP WITHIN each (replicated) expert on F — the standard hybrid
    when n_experts < TP degree (SSPerf hillclimb 1 iter 1: mixtral's 8
    experts on a 16-way model axis were silently fully replicated, 16x
    per-chip MoE compute)."""
    e = shape[-3]
    t = mesh.shape.get("model", 1)
    return (TENSOR, FSDP, None) if (t > 1 and e % t == 0) \
        else (None, FSDP, TENSOR)


def _moe_out_spec(shape, mesh):
    e = shape[-3]
    t = mesh.shape.get("model", 1)
    return (TENSOR, None, FSDP) if (t > 1 and e % t == 0) \
        else (None, TENSOR, FSDP)


# (path regex, spec for trailing dims) — first match wins, most specific
# first.  A spec may be a callable(shape, mesh) -> trailing spec tuple.
PARAM_RULES = [
    (r"shared/w_(gate|up)$", (FSDP, TENSOR)),
    (r"shared/w_down$", (TENSOR, FSDP)),
    (r"moe/w_(gate|up)$", _moe_in_spec),             # (E, D, F)
    (r"moe/w_down$", _moe_out_spec),                 # (E, F, D)
    (r"router$", (FSDP, None)),
    (r"(wq|wk|wv|wqkv|wx)$", (FSDP, TENSOR)),
    (r"(bq|bk|bv)$", (TENSOR,)),
    (r"\bwo$", (TENSOR, FSDP)),
    (r"w_(gate|up)$", (FSDP, TENSOR)),
    (r"w_down$", (TENSOR, FSDP)),
    (r"w1$", (FSDP, TENSOR)),
    (r"b1$", (TENSOR,)),
    (r"w2$", (TENSOR, FSDP)),
    (r"in_proj$", (FSDP, TENSOR)),
    (r"out_proj$", (TENSOR, FSDP)),
    (r"\bembed$", (TENSOR, FSDP)),
    (r"lm_head$", (FSDP, TENSOR)),
    (r"patch_proj$", (None, TENSOR)),
    (r"wif$", (FSDP, None)),
    (r"/r$", (None, TENSOR, None, None)),            # sLSTM recurrent blocks
]

def _kv_spec(shape, mesh):
    """(b, hkv, S, hd): heads over the tensor axis when divisible; else
    shard the SLOT axis S (flash-decoding split-K layout) — leaving the
    cache replicated over a 16-way axis costs a full-cache all-gather per
    decode step (SSPerf hillclimb 2)."""
    w = shape[-4:]                       # trailing (b, hkv, S, hd) window
    hkv, s = w[1], w[2]
    t = mesh.shape.get("model", 1)
    if t > 1 and hkv % t == 0:
        return ("batch", TENSOR, None, None)
    if t > 1 and s % t == 0:
        return ("batch", None, TENSOR, None)
    return ("batch", None, None, None)


# KV caches / recurrent state: batch + heads/width axes.
CACHE_RULES = [
    (r"(k|v)_scale$", _kv_spec),                       # (b, hkv, S, 1)
    (r"(^|/)(k|v)$", _kv_spec),                        # (b, hkv, S, hd)
    (r"kpos$", (None,)),
    (r"conv$", ("batch", None, TENSOR)),               # (b, K-1, conv_dim)
    (r"(^|/)h$", ("batch", TENSOR, None, None)),       # ssm state (b,h,N,P)
    (r"(^|/)c$", ("batch", TENSOR, None, None)),       # mlstm C (b,h,p,p)
    (r"(^|/)n$", ("batch", TENSOR, None)),             # mlstm n (b,h,p)
    (r"(^|/)m$", ("batch", TENSOR)),                   # mlstm m (b,h)
    (r"cross$", ("batch", TENSOR, None, None)),        # (b, hkv, se, hd)
]


def mesh_axes(mesh: Mesh):
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    return {FSDP: fsdp if fsdp else None, TENSOR: "model" if "model" in names
            else None}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _resolve(mesh: Mesh, rules, path: str, shape, batch_axes=None):
    """Build a PartitionSpec for ``shape`` from the first matching rule."""
    logical = mesh_axes(mesh)
    for pat, trailing in rules:
        if callable(trailing):
            if not re.search(pat, path):
                continue
            trailing = trailing(shape, mesh)
        if re.search(pat, path) and len(trailing) <= len(shape):
            spec = [None] * (len(shape) - len(trailing)) + list(trailing)
            out = []
            for dim, ax in zip(shape, spec):
                if ax == "batch":
                    ax = batch_axes
                else:
                    ax = logical.get(ax) if isinstance(ax, str) else ax
                if ax is None or dim % _axis_size(mesh, ax) != 0:
                    out.append(None)
                else:
                    out.append(ax)
            return P(*out)
    return P()   # replicate


def batch_axes_for(mesh: Mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    cand = [a for a in ("pod", "data") if a in mesh.axis_names]
    while cand and global_batch % _axis_size(mesh, tuple(cand)) != 0:
        cand.pop(0)
    return tuple(cand) if cand else None


def param_shardings(mesh: Mesh, abstract_params):
    """NamedSharding tree for a parameter pytree (shapes from eval_shape)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _resolve(mesh, PARAM_RULES, _path_str(path), leaf.shape)),
        abstract_params)


def cache_shardings(mesh: Mesh, abstract_cache, global_batch: int):
    baxes = batch_axes_for(mesh, global_batch)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _resolve(mesh, CACHE_RULES, _path_str(path), leaf.shape,
                           batch_axes=baxes)),
        abstract_cache)


def batch_shardings(mesh: Mesh, abstract_batch, global_batch: int):
    """Token batches: leading dim = batch -> (pod, data); rest replicated."""
    baxes = batch_axes_for(mesh, global_batch)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] == global_batch and baxes:
            return P(baxes)
        return P()

    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, spec(leaf)), abstract_batch)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def ambient_mesh():
    """The mesh in scope: the new-style abstract mesh, or the legacy
    ``with mesh:`` thread-resources mesh, or None."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and pm.axis_names:
            return pm
    except Exception:
        pass
    return None


def expert_parallel_ok(num_experts: int) -> bool:
    """True when the ambient mesh's model axis divides num_experts (EP);
    False -> TP-within-expert fallback.  True outside any mesh context."""
    mesh = ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return True
    return num_experts % dict(mesh.shape)["model"] == 0


def constrain(x, *logical):
    """with_sharding_constraint by LOGICAL axes, mesh-context-aware.

    ``logical`` entries: "batch" (pod+data), "fsdp", "tensor", or None —
    one per dim of x.  No-op outside a mesh context (CPU smoke tests) and
    for any dim the mesh axis does not divide.  This is how the model code
    pins GSPMD's intermediate-sharding decisions without knowing the mesh
    (SSPerf hillclimb 1 iter 3: GSPMD chose to replicate MoE expert
    activations' gradients, inserting ~20 GB/chip f32 all-reduces).
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    fsdp = tuple(a for a in ("pod", "data") if a in names) or None
    table = {"batch": fsdp, "fsdp": fsdp,
             "tensor": "model" if "model" in names else None}
    spec = []
    for dim, ax in zip(x.shape, logical):
        ax = table.get(ax) if isinstance(ax, str) else ax
        size = 1
        if ax is not None:
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                size *= dict(mesh.shape)[a]
        spec.append(ax if ax is not None and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
