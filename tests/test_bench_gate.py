"""tools/bench_gate.py — the CI perf gate over kernel_bench JSON."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))
import bench_gate  # noqa: E402


def _payload(rows):
    return {"suite": "kernel_bench", "rows": rows}


GOOD_HLO_ROW = {
    "name": "pipelined_hlo_p4_poisson32x32_m20",
    "us": 0.0,
    "loop_coll_ops_split": 4, "loop_coll_ops_pipelined": 2,
    "loop_psums_split": 3, "loop_psums_pipelined": 1,
    "restarts_split": 3, "restarts_pipelined": 3,
    "loop_coll_ratio": 2.0,
    "derived": "x", "mode": "modeled",
}


def test_clean_run_passes():
    cur = _payload([
        {"name": "a", "us": 1.0, "derived": "", "mode": "modeled",
         "traffic_ratio": 0.4, "hbm_bytes_x": 1, "hbm_bytes_y": 2},
        dict(GOOD_HLO_ROW),
    ])
    base = _payload([
        {"name": "a", "us": 1.0, "derived": "", "mode": "modeled",
         "traffic_ratio": 0.4, "hbm_bytes_x": 1, "hbm_bytes_y": 2},
    ])
    assert bench_gate.check(cur, base, tol=0.05, min_pipeline_ratio=2.0) == []


def test_traffic_ratio_regression_fails():
    cur = _payload([{"name": "a", "us": 1.0, "derived": "",
                     "traffic_ratio": 0.5}])
    base = _payload([{"name": "a", "us": 1.0, "derived": "",
                      "traffic_ratio": 0.4}])
    fails = bench_gate.check(cur, base, tol=0.05, min_pipeline_ratio=2.0)
    assert len(fails) == 1 and "traffic_ratio" in fails[0]


def test_traffic_ratio_within_tol_passes():
    cur = _payload([{"name": "a", "us": 1.0, "derived": "",
                     "traffic_ratio": 0.41}])
    base = _payload([{"name": "a", "us": 1.0, "derived": "",
                      "traffic_ratio": 0.40}])
    assert bench_gate.check(cur, base, tol=0.05,
                            min_pipeline_ratio=2.0) == []


def test_pipeline_ratio_below_floor_fails():
    row = dict(GOOD_HLO_ROW, loop_coll_ops_pipelined=3,
               loop_coll_ratio=4 / 3)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("collective ratio" in f for f in fails)


def test_restart_parity_broken_fails():
    row = dict(GOOD_HLO_ROW, restarts_pipelined=6)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("parity" in f for f in fails)


def test_collective_count_growth_vs_baseline_fails():
    cur = _payload([dict(GOOD_HLO_ROW, loop_coll_ops_pipelined=2)])
    base = _payload([dict(GOOD_HLO_ROW, loop_coll_ops_pipelined=1)])
    fails = bench_gate.check(cur, base, tol=0.05, min_pipeline_ratio=2.0)
    assert any("loop_coll_ops_pipelined" in f for f in fails)


def test_psum_schedule_must_stay_single():
    row = {"name": "pipelined_schedule_m20_n16384", "us": 1.0,
           "derived": "", "psums_per_step_split": 3,
           "psums_per_step_pipelined": 2}
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("psum once" in f for f in fails)


def test_main_exit_codes(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_payload([dict(GOOD_HLO_ROW)])))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_payload(
        [dict(GOOD_HLO_ROW, restarts_pipelined=9)])))
    missing_base = str(tmp_path / "nope.json")
    assert bench_gate.main([str(good), "--baseline", missing_base]) == 0
    assert bench_gate.main([str(bad), "--baseline", missing_base]) == 1


def test_smoke_subset_skips_unmatched_rows():
    """Smoke rows use smaller cases; names absent from baseline are only
    checked against absolute invariants, not diffed."""
    cur = _payload([{"name": "only_in_smoke", "us": 1.0, "derived": "",
                     "traffic_ratio": 0.9}])
    base = _payload([{"name": "full_run_row", "us": 1.0, "derived": "",
                      "traffic_ratio": 0.1}])
    assert bench_gate.check(cur, base, tol=0.05,
                            min_pipeline_ratio=2.0) == []


GOOD_SERVE_ROW = {
    "name": "solver_serve_n160_k8_req32",
    "us": 100.0,
    "cycles_packed": 127, "cycles_sequential": 946, "cycles_ideal": 119,
    "hbm_bytes_packed_A": 127, "hbm_bytes_sequential_A": 946,
    "traffic_ratio": 127 / 946,
    "derived": "x", "mode": "modeled",
}


def test_serve_row_clean_passes():
    assert bench_gate.check(_payload([dict(GOOD_SERVE_ROW)]), None,
                            tol=0.05, min_pipeline_ratio=2.0) == []


def test_serve_packed_no_better_than_sequential_fails():
    row = dict(GOOD_SERVE_ROW, cycles_packed=946,
               traffic_ratio=1.0)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("no better" in f for f in fails)


def test_serve_packed_beyond_ideal_slack_fails():
    row = dict(GOOD_SERVE_ROW, cycles_packed=140, traffic_ratio=140 / 946)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0, serve_ideal_slack=1.1)
    assert any("ideal" in f for f in fails)


def test_serve_ideal_slack_is_configurable():
    row = dict(GOOD_SERVE_ROW, cycles_packed=140, traffic_ratio=140 / 946)
    assert bench_gate.check(_payload([row]), None, tol=0.05,
                            min_pipeline_ratio=2.0,
                            serve_ideal_slack=1.25) == []


def test_serve_broken_ideal_model_fails():
    row = dict(GOOD_SERVE_ROW, cycles_ideal=2000)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("model arithmetic" in f for f in fails)


def test_serve_traffic_ratio_diffed_like_any_other():
    cur = _payload([dict(GOOD_SERVE_ROW, cycles_packed=140,
                         traffic_ratio=140 / 946)])
    base = _payload([dict(GOOD_SERVE_ROW)])
    fails = bench_gate.check(cur, base, tol=0.05, min_pipeline_ratio=2.0,
                             serve_ideal_slack=1.25)
    assert any("traffic_ratio" in f for f in fails)


GOOD_RECOVERY_ROW = {
    "name": "recovery_selfheal_n96_m4",
    "us": 100.0,
    "restarts_plain": 45, "cycles_fault_free": 45, "cycles_stepped": 45,
    "overhead_ratio": 1.0, "stepped_overhead_ratio": 1.0,
    "restarts_recovered": 46, "recovery_extra_restarts": 1,
    "stepdowns_recovered": 1,
    "derived": "x", "mode": "modeled",
}


def test_recovery_row_clean_passes():
    assert bench_gate.check(_payload([dict(GOOD_RECOVERY_ROW)]), None,
                            tol=0.05, min_pipeline_ratio=2.0) == []


def test_recovery_fault_free_overhead_fails():
    row = dict(GOOD_RECOVERY_ROW, cycles_fault_free=47,
               overhead_ratio=47 / 45)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("fault-free" in f and "overhead_ratio" in f for f in fails)


def test_recovery_stepped_overhead_fails_independently():
    row = dict(GOOD_RECOVERY_ROW, cycles_stepped=47,
               stepped_overhead_ratio=47 / 45)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("stepped_overhead_ratio" in f for f in fails)


def test_recovery_extra_restarts_beyond_one_fails():
    row = dict(GOOD_RECOVERY_ROW, restarts_recovered=47,
               recovery_extra_restarts=2)
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("extra restarts" in f for f in fails)


def test_recovery_fewer_restarts_than_plain_passes():
    """A lower ladder rung may converge FASTER; negative deltas are fine."""
    row = dict(GOOD_RECOVERY_ROW, restarts_recovered=43,
               recovery_extra_restarts=-2)
    assert bench_gate.check(_payload([row]), None, tol=0.05,
                            min_pipeline_ratio=2.0) == []


def test_recovery_overhead_slack_is_configurable():
    row = dict(GOOD_RECOVERY_ROW, cycles_fault_free=47,
               overhead_ratio=47 / 45)
    assert bench_gate.check(_payload([row]), None, tol=0.05,
                            min_pipeline_ratio=2.0,
                            recovery_overhead_slack=1.05) == []


GOOD_SELL_ROW = {
    "name": "sell_spmv_powerlaw_n4096", "us": 0.0, "derived": "x",
    "mode": "modeled", "hbm_bytes_ell": 900_000, "hbm_bytes_sell": 200_000,
}


def test_sell_powerlaw_traffic_cut_passes():
    assert bench_gate.check(_payload([dict(GOOD_SELL_ROW)]), None, tol=0.05,
                            min_pipeline_ratio=2.0) == []


def test_sell_powerlaw_below_factor_fails():
    row = dict(GOOD_SELL_ROW, hbm_bytes_sell=400_000)   # only 2.25x cut
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("power-law" in f for f in fails)


def test_sell_traffic_factor_is_configurable():
    row = dict(GOOD_SELL_ROW, hbm_bytes_sell=400_000)
    assert bench_gate.check(_payload([row]), None, tol=0.05,
                            min_pipeline_ratio=2.0,
                            sell_traffic_factor=2.0) == []


def test_sell_stencil_never_worse_passes():
    row = dict(GOOD_SELL_ROW, name="sell_spmv_poisson2d_64x64",
               hbm_bytes_sell=930_000)                  # 1.033x: within slack
    assert bench_gate.check(_payload([row]), None, tol=0.05,
                            min_pipeline_ratio=2.0) == []


def test_sell_stencil_beyond_slack_fails():
    row = dict(GOOD_SELL_ROW, name="sell_spmv_poisson2d_64x64",
               hbm_bytes_sell=990_000)                  # 1.1x ELL
    fails = bench_gate.check(_payload([row]), None, tol=0.05,
                             min_pipeline_ratio=2.0)
    assert any("never-worse" in f for f in fails)
