"""Block Gram-Schmidt + matrix-powers kernels vs their jnp oracles.

All kernel calls run through the Pallas interpreter on CPU (the real
kernel arithmetic, bit-accurate), matching the dispatch CI exercises via
``kernels.tuning.kernel_mode()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, operators, stencils
from repro.kernels import block_gs, matrix_powers, ref, tuning

KEY = jax.random.PRNGKey(0)
EPS = float(jnp.finfo(jnp.float32).eps) * 100


def _basis(m1, n, rows, seed=1):
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed),
                                           (n, min(rows, n))))
    v = jnp.zeros((m1, n)).at[:min(rows, n)].set(q.T)
    return v


# --------------------------------------------------------------------------
# matrix-powers kernels vs the sequential-matvec reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("nx,ny,s", [(8, 8, 2), (12, 10, 4), (16, 16, 8)])
def test_banded_powers_matches_sequential_matvecs(nx, ny, s):
    op = stencils.poisson_2d(nx, ny)
    x = jax.random.normal(KEY, (nx * ny,))
    x = x / jnp.linalg.norm(x)
    u_k, s_k = matrix_powers.banded_powers(op.bands, x, op.offsets, s,
                                           interpret=True)
    u_r, s_r = matrix_powers.matrix_powers_ref(op, x, s, EPS)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n,s", [(64, 2), (120, 4), (300, 3)])
def test_dense_powers_matches_sequential_matvecs(n, s):
    """Includes padding paths (n not a lane/tile multiple)."""
    a = operators.random_diagdom(jax.random.PRNGKey(2), n)
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    x = x / jnp.linalg.norm(x)
    u_k, s_k = matrix_powers.dense_powers(a, x, s, interpret=True)
    u_r, s_r = matrix_powers.matrix_powers_ref(operators.DenseOperator(a),
                                               x, s, EPS)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=3e-5, atol=3e-5)


def test_banded_powers_bf16_bands():
    """bf16 band storage halves the A stream; accumulation stays f32."""
    op = stencils.convection_diffusion_2d(10, 10, dtype=jnp.bfloat16)
    x = jax.random.normal(KEY, (100,))
    x = x / jnp.linalg.norm(x)
    u_k, s_k = matrix_powers.banded_powers(op.bands, x, op.offsets, 4,
                                           interpret=True)
    u_r, s_r = matrix_powers.matrix_powers_ref(op, x, 4, EPS)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-2, atol=2e-2)


def test_banded_powers_degenerate_operand_is_finite():
    """A zero operand must produce zeros (breakdown guard), not NaN."""
    op = stencils.poisson_2d(8, 8)
    u, s = matrix_powers.banded_powers(op.bands, jnp.zeros((64,)),
                                       op.offsets, 4, interpret=True)
    assert bool(jnp.isfinite(u).all()) and bool(jnp.isfinite(s).all())
    np.testing.assert_allclose(np.asarray(u), 0.0)


def test_powers_shape_validation():
    op = stencils.poisson_2d(8, 8)
    with pytest.raises(TypeError):
        matrix_powers.banded_powers(op.bands, jnp.zeros((63,)), op.offsets,
                                    4, interpret=True)
    with pytest.raises(TypeError):
        matrix_powers.dense_powers(jnp.zeros((8, 8)), jnp.zeros((9,)), 2,
                                   interpret=True)


# --------------------------------------------------------------------------
# block GS pass kernel vs the psum-safe reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m1,n,s,rows", [
    (21, 256, 4, 8),
    (33, 300, 4, 12),      # padding path (n not a lane multiple)
    (17, 128, 8, 4),
    (9, 512, 2, 5),
])
def test_block_gs_pass_matches_reference(m1, n, s, rows):
    v = _basis(m1, n, rows)
    w = jax.random.normal(jax.random.PRNGKey(4), (s, n))
    tin = jnp.triu(jax.random.normal(jax.random.PRNGKey(5), (s, s)))
    mask = (jnp.arange(m1) < rows).astype(jnp.float32)
    c_k, w_k, g_k = block_gs.block_gs_pass(v, w, tin, mask, interpret=True)
    c_r, w_r, g_r = block_gs.block_gs_pass_ref(v, w, tin, mask)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=3e-5, atol=3e-4)


def test_block_gs_pass_bf16_basis():
    """bf16 basis storage upcasts in-register (f32 accumulation)."""
    m1, n, s = 17, 256, 4
    v = _basis(m1, n, 8).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(6), (s, n))
    mask = (jnp.arange(m1) < 8).astype(jnp.float32)
    c_k, w_k, g_k = block_gs.block_gs_pass(v, w, jnp.eye(s), mask,
                                           interpret=True)
    c_r, w_r, g_r = block_gs.block_gs_pass_ref(v, w, jnp.eye(s), mask)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# batched per-lane CGS2 kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k,m1,n", [(1, 31, 160), (4, 21, 200), (3, 9, 96)])
def test_batched_cgs2_matches_vmapped_reference(k, m1, n):
    v = jnp.stack([_basis(m1, n, 5 + i, seed=7 + i) for i in range(k)])
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n))
    js = jnp.arange(k) % m1                # lanes at DIFFERENT step counts
    mask = (jnp.arange(m1)[None, :] <= js[:, None]).astype(jnp.float32)
    h_k, w_k = block_gs.batched_cgs2(v, w, mask, interpret=True)
    h_r, w_r = jax.vmap(ref.cgs2)(v, w, mask)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=3e-5, atol=3e-5)


def test_batched_cgs2_shape_validation():
    with pytest.raises(TypeError):
        block_gs.batched_cgs2(jnp.zeros((2, 5, 64)), jnp.zeros((2, 63)),
                              jnp.zeros((2, 5)), interpret=True)


# --------------------------------------------------------------------------
# gmres_batched dispatch: kernel when it fits, jnp fallback otherwise
# --------------------------------------------------------------------------
def test_gmres_batched_runs_through_block_gs(monkeypatch):
    """The kernel path must actually engage on a fitting problem."""
    calls = []
    orig = block_gs.batched_cgs2

    def spy(*args, **kw):
        calls.append(1)
        return orig(*args, **kw)

    import repro.kernels.block_gs as bg_mod
    monkeypatch.setattr(bg_mod, "batched_cgs2", spy)
    a = operators.random_diagdom(jax.random.PRNGKey(9), 96)
    bs = jax.random.normal(jax.random.PRNGKey(10), (3, 96))
    res = gmres_batched(a, bs, m=16, tol=1e-5)
    assert bool(res.converged.all())
    assert calls, "batched_cgs2 kernel was never invoked"


def test_gmres_batched_forced_overflow_falls_back(monkeypatch):
    """With block_gs_fits forced False the jnp fallback must produce the
    same solve (the silent-degrade contract)."""
    a = operators.random_diagdom(jax.random.PRNGKey(11), 128)
    bs = jax.random.normal(jax.random.PRNGKey(12), (3, 128))
    res_kernel = gmres_batched(a, bs, m=20, tol=1e-5)

    import repro.kernels.block_gs as bg_mod

    def boom(*args, **kw):
        raise AssertionError("kernel path taken despite forced overflow")

    monkeypatch.setattr(tuning, "block_gs_fits",
                        lambda *a_, **k_: False)
    monkeypatch.setattr(bg_mod, "batched_cgs2", boom)
    res_ref = gmres_batched(a, bs, m=20, tol=1e-5)
    assert bool(res_ref.converged.all())
    np.testing.assert_allclose(np.asarray(res_ref.x),
                               np.asarray(res_kernel.x),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(res_ref.restarts),
                                  np.asarray(res_kernel.restarts))


def test_block_gs_fits_rejects_vmem_overflow():
    assert tuning.block_gs_fits(31, 4096, jnp.float32)
    assert tuning.block_gs_fits(33, 8192, jnp.float32, s=8)
    # a basis block too large for VMEM must push the solve to jnp
    assert not tuning.block_gs_fits(513, 262144, jnp.float32)


def test_choose_block_gs_alignment():
    m1p, np_, sp = tuning.choose_block_gs(21, 300, 4, "float32")
    assert m1p % tuning.sublane("float32") == 0 and m1p >= 21
    assert np_ % tuning.LANE == 0 and np_ >= 300
    assert sp % tuning.sublane("float32") == 0 and sp >= 4


# --------------------------------------------------------------------------
# gmres single-RHS sanity through the batched path stays untouched
# --------------------------------------------------------------------------
def test_gmres_batched_kernel_path_matches_per_lane_gmres():
    a = operators.random_diagdom(jax.random.PRNGKey(13), 160)
    bs = jax.random.normal(jax.random.PRNGKey(14), (2, 160))
    res = gmres_batched(a, bs, m=20, tol=1e-5)
    for i in range(2):
        single = gmres(a, bs[i], m=20, tol=1e-5)
        np.testing.assert_allclose(np.asarray(res.x[i]),
                                   np.asarray(single.x),
                                   rtol=1e-4, atol=1e-5)
        assert int(res.restarts[i]) == int(single.restarts)
