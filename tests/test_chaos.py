"""Chaos soak: a 200-request server run under a randomized fault schedule.

The acceptance bar (ISSUE 8): every submitted request reaches EXACTLY one
terminal state (DONE / FAILED / REJECTED / TIMEOUT) — no lost requests,
no double retirements — and recovered solves still meet their tolerance.
The schedule is seeded, so a failure replays exactly.

``test_ambient_schedule_soak`` deliberately does NOT isolate REPRO_FAULT:
it is the CI injection-matrix target — run it under any schedule from
``tools/faultinject.py`` and the accounting invariants must still hold.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import operators
from repro.runtime import faultinject
from repro.serve import (DONE, FAILED, REJECTED, TERMINAL, TIMEOUT,
                         SolverServer)

N, K, M = 32, 4, 8


def _op(seed=2):
    return operators.DenseOperator(
        operators.random_diagdom(jax.random.PRNGKey(seed), N))


def _server(op, **kw):
    kw.setdefault("fault_retries", 2)
    kw.setdefault("cycle_retries", 2)
    kw.setdefault("max_pending", 64)
    return SolverServer(op, m=M, k=K, **kw)


def _drain_collecting(srv, max_ticks=5000):
    """run(), but collecting every Retirement step() hands back."""
    events, ticks = [], 0
    while srv.state.busy or srv.ingress.peek() is not None:
        assert ticks < max_ticks, "server failed to drain"
        events.extend(srv.step())
        ticks += 1
    return events


def _random_schedule(rng, max_tick=400):
    """A seeded REPRO_FAULT spec: lane poisons + transient cycle raises."""
    lane = rng.choice(max_tick, size=8, replace=False)
    cyc = rng.choice(max_tick, size=4, replace=False)
    return ",".join([f"serve.lane_nan:{t}" for t in sorted(lane)]
                    + [f"serve.cycle:{t}" for t in sorted(cyc)])


def _check_soak_invariants(srv, rids, retire_events, bs):
    # Exactly one terminal state per request; none lost, none invented.
    assert set(srv.results) == set(rids)
    for rid in rids:
        assert srv.results[rid].status in TERMINAL, srv.results[rid]
    # No double retirement: each rid crosses the retirement boundary at
    # most once (REJECTED requests never cross it at all).
    seen = [r.req.rid for r in retire_events]
    assert len(seen) == len(set(seen))
    rejected = {r for r in rids if srv.results[r].status == REJECTED}
    assert set(seen) == set(rids) - rejected
    # Scheduler counters agree with the outcome map.
    m = srv.metrics()
    by_status = {s: sum(1 for r in rids if srv.results[r].status == s)
                 for s in (DONE, FAILED, TIMEOUT, REJECTED)}
    assert m["retired_done"] == by_status[DONE]
    assert m["retired_timeout"] == by_status[TIMEOUT]
    assert m["retired_failed"] == by_status[FAILED]
    assert sum(by_status.values()) == len(rids)
    # Every DONE solve — faulted-and-retried ones included — meets its
    # OWN tolerance against the true recomputed residual.
    op = srv.handle.op
    for rid, (b, tol) in bs.items():
        out = srv.results[rid]
        if out.status != DONE:
            continue
        bj = jnp.asarray(b, jnp.float32)
        true_res = float(jnp.linalg.norm(bj - op(jnp.asarray(out.x))))
        assert true_res <= tol * float(np.linalg.norm(b)) * 1.05, (
            rid, true_res, tol)


def _submit_mixed_workload(srv, rng, n_req):
    """Seeded mix: solvable, hopeless-tol, deadlined, and invalid
    requests, with arrival interleaved against server ticks."""
    rids, bs = [], {}
    for _ in range(n_req):
        kind = rng.random()
        if kind < 0.03:
            rid = srv.submit(np.full(N, np.nan))               # REJECTED
        elif kind < 0.06:
            rid = srv.submit(rng.standard_normal(N), tol=-1.0)  # REJECTED
        else:
            b = rng.standard_normal(N)
            tol = float(rng.choice([1e-3, 1e-4, 1e-5, 1e-12]))
            deadline = (int(rng.integers(1, 6))
                        if rng.random() < 0.15 else None)
            rid = srv.submit(b, tol=tol,
                             max_restarts=int(rng.integers(2, 30)),
                             deadline_ticks=deadline)
            if srv.results.get(rid) is None:   # not backpressure-rejected
                bs[rid] = (b, tol)
        rids.append(rid)
        if rng.random() < 0.4:
            srv.step()
    return rids, bs


def test_chaos_soak_200_requests(monkeypatch):
    rng = np.random.default_rng(1234)
    monkeypatch.setenv("REPRO_FAULT", _random_schedule(rng))
    faultinject.reset()
    srv = _server(_op())
    rids, bs = _submit_mixed_workload(srv, rng, 200)
    # Interleaved submission already retires some; collect those too.
    # (step() return values during submission are lost by design — the
    # results map is the authority; retire events only need the drain.)
    pre_done = {r for r in rids if r in srv.results}
    events = _drain_collecting(srv)
    assert len(rids) == 200 and len(set(rids)) == 200
    # Rebuild the full event view: anything terminal before the drain
    # was either REJECTED at submit or retired during interleaved steps.
    assert set(srv.results) == set(rids)
    for rid in rids:
        assert srv.results[rid].status in TERMINAL
    m = srv.metrics()
    by_status = {s: sum(1 for r in rids if srv.results[r].status == s)
                 for s in (DONE, FAILED, TIMEOUT, REJECTED)}
    assert sum(by_status.values()) == 200
    assert m["retired_done"] == by_status[DONE]
    assert m["retired_timeout"] == by_status[TIMEOUT]
    assert m["retired_failed"] == by_status[FAILED]
    assert by_status[DONE] > 100               # chaos didn't eat the fleet
    assert m["lane_faults"] >= 1               # ...but faults DID happen
    # Recovered DONE solves meet their contract on the true residual.
    op = srv.handle.op
    for rid, (b, tol) in bs.items():
        out = srv.results[rid]
        if out.status == DONE:
            bj = jnp.asarray(b, jnp.float32)
            true_res = float(jnp.linalg.norm(bj - op(jnp.asarray(out.x))))
            assert true_res <= tol * float(np.linalg.norm(b)) * 1.05


def test_chaos_no_double_retirement(monkeypatch):
    """Batch-submit (no interleaving) so EVERY retirement is observed:
    each request crosses the retirement boundary exactly once."""
    rng = np.random.default_rng(99)
    monkeypatch.setenv("REPRO_FAULT",
                       "serve.lane_nan:0,serve.lane_nan:3,serve.cycle:2")
    faultinject.reset()
    srv = _server(_op())
    rids, bs = [], {}
    for i in range(40):
        b = rng.standard_normal(N)
        tol = float(rng.choice([1e-3, 1e-5, 1e-12]))
        deadline = int(rng.integers(2, 8)) if i % 5 == 0 else None
        rid = srv.submit(b, tol=tol, max_restarts=int(rng.integers(2, 20)),
                         deadline_ticks=deadline)
        rids.append(rid)
        bs[rid] = (b, tol)
    events = _drain_collecting(srv)
    _check_soak_invariants(srv, rids, events, bs)
    assert faultinject.fired.get("serve.lane_nan", 0) >= 1


def test_chaos_kill_resume_equivalence(tmp_path, monkeypatch):
    """Kill the server mid-chaos (checkpoint at a tick boundary), resume
    in a FRESH server: every request must reach the same terminal state
    with the same restart count and bit-identical x as the uninterrupted
    run under the same fault schedule."""
    schedule = "serve.lane_nan:1,serve.cycle:4,serve.lane_nan:7"
    op = _op(seed=3)
    rng = np.random.default_rng(7)
    work = [(rng.standard_normal(N), float(t), int(mr))
            for t, mr in zip(rng.choice([1e-3, 1e-5, 1e-12], size=24),
                             rng.integers(2, 25, size=24))]

    def submit_all(srv):
        for b, tol, mr in work:
            srv.submit(b, tol=tol, max_restarts=mr)

    monkeypatch.setenv("REPRO_FAULT", schedule)
    faultinject.reset()
    ref = _server(op, fault_retries=1)
    submit_all(ref)
    ref.run()

    faultinject.reset()
    srv = _server(op, fault_retries=1)
    submit_all(srv)
    for _ in range(5):
        srv.step()
    srv.save_checkpoint(str(tmp_path))
    already = dict(srv.results)

    # "New process": full schedule re-armed; entries for ticks already
    # behind the restored tick counter can never match again.
    faultinject.reset()
    srv2 = _server(op, fault_retries=1).restore_checkpoint(str(tmp_path))
    srv2.results.update(already)
    srv2.run()

    assert set(srv2.results) == set(ref.results)
    for rid, a in ref.results.items():
        b2 = srv2.results[rid]
        assert (a.status, a.restarts) == (b2.status, b2.restarts), rid
        assert a.residual == b2.residual, rid
        if a.x is not None:
            assert np.array_equal(a.x, b2.x), rid
    assert ref.metrics()["tick"] == srv2.metrics()["tick"]


def test_ambient_schedule_soak():
    """CI injection-matrix target: runs under WHATEVER REPRO_FAULT the
    environment carries (including none).  Only schedule-independent
    invariants are asserted — terminal accounting and the DONE
    contract — so any valid schedule must leave it green."""
    faultinject.reset()                    # re-arm the ambient schedule
    rng = np.random.default_rng(555)
    srv = _server(_op(seed=4))
    rids, bs = [], {}
    for i in range(40):
        b = rng.standard_normal(N)
        tol = float(rng.choice([1e-3, 1e-5, 1e-12]))
        rid = srv.submit(b, tol=tol, max_restarts=int(rng.integers(2, 20)),
                         deadline_ticks=int(rng.integers(3, 10)))
        rids.append(rid)
        bs[rid] = (b, tol)
    events = _drain_collecting(srv)
    _check_soak_invariants(srv, rids, events, bs)
    faultinject.reset()
