"""Distributed GMRES + sharded step lowering on fake devices.

The 8-device cases run in a subprocess because the XLA host-device-count
flag must be set before jax initializes (the main pytest process keeps the
real 1-device view, as required).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_gmres_matches_dense_8dev():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import gmres, gmres_sharded, operators
        mesh = make_mesh((8,), ('model',))
        a = operators.random_diagdom(jax.random.PRNGKey(0), 256)
        b = jax.random.normal(jax.random.PRNGKey(1), (256,))
        res_d = gmres_sharded(mesh, 'model', a, b, m=20, tol=1e-5)
        res_s = gmres(a, b, m=20, tol=1e-5)
        err = float(jnp.linalg.norm(res_d.x - res_s.x)
                    / jnp.linalg.norm(res_s.x))
        rel = float(jnp.linalg.norm(a @ res_d.x - b) / jnp.linalg.norm(b))
        print(json.dumps({"err": err, "rel": rel,
                          "conv": bool(res_d.converged),
                          "restarts": int(res_d.restarts)}))
    """)
    r = _run_subprocess(code)
    assert r["conv"]
    assert r["rel"] < 5e-5
    assert r["err"] < 1e-3


def test_train_step_runs_on_2x4_mesh():
    """REAL sharded train step executes (not just lowers) on 8 fake devices."""
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from repro import configs
        from repro.launch.steps import make_train_step, TrainState, \\
            make_optimizer
        from repro.models import build
        from repro.models.config import ShapeConfig
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg = configs.get('tinyllama-1.1b').reduced()
        shape = ShapeConfig('t', 32, 4, 'train')
        opt = make_optimizer(cfg)
        step_fn, st_sh, b_sh = make_train_step(cfg, mesh, shape, opt=opt)
        model = build(cfg)
        with mesh:
            params = jax.jit(model.init, out_shardings=st_sh.params)(
                jax.random.PRNGKey(0))
            opt_state = jax.jit(opt.init, out_shardings=st_sh.opt)(params)
            batch = {
              'tokens': jnp.ones((4, 32), jnp.int32),
              'labels': jnp.ones((4, 32), jnp.int32),
              'mask': jnp.ones((4, 32), jnp.float32),
            }
            batch = jax.device_put(batch, b_sh)
            state = TrainState(params=params, opt=opt_state)
            losses = []
            for _ in range(3):
                state, m = step_fn(state, batch)
                losses.append(float(m['loss']))
        print(json.dumps({"losses": losses}))
    """)
    r = _run_subprocess(code)
    assert all(np.isfinite(r["losses"]))
    assert r["losses"][-1] < r["losses"][0]    # optimizes a repeated batch


def test_serve_step_runs_on_2x4_mesh():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro import configs
        from repro.launch.steps import make_serve_step
        from repro.models import build
        from repro.models.config import ShapeConfig
        mesh = make_mesh((2, 4), ('data', 'model'))
        cfg = configs.get('mixtral-8x22b').reduced()
        shape = ShapeConfig('d', 64, 4, 'decode')
        model = build(cfg)
        serve, p_sh, _ = make_serve_step(cfg, mesh, shape)
        with mesh:
            params = jax.jit(model.init, out_shardings=p_sh)(
                jax.random.PRNGKey(0))
            cache = model.init_cache(4, 64)
            tok = jnp.array([2, 3, 4, 5], jnp.int32)
            outs = []
            for i in range(4):
                tok, cache = serve(params, cache, tok, jnp.int32(i))
                outs.append(int(tok[0]))
        print(json.dumps({"tokens": outs}))
    """)
    r = _run_subprocess(code)
    assert len(r["tokens"]) == 4


def test_sharded_block_jacobi_cuts_steps_8dev():
    """Shard-local block-Jacobi: large step (= collective-round) reduction
    with zero preconditioner communication (SSPerf hillclimb 3)."""
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import gmres_sharded, operators
        mesh = make_mesh((8,), ('model',))
        n = 1024
        a = operators.convection_diffusion(n, beta=0.7)
        b = jnp.sin(jnp.arange(n) * 0.1)
        base = gmres_sharded(mesh, 'model', a, b, m=20, tol=1e-4,
                             max_restarts=300)
        pc = gmres_sharded(mesh, 'model', a, b, m=20, tol=1e-4,
                           max_restarts=300, precond='block_jacobi')
        bn = float(jnp.linalg.norm(b))
        print(json.dumps({
            "base_steps": int(base.inner_steps),
            "pc_steps": int(pc.inner_steps),
            "pc_rel": float(pc.residual) / bn,
            "pc_conv": bool(pc.converged)}))
    """)
    r = _run_subprocess(code)
    assert r["pc_conv"]
    assert r["pc_rel"] < 5e-4
    assert r["pc_steps"] * 20 < r["base_steps"]   # >=20x fewer rounds


def test_compressed_psum_8dev():
    """int8 compressed all-reduce ~= f32 psum within quantization error."""
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh
        from repro.optim.compression import compressed_psum
        mesh = make_mesh((8,), ('d',))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

        def f(xs):
            exact = jax.lax.psum(xs, 'd')
            approx = compressed_psum(xs, 'd')
            err = jnp.linalg.norm(exact - approx) / jnp.linalg.norm(exact)
            return err[None]
        from repro import compat
        err = compat.shard_map(f, mesh=mesh,
                               in_specs=jax.sharding.PartitionSpec('d'),
                               out_specs=jax.sharding.PartitionSpec('d'),
                               )(x)
        print(json.dumps({"err": float(jnp.max(err))}))
    """)
    r = _run_subprocess(code)
    assert r["err"] < 2e-2


def test_singleton_mesh_inprocess():
    """shard_map solver on the real (1-device) mesh — no subprocess."""
    from repro.core import gmres_sharded, operators
    mesh = make_mesh((1,), ("model",))
    a = operators.random_diagdom(jax.random.PRNGKey(0), 64)
    b = jax.random.normal(jax.random.PRNGKey(1), (64,))
    res = gmres_sharded(mesh, "model", a, b, m=16, tol=1e-5)
    assert bool(res.converged)
    err = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    assert err < 5e-5
