"""The multi-pod dry-run path, end-to-end, in a subprocess (512 fake

devices; the flag must precede jax init, hence not in-process).  One small
cell per mesh keeps it CI-fast while guarding the whole lowering stack:
configs -> input_specs -> shardings -> jit -> lower -> compile -> roofline.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # dryrun.py sets its own
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return out.stdout


def test_dryrun_cell_single_pod(tmp_path):
    out_file = str(tmp_path / "cell.jsonl")
    _run(["--arch", "xlstm-125m", "--shape", "decode_32k",
          "--out", out_file])
    rec = json.loads(open(out_file).read().strip())
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    rf = rec["roofline"]
    assert rf["flops_per_chip"] > 0
    assert rf["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["output_size_in_bytes"] > 0


def test_dryrun_cell_multi_pod(tmp_path):
    out_file = str(tmp_path / "cell.jsonl")
    _run(["--arch", "xlstm-125m", "--shape", "decode_32k", "--multi-pod",
          "--out", out_file])
    rec = json.loads(open(out_file).read().strip())
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["mesh"] == "2x16x16"


def test_dryrun_skip_reason(tmp_path):
    out_file = str(tmp_path / "cell.jsonl")
    _run(["--arch", "tinyllama-1.1b", "--shape", "long_500k",
          "--out", out_file])
    rec = json.loads(open(out_file).read().strip())
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]
