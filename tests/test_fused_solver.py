"""Kernel-backed solver paths vs the jnp reference (Pallas interpret on CPU).

The tentpole wiring: ``gmres(gs="cgs2_fused")``, ``gmres(gs="fused")``,
``DenseOperator(backend="pallas")`` and the block multi-RHS ``gmres_batched``
must all reproduce the reference solver to dtype tolerance.  On CPU
``kernels.tuning.kernel_mode()`` returns "interpret", so every test here
exercises the REAL kernel arithmetic through the Pallas interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, operators
from repro.kernels import arnoldi_fused, tuning

KEY = jax.random.PRNGKey(0)


def _system(n=160, seed=0):
    a = operators.random_diagdom(jax.random.PRNGKey(seed), n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    return a, b


def relres(a, x, b):
    return float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))


# --------------------------------------------------------------------------
# fused Arnoldi-step kernel vs the jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,m1,j", [
    (160, 31, 0),
    (160, 31, 7),
    (300, 12, 5),       # padding path (n not a lane multiple)
    (96, 97, 40),       # full-memory regime: m1 > n
])
def test_arnoldi_fused_kernel_matches_reference(n, m1, j):
    a = jax.random.normal(KEY, (n, n)) / np.sqrt(n)
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1),
                                           (n, min(m1, n))))
    vb = jnp.zeros((m1, n)).at[:min(m1, n)].set(q.T)
    vb = jnp.where(jnp.arange(m1)[:, None] <= j, vb, 0.0)
    h_k, w_k = arnoldi_fused.arnoldi_step(a, vb, j, interpret=True)
    h_r, w_r = arnoldi_fused.arnoldi_step_ref(a, vb, j)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=3e-5, atol=3e-5)


def test_arnoldi_fused_kernel_bf16_basis():
    """bf16 basis storage, f32 accumulation inside the kernel."""
    n, m1, j = 256, 17, 9
    a = jax.random.normal(KEY, (n, n)).astype(jnp.bfloat16)
    vb = (jax.random.normal(jax.random.PRNGKey(2), (m1, n)) / np.sqrt(n)
          ).astype(jnp.bfloat16)
    vb = jnp.where(jnp.arange(m1)[:, None] <= j, vb, 0.0)
    h_k, w_k = arnoldi_fused.arnoldi_step(a, vb, j, interpret=True)
    h_r, w_r = arnoldi_fused.arnoldi_step_ref(a, vb, j)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# solver parity: kernel-backed schemes vs reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("gs", ["cgs2_fused", "fused"])
def test_gmres_kernel_schemes_match_reference(gs):
    a, b = _system()
    res_ref = gmres(a, b, m=20, tol=1e-5)
    res = gmres(a, b, m=20, tol=1e-5, gs=gs)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-5
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_ref.x),
                               rtol=1e-4, atol=1e-5)


def test_gmres_fused_scheme_under_jit():
    a, b = _system(n=128, seed=3)
    res = jax.jit(lambda a, b: gmres(a, b, m=16, tol=1e-5, gs="fused"))(a, b)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-5


def test_fused_scheme_degrades_with_function_operator():
    """gs="fused" needs a dense A; matrix-free falls back to cgs2_fused."""
    a, b = _system(n=96, seed=5)
    op = operators.FunctionOperator(lambda v, mat: mat @ v, a.shape[0],
                                    captures=(a,))
    res = gmres(op, b, m=20, tol=1e-5, gs="fused")
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-5


# --------------------------------------------------------------------------
# DenseOperator pallas backend
# --------------------------------------------------------------------------
def test_dense_operator_pallas_matvec_parity():
    a, b = _system(n=200, seed=7)  # padding path
    op = operators.DenseOperator(a, backend="pallas")
    np.testing.assert_allclose(np.asarray(op(b)), np.asarray(a @ b),
                               rtol=3e-5, atol=3e-5)
    x = jax.random.normal(jax.random.PRNGKey(9), (200, 6))
    np.testing.assert_allclose(np.asarray(op(x)), np.asarray(a @ x),
                               rtol=3e-5, atol=3e-5)


def test_gmres_with_pallas_operator_matches_reference():
    a, b = _system()
    res_ref = gmres(a, b, m=20, tol=1e-5)
    res = gmres(operators.DenseOperator(a, backend="pallas"), b, m=20,
                tol=1e-5)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(res_ref.x),
                               rtol=1e-4, atol=1e-5)


def test_dense_operator_backend_survives_jit_roundtrip():
    a, _ = _system(n=64)
    op = operators.DenseOperator(a, backend="pallas")
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert op2.backend == "pallas"


# --------------------------------------------------------------------------
# block multi-RHS gmres_batched
# --------------------------------------------------------------------------
def test_gmres_batched_matches_per_lane_solves():
    a, _ = _system()
    bs = jax.random.normal(jax.random.PRNGKey(11), (4, a.shape[0]))
    res = gmres_batched(a, bs, m=20, tol=1e-5)
    assert bool(res.converged.all())
    for i in range(4):
        single = gmres(a, bs[i], m=20, tol=1e-5)
        np.testing.assert_allclose(np.asarray(res.x[i]),
                                   np.asarray(single.x),
                                   rtol=1e-4, atol=1e-5)
        assert int(res.restarts[i]) == int(single.restarts)
        assert int(res.inner_steps[i]) == int(single.inner_steps)


def test_gmres_batched_mixed_convergence_lanes():
    """Lanes converging at different speeds must not corrupt each other."""
    n = 96
    a = jnp.diag(jnp.arange(1.0, n + 1))
    easy = jnp.zeros((n,)).at[3].set(1.0)       # eigvec: 1-step convergence
    hard = jax.random.normal(jax.random.PRNGKey(13), (n,))
    bs = jnp.stack([easy, hard])
    res = gmres_batched(a, bs, m=30, tol=1e-6, max_restarts=100)
    assert bool(res.converged.all())
    assert int(res.inner_steps[0]) <= 2
    assert int(res.inner_steps[1]) > int(res.inner_steps[0])
    for i in range(2):
        assert relres(a, res.x[i], bs[i]) < 1e-5


def test_gmres_batched_zero_rhs_lane():
    a, _ = _system(n=64)
    bs = jnp.zeros((2, 64)).at[1].set(
        jax.random.normal(jax.random.PRNGKey(15), (64,)))
    res = gmres_batched(a, bs, m=20, tol=1e-5)
    assert bool(res.converged.all())
    np.testing.assert_allclose(np.asarray(res.x[0]), 0.0, atol=1e-7)


# --------------------------------------------------------------------------
# compute_dtype knob
# --------------------------------------------------------------------------
def test_compute_dtype_bf16_basis_converges():
    a, b = _system(n=128, seed=17)
    res = gmres(a, b, m=20, tol=1e-4, compute_dtype=jnp.bfloat16,
                max_restarts=100)
    assert bool(res.converged)
    # true residual is recomputed in f32 per restart, so the reported
    # convergence is trustworthy despite bf16 basis storage
    assert relres(a, res.x, b) < 5e-4


def test_compute_dtype_bf16_streams_a_on_fused_path():
    """compute_dtype=bf16 + gs="fused" downcasts the A STREAM too: the
    solve must still converge to the f32 solution within bf16 tolerance,
    with at most a few extra restarts."""
    a, b = _system(n=128, seed=19)
    ref = gmres(a, b, m=20, tol=1e-4, gs="fused", max_restarts=100)
    res = gmres(a, b, m=20, tol=1e-4, gs="fused",
                compute_dtype=jnp.bfloat16, max_restarts=100)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-4
    assert int(res.restarts) <= int(ref.restarts) + 5
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=3e-2, atol=3e-3)


# --------------------------------------------------------------------------
# tuning
# --------------------------------------------------------------------------
def test_choose_matvec_blocks_respects_budget():
    for (m, n, k) in [(256, 256, 1), (8192, 8192, 1), (4096, 4096, 16)]:
        bm, bn = tuning.choose_matvec_blocks(m, n, "float32", k=k)
        s = 4
        assert 2 * bm * bn * s + bn * k * s + bm * k * 4 <= tuning.VMEM_BUDGET
        assert bn % tuning.LANE == 0 or bn >= n


def test_fused_step_fits_scales_with_n():
    assert tuning.fused_step_fits(31, 1024, jnp.float32)
    assert tuning.fused_step_fits(97, 96, jnp.float32)
    # a basis too large for VMEM must be rejected
    assert not tuning.fused_step_fits(513, 262144, jnp.float32)


def test_kernel_mode_on_cpu_is_interpret(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    if jax.default_backend() == "cpu":
        assert tuning.kernel_mode() == "interpret"
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    assert tuning.kernel_mode() == "ref"
