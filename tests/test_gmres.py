"""Core GMRES correctness: vs dense solve, vs NumPy oracle, all schemes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, operators, preconditioners
from repro.core.strategies import serial_numpy


def _system(n=160, seed=0, kind="diagdom"):
    key = jax.random.PRNGKey(seed)
    if kind == "diagdom":
        a = operators.random_diagdom(key, n)
    elif kind == "convdiff":
        a = operators.convection_diffusion(n, beta=0.4)
    else:
        a = operators.poisson_1d(n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    return a, b


def relres(a, x, b):
    return float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))


@pytest.mark.parametrize("gs", ["cgs", "mgs", "cgs2"])
@pytest.mark.parametrize("kind", ["diagdom", "convdiff", "poisson"])
def test_converges_all_schemes(gs, kind):
    # restarted GMRES stagnates on the (ill-conditioned SPD) Poisson matrix
    # — a known property, not a bug — so that case runs full-memory m=n
    # with an fp32-realistic tolerance.
    n = 96 if kind == "poisson" else 160
    m, tol = (96, 1e-4) if kind == "poisson" else (30, 1e-5)
    a, b = _system(n=n, kind=kind)
    res = jax.jit(lambda a, b: gmres(a, b, m=m, tol=tol, gs=gs,
                                     max_restarts=200))(a, b)
    assert bool(res.converged), (gs, kind, float(res.residual))
    assert relres(a, res.x, b) < 5 * tol


def test_matches_dense_solve():
    a, b = _system()
    res = gmres(a, b, m=40, tol=1e-6, max_restarts=100)
    x_dense = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_dense),
                               rtol=2e-3, atol=2e-4)


def test_matches_numpy_oracle():
    a, b = _system(n=120)
    res = gmres(a, b, m=20, tol=1e-5)
    x_np, beta, _, conv, _ = serial_numpy(np.asarray(a), np.asarray(b),
                                          m=20, tol=1e-5)
    assert conv
    np.testing.assert_allclose(np.asarray(res.x), x_np, rtol=5e-3, atol=5e-4)


def test_restart_counting_and_early_stop():
    # convection-diffusion with strong convection needs >5 Krylov dims
    a = operators.convection_diffusion(200, beta=0.9)
    b = jax.random.normal(jax.random.PRNGKey(1), (200,))
    res = gmres(a, b, m=5, tol=1e-5, max_restarts=200)
    assert bool(res.converged)
    assert int(res.restarts) > 1
    # already-converged x0 does nothing
    res2 = gmres(a, b, x0=res.x, m=5, tol=1e-5)
    assert int(res2.restarts) == 0
    assert int(res2.inner_steps) == 0


def test_early_convergence_masks_basis():
    """m far larger than needed: masked steps must not corrupt x."""
    a, b = _system(n=64)
    res = gmres(a, b, m=60, tol=1e-5)
    assert bool(res.converged)
    assert int(res.inner_steps) < 60
    assert relres(a, res.x, b) < 5e-5


def test_matrix_free_operator():
    a, b = _system()
    op = operators.FunctionOperator(lambda v, mat: mat @ v, a.shape[0],
                                    captures=(a,))
    res = gmres(op, b, m=30, tol=1e-5)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-5


def test_batched_rhs():
    a, _ = _system()
    bs = jax.random.normal(jax.random.PRNGKey(7), (5, a.shape[0]))
    res = gmres_batched(a, bs, m=30, tol=1e-5)
    assert bool(res.converged.all())
    for i in range(5):
        assert relres(a, res.x[i], bs[i]) < 5e-5


@pytest.mark.parametrize("precond", ["jacobi", "neumann", "block_jacobi"])
def test_preconditioners_cut_iterations(precond):
    a, b = _system(n=128, kind="diagdom")
    base = gmres(a, b, m=20, tol=1e-5, max_restarts=100)
    pc = preconditioners.PRECONDITIONERS[precond](a, block=32, order=2)
    res = gmres(a, b, m=20, tol=1e-5, max_restarts=100, precond=pc)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 1e-4
    assert int(res.inner_steps) <= int(base.inner_steps)


def test_singular_direction_breakdown_is_safe():
    """Happy breakdown: b in a low-dim invariant subspace."""
    n = 64
    a = jnp.diag(jnp.arange(1.0, n + 1))
    b = jnp.zeros((n,)).at[3].set(1.0)   # eigvec -> 1-step convergence
    res = gmres(a, b, m=10, tol=1e-6)
    assert bool(res.converged)
    assert int(res.inner_steps) <= 2
    assert relres(a, res.x, b) < 1e-5


def test_jvp_operator_gauss_newton():
    """GMRES on a J^T J system via the matrix-free jvp operator."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (24,))

    def f(p):
        return jnp.tanh(p) * 2.0 - w

    op = operators.jvp_operator(f, w * 0.1, damping=0.1)
    g = jax.grad(lambda p: 0.5 * jnp.sum(f(p) ** 2))(w * 0.1)
    res = gmres(op, -g, m=24, tol=1e-5, max_restarts=10)
    assert bool(res.converged)
