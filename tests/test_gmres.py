"""Core GMRES correctness: vs dense solve, vs NumPy oracle, all schemes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, operators, preconditioners
from repro.core.strategies import serial_numpy


def _system(n=160, seed=0, kind="diagdom"):
    key = jax.random.PRNGKey(seed)
    if kind == "diagdom":
        a = operators.random_diagdom(key, n)
    elif kind == "convdiff":
        a = operators.convection_diffusion(n, beta=0.4)
    else:
        a = operators.poisson_1d(n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    return a, b


def relres(a, x, b):
    return float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))


@pytest.mark.parametrize("gs", ["cgs", "mgs", "cgs2"])
@pytest.mark.parametrize("kind", ["diagdom", "convdiff", "poisson"])
def test_converges_all_schemes(gs, kind):
    # restarted GMRES stagnates on the (ill-conditioned SPD) Poisson matrix
    # — a known property, not a bug — so that case runs full-memory m=n
    # with an fp32-realistic tolerance.
    n = 96 if kind == "poisson" else 160
    m, tol = (96, 1e-4) if kind == "poisson" else (30, 1e-5)
    a, b = _system(n=n, kind=kind)
    res = jax.jit(lambda a, b: gmres(a, b, m=m, tol=tol, gs=gs,
                                     max_restarts=200))(a, b)
    assert bool(res.converged), (gs, kind, float(res.residual))
    assert relres(a, res.x, b) < 5 * tol


def test_matches_dense_solve():
    a, b = _system()
    res = gmres(a, b, m=40, tol=1e-6, max_restarts=100)
    x_dense = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_dense),
                               rtol=2e-3, atol=2e-4)


def test_matches_numpy_oracle():
    a, b = _system(n=120)
    res = gmres(a, b, m=20, tol=1e-5)
    x_np, beta, _, conv, _ = serial_numpy(np.asarray(a), np.asarray(b),
                                          m=20, tol=1e-5)
    assert conv
    np.testing.assert_allclose(np.asarray(res.x), x_np, rtol=5e-3, atol=5e-4)


def test_restart_counting_and_early_stop():
    # convection-diffusion with strong convection needs >5 Krylov dims
    a = operators.convection_diffusion(200, beta=0.9)
    b = jax.random.normal(jax.random.PRNGKey(1), (200,))
    res = gmres(a, b, m=5, tol=1e-5, max_restarts=200)
    assert bool(res.converged)
    assert int(res.restarts) > 1
    # already-converged x0 does nothing
    res2 = gmres(a, b, x0=res.x, m=5, tol=1e-5)
    assert int(res2.restarts) == 0
    assert int(res2.inner_steps) == 0


def test_early_convergence_masks_basis():
    """m far larger than needed: masked steps must not corrupt x."""
    a, b = _system(n=64)
    res = gmres(a, b, m=60, tol=1e-5)
    assert bool(res.converged)
    assert int(res.inner_steps) < 60
    assert relres(a, res.x, b) < 5e-5


def test_matrix_free_operator():
    a, b = _system()
    op = operators.FunctionOperator(lambda v, mat: mat @ v, a.shape[0],
                                    captures=(a,))
    res = gmres(op, b, m=30, tol=1e-5)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 5e-5


def test_batched_rhs():
    a, _ = _system()
    bs = jax.random.normal(jax.random.PRNGKey(7), (5, a.shape[0]))
    res = gmres_batched(a, bs, m=30, tol=1e-5)
    assert bool(res.converged.all())
    for i in range(5):
        assert relres(a, res.x[i], bs[i]) < 5e-5


def test_batched_mixed_tolerance_parity():
    """Per-lane tol/budget arrays: every lane must stop on ITS OWN
    contract — same restarts and solution as a standalone gmres with that
    tol — and a loose lane must burn fewer cycles than a tight one."""
    # Convection-diffusion needs tens of restarts at m=10, so the four
    # tolerances land on genuinely different restart counts (~20/33/49/24).
    a, _ = _system(kind="convdiff")
    n = a.shape[0]
    bs = jax.random.normal(jax.random.PRNGKey(11), (4, n))
    tols = jnp.array([1e-2, 1e-4, 1e-6, 1e-3])
    budgets = jnp.array([80, 80, 80, 80])
    res = gmres_batched(a, bs, m=10, tol=tols, max_restarts=budgets)
    assert bool(res.converged.all()) and bool(res.done.all())
    for i in range(4):
        tol = float(tols[i])
        ref = gmres(a, bs[i], m=10, tol=tol, max_restarts=80)
        # +-1: block and scalar cycles round differently at fp32, the
        # same residual-parity contract the pipelined scheme tests use.
        assert abs(int(res.restarts[i]) - int(ref.restarts)) <= 1, i
        # The solver's own residual meets the lane tol exactly; the
        # independent recomputation here gets fp32 matmul slack.
        bnorm = float(jnp.linalg.norm(bs[i]))
        assert float(res.residual[i]) <= tol * bnorm * (1 + 1e-6)
        assert relres(a, res.x[i], bs[i]) <= 2 * tol
        np.testing.assert_allclose(np.asarray(res.x[i]), np.asarray(ref.x),
                                   rtol=5e-2, atol=5e-3)
    # The mixed block really is heterogeneous: loose < tight lane cost.
    assert int(res.restarts[0]) < int(res.restarts[2])
    assert int(res.inner_steps[0]) < int(res.inner_steps[2])


def test_batched_per_lane_budget_failed_lane_flagged():
    """A lane out of budget reports done=True / converged=False (the
    FAILED retirement signal) without disturbing its cohort."""
    a, _ = _system()
    bs = jax.random.normal(jax.random.PRNGKey(12), (3, a.shape[0]))
    res = gmres_batched(a, bs, m=4, tol=jnp.array([1e-5, 1e-14, 1e-5]),
                        max_restarts=jnp.array([50, 2, 50]))
    assert bool(res.done.all())
    assert bool(res.converged[0]) and bool(res.converged[2])
    assert not bool(res.converged[1]) and int(res.restarts[1]) == 2
    for i in (0, 2):
        assert relres(a, res.x[i], bs[i]) < 5e-5


@pytest.mark.parametrize("precond", ["jacobi", "neumann", "block_jacobi"])
def test_preconditioners_cut_iterations(precond):
    a, b = _system(n=128, kind="diagdom")
    base = gmres(a, b, m=20, tol=1e-5, max_restarts=100)
    pc = preconditioners.PRECONDITIONERS[precond](a, block=32, order=2)
    res = gmres(a, b, m=20, tol=1e-5, max_restarts=100, precond=pc)
    assert bool(res.converged)
    assert relres(a, res.x, b) < 1e-4
    assert int(res.inner_steps) <= int(base.inner_steps)


def test_singular_direction_breakdown_is_safe():
    """Happy breakdown: b in a low-dim invariant subspace."""
    n = 64
    a = jnp.diag(jnp.arange(1.0, n + 1))
    b = jnp.zeros((n,)).at[3].set(1.0)   # eigvec -> 1-step convergence
    res = gmres(a, b, m=10, tol=1e-6)
    assert bool(res.converged)
    assert int(res.inner_steps) <= 2
    assert relres(a, res.x, b) < 1e-5


def test_jvp_operator_gauss_newton():
    """GMRES on a J^T J system via the matrix-free jvp operator."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (24,))

    def f(p):
        return jnp.tanh(p) * 2.0 - w

    op = operators.jvp_operator(f, w * 0.1, damping=0.1)
    g = jax.grad(lambda p: 0.5 * jnp.sum(f(p) ** 2))(w * 0.1)
    res = gmres(op, -g, m=24, tol=1e-5, max_restarts=10)
    assert bool(res.converged)
