"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import cgs2 as cgs2_k
from repro.kernels import matvec as matvec_k
from repro.kernels import attention as attn_k
from repro.kernels import ref, ops

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# matvec
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,bm,bn", [
    (256, 256, 128, 128),
    (512, 384, 256, 128),
    (100, 300, 64, 128),      # non-divisible -> padding path
    (64, 64, 128, 128),       # block > dim
    (1024, 128, 256, 128),
])
def test_matvec_sweep(m, n, bm, bn, dtype):
    a = jax.random.normal(KEY, (m, n), jnp.float32).astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32
                          ).astype(dtype)
    got = matvec_k.matvec(a, x, block_m=bm, block_n=bn, interpret=True)
    want = ref.matvec(a.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), want, **_tol(dtype))


@pytest.mark.parametrize("m,n,k,bm,bn", [
    (256, 256, 4, 128, 128),
    (100, 300, 7, 64, 128),      # non-divisible -> padding path
    (512, 384, 16, 256, 128),
])
def test_block_matvec_sweep(m, n, k, bm, bn):
    a = jax.random.normal(KEY, (m, n))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, k))
    got = matvec_k.block_matvec(a, x, block_m=bm, block_n=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ x),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# fused Gram-Schmidt
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m1,n,bn,j", [
    (8, 512, 256, 3),
    (33, 1024, 512, 31),
    (16, 700, 256, 0),        # padding path
    (4, 256, 512, 3),
])
def test_gs_fused_sweep(m1, n, bn, j, dtype):
    v = (jax.random.normal(KEY, (m1, n)) / np.sqrt(n)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(2), (n,)).astype(dtype)
    mask = (jnp.arange(m1) <= j).astype(jnp.float32)
    h_k, w_k = cgs2_k.gs_project(v, w, mask, block_n=bn, interpret=True)
    h_r, w_r = ref.gs_project(v.astype(jnp.float32), w.astype(jnp.float32),
                              mask)
    np.testing.assert_allclose(np.asarray(h_k, np.float32), h_r, **_tol(dtype))
    np.testing.assert_allclose(np.asarray(w_k, np.float32), w_r, **_tol(dtype))


def test_cgs2_fused_orthogonalizes():
    m1, n = 12, 2048
    q, _ = jnp.linalg.qr(jax.random.normal(KEY, (n, m1)))
    v = q.T                       # orthonormal basis rows
    w = jax.random.normal(jax.random.PRNGKey(3), (n,))
    mask = jnp.ones((m1,), jnp.float32)
    h, w2 = cgs2_k.cgs2(v, w, mask, block_n=512, interpret=True)
    # after CGS2, w2 is orthogonal to every basis row to ~machine precision
    dots = np.asarray(v @ w2)
    np.testing.assert_allclose(dots, np.zeros(m1), atol=5e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,skv,window,causal", [
    (2, 4, 2, 256, 256, None, True),     # GQA prefill
    (1, 8, 8, 128, 128, None, True),     # MHA
    (1, 8, 2, 128, 384, None, True),     # decode-ish chunk
    (2, 4, 4, 256, 256, 64, True),       # sliding window
    (1, 4, 2, 1, 300, None, True),       # single-token decode, ragged skv
    (1, 4, 4, 128, 128, None, False),    # encoder (bidirectional)
    (1, 2, 2, 320, 320, 96, True),       # window + padding path
])
def test_attention_sweep(b, hq, hkv, sq, skv, window, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, 64)).astype(dtype)
    got = attn_k.attention(q, k, v, causal=causal, window=window,
                           interpret=True)
    want = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=causal, window=window)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_ops_dispatch_modes():
    a = jax.random.normal(KEY, (64, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))
    y_ref = ops.matvec(a, x)
    with ops.use_kernels("interpret"):
        assert ops.get_mode() == "interpret"
        y_k = ops.matvec(a, x)
    assert ops.get_mode() == "ref"
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# SSD chunk scan (Mamba2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("batch,heads,s,p,n,q", [
    (2, 3, 64, 16, 8, 16),
    (1, 2, 96, 32, 16, 32),
    (1, 1, 48, 8, 8, 48),      # single chunk
])
def test_ssd_scan_sweep(batch, heads, s, p, n, q):
    from repro.kernels import ssd_scan, ssd_scan_ref
    ks = jax.random.split(KEY, 5)
    bh = batch * heads
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    lg = -jnp.abs(jax.random.normal(ks[2], (bh, s))) * 0.1
    b = jax.random.normal(ks[3], (batch, s, n))
    c = jax.random.normal(ks[4], (batch, s, n))
    got = ssd_scan(x, dt, lg, b, c, heads=heads, chunk=q, interpret=True)
    want = ssd_scan_ref(x, dt, lg, b, c, heads=heads, chunk=q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_matches_model_oracle():
    """Kernel semantics == the models/ssm.py production scan."""
    from repro.kernels import ssd_scan
    from repro.models import ssm
    batch, heads, s, p, n, q = 2, 2, 32, 8, 8, 16
    ks = jax.random.split(KEY, 5)
    bh = batch * heads
    x = jax.random.normal(ks[0], (bh, s, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bh, s)))
    b = jax.random.normal(ks[3], (batch, s, n))
    c = jax.random.normal(ks[4], (batch, s, n))
    xh = x.reshape(batch, heads, s, p).transpose(0, 2, 1, 3)
    dth = dt.reshape(batch, heads, s).transpose(0, 2, 1)
    want, _ = ssm._ssd_chunk_scan(
        xh, dth, jnp.zeros(heads), b, c,
        jnp.zeros((batch, heads, n, p), jnp.float32), q)
    want = want.transpose(0, 2, 1, 3).reshape(bh, s, p)
    # a_log = 0 -> lg = dt * (-exp(0)) = -dt
    got = ssd_scan(x, dt, -dt, b, c, heads=heads, chunk=q, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("shape,dtype", [
    ((4, 64, 256), jnp.float32),
    ((100, 512), jnp.float32),
    ((2, 33, 384), jnp.bfloat16),
])
def test_gated_rmsnorm_sweep(shape, dtype):
    from repro.kernels import gated_rmsnorm, gated_rmsnorm_ref
    ks = jax.random.split(KEY, 3)
    y = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    z = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    w = jax.random.normal(ks[2], (shape[-1],), jnp.float32).astype(dtype)
    got = gated_rmsnorm(y, z, w, interpret=True)
    want = gated_rmsnorm_ref(y, z, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("arch", ["zamba2_7b", "tinyllama_1_1b"])
def test_model_through_pallas_kernels_end_to_end(arch):
    """Whole-model forward with the Pallas kernels swapped in (interpret)

    equals the jnp reference path: zamba2 exercises the SSD + gated-norm
    kernels, tinyllama the flash-attention kernel — the deployability
    proof that `ops.set_mode("pallas")` is a one-line switch on TPU.
    """
    from repro import configs
    from repro.models import build
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 2,
                              cfg.vocab_size)
    want = model.prefill(params, {"tokens": toks})
    with ops.use_kernels("interpret"):
        got = model.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_gmres_with_pallas_kernels_end_to_end():
    """The solver converges with the fused kernels swapped in (interpret)."""
    from repro.core import gmres
    from repro.core.operators import random_diagdom
    from repro.kernels.matvec import matvec as kernel_mv

    n = 256
    a = random_diagdom(KEY, n)
    b = jax.random.normal(jax.random.PRNGKey(5), (n,))
    mv = lambda v: kernel_mv(a, v, block_m=128, block_n=128, interpret=True)
    res = gmres(mv, b, m=20, tol=1e-5)
    assert bool(res.converged)
    err = float(jnp.linalg.norm(a @ res.x - b) / jnp.linalg.norm(b))
    assert err < 5e-5
