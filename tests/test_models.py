"""Per-arch smoke tests (REDUCED configs): one train step + decode on CPU,

output shapes + finiteness, and prefill/decode cache consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build, param_count
from repro.models.transformer import D_VISION

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    ks = jax.random.split(KEY, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 2, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (b, s), 2, cfg.vocab_size),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (b, cfg.encoder_seq,
                                                    cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(ks[2], (b, cfg.num_patches,
                                                     D_VISION))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced config: loss + grads finite, params update."""
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.loss, has_aux=True))(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = configs.get(arch).reduced()
    model = build(cfg)
    params = model.init(KEY)
    b = 2
    cache = model.init_cache(b, 16)
    tok = jnp.array([3, 5], jnp.int32)
    logits, cache2 = jax.jit(model.decode)(params, cache, tok, jnp.int32(0))
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b_.astype(jnp.float32))))
               for a, b_ in zip(jax.tree.leaves(cache),
                                jax.tree.leaves(cache2)))
    assert diff > 0, arch


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mixtral_8x22b",
                                  "zamba2_7b", "xlstm_125m",
                                  "whisper_small", "pixtral_12b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced step-by-step decode == full forward at last position.

    This is the strongest cache-path test: every family's cache semantics
    (full KV, ring KV, SSM state, mLSTM/sLSTM state, cross-attn) must
    reproduce the parallel forward exactly.

    MoE archs run with ample expert capacity: GShard capacity DROPS are
    grouping-dependent by design (prefill groups a whole sequence, decode
    groups one token), so equality only holds when nothing is dropped.
    """
    import dataclasses
    cfg = configs.get(arch).reduced()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    batch = _batch(cfg, b=b, s=s)
    want = model.prefill(params, batch)            # (b, V) logits at s-1

    cache = model.init_cache(b, s)
    if cfg.family == "encdec":
        from repro.models import encdec
        cross = encdec.precompute_cross(params, cfg, batch["frames"])
        cache = {"self": cache["self"], "cross": cross}
    decode = jax.jit(model.decode)
    if cfg.family == "vlm":
        # patch positions occupy the cache first: feed patches via prefill
        # path is exercised separately; skip token-level replay for vlm.
        logits, _ = decode(params, cache, batch["tokens"][:, 0], jnp.int32(0))
        assert bool(jnp.isfinite(logits).all())
        return
    got = None
    for i in range(s):
        got, cache = decode(params, cache, batch["tokens"][:, i],
                            jnp.int32(i))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_ring_cache():
    """Ring cache (slots = window) must equal full attention w/ window."""
    import dataclasses
    cfg = dataclasses.replace(configs.get("mixtral_8x22b").reduced(),
                              capacity_factor=8.0)
    assert cfg.window == 32
    model = build(cfg)
    params = model.init(KEY)
    b, s = 1, 48                       # s > window -> ring wraps
    batch = _batch(cfg, b=b, s=s)
    want = model.prefill(params, batch)
    cache = model.init_cache(b, s)     # slots = min(s, window) = 32
    k_slots = jax.tree.leaves(cache)[0].shape
    got = None
    decode = jax.jit(model.decode)
    for i in range(s):
        got, cache = decode(params, cache, batch["tokens"][:, i],
                            jnp.int32(i))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_kv_quant_decode_close_to_fp():
    """int8 KV cache replay stays within ~2% of the fp prefill logits."""
    import dataclasses
    cfg0 = configs.get("tinyllama_1_1b").reduced()
    cfgq = dataclasses.replace(cfg0, kv_quant=True)
    m0, mq = build(cfg0), build(cfgq)
    params = m0.init(KEY)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 2,
                              cfg0.vocab_size)
    want = m0.prefill(params, {"tokens": toks})
    cache = mq.init_cache(b, s)
    assert jax.tree.leaves(cache)[0].dtype == jnp.int8
    dec = jax.jit(mq.decode)
    got = None
    for i in range(s):
        got, cache = dec(params, cache, toks[:, i], jnp.int32(i))
    rel = float(jnp.max(jnp.abs(got - want))) / \
        float(jnp.max(jnp.abs(want)))
    assert rel < 0.05, rel


def test_param_counts_match_published():
    expected = {
        "tinyllama_1_1b": 1.10e9,
        "granite_3_8b": 8.4e9,
        "qwen2_7b": 7.6e9,
        "mixtral_8x22b": 141e9,
        "llama4_maverick_400b_a17b": 398e9,
        "pixtral_12b": 12.2e9,
        "whisper_small": 0.24e9,
        "xlstm_125m": 0.11e9,
    }
    for arch, want in expected.items():
        got = param_count(configs.get(arch))
        assert abs(got - want) / want < 0.08, (arch, got, want)


def test_moe_capacity_and_router():
    """MoE invariants: combine weights sum to <=1, capacity drops work."""
    from repro.models import moe as moe_mod
    cfg = configs.get("mixtral_8x22b").reduced()
    p = moe_mod.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe_mod.apply(p, x, cfg, compute_dtype=jnp.float32)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # capacity 0.01 -> nearly everything dropped -> much smaller output
    import dataclasses
    tight = dataclasses.replace(cfg, capacity_factor=1e-6)
    y2 = moe_mod.apply(p, x, tight, compute_dtype=jnp.float32)
    assert float(jnp.abs(y2).sum()) < float(jnp.abs(y).sum())


def test_moe_matches_dense_expert_computation():
    """With ample capacity, the gather/scatter path == explicit per-token
    expert evaluation."""
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(configs.get("mixtral_8x22b").reduced(),
                              capacity_factor=8.0)
    p = moe_mod.init(KEY, cfg)
    b, s, d = 1, 8, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, d))
    got = moe_mod.apply(p, x, cfg, compute_dtype=jnp.float32)

    # oracle: loop tokens, run top-k experts densely
    logits = x.astype(jnp.float32) @ p["router"]
    w, sel = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(w, axis=-1)
    want = np.zeros((b, s, d), np.float32)
    for bi in range(b):
        for si in range(s):
            for ki in range(cfg.top_k):
                e = int(sel[bi, si, ki])
                xe = x[bi, si].astype(jnp.float32)
                g = xe @ p["w_gate"][e]
                u = xe @ p["w_up"][e]
                y = (jax.nn.silu(g) * u) @ p["w_down"][e]
                want[bi, si] += float(w[bi, si, ki]) * np.asarray(y)
    if cfg.num_shared_experts:
        from repro.models import layers as L
        want += np.asarray(L.mlp_apply(p["shared"], x, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
