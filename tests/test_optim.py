"""Optimizer substrate: AdamW, schedules, compression, Newton-Krylov."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, newton_krylov, schedules
from repro.optim import compression as comp


def test_adamw_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw(0.1, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(grads, state, params)

    for _ in range(200):
        params, state, m = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(state.step) == 200


def test_adamw_bf16_moments_close_to_fp32():
    key = jax.random.PRNGKey(0)
    w0 = jax.random.normal(key, (64,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}

    outs = {}
    for mdt in ("float32", "bfloat16"):
        params = {"w": w0}
        opt = adamw(1e-2, moment_dtype=mdt)
        state = opt.init(params)
        for _ in range(10):
            params, state, _ = opt.update(grads, state, params)
        outs[mdt] = np.asarray(params["w"])
        assert state.m["w"].dtype == jnp.dtype(mdt)
    np.testing.assert_allclose(outs["bfloat16"], outs["float32"],
                               rtol=2e-2, atol=2e-3)


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    opt = adamw(1e-3, grad_clip=1.0)
    state = opt.init(params)
    big = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = opt.update(big, state, params)
    assert float(metrics["grad_norm"]) > 1e5   # pre-clip norm reported


def test_schedules():
    cos = schedules.cosine_warmup(1.0, warmup_steps=10, total_steps=100)
    assert float(cos(jnp.asarray(0))) == 0.0
    assert abs(float(cos(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(cos(jnp.asarray(100))) < 1e-6
    inv = schedules.inverse_sqrt(1.0, warmup_steps=100)
    assert abs(float(inv(jnp.asarray(400))) - 0.5) < 1e-6


@pytest.mark.parametrize("shape", [(100,), (33, 7), (1024,)])
def test_quantize_roundtrip(shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
    q = comp.quantize(x)
    y = comp.dequantize(q)
    assert q.q.dtype == jnp.int8
    err = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
    assert err < 1e-2, err


def test_error_feedback_reduces_bias():
    x = jax.random.normal(jax.random.PRNGKey(0), (512,))
    ef = comp.ef_init(x)
    total = jnp.zeros_like(x)
    for _ in range(20):
        q, ef = comp.ef_compress(x, ef)
        total = total + comp.dequantize(q)
    # mean of compressed stream -> x (error feedback kills the bias)
    err = float(jnp.linalg.norm(total / 20 - x) / jnp.linalg.norm(x))
    assert err < 2e-3


def test_newton_krylov_quadratic_one_step():
    """On a quadratic, NK with exact-enough GMRES converges in ~1 step."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (12, 12))
    a = q @ q.T + 5.0 * jnp.eye(12)
    target = jax.random.normal(jax.random.PRNGKey(1), (12,))

    def loss_fn(params, batch):
        del batch
        d = params["w"] - target
        return 0.5 * d @ a @ d

    init, update = newton_krylov(loss_fn, m=12, tol=1e-6, damping=1e-3)
    params = {"w": jnp.zeros(12)}
    state = init(params)
    params, state, metrics = update(params, state, None)
    final = float(loss_fn(params, None))
    assert final < 1e-4 * float(metrics["loss"])


def test_newton_krylov_trains_tiny_model():
    from repro import configs
    from repro.models import build
    cfg = configs.get("tinyllama-1.1b").reduced(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=64, loss_chunk=16)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 2, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 2, 64),
        "mask": jnp.ones((2, 16), jnp.float32),
    }

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    init, update = newton_krylov(loss_fn, m=6, tol=1e-2, damping=10.0)
    state = init(params)
    upd = jax.jit(update)
    losses = []
    for _ in range(4):
        params, state, metrics = upd(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
