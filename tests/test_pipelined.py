"""Pipelined single-reduce GMRES: one fused psum per Arnoldi step.

Four contracts:

  1. parity: ``gs="cgs2_pipelined"`` matches the split-phase/fused CGS2
     solvers on dense / banded / ELL operators, locally and under
     ``gmres_sharded`` (including 4 REAL fake devices in a subprocess);
  2. stability: the delayed-reorthogonalization basis stays as orthogonal
     as CGS2 promises (bounded by MGS loss, not merely finite), and the
     scheme is scale-invariant at c in {1e-6, 1e6} (PR 3 contract);
  3. dispatch: the payload kernel engages under the standard policy, and
     a forced VMEM-overflow verdict degrades to the psum-safe jnp
     reference with the same answer;
  4. the s-step single-reduce block pass (one stacked psum per GS pass)
     matches the split-phase s-step solver and rejects unknown schemes.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import (arnoldi, gmres, gmres_sharded, gmres_sstep,
                        gmres_sstep_sharded, operators, stencils)
from repro.kernels import tuning

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SHARDS = [p for p in (1, 2, 4) if p <= jax.device_count()]


def _mesh(p):
    return make_mesh((p,), ("rows",))


def _system(fmt, nx, key):
    n = nx * nx
    if fmt == "dense":
        a = operators.random_diagdom(jax.random.PRNGKey(key), n)
        op = operators.DenseOperator(a, backend="pallas")
    elif fmt == "banded":
        op = stencils.poisson_2d(nx, nx, backend="pallas")
    elif fmt == "ell":
        op = stencils.poisson_2d(nx, nx, backend="pallas").to_ell()
    else:
        raise ValueError(fmt)
    b = jax.random.normal(jax.random.PRNGKey(key + 1), (n,))
    return op, b


def _rel_err(x, ref):
    return (float(jnp.linalg.norm(x - ref))
            / max(float(jnp.linalg.norm(ref)), 1e-30))


# --------------------------------------------------------------------------
# 1. parity vs the established CGS2 solvers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ["dense", "banded", "ell"])
def test_pipelined_matches_cgs2_fused(fmt):
    op, b = _system(fmt, 8, key=0)
    ref = gmres(op, b, m=16, tol=1e-5, max_restarts=100, gs="cgs2_fused")
    pipe = gmres(op, b, m=16, tol=1e-5, max_restarts=100,
                 gs="cgs2_pipelined")
    assert bool(pipe.converged)
    a_dense = op.a if fmt == "dense" else op.todense()
    rel = (float(jnp.linalg.norm(a_dense @ pipe.x - b))
           / float(jnp.linalg.norm(b)))
    assert rel < 5e-5, rel
    assert _rel_err(pipe.x, ref.x) < 2e-3
    # residual parity: the schemes may stop +-1 restart apart, no worse
    assert abs(int(pipe.restarts) - int(ref.restarts)) <= 1


@pytest.mark.parametrize("p", SHARDS)
def test_pipelined_sharded_matches_single(p):
    op, b = _system("banded", 8, key=2)
    ref = gmres(op, b, m=16, tol=1e-5, max_restarts=100, gs="cgs2")
    pipe = gmres_sharded(_mesh(p), "rows", op, b, m=16, tol=1e-5,
                         max_restarts=100, gs="cgs2_pipelined")
    assert bool(pipe.converged)
    assert _rel_err(pipe.x, ref.x) < 2e-3


def test_pipelined_batched_degrades_to_cgs2():
    """gmres_batched has no whole-cycle pipelining; the scheme fallback
    must quietly run cgs2 rather than crash."""
    from repro.core.gmres import gmres_batched

    n = 64
    a = operators.random_diagdom(jax.random.PRNGKey(0), n)
    bb = jax.random.normal(jax.random.PRNGKey(1), (3, n))
    res = gmres_batched(a, bb, m=12, tol=1e-5, max_restarts=50,
                        gs="cgs2_pipelined")
    assert bool(res.converged.all())


# --------------------------------------------------------------------------
# 2. stability: orthogonality loss + scale invariance
# --------------------------------------------------------------------------
def _pipelined_basis(a, b, m):
    """Drive the single-reduce recurrence directly; return the basis."""
    n = b.shape[0]
    v = jnp.zeros((m + 1, n))
    v = v.at[0].set(b / jnp.linalg.norm(b))
    gram = jnp.eye(m + 1)
    hraw = jnp.zeros((m + 1, m))
    z = a @ v[0]
    for j in range(m):
        payload = arnoldi.sr_payload_ref(v, z, j)
        h_tot, s_norm, _, gram = arnoldi.sr_recover(payload, gram, j)
        u = a @ z
        w2 = z - h_tot @ v
        v = v.at[j + 1].set(w2 / s_norm)
        lt = (jnp.arange(m) < j).astype(z.dtype)
        c_vec = hraw @ (h_tot[:m] * lt)
        z = (u - c_vec @ v - h_tot[j] * z) / s_norm
        hraw = hraw.at[:, j].set(h_tot.at[j + 1].set(s_norm))
    return v


def _mgs_basis(a, b, m):
    n = b.shape[0]
    v = jnp.zeros((m + 1, n))
    v = v.at[0].set(b / jnp.linalg.norm(b))
    for j in range(m):
        w = a @ v[j]
        for i in range(j + 1):
            w = w - jnp.vdot(v[i], w) * v[i]
        v = v.at[j + 1].set(w / jnp.linalg.norm(w))
    return v


def test_pipelined_orthogonality_loss_bounded_vs_mgs():
    """CGS2-class orthogonality: ||I - V V^T|| stays within a small factor
    of the MGS loss (MGS loses O(eps * kappa); CGS2 O(eps))."""
    n, m = 96, 20
    a = operators.random_diagdom(jax.random.PRNGKey(5), n, dominance=1.5)
    b = jax.random.normal(jax.random.PRNGKey(6), (n,))
    vp = _pipelined_basis(a, b, m)
    vm = _mgs_basis(a, b, m)
    eye = jnp.eye(m + 1)
    loss_pipe = float(jnp.linalg.norm(eye - vp @ vp.T))
    loss_mgs = float(jnp.linalg.norm(eye - vm @ vm.T))
    eps = float(jnp.finfo(jnp.float32).eps)
    assert loss_pipe <= max(10.0 * loss_mgs, 100 * eps * (m + 1)), \
        (loss_pipe, loss_mgs)


@pytest.mark.parametrize("c", [1e-6, 1e6])
def test_pipelined_scale_invariant(c):
    """The scale-relative guards must survive extreme system scales."""
    n = 100
    a = operators.random_diagdom(jax.random.PRNGKey(7), n)
    b = jax.random.normal(jax.random.PRNGKey(8), (n,))
    ref = gmres(a, b, m=16, tol=1e-5, max_restarts=100, gs="cgs2_pipelined")
    scaled = gmres(a * c, b * c, m=16, tol=1e-5, max_restarts=100,
                   gs="cgs2_pipelined")
    assert bool(jnp.isfinite(scaled.x).all()), f"non-finite x at c={c}"
    assert bool(scaled.converged)
    assert _rel_err(scaled.x, ref.x) < 1e-3
    assert int(scaled.restarts) == int(ref.restarts)


# --------------------------------------------------------------------------
# 3. dispatch: kernel engages; forced overflow degrades safely
# --------------------------------------------------------------------------
def _spy(monkeypatch, mod, name, calls):
    orig = getattr(mod, name)

    def wrapper(*args, **kw):
        calls[name] = calls.get(name, 0) + 1
        return orig(*args, **kw)

    monkeypatch.setattr(mod, name, wrapper)


def test_pipelined_dispatch_hits_payload_kernel(monkeypatch):
    import repro.kernels.cgs2 as cgs2_mod

    calls = {}
    _spy(monkeypatch, cgs2_mod, "gs_project_norm_partial", calls)
    _spy(monkeypatch, cgs2_mod, "gs_update", calls)
    op, b = _system("dense", 8, key=10)
    res = gmres(op, b, m=12, tol=1e-5, max_restarts=100,
                gs="cgs2_pipelined")
    assert bool(res.converged)
    assert calls.get("gs_project_norm_partial", 0) > 0, \
        "fused payload kernel never engaged"
    assert calls.get("gs_update", 0) > 0, "update kernel never engaged"


def test_pipelined_forced_overflow_falls_back(monkeypatch):
    """gs_payload_fits forced False: the jnp reference must carry the solve
    with the same answer, and the payload kernel must never run."""
    op, b = _system("dense", 8, key=12)
    res_kernel = gmres(op, b, m=12, tol=1e-5, max_restarts=100,
                       gs="cgs2_pipelined")

    import repro.kernels.cgs2 as cgs2_mod

    def boom(*a, **k):
        raise AssertionError("payload kernel ran despite forced overflow")

    monkeypatch.setattr(tuning, "gs_payload_fits", lambda *a, **k: False)
    monkeypatch.setattr(cgs2_mod, "gs_project_norm_partial", boom)
    res_ref = gmres(op, b, m=12, tol=1e-5, max_restarts=100,
                    gs="cgs2_pipelined")
    assert bool(res_ref.converged)
    np.testing.assert_allclose(np.asarray(res_ref.x),
                               np.asarray(res_kernel.x),
                               rtol=1e-4, atol=1e-5)


def test_step_rejects_pipelined_scheme():
    """arnoldi.step is a per-step API; the whole-cycle scheme must raise."""
    with pytest.raises(ValueError, match="cgs2_pipelined"):
        arnoldi.step("cgs2_pipelined")


# --------------------------------------------------------------------------
# 4. s-step single-reduce block pass
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", ["dense", "banded"])
def test_sstep_single_reduce_matches_split(fmt):
    op, b = _system(fmt, 8, key=14)
    ref = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60,
                      gs="cgs2")
    sr = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60,
                     gs="cgs2_pipelined")
    assert bool(sr.converged)
    assert _rel_err(sr.x, ref.x) < 2e-3
    assert abs(int(sr.restarts) - int(ref.restarts)) <= 1


@pytest.mark.parametrize("p", SHARDS)
def test_sstep_single_reduce_sharded(p):
    op, b = _system("banded", 10, key=16)
    ref = gmres_sstep(op, b, s=4, blocks=5, tol=1e-5, max_restarts=60)
    sr = gmres_sstep_sharded(_mesh(p), "rows", op, b, s=4, blocks=5,
                             tol=1e-5, max_restarts=60, gs="cgs2_pipelined")
    assert bool(sr.converged)
    assert _rel_err(sr.x, ref.x) < 2e-3


def test_sstep_single_reduce_dispatch(monkeypatch):
    import repro.kernels.block_gs as bg_mod

    calls = {}
    _spy(monkeypatch, bg_mod, "block_gs_pass_single_reduce", calls)
    op, b = _system("banded", 8, key=18)
    res = gmres_sstep(op, b, s=2, blocks=4, tol=1e-5, max_restarts=40,
                      gs="cgs2_pipelined")
    assert bool(res.converged)
    assert calls.get("block_gs_pass_single_reduce", 0) > 0, \
        "single-reduce block pass never engaged"


def test_sstep_rejects_unknown_gs():
    op, b = _system("banded", 8, key=19)
    with pytest.raises(ValueError, match="unknown gs"):
        gmres_sstep(op, b, s=2, blocks=2, gs="mgs")


# --------------------------------------------------------------------------
# multi-shard for real: 4 fake host devices in a subprocess
# --------------------------------------------------------------------------
def test_pipelined_parity_4dev_subprocess():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import (gmres, gmres_sharded, gmres_sstep,
                                gmres_sstep_sharded, operators, stencils)
        mesh = make_mesh((4,), ('rows',))
        out = {}
        b = jax.random.normal(jax.random.PRNGKey(1), (144,))
        banded = stencils.poisson_2d(12, 12, backend='pallas')
        cases = {
            'dense': operators.DenseOperator(
                operators.random_diagdom(jax.random.PRNGKey(0), 144),
                backend='pallas'),
            'banded': banded,
            'ell': banded.to_ell(),
        }
        for fmt, op in cases.items():
            ref = gmres(op, b, m=16, tol=1e-5, max_restarts=150)
            sh = gmres_sharded(mesh, 'rows', op, b, m=16, tol=1e-5,
                               max_restarts=150, gs='cgs2_pipelined')
            out[fmt] = {
                'conv': bool(sh.converged),
                'restarts_ref': int(ref.restarts),
                'restarts_pipe': int(sh.restarts),
                'err': float(jnp.linalg.norm(sh.x - ref.x)
                             / jnp.linalg.norm(ref.x)),
            }
        ref = gmres_sstep(banded, b, s=4, blocks=5, tol=1e-5,
                          max_restarts=60)
        sh = gmres_sstep_sharded(mesh, 'rows', banded, b, s=4, blocks=5,
                                 tol=1e-5, max_restarts=60,
                                 gs='cgs2_pipelined')
        out['sstep_banded'] = {
            'conv': bool(sh.converged),
            'restarts_ref': int(ref.restarts),
            'restarts_pipe': int(sh.restarts),
            'err': float(jnp.linalg.norm(sh.x - ref.x)
                         / jnp.linalg.norm(ref.x)),
        }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for fmt, r in out.items():
        assert r["conv"], (fmt, r)
        assert r["err"] < 2e-3, (fmt, r)
