"""Preconditioning subsystem (core/preconditioners.py + kernels/trisolve.py).

  1. kernel correctness: ILU(0) factor vs dense oracle, trisweep kernel
     vs scan ref, fused Chebyshev kernel vs the plain recurrence, the
     shifted (Newton-basis) matrix-powers variant, the ELL powers kernel;
  2. the spectral-interval estimator UPPER-bounds the spectrum (an
     underestimated lam_max flips A.M^-1 indefinite — the one direction
     Chebyshev cannot tolerate);
  3. parity: preconditioned solves reach the same solution as
     unpreconditioned within tol with STRICTLY fewer restarts on the 2-D
     Poisson and convection-diffusion stencils, for gmres / gmres_sstep /
     gmres_batched and the pipelined gs;
  4. scale invariance at c in {1e-6, 1e6} (the PR 3 contract);
  5. every public solver honors precond= or raises a clear ValueError;
  6. dispatch spies: the fused Chebyshev / trisweep / ELL-powers kernels
     actually engage when they fit, and a forced VMEM-overflow verdict
     degrades to the identical-result reference;
  7. serve admission: a precond/operator mismatch is refused at
     construction with the FIELD NAMED, never inside a lane;
  8. hypothesis property: random SPD stencil x precond x fmt converges
     and matches the dense oracle.

The 4-fake-device sharded composition (halo-exchange Chebyshev, shard-
local banded block-Jacobi, one-psum-per-step pipelined HLO) runs in a
subprocess, same pattern as tests/test_distributed.py.
"""
import inspect
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import preconditioners as P
from repro.core import operators, stencils
from repro.core.gmres import gmres, gmres_batched, gmres_batched_cycle
from repro.core.sstep import gmres_sstep
from repro.kernels import matrix_powers, trisolve, tuning

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _dense_of(op) -> np.ndarray:
    return np.asarray(op.todense())


def _rel_err(x, y):
    return float(np.linalg.norm(np.asarray(x) - np.asarray(y))
                 / max(np.linalg.norm(np.asarray(y)), 1e-30))


def _sym_banded(key, n, *, halo=1, dtype=jnp.float32):
    """Random symmetric diagonally-dominant banded operator (SPD)."""
    offs = tuple(range(-halo, halo + 1))
    vals = jax.random.uniform(key, (halo, n), minval=0.1, maxval=1.0)
    rows = []
    for off in offs:
        if off == 0:
            rows.append(jnp.zeros((n,)))
        elif off > 0:
            rows.append(-vals[off - 1])                   # A[i, i+off]
        else:
            rows.append(-jnp.roll(vals[-off - 1], -off))  # A[i-1,i] mirrored
    bands = jnp.stack(rows)
    bands = trisolve._mask_oob(bands, offs)
    diag = jnp.sum(jnp.abs(bands), axis=0) + 0.5
    bands = bands.at[offs.index(0)].set(diag)
    return operators.BandedOperator(bands.astype(dtype), offs)


# --------------------------------------------------------------------------
# 1. kernel correctness
# --------------------------------------------------------------------------
def test_ilu0_tridiagonal_is_exact():
    """On a tridiagonal pattern ILU(0) IS the LU factorization."""
    op = _sym_banded(jax.random.PRNGKey(0), 48, halo=1)
    pc = P.banded_ilu0(op)
    v = jax.random.normal(jax.random.PRNGKey(1), (48,))
    exact = np.linalg.solve(_dense_of(op), np.asarray(v))
    np.testing.assert_allclose(np.asarray(pc(v)), exact, rtol=2e-4,
                               atol=2e-4)


def test_ilu0_pentadiagonal_residual_small():
    """ILU(0) on the 2-D Poisson pattern: ||L U - A|| confined to fill-in."""
    op = stencils.poisson_2d(6)
    pc = P.banded_ilu0(op)
    n = pc.n
    lu = np.eye(n, dtype=np.float64)

    def dense(bands, offsets, unit):
        a = np.zeros((n, n))
        for d, off in enumerate(offsets):
            for i in range(n):
                j = i + off
                if 0 <= j < n:
                    a[i, j] = float(bands[d, i])
        if unit:
            np.fill_diagonal(a, 1.0)
        return a

    l = dense(np.asarray(pc.l_bands), pc.l_offsets, unit=True)
    u = dense(np.asarray(pc.u_bands), pc.u_offsets, unit=False)
    resid = l @ u - _dense_of(op)
    # Zero on the stencil pattern itself; the dropped fill-in is bounded.
    for d, off in enumerate(op.offsets):
        on_pattern = np.diagonal(resid, offset=int(off))
        np.testing.assert_allclose(on_pattern, 0.0, atol=5e-5)
    assert np.abs(resid).max() < 0.5


@pytest.mark.parametrize("lower,unit", [(True, True), (True, False),
                                        (False, False)])
def test_trisweep_kernel_matches_ref(lower, unit):
    key = jax.random.PRNGKey(7)
    n = 200
    offs = (-2, -1, 0) if lower else (0, 1, 2)
    bands = jax.random.uniform(key, (3, n), minval=0.2, maxval=1.0)
    bands = bands.at[offs.index(0)].add(2.0)
    bands = trisolve._mask_oob(bands, offs)
    v = jax.random.normal(jax.random.PRNGKey(8), (n,))
    ref = trisolve.banded_trisweep_ref(bands, v, offs, unit_diag=unit,
                                       lower=lower)
    ker = trisolve.banded_trisweep_kernel(bands, v, offs, unit_diag=unit,
                                          lower=lower, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fused_cheb_kernel_matches_recurrence():
    op = stencils.poisson_2d(8)
    pc = P.chebyshev(op, order=5)
    v = jax.random.normal(jax.random.PRNGKey(3), (pc.n,))
    ref = pc._apply_ref(v, op)
    ker = matrix_powers.banded_cheb_apply(op.bands, v, op.offsets,
                                          theta=pc.theta, delta=pc.delta,
                                          rhos=pc.rhos, interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_shifted_banded_powers_matches_ref():
    op = stencils.poisson_2d(8)
    n, s = 64, 4
    x = jax.random.normal(jax.random.PRNGKey(4), (n,))
    shifts = jnp.asarray([0.9, 4.1, 2.2, 6.6], jnp.float32)
    u_k, sg_k = matrix_powers.banded_powers(op.bands, x, op.offsets, s,
                                            shifts=shifts, interpret=True)
    u_r, sg_r = matrix_powers.matrix_powers_ref(op, x, s, eps=1e-30,
                                                shifts=shifts)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(sg_k), np.asarray(sg_r),
                               rtol=3e-4)


def test_ell_powers_matches_ref():
    op = stencils.poisson_2d(8, fmt="ell")
    n, s = 64, 4
    x = jax.random.normal(jax.random.PRNGKey(5), (n,))
    u_k, sg_k = matrix_powers.ell_powers(op.values, op.cols, x, s,
                                         interpret=True)
    u_r, sg_r = matrix_powers.matrix_powers_ref(op, x, s, eps=1e-30)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(sg_k), np.asarray(sg_r),
                               rtol=3e-4)


# --------------------------------------------------------------------------
# 2. the spectral interval must bound the spectrum from ABOVE
# --------------------------------------------------------------------------
@pytest.mark.parametrize("make", [lambda: stencils.poisson_2d(8),
                                  lambda: stencils.convection_diffusion_2d(8)])
def test_estimate_interval_upper_bounds_spectrum(make):
    op = make()
    lam_min, lam_max = P.estimate_interval(op)
    eigs = np.linalg.eigvals(_dense_of(op).astype(np.float64))
    assert lam_max >= float(eigs.real.max()) - 1e-4, (
        "lam_max below the true spectrum: Chebyshev would go indefinite")
    assert 0.0 < lam_min < lam_max


# --------------------------------------------------------------------------
# 3. parity: same solution, strictly fewer restarts
# --------------------------------------------------------------------------
STENCILS = {"poisson": lambda: stencils.poisson_2d(8),
            "convdiff": lambda: stencils.convection_diffusion_2d(8)}
PC = {"chebyshev": lambda op: P.chebyshev(op, order=4),
      "banded_ilu0": P.banded_ilu0,
      "line_jacobi": P.line_jacobi}


@pytest.mark.parametrize("stencil", sorted(STENCILS))
@pytest.mark.parametrize("pcname", sorted(PC))
def test_gmres_parity_fewer_restarts(stencil, pcname):
    op = STENCILS[stencil]()
    n = op.shape[0]
    b = jnp.sin(jnp.arange(n) * 0.37)
    plain = gmres(op, b, m=16, tol=1e-5, max_restarts=100)
    pc = PC[pcname](op)
    res = gmres(op, b, m=16, tol=1e-5, max_restarts=100, precond=pc)
    assert bool(plain.converged) and bool(res.converged)
    assert _rel_err(res.x, plain.x) < 1e-3
    assert int(res.restarts) < int(plain.restarts), (
        f"{pcname} on {stencil}: {int(res.restarts)} vs "
        f"{int(plain.restarts)} restarts")


@pytest.mark.parametrize("stencil", sorted(STENCILS))
@pytest.mark.parametrize("pcname", ["chebyshev", "banded_ilu0"])
def test_sstep_parity_fewer_restarts(stencil, pcname):
    op = STENCILS[stencil]()
    n = op.shape[0]
    b = jnp.sin(jnp.arange(n) * 0.37)
    plain = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    pc = PC[pcname](op)
    res = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60,
                      precond=pc)
    assert bool(plain.converged) and bool(res.converged)
    assert _rel_err(res.x, plain.x) < 1e-3
    assert int(res.restarts) < int(plain.restarts)


def test_sstep_newton_basis_matches_monomial():
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    mono = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    newt = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60,
                       basis="newton")
    assert bool(newt.converged)
    assert _rel_err(newt.x, mono.x) < 1e-3


def test_pipelined_gs_composes_with_precond():
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    pc = P.chebyshev(op, order=4)
    split = gmres(op, b, m=16, tol=1e-5, max_restarts=60, precond=pc)
    piped = gmres(op, b, m=16, tol=1e-5, max_restarts=60, precond=pc,
                  gs="cgs2_pipelined")
    assert bool(piped.converged)
    assert _rel_err(piped.x, split.x) < 1e-3
    assert int(piped.restarts) == int(split.restarts)


def test_self_healing_composes_with_precond():
    from repro.core.recovery import gmres_self_healing
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    plain, _ = gmres_self_healing(op, b, m=16, tol=1e-5, max_restarts=60)
    res, report = gmres_self_healing(op, b, m=16, tol=1e-5, max_restarts=60,
                                     precond=P.chebyshev(op, order=4))
    assert bool(res.converged)
    assert int(res.restarts) < int(plain.restarts)


def test_batched_precond_fewer_restarts():
    op = stencils.poisson_2d(8)
    bs = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
    plain = gmres_batched(op, bs, m=16, tol=1e-4, max_restarts=80)
    pc = P.chebyshev(op, order=4)
    res = gmres_batched(op, bs, m=16, tol=1e-4, max_restarts=80, precond=pc)
    assert bool(res.converged.all())
    assert _rel_err(res.x, plain.x) < 1e-2
    assert int(np.max(np.asarray(res.restarts))) < int(
        np.max(np.asarray(plain.restarts)))


# --------------------------------------------------------------------------
# 4. scale invariance (PR 3 contract): c*A x = c*b has the SAME trajectory
# --------------------------------------------------------------------------
@pytest.mark.parametrize("c", [1e-6, 1e6])
@pytest.mark.parametrize("pcname", ["chebyshev", "banded_ilu0"])
def test_precond_scale_invariant(c, pcname):
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    sop = operators.BandedOperator(op.bands * c, op.offsets)
    ref = gmres(op, b, m=16, tol=1e-5, max_restarts=60,
                precond=PC[pcname](op))
    res = gmres(sop, b * c, m=16, tol=1e-5, max_restarts=60,
                precond=PC[pcname](sop))
    assert bool(jnp.isfinite(res.x).all()), f"non-finite x at c={c}"
    assert bool(res.converged)
    assert _rel_err(res.x, ref.x) < 1e-3
    assert int(res.restarts) == int(ref.restarts)


# --------------------------------------------------------------------------
# 5. every public solver honors precond= or raises a clear ValueError
# --------------------------------------------------------------------------
def test_every_public_solver_takes_precond():
    from repro.core.distributed import gmres_sharded, gmres_sstep_sharded
    from repro.core.recovery import gmres_self_healing
    for fn in (gmres, gmres_batched, gmres_batched_cycle, gmres_sstep,
               gmres_sharded, gmres_sstep_sharded, gmres_self_healing):
        assert "precond" in inspect.signature(fn).parameters, fn.__name__


@pytest.mark.parametrize("call", [
    lambda op, b, pc: gmres(op, b, m=8, precond=pc),
    lambda op, b, pc: gmres_sstep(op, b, s=2, blocks=4, precond=pc),
    lambda op, b, pc: gmres_batched(op, b[None, :], m=8, precond=pc),
])
def test_non_callable_precond_raises(call):
    op = stencils.poisson_2d(4)
    b = jnp.ones((16,))
    with pytest.raises(ValueError, match="precond must be callable"):
        call(op, b, "chebyshev")


def test_sharded_rejects_unknown_and_unshardable():
    from repro.compat import make_mesh
    from repro.core.distributed import gmres_sharded
    mesh = make_mesh((1,), ("model",))
    op = stencils.poisson_2d(4)
    b = jnp.ones((16,))
    with pytest.raises(ValueError, match="precond"):
        gmres_sharded(mesh, "model", op, b, m=8, precond="nonsense")
    with pytest.raises(ValueError, match="not shard-aware"):
        gmres_sharded(mesh, "model", op, b, m=8,
                      precond=P.banded_ilu0(op))


def test_sstep_unknown_basis_raises():
    op = stencils.poisson_2d(4)
    with pytest.raises(ValueError, match="basis"):
        gmres_sstep(op, jnp.ones((16,)), s=2, blocks=2, basis="legendre")


# --------------------------------------------------------------------------
# 6. dispatch spies + forced VMEM overflow
# --------------------------------------------------------------------------
def test_cheb_kernel_engages_and_overflow_degrades(monkeypatch):
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    calls = []
    orig = matrix_powers.banded_cheb_apply

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(matrix_powers, "banded_cheb_apply", spy)
    pc = P.chebyshev(op, order=4)
    res_k = gmres(op, b, m=16, tol=1e-5, max_restarts=60, precond=pc)
    assert bool(res_k.converged)
    assert calls, "fused Chebyshev kernel never engaged"

    def boom(*a, **k):
        raise AssertionError("kernel path taken despite forced overflow")

    monkeypatch.setattr(matrix_powers, "banded_cheb_apply", boom)
    monkeypatch.setattr(tuning, "cheb_fits", lambda *a, **k: False)
    res_r = gmres(op, b, m=16, tol=1e-5, max_restarts=60,
                  precond=P.chebyshev(op, order=4))
    assert bool(res_r.converged)
    assert _rel_err(res_r.x, res_k.x) < 1e-4
    assert int(res_r.restarts) == int(res_k.restarts)


def test_trisweep_kernel_engages_and_overflow_degrades(monkeypatch):
    op = stencils.poisson_2d(8)
    b = jnp.sin(jnp.arange(64) * 0.37)
    calls = []
    orig = trisolve.banded_trisweep_kernel

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(trisolve, "banded_trisweep_kernel", spy)
    res_k = gmres(op, b, m=16, tol=1e-5, max_restarts=60,
                  precond=P.banded_ilu0(op))
    assert bool(res_k.converged)
    assert calls, "trisweep kernel never engaged"

    def boom(*a, **k):
        raise AssertionError("kernel path taken despite forced overflow")

    monkeypatch.setattr(trisolve, "banded_trisweep_kernel", boom)
    monkeypatch.setattr(tuning, "trisweep_fits", lambda *a, **k: False)
    res_r = gmres(op, b, m=16, tol=1e-5, max_restarts=60,
                  precond=P.banded_ilu0(op))
    assert bool(res_r.converged)
    assert _rel_err(res_r.x, res_k.x) < 1e-4
    assert int(res_r.restarts) == int(res_k.restarts)


def test_ell_powers_engages_and_overflow_degrades(monkeypatch):
    op = stencils.poisson_2d(8, fmt="ell")
    b = jnp.sin(jnp.arange(64) * 0.37)
    calls = []
    orig = matrix_powers.ell_powers

    def spy(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    monkeypatch.setattr(matrix_powers, "ell_powers", spy)
    res_k = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    assert bool(res_k.converged)
    assert calls, "ELL matrix-powers kernel never engaged"

    def boom(*a, **k):
        raise AssertionError("kernel path taken despite forced overflow")

    monkeypatch.setattr(matrix_powers, "ell_powers", boom)
    monkeypatch.setattr(tuning, "ell_powers_fits", lambda *a, **k: False)
    res_r = gmres_sstep(op, b, s=4, blocks=4, tol=1e-5, max_restarts=60)
    assert bool(res_r.converged)
    assert _rel_err(res_r.x, res_k.x) < 1e-3


# --------------------------------------------------------------------------
# 7. serve admission: mismatch refused with the field named
# --------------------------------------------------------------------------
def test_serve_rejects_precond_mismatch():
    from repro.serve.request import AdmissionError, validate_params
    from repro.serve.server import SolverServer
    op = stencils.poisson_2d(8)
    wrong_n = P.banded_ilu0(stencils.poisson_2d(4))
    with pytest.raises(AdmissionError, match=r"precond .* has n=16"):
        SolverServer(op, m=10, k=4, precond=wrong_n)
    dense_only = P.block_jacobi(jnp.eye(64) * 4.0, block=8)
    with pytest.raises(AdmissionError,
                       match="precond .* requires a dense operator"):
        SolverServer(op, m=10, k=4, precond=dense_only)
    with pytest.raises(AdmissionError, match="precond is not callable"):
        validate_params(1e-5, 10, precond=42, op=op)
    # The matching pairing sails through.
    validate_params(1e-5, 10, precond=P.banded_ilu0(op), op=op)


def test_serve_precond_cuts_restarts():
    from repro.serve.server import SolverServer
    op = stencils.poisson_2d(8)
    b = np.sin(np.arange(64) * 0.37).astype(np.float32)
    outs = {}
    for name, pc in (("none", None), ("cheb", P.chebyshev(op, order=4))):
        srv = SolverServer(op, m=10, k=4, precond=pc)
        rid = srv.submit(b, tol=1e-4, max_restarts=80)
        srv.run()
        outs[name] = srv.results[rid]
    assert outs["cheb"].status == "done"
    assert outs["cheb"].restarts < outs["none"].restarts
    r = np.linalg.norm(np.asarray(op(jnp.asarray(outs["cheb"].x))) - b)
    assert r / np.linalg.norm(b) < 1e-3


# --------------------------------------------------------------------------
# 8. hypothesis: random SPD stencil x precond x fmt -> dense-oracle match
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:                     # plain-pytest fallback: fixed grid
    _HYP = False

    def given(**kw):                    # noqa: D103 - deterministic sweep
        def deco(fn):
            cases = [(0, 16, 1, "jacobi", "banded"),
                     (1, 24, 2, "chebyshev", "dense"),
                     (2, 33, 1, "banded_ilu0", "banded"),
                     (3, 48, 2, "chebyshev", "ell")]

            @pytest.mark.parametrize("seed,n,halo,pcname,fmt", cases)
            def wrapped(seed, n, halo, pcname, fmt):
                return fn(seed=seed, n=n, halo=halo, pcname=pcname, fmt=fmt)
            return wrapped
        return deco

    class settings:                     # noqa: N801 - decorator stub
        def __init__(self, **kw): pass
        def __call__(self, fn): return fn


@given(**({"seed": st.integers(0, 10_000), "n": st.integers(16, 48),
           "halo": st.integers(1, 2),
           "pcname": st.sampled_from(["jacobi", "chebyshev",
                                      "banded_ilu0"]),
           "fmt": st.sampled_from(["banded", "dense", "ell"])}
          if _HYP else {}))
@settings(max_examples=25, deadline=None)
def test_random_stencil_precond_matches_dense_oracle(seed, n, halo, pcname,
                                                     fmt):
    bop = _sym_banded(jax.random.PRNGKey(seed), n, halo=halo)
    if pcname == "banded_ilu0":
        fmt = "banded"             # requires the band pattern
    if fmt == "banded":
        op = bop
    elif fmt == "ell":
        op = bop.to_ell()
    else:
        op = operators.DenseOperator(bop.todense())
    pc = (P.banded_ilu0(bop) if pcname == "banded_ilu0"
          else P.make_preconditioner(pcname, op))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    res = gmres(op, b, m=min(16, n - 2), tol=1e-5, max_restarts=80,
                precond=pc)
    oracle = np.linalg.solve(_dense_of(bop).astype(np.float64),
                             np.asarray(b, np.float64))
    assert bool(res.converged)
    assert _rel_err(res.x, oracle) < 1e-2


# --------------------------------------------------------------------------
# 9. sharded composition on 4 fake devices (subprocess)
# --------------------------------------------------------------------------
def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_precond_matches_oracle_and_one_psum_4dev():
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import gmres, gmres_sharded, stencils
        from repro.core.distributed import gmres_sstep_sharded
        from repro.roofline import innermost_loop_collectives
        mesh = make_mesh((4,), ('model',))
        op = stencils.poisson_2d(16)          # n=256, halo=16
        b = jnp.sin(jnp.arange(256) * 0.37)
        oracle = gmres(op, b, m=16, tol=1e-4, max_restarts=80)
        out = {"oracle_restarts": int(oracle.restarts)}
        for tag, pc in (("none", None), ("cheb", "chebyshev"),
                        ("bbj", "banded_block_jacobi")):
            jsol = jax.jit(lambda bb, pc=pc: gmres_sharded(
                mesh, 'model', op, bb, m=16, tol=1e-4, max_restarts=80,
                gs='cgs2_pipelined', precond=pc))
            hlo = jsol.lower(b).compile().as_text()
            _, ops = innermost_loop_collectives(hlo)
            r = jsol(b)
            out["restarts_" + tag] = int(r.restarts)
            out["conv_" + tag] = bool(r.converged)
            out["err_" + tag] = float(jnp.linalg.norm(r.x - oracle.x)
                                      / jnp.linalg.norm(oracle.x))
            out["psums_" + tag] = sum(o.count for o in ops
                                      if o.kind == "all-reduce")
        rs = gmres_sstep_sharded(mesh, 'model', op, b, s=4, blocks=4,
                                 tol=1e-4, max_restarts=60,
                                 precond='chebyshev')
        out["sstep_conv"] = bool(rs.converged)
        out["sstep_err"] = float(jnp.linalg.norm(rs.x - oracle.x)
                                 / jnp.linalg.norm(oracle.x))
        print(json.dumps(out))
    """)
    r = _run_subprocess(code)
    assert r["conv_none"] and r["conv_cheb"] and r["conv_bbj"]
    for tag in ("cheb", "bbj"):
        assert r["err_" + tag] < 1e-2
        assert r["restarts_" + tag] < r["restarts_none"]
        # Preconditioning must not add collectives to the inner loop:
        # Chebyshev rides the halo-exchange ppermutes, block-Jacobi is
        # shard-local — the pipelined one-psum-per-step schedule holds.
        assert r["psums_" + tag] <= r["psums_none"]
    assert r["psums_none"] >= 1
    assert r["sstep_conv"] and r["sstep_err"] < 1e-2
