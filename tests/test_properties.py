"""Hypothesis property tests on the solver's numerical invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import arnoldi, givens, stencils
from repro.core.gmres import gmres
from repro.core.operators import SparseOperator, random_diagdom

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(seed=st.integers(0, 10_000), n=st.integers(8, 64),
       m=st.integers(2, 8))
def test_arnoldi_basis_orthonormal(seed, n, m):
    """After j steps of CGS2 the basis rows are orthonormal."""
    key = jax.random.PRNGKey(seed)
    a = random_diagdom(key, n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    m = min(m, n - 1)
    v = jnp.zeros((m + 1, n)).at[0].set(b / jnp.linalg.norm(b))
    for j in range(m):
        stp = arnoldi.cgs2_step(v, a @ v[j], j)
        v = v.at[j + 1].set(stp.v_next)
    gram = np.asarray(v @ v.T)
    np.testing.assert_allclose(gram, np.eye(m + 1), atol=5e-4)


@given(seed=st.integers(0, 10_000), n=st.integers(8, 48))
def test_arnoldi_relation(seed, n):
    """A V_m^T = V_{m+1}^T H~_m (the defining Arnoldi identity)."""
    m = 5
    key = jax.random.PRNGKey(seed)
    a = random_diagdom(key, n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    v = jnp.zeros((m + 1, n)).at[0].set(b / jnp.linalg.norm(b))
    h = np.zeros((m + 1, m), np.float32)
    for j in range(m):
        stp = arnoldi.cgs2_step(v, a @ v[j], j)
        v = v.at[j + 1].set(stp.v_next)
        h[:, j] = np.asarray(stp.h)
    lhs = np.asarray(a @ v[:m].T)             # (n, m)
    rhs = np.asarray(v.T) @ h                 # (n, m)
    scale = max(1.0, float(np.abs(lhs).max()))
    np.testing.assert_allclose(lhs / scale, rhs / scale, atol=5e-4)


@given(seed=st.integers(0, 10_000), m=st.integers(1, 12))
def test_givens_matches_lstsq(seed, m):
    """Incremental Givens LS == numpy lstsq on a random Hessenberg system."""
    rng = np.random.default_rng(seed)
    h = np.triu(rng.normal(size=(m + 1, m)), -1).astype(np.float32)
    for j in range(m):   # diagonal boost keeps the system well-conditioned
        h[j, j] += 3.0 * np.sign(h[j, j]) if h[j, j] != 0 else 3.0
    beta = float(rng.normal()) + 5.0

    st_g = givens.init(m, jnp.asarray(beta))
    for j in range(m):
        col = jnp.zeros((m + 1,)).at[:j + 2].set(h[:j + 2, j])
        st_g = givens.update(st_g, col, j, active=jnp.asarray(True))
    y = np.asarray(givens.solve(st_g))

    e1 = np.zeros(m + 1, np.float32)
    e1[0] = beta
    y_ref, *_ = np.linalg.lstsq(h, e1, rcond=None)
    np.testing.assert_allclose(y, y_ref, rtol=2e-3, atol=2e-3)
    # residual estimate matches true LS residual
    resid_est = float(np.abs(np.asarray(st_g.g)[m]))
    resid_true = float(np.linalg.norm(h @ y_ref - e1))
    np.testing.assert_allclose(resid_est, resid_true, rtol=5e-2, atol=5e-3)


@given(seed=st.integers(0, 10_000))
def test_gmres_residual_reported_is_true(seed):
    """Reported residual == ||b - Ax|| recomputed (no estimate drift)."""
    key = jax.random.PRNGKey(seed)
    a = random_diagdom(key, 48)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (48,))
    res = gmres(a, b, m=10, tol=1e-4, max_restarts=50)
    true = float(jnp.linalg.norm(b - a @ res.x))
    np.testing.assert_allclose(float(res.residual), true,
                               rtol=1e-4, atol=1e-6)


@given(seed=st.integers(0, 10_000), n=st.integers(4, 40),
       width=st.integers(1, 5),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_sparse_matvec_matches_dense_materialization(seed, n, width, dtype):
    """SparseOperator matvec == its dense materialization @ v, any width/dtype."""
    key = jax.random.PRNGKey(seed)
    a = np.array(jax.random.normal(key, (n, n)))
    keep = np.asarray(jax.random.uniform(jax.random.PRNGKey(seed + 1),
                                         (n, n)))
    a[keep > width / n] = 0.0              # ~width nonzeros per row (ragged)
    a = a.astype(dtype)
    op = SparseOperator.from_dense(a)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (n,)
                          ).astype(dtype)
    got = np.asarray(op(v), np.float32)
    want = np.asarray(op.todense() @ v, np.float32)
    tol = 3e-2 if dtype == "bfloat16" else 3e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@given(seed=st.integers(0, 10_000), n=st.integers(8, 96),
       hubs=st.integers(1, 6), c=st.sampled_from([1, 8, 16, 64]),
       k=st.sampled_from([1, 3]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_sell_matvec_matches_dense_materialization(seed, n, hubs, c, k,
                                                   dtype):
    """SlicedEllOperator matvec == its dense materialization @ v across
    random power-law-ish patterns, slice heights, operand ranks and
    storage dtypes — sorted and identity layouts alike."""
    from repro.core.operators import SlicedEllOperator, with_dtype

    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float32)
    for i in range(n):                       # heavy rows for the first few
        w = n - 1 if i < hubs else int(rng.integers(1, max(2, n // 8)))
        cols = rng.choice(n, size=w, replace=False)
        a[i, cols] = rng.normal(size=w).astype(np.float32)
    p = rng.permutation(n)                   # hide the hubs: force a sort
    a = a[p][:, p]
    op = SlicedEllOperator.from_dense(a, slice_height=c)
    if dtype == "bfloat16":
        op = with_dtype(op, jnp.bfloat16)
    shape = (n,) if k == 1 else (n, k)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), shape)
    got = np.asarray(op(v), np.float32)
    want = np.asarray(op.todense(), np.float32) @ np.asarray(v)
    tol = 3e-2 if dtype == "bfloat16" else 3e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@given(seed=st.integers(0, 10_000), nx=st.integers(2, 8),
       ny=st.integers(2, 8),
       fmt=st.sampled_from(["banded", "ell", "sell"]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_stencil_operator_matches_dense_materialization(seed, nx, ny, fmt,
                                                        dtype):
    """Both sparse formats agree with the dense matrix they represent."""
    op = stencils.convection_diffusion_2d(nx, ny, beta=(0.4, 0.2),
                                          dtype=jnp.dtype(dtype), fmt=fmt)
    v = jax.random.normal(jax.random.PRNGKey(seed), (nx * ny,)
                          ).astype(dtype)
    got = np.asarray(op(v), np.float32)
    want = np.asarray(op.todense() @ v, np.float32)
    tol = 3e-2 if dtype == "bfloat16" else 3e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@given(seed=st.integers(0, 10_000), nx=st.integers(3, 10),
       ny=st.integers(3, 10), s=st.integers(1, 8))
def test_matrix_powers_matches_sequential_matvecs(seed, nx, ny, s):
    """The one-launch matrix-powers kernel == s sequential matvec+normalize
    steps, for any stencil shape and power count."""
    from repro.kernels import matrix_powers

    op = stencils.convection_diffusion_2d(nx, ny, beta=(0.4, 0.2))
    x = jax.random.normal(jax.random.PRNGKey(seed), (nx * ny,))
    x = x / jnp.linalg.norm(x)
    eps = float(jnp.finfo(jnp.float32).eps) * 100
    u_k, s_k = matrix_powers.banded_powers(op.bands, x, op.offsets, s,
                                           interpret=True)
    u = x
    for j in range(s):
        w = op(u)
        sigma = jnp.linalg.norm(w)
        u = w / jnp.maximum(sigma, eps)
        np.testing.assert_allclose(np.asarray(u_k[j]), np.asarray(u),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(s_k[j]), float(sigma),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), nx=st.sampled_from([8, 12]),
       fmt=st.sampled_from(["dense", "ell", "banded"]),
       p=st.sampled_from([1, 2, 4]))
def test_sharded_solve_matches_single_device(seed, nx, fmt, p):
    """Row-sharded solves == single-device solves, any format/shard count.

    Shard counts are capped at the devices the running process hosts (1
    in the plain tier-1 run — the shard_map wrapper, shard_context and
    collectives still execute; the CI distributed step re-runs this under
    XLA_FLAGS=--xla_force_host_platform_device_count=4, where hypothesis
    genuinely sweeps 1/2/4-way meshes).
    """
    from repro.compat import make_mesh
    from repro.core import gmres_sharded
    from repro.core.operators import DenseOperator

    p = min(p, jax.device_count())
    n = nx * nx
    if fmt == "dense":
        op = DenseOperator(random_diagdom(jax.random.PRNGKey(seed), n),
                           backend="pallas")
        a_dense = op.a
    else:
        op = stencils.poisson_2d(nx, nx, backend="pallas")
        a_dense = op.todense()
        if fmt == "ell":
            op = op.to_ell()
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    mesh = make_mesh((p,), ("rows",))
    res_s = gmres(op, b, m=16, tol=1e-5, max_restarts=150)
    res_d = gmres_sharded(mesh, "rows", op, b, m=16, tol=1e-5,
                          max_restarts=150)
    assert bool(res_d.converged)
    rel = float(jnp.linalg.norm(a_dense @ res_d.x - b)
                / jnp.linalg.norm(b))
    assert rel < 5e-5
    err = (float(jnp.linalg.norm(res_d.x - res_s.x))
           / max(float(jnp.linalg.norm(res_s.x)), 1e-30))
    assert err < 2e-3


@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
def test_gmres_scale_invariance(seed, scale):
    """x(c*A, c*b) == x(A, b): relative-tolerance solves are scale-free."""
    key = jax.random.PRNGKey(seed)
    a = random_diagdom(key, 32)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (32,))
    r1 = gmres(a, b, m=16, tol=1e-5)
    r2 = gmres(a * scale, b * scale, m=16, tol=1e-5)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=5e-3, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), nx=st.sampled_from([6, 8, 10]),
       fmt=st.sampled_from(["dense", "banded"]),
       m=st.sampled_from([8, 16]))
def test_pipelined_solve_matches_cgs2(seed, nx, fmt, m):
    """gs='cgs2_pipelined' (single-reduce, depth-1 pipelined) solves any
    system the split-phase CGS2 solver does, to the same solution, with
    restart counts within +-1 (the residual-parity contract)."""
    n = nx * nx
    if fmt == "dense":
        from repro.core.operators import DenseOperator
        op = DenseOperator(random_diagdom(jax.random.PRNGKey(seed), n),
                           backend="pallas")
    else:
        op = stencils.poisson_2d(nx, nx, backend="pallas")
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    ref = gmres(op, b, m=m, tol=1e-5, max_restarts=150, gs="cgs2")
    pipe = gmres(op, b, m=m, tol=1e-5, max_restarts=150,
                 gs="cgs2_pipelined")
    assert bool(pipe.converged) == bool(ref.converged)
    if bool(ref.converged):
        err = (float(jnp.linalg.norm(pipe.x - ref.x))
               / max(float(jnp.linalg.norm(ref.x)), 1e-30))
        assert err < 2e-3, err
        assert abs(int(pipe.restarts) - int(ref.restarts)) <= 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), k=st.sampled_from([2, 3, 4]),
       order=st.permutations(list(range(6))),
       tols=st.lists(st.sampled_from([1e-2, 1e-3, 1e-4, 1e-5]),
                     min_size=6, max_size=6),
       buckets=st.lists(st.sampled_from([32, 48]), min_size=6, max_size=6))
def test_serve_no_cross_lane_contamination(seed, k, order, tols, buckets):
    """Serving invariant: whatever the arrival order, lane count, and
    (n-bucket, tol) mix, every request's residual meets ITS OWN tol and
    its solution matches a standalone gmres of the same system — packing,
    early retirement and mid-solve refill never leak between lanes."""
    from repro.serve import HandleCache, SolverServer
    ops = {n: random_diagdom(jax.random.PRNGKey(n), n) for n in set(buckets)}
    cache = HandleCache()
    servers = {n: SolverServer(ops[n], m=8, k=k, handle_cache=cache)
               for n in set(buckets)}
    placed = []   # (server, rid, n, b, tol)
    for i in order:
        n, tol = buckets[i], tols[i]
        b = np.asarray(jax.random.normal(
            jax.random.PRNGKey(seed * 100 + i), (n,)))
        rid = servers[n].submit(b, tol=tol, max_restarts=60)
        placed.append((servers[n], rid, n, b, tol))
    for srv in servers.values():
        srv.run()
    for srv, rid, n, b, tol in placed:
        out = srv.results[rid]
        assert out.status == "done", (rid, out.status, out.residual)
        assert out.residual <= tol * np.linalg.norm(b) * (1 + 1e-6)
        ref = gmres(ops[n], jnp.asarray(b, jnp.float32), m=8, tol=tol,
                    max_restarts=60)
        err = (np.linalg.norm(out.x - np.asarray(ref.x))
               / max(np.linalg.norm(np.asarray(ref.x)), 1e-30))
        assert err < 5e-3, (rid, err)
