"""Self-healing solver stack: detection, ladder, injection, checkpoints.

Layered like the code under test: pure classifier/ladder/breaker units
first (no solves), then the deterministic fault injector, then
``gmres_self_healing`` end-to-end on tiny dense systems — including the
acceptance bar from the issue: a scripted fault at any ladder rung must
converge to the same answer as the fault-free solve within tolerance and
at most one extra restart, and a killed + resumed solve must be
bit-identical to an uninterrupted one.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gmres import (BREAKDOWN, HEALTHY, NAN_INF, STAGNATED,
                              classify_residuals, gmres)
from repro.core import operators
from repro.core.recovery import (CircuitBreaker, DEGRADATION_SCHEMES,
                                 build_ladder, gmres_self_healing)
from repro.kernels import tuning
from repro.runtime import faultinject
from repro.runtime.faultinject import InjectedFault


@pytest.fixture(autouse=True)
def _isolated_fault_schedule(monkeypatch):
    """Exact-counter tests must not see an ambient REPRO_FAULT (the CI
    injection leg replays OTHER suites under env schedules)."""
    monkeypatch.delenv("REPRO_FAULT", raising=False)
    faultinject.reset()
    yield
    faultinject.reset()


def _op(n=48, seed=0):
    return operators.DenseOperator(
        operators.random_diagdom(jax.random.PRNGKey(seed), n))


def _rhs(n, seed=1):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(n),
                       jnp.float32)


# =====================================================================
# classify_residuals: the cycle-level health check (pure, jit-safe)
# =====================================================================

def _ring(*vals, window=8):
    h = np.full(window, np.inf)
    h[-len(vals):] = vals
    return jnp.asarray(h)


def test_classify_healthy_decreasing():
    s = classify_residuals(_ring(10.0, 1.0, 0.1), converged=False)
    assert int(s) == HEALTHY


def test_classify_nan_inf():
    assert int(classify_residuals(_ring(1.0, np.nan),
                                  converged=False)) == NAN_INF
    assert int(classify_residuals(_ring(1.0, np.inf),
                                  converged=False)) == NAN_INF


def test_classify_breakdown_growth():
    s = classify_residuals(_ring(1.0, 20.0), converged=False)
    assert int(s) == BREAKDOWN


def test_classify_stagnated_full_window():
    vals = [1.0] * 8                       # flat across the whole ring
    s = classify_residuals(_ring(*vals), converged=False)
    assert int(s) == STAGNATED


def test_classify_partial_window_never_stagnates():
    """Inf left-padding means a young solve (fewer cycles than the
    window) can never be declared stagnated: oldest slot is inf."""
    s = classify_residuals(_ring(5.0, 4.9, 4.8), converged=False)
    assert int(s) == HEALTHY


def test_classify_converged_overrides_plateau():
    """A converged solve sitting at tol for the whole window is DONE,
    not stagnated — and never 'breaks down' from float noise."""
    vals = [1e-7] * 8
    assert int(classify_residuals(_ring(*vals), converged=True)) == HEALTHY


def test_classify_scale_invariant():
    """Thresholds are ratios: scaling the whole history by 1e6 (c·A, c·b)
    must classify identically."""
    for vals, expect in (( [10.0, 1.0, 0.1], HEALTHY),
                         ([1.0, 50.0], BREAKDOWN),
                         ([1.0] * 8, STAGNATED)):
        lo = classify_residuals(_ring(*vals), converged=False)
        hi = classify_residuals(_ring(*[v * 1e6 for v in vals]),
                                converged=False)
        assert int(lo) == int(hi) == expect


def test_classify_priority_nan_beats_breakdown():
    s = classify_residuals(_ring(1.0, np.nan), converged=False)
    assert int(s) == NAN_INF


def test_classify_is_jittable():
    f = jax.jit(lambda h: classify_residuals(h, converged=False))
    assert int(f(_ring(10.0, 1.0))) == HEALTHY


# =====================================================================
# GmresResult.diagnostics: the residual ring on the real solvers
# =====================================================================

def test_gmres_residual_history_chronological():
    op, b = _op(), _rhs(48)
    res = gmres(op, b, m=10, tol=1e-5, max_restarts=30, history=8)
    hist = np.asarray(res.diagnostics.residual_history)
    k = int(res.restarts)
    assert hist.shape == (8,)
    assert int(res.diagnostics.status) == HEALTHY and bool(res.converged)
    # inf padding on the left, then strictly the per-cycle residuals with
    # the FINAL residual in the last slot.
    filled = hist[np.isfinite(hist)]
    assert len(filled) == min(k + 1, 8)    # seed ||b - A x0|| + k cycles
    assert filled[-1] == pytest.approx(float(res.residual), rel=1e-6)
    assert (np.diff(filled) <= 0).all()    # diagdom: monotone decrease
    assert int(res.diagnostics.history_len) == min(k + 1, 8)


def test_gmres_history_window_is_bounded():
    op, b = _op(), _rhs(48)
    res = gmres(op, b, m=4, tol=1e-12, max_restarts=20, history=4)
    assert np.asarray(res.diagnostics.residual_history).shape == (4,)


def test_sstep_carries_diagnostics():
    from repro.core.sstep import gmres_sstep
    op, b = _op(), _rhs(48)
    res = gmres_sstep(op, b, s=2, blocks=5, tol=1e-5, max_restarts=30)
    assert res.diagnostics is not None
    assert int(res.diagnostics.status) == HEALTHY
    assert res.residual_history is not None


def test_nan_system_diagnosed_nan_inf():
    n = 16
    a = jnp.full((n, n), jnp.nan, jnp.float32)
    res = gmres(a, jnp.ones(n, jnp.float32), m=4, tol=1e-5, max_restarts=3)
    assert int(res.diagnostics.status) == NAN_INF
    assert not bool(res.converged)


# =====================================================================
# build_ladder + force_kernel_mode
# =====================================================================

def test_ladder_full_from_top():
    rungs = build_ladder("cgs2_pipelined", mode="compiled")
    assert rungs[0] == ("cgs2_pipelined", "compiled")
    assert rungs[-1] == ("mgs", "ref")
    # 4 schemes at each of 3 modes.
    assert len(rungs) == 12
    assert rungs[4] == ("cgs2_pipelined", "interpret")


def test_ladder_starts_at_callers_scheme():
    rungs = build_ladder("cgs2", mode="ref")
    assert rungs == (("cgs2", "ref"), ("mgs", "ref"))


def test_ladder_unknown_scheme_is_rung_zero():
    rungs = build_ladder("fused", mode="ref")
    assert rungs[0] == ("fused", "ref")
    assert rungs[1:] == tuple((s, "ref") for s in DEGRADATION_SCHEMES)


def test_ladder_rejects_unknown_mode():
    with pytest.raises(ValueError, match="kernel mode"):
        build_ladder("mgs", mode="gpu")


def test_force_kernel_mode_nests_and_restores():
    base = tuning.kernel_mode()
    with tuning.force_kernel_mode("ref"):
        assert tuning.kernel_mode() == "ref"
        with tuning.force_kernel_mode("interpret"):
            assert tuning.kernel_mode() == "interpret"
        assert tuning.kernel_mode() == "ref"
    assert tuning.kernel_mode() == base


def test_force_kernel_mode_rejects_unknown():
    with pytest.raises(ValueError):
        with tuning.force_kernel_mode("tpu"):
            pass


# =====================================================================
# Deterministic fault injector
# =====================================================================

def test_parse_schedule_forms():
    s = faultinject.parse_schedule("core.cycle:3,serve.cycle:*:2,"
                                   "core.cycle_nan:1:*")
    assert s["core.cycle"] == [[3, 1]]
    assert s["serve.cycle"] == [[None, 2]]
    assert s["core.cycle_nan"] == [[1, None]]


def test_parse_schedule_rejects_unknown_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        faultinject.parse_schedule("bogus.site:1")


def test_parse_schedule_rejects_malformed():
    with pytest.raises(ValueError, match="expected"):
        faultinject.parse_schedule("core.cycle")


def test_env_schedule_fires_and_consumes(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT", "core.cycle:2")
    faultinject.reset()
    assert not faultinject.fire("core.cycle", index=1)
    assert faultinject.fire("core.cycle", index=2)
    assert not faultinject.fire("core.cycle", index=2)   # consumed
    assert faultinject.fired["core.cycle"] == 1


def test_context_schedule_wins_over_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT", "core.cycle:5")
    faultinject.reset()
    with faultinject.inject("core.cycle", at=5) as entry:
        assert faultinject.fire("core.cycle", index=5)
        assert entry[1] == 0               # the SCOPED entry was consumed
    # The env entry is still live after the context exits.
    assert faultinject.armed("core.cycle")
    assert faultinject.fire("core.cycle", index=5)


def test_armed_is_non_consuming():
    with faultinject.inject("core.cycle", at=1):
        assert faultinject.armed("core.cycle")
        assert faultinject.armed("core.cycle", "serve.cycle")
        assert not faultinject.armed("serve.cycle")
        assert faultinject.fire("core.cycle", index=1)
        assert not faultinject.armed("core.cycle")       # exhausted


def test_check_raises_injected_fault():
    with faultinject.inject("serve.cycle", at=0):
        with pytest.raises(InjectedFault) as ei:
            faultinject.check("serve.cycle", index=0)
    assert ei.value.site == "serve.cycle" and ei.value.index == 0


def test_inject_unknown_site_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        with faultinject.inject("nope"):
            pass


def test_reset_rearms_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT", "core.cycle:0")
    faultinject.reset()
    assert faultinject.fire("core.cycle", index=0)
    assert not faultinject.fire("core.cycle", index=0)
    faultinject.reset()
    assert faultinject.fire("core.cycle", index=0)       # re-armed


# =====================================================================
# CircuitBreaker (tick-deterministic, no clock)
# =====================================================================

def test_breaker_opens_after_threshold():
    br = CircuitBreaker(threshold=2, cooldown=3, max_trips=2)
    assert br.allow(0)
    br.record_failure(0)
    assert br.state == "closed"
    br.record_failure(1)
    assert br.state == "open" and not br.allow(2)


def test_breaker_half_open_trial_then_close():
    br = CircuitBreaker(threshold=1, cooldown=2, max_trips=3)
    br.record_failure(0)                   # open until 2
    assert not br.allow(1)
    assert br.allow(2) and br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.trips == 0


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(threshold=3, cooldown=2, max_trips=1)
    br.record_failure(0), br.record_failure(1)
    br.record_success()
    br.record_failure(2), br.record_failure(3)
    assert br.state == "closed"            # never 3 consecutive


def test_breaker_dies_after_max_trips():
    br = CircuitBreaker(threshold=1, cooldown=1, max_trips=1)
    br.record_failure(0)                   # trip 1 -> open
    br.allow(1)                            # half-open
    br.record_failure(1)                   # trip 2 > max_trips -> dead
    assert br.dead and not br.allow(100)
    br.record_success()                    # death is permanent
    assert br.dead


# =====================================================================
# gmres_self_healing end-to-end
# =====================================================================

def test_fast_path_matches_plain_gmres():
    op, b = _op(), _rhs(48)
    ref = gmres(op, b, m=10, tol=1e-5, max_restarts=40,
                gs="cgs2_pipelined")   # the self-healing default
    res, rep = gmres_self_healing(op, b, m=10, tol=1e-5, max_restarts=40)
    assert rep.fast_path and rep.stepdowns == 0 and rep.faults == 0
    assert bool(res.converged)
    assert int(res.restarts) == int(ref.restarts)
    assert np.array_equal(np.asarray(res.x), np.asarray(ref.x))


def test_stepped_loop_commits_same_cycles_as_fused():
    """The restart-count parity the bench gate leans on: an ARMED (but
    never-firing) schedule forces the stepped loop, which must commit
    exactly the cycles the fused while_loop would."""
    op, b = _op(), _rhs(48)
    ref = gmres(op, b, m=10, tol=1e-5, max_restarts=40)
    with faultinject.inject("core.cycle", at=10_000):    # armed, never hit
        res, rep = gmres_self_healing(op, b, m=10, tol=1e-5,
                                      max_restarts=40)
    assert not rep.fast_path
    assert int(res.restarts) == int(ref.restarts)
    assert bool(res.converged)


@pytest.mark.parametrize("stepdowns", [1, 2])
def test_injected_nan_recovers_via_ladder(stepdowns):
    """A NaN-poisoned cycle is discarded and re-run one rung down; the
    recovered solve must match fault-free within tol and ≤ +1 restart."""
    op, b = _op(), _rhs(48)
    tol = 1e-6                         # m=3: several cycles, so cycle 1 exists
    ref = gmres(op, b, m=3, tol=tol, max_restarts=40, gs="cgs2_pipelined")
    with faultinject.inject("core.cycle_nan", at=1, times=stepdowns):
        res, rep = gmres_self_healing(op, b, m=3, tol=tol,
                                      max_restarts=40)
    assert bool(res.converged)
    assert rep.stepdowns == stepdowns and rep.faults == stepdowns
    assert not rep.gave_up
    assert int(res.restarts) - int(ref.restarts) <= 1
    bnorm = float(jnp.linalg.norm(b))
    assert float(res.residual) <= tol * bnorm
    # Recovered x solves the SAME system: compare through the operator.
    err = np.linalg.norm(np.asarray(res.x) - np.asarray(ref.x))
    assert err / np.linalg.norm(np.asarray(ref.x)) < 1e-3


def test_every_rung_converges():
    """Walk the ladder all the way down with repeated NaN injections:
    even the final ("mgs", "ref") rung must finish the solve."""
    op, b = _op(), _rhs(48)
    tol = 1e-5
    ladder = build_ladder("cgs2_pipelined")
    ref = gmres(op, b, m=10, tol=tol, max_restarts=40)
    with faultinject.inject("core.cycle_nan", times=len(ladder) - 1):
        res, rep = gmres_self_healing(op, b, m=10, tol=tol,
                                      max_restarts=40)
    assert rep.rung == len(ladder) - 1     # bottom of the ladder
    assert rep.ladder[rep.rung] == ("mgs", "ref")
    assert not rep.gave_up and bool(res.converged)
    assert float(res.residual) <= tol * float(jnp.linalg.norm(b))
    assert int(res.restarts) - int(ref.restarts) <= 1


def test_transient_exception_absorbed_by_retries():
    op, b = _op(), _rhs(48)
    sleeps = []
    with faultinject.inject("core.cycle", at=1, times=2):
        res, rep = gmres_self_healing(op, b, m=3, tol=1e-6,
                                      max_restarts=40, max_retries=2,
                                      backoff_base=0.5,
                                      sleep=sleeps.append)
    assert bool(res.converged)
    assert rep.retries == 2 and rep.stepdowns == 0
    assert sleeps == [0.5, 1.0]            # exponential backoff, injectable


def test_exception_past_retries_costs_a_rung():
    op, b = _op(), _rhs(48)
    with faultinject.inject("core.cycle", at=1, times=3):
        res, rep = gmres_self_healing(op, b, m=3, tol=1e-6,
                                      max_restarts=40, max_retries=2)
    assert bool(res.converged)
    assert rep.retries == 2 and rep.stepdowns == 1


def test_permanent_fault_gives_up_cleanly():
    """A fault that fires at EVERY rung exhausts the ladder: gave_up is
    set, done is True, and the result carries the last good iterate."""
    op, b = _op(), _rhs(48)
    with faultinject.inject("core.cycle", times=None):
        res, rep = gmres_self_healing(op, b, m=10, tol=1e-5,
                                      max_restarts=40, max_retries=0)
    assert rep.gave_up and not bool(res.converged) and bool(res.done)
    assert rep.rung == len(rep.ladder) - 1
    assert np.isfinite(np.asarray(res.x)).all()


def test_checkpoint_resume_bit_identical(tmp_path):
    """Kill a checkpointed solve after 3 cycles (max_restarts as the
    kill switch), resume from disk: trajectory must be BIT-identical to
    an uninterrupted stepped solve."""
    op, b = _op(), _rhs(48)
    full_dir, kill_dir = str(tmp_path / "full"), str(tmp_path / "kill")
    ref, ref_rep = gmres_self_healing(op, b, m=10, tol=1e-7,
                                      max_restarts=40,
                                      checkpoint_dir=full_dir)
    assert not ref_rep.fast_path and ref_rep.checkpoints == ref_rep.cycles

    _, rep1 = gmres_self_healing(op, b, m=10, tol=1e-7, max_restarts=3,
                                 checkpoint_dir=kill_dir)
    assert rep1.cycles == 3
    res, rep2 = gmres_self_healing(op, b, m=10, tol=1e-7, max_restarts=40,
                                   checkpoint_dir=kill_dir)
    assert rep2.resumed_from == 3
    assert np.array_equal(np.asarray(res.x), np.asarray(ref.x))
    assert float(res.residual) == float(ref.residual)
    assert int(res.restarts) == int(ref.restarts)


def test_checkpoint_every_thins_writes(tmp_path):
    op, b = _op(), _rhs(48)
    _, rep = gmres_self_healing(op, b, m=10, tol=1e-7, max_restarts=40,
                                checkpoint_dir=str(tmp_path),
                                checkpoint_every=2)
    assert rep.cycles > 2
    assert rep.checkpoints == rep.cycles // 2


def test_resume_false_ignores_checkpoints(tmp_path):
    op, b = _op(), _rhs(48)
    gmres_self_healing(op, b, m=10, tol=1e-7, max_restarts=3,
                       checkpoint_dir=str(tmp_path))
    _, rep = gmres_self_healing(op, b, m=10, tol=1e-7, max_restarts=40,
                                checkpoint_dir=str(tmp_path), resume=False)
    assert rep.resumed_from is None
