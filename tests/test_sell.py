"""Sliced-ELL subsystem: kernel, operator, workloads, solvers, serving.

Mirrors tests/test_sparse.py for the irregular-sparsity format: the
row-binned ``sell_matvec`` kernel vs its jnp oracle (Pallas interpreter
on CPU), ``SlicedEllOperator`` vs dense materialization across builders
and dtypes, the power-law graph workloads (core/graphs.py), gmres /
gmres_batched / gmres_sstep convergence parity vs dense, the sharded
path on fake devices, and a PageRank burst end-to-end through
``SolverServer`` with a ``slicedell`` handle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gmres, gmres_batched, graphs, stencils
from repro.core.operators import (SlicedEllOperator, SparseOperator,
                                  with_dtype)
from repro.core.sstep import gmres_sstep
from repro.kernels import spmv, tuning


def _powerlaw_dense(n, seed=0, dtype=np.float32, shuffle=True):
    """Dense power-law-ish matrix with a diagonally dominant diagonal.

    Row i carries ~max(2, n//8/(i+1)) off-diagonal nonzeros; rows are
    shuffled so the nnz sort is NOT the identity — the permutation path
    must do real work.
    """
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float64)
    for i in range(n):
        k = max(2, (n // 8) // (i + 1))
        cols = rng.choice(n, size=min(k, n), replace=False)
        a[i, cols] = rng.normal(size=len(cols))
    if shuffle:
        p = rng.permutation(n)
        a = a[p][:, p]
    np.fill_diagonal(a, 0.0)
    a[np.arange(n), np.arange(n)] = 2.0 * np.abs(a).sum(axis=1) + 1.0
    return a.astype(dtype)


def _bins_of(a_np, slice_height=16, **kw):
    op = SlicedEllOperator.from_dense(a_np, slice_height=slice_height, **kw)
    return op.bin_values, op.bin_cols, op.perm


# --------------------------------------------------------------------------
# row-binned kernel vs the jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,c", [(200, 16), (256, 64), (130, 8)])
def test_sell_kernel_matches_reference(n, c):
    a = _powerlaw_dense(n, seed=n)
    bv, bc, _ = _bins_of(a, slice_height=c)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    y_k = spmv.sell_matvec(bv, bc, x, interpret=True)
    y_r = spmv.sell_matvec_ref(bv, bc, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_sell_kernel_multi_rhs_and_blocks():
    a = _powerlaw_dense(192, seed=3)
    bv, bc, _ = _bins_of(a, slice_height=16)
    x = jax.random.normal(jax.random.PRNGKey(5), (192, 6))
    bms = tuple(64 for _ in bv)          # forces the per-bin row padding
    y_k = spmv.sell_matvec(bv, bc, x, block_ms=bms, interpret=True)
    y_r = spmv.sell_matvec_ref(bv, bc, x)
    assert y_k.shape == y_r.shape
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=3e-5, atol=3e-5)


def test_sell_kernel_bf16_values():
    """bf16 bin storage, f32 operand: f32 accumulation in-kernel."""
    a = _powerlaw_dense(160, seed=7)
    op = SlicedEllOperator.from_dense(a, slice_height=16)
    opb = with_dtype(op, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(9), (160,))
    y_k = spmv.sell_matvec(opb.bin_values, opb.bin_cols, x, interpret=True)
    assert y_k.dtype == jnp.float32         # f32 accumulation, not bf16
    # Kernel output is in sorted row order; scatter through perm to compare.
    y = np.zeros(160, np.float32)
    y[np.asarray(opb.perm)] = np.asarray(y_k)
    np.testing.assert_allclose(y, a @ np.asarray(x), rtol=3e-2, atol=3e-2)


def test_sell_kernel_validates_shapes():
    a = _powerlaw_dense(64)
    bv, bc, _ = _bins_of(a)
    with pytest.raises(TypeError):
        spmv.sell_matvec(bv, bc[:-1], jnp.zeros((64,)), interpret=True)
    with pytest.raises(TypeError):
        spmv.sell_matvec(bv, bc, jnp.zeros((64,)),
                         block_ms=(8,) * (len(bv) + 1), interpret=True)
    with pytest.raises(TypeError):
        spmv.sell_matvec((), (), jnp.zeros((64,)), interpret=True)


# --------------------------------------------------------------------------
# operator: builders, conversions, dispatch vs dense materialization
# --------------------------------------------------------------------------
def test_operator_matches_dense_both_backends():
    a = _powerlaw_dense(200, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (200,))
    want = a @ np.asarray(x)
    for backend in ("jnp", "pallas"):
        op = SlicedEllOperator.from_dense(a, slice_height=16,
                                          backend=backend)
        np.testing.assert_allclose(np.asarray(op(x)), want,
                                   rtol=3e-5, atol=3e-5)
        xb = jax.random.normal(jax.random.PRNGKey(4), (200, 3))
        np.testing.assert_allclose(np.asarray(op(xb)), a @ np.asarray(xb),
                                   rtol=3e-5, atol=3e-5)


def test_sorted_build_cuts_storage_and_matches():
    """The hub-row case the format exists for: sorted slicing must cut
    stored entries well below plain ELL's n * max_width."""
    a = _powerlaw_dense(256, seed=2)
    op = SlicedEllOperator.from_dense(a, slice_height=16)
    ell = SparseOperator.from_dense(a)
    assert not op.identity_perm             # shuffled rows -> real sort
    assert op.storage_entries < 0.5 * ell.values.shape[0] * ell.values.shape[1]
    np.testing.assert_allclose(np.asarray(op.todense()), a, atol=0)


def test_stencil_build_degenerates_to_identity():
    """Near-uniform rows (sort='auto'): keep original order, no perm cost,
    never worse than plain ELL."""
    op = stencils.poisson_2d(16, 16, fmt="sell")
    ell = stencils.poisson_2d(16, 16, fmt="ell")
    assert isinstance(op, SlicedEllOperator)
    assert op.identity_perm
    assert op.storage_entries <= ell.values.shape[0] * ell.values.shape[1]
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    np.testing.assert_allclose(np.asarray(op(x)), np.asarray(ell(x)),
                               rtol=3e-5, atol=3e-5)


def test_from_ell_to_ell_roundtrip():
    a = _powerlaw_dense(130, seed=5)
    sp = SparseOperator.from_dense(a)
    op = SlicedEllOperator.from_ell(sp, slice_height=8)
    assert op.halo == sp.halo
    np.testing.assert_allclose(np.asarray(op.todense()), a, atol=0)
    back = op.to_ell()
    np.testing.assert_allclose(np.asarray(back.todense()), a, atol=0)


def test_max_bins_caps_launch_count():
    a = _powerlaw_dense(512, seed=6)
    op = SlicedEllOperator.from_dense(a, slice_height=8, max_bins=3)
    assert len(op.bin_values) <= 3
    np.testing.assert_allclose(np.asarray(op.todense()), a, atol=0)


def test_pytree_roundtrip_and_jit():
    a = _powerlaw_dense(96, seed=8)
    op = SlicedEllOperator.from_dense(a, slice_height=16, backend="pallas")
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (op2.backend, op2.halo, op2.slice_height, op2.identity_perm) == \
        (op.backend, op.halo, op.slice_height, op.identity_perm)
    x = jax.random.normal(jax.random.PRNGKey(3), (96,))
    y = jax.jit(lambda o, v: o(v))(op, x)
    np.testing.assert_allclose(np.asarray(y), a @ np.asarray(x),
                               rtol=3e-5, atol=3e-5)


def test_ref_env_override(monkeypatch):
    """REPRO_KERNELS=ref must keep the pallas-backend operator correct."""
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    a = _powerlaw_dense(128, seed=9)
    op = SlicedEllOperator.from_dense(a, slice_height=16, backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(7), (128,))
    np.testing.assert_allclose(np.asarray(op(x)), a @ np.asarray(x),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# pseudo-hypothesis sweep: random patterns x slice heights x operands x dtype
# (the strategy-driven version lives in tests/test_properties.py)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed,c,k,dtype", [
    (11, 1, 1, jnp.float32),
    (12, 8, 1, jnp.float32),
    (13, 16, 4, jnp.float32),
    (14, 64, 1, jnp.bfloat16),
    (15, 32, 2, jnp.bfloat16),
])
def test_random_pattern_matches_dense(seed, c, k, dtype):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 220))
    a = _powerlaw_dense(n, seed=seed)
    op = SlicedEllOperator.from_dense(
        a.astype(jnp.dtype(dtype).name if dtype != jnp.bfloat16 else
                 np.float32), slice_height=c)
    if dtype == jnp.bfloat16:
        op = with_dtype(op, jnp.bfloat16)
    shape = (n,) if k == 1 else (n, k)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    want = np.asarray(op.todense(), np.float32) @ np.asarray(x)
    got = np.asarray(op(x), np.float32)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# graph workloads
# --------------------------------------------------------------------------
def test_powerlaw_adjacency_contract():
    a = graphs.powerlaw_adjacency(128, seed=0)
    assert np.array_equal(a, a.T)
    assert np.all(np.diag(a) == 0)
    deg = a.sum(axis=1)
    assert deg.min() >= 2                   # ring guarantees this
    assert deg.max() >= 4 * np.median(deg)  # hub regime
    assert np.array_equal(a, graphs.powerlaw_adjacency(128, seed=0))
    assert not np.array_equal(a, graphs.powerlaw_adjacency(128, seed=1))


def test_graph_laplacian_formats_agree():
    ops = {fmt: graphs.graph_laplacian(96, seed=3, fmt=fmt, slice_height=16)
           for fmt in ("sell", "ell", "dense")}
    x = jax.random.normal(jax.random.PRNGKey(2), (96,))
    want = np.asarray(ops["dense"](x))
    for fmt in ("sell", "ell"):
        np.testing.assert_allclose(np.asarray(ops[fmt](x)), want,
                                   rtol=3e-5, atol=3e-5)
    assert isinstance(ops["sell"], SlicedEllOperator)
    # Chung-Lu places hubs at low indices, so rows arrive near-sorted and
    # either order works — but slicing must still beat flat ELL padding.
    ell = ops["ell"]
    assert ops["sell"].storage_entries < \
        0.7 * ell.values.shape[0] * ell.values.shape[1]


def test_pagerank_solution_is_a_distribution():
    op, make_rhs = graphs.pagerank_system(128, seed=4, fmt="sell")
    b = make_rhs(jnp.ones(128))
    res = gmres(op, b, m=20, tol=1e-6, max_restarts=50)
    assert bool(res.converged)
    x = np.asarray(res.x)
    assert abs(x.sum() - 1.0) < 1e-4        # PageRank mass conservation
    assert x.min() > -1e-6


# --------------------------------------------------------------------------
# solvers end-to-end (interpret-mode kernels on CPU)
# --------------------------------------------------------------------------
def test_gmres_convergence_parity_sell_vs_dense():
    n = 192
    op = graphs.graph_laplacian(n, seed=5, fmt="sell", shift=1.0,
                               backend="pallas")
    dn = graphs.graph_laplacian(n, seed=5, fmt="dense", shift=1.0)
    b = jax.random.normal(jax.random.PRNGKey(5), (n,))
    rs = gmres(op, b, m=30, tol=1e-6, max_restarts=60)
    rd = gmres(dn, b, m=30, tol=1e-6, max_restarts=60)
    assert bool(rs.converged) and bool(rd.converged)
    assert abs(int(rs.restarts) - int(rd.restarts)) <= 1
    np.testing.assert_allclose(np.asarray(rs.x), np.asarray(rd.x),
                               rtol=2e-3, atol=2e-3)


def test_gmres_batched_block_path_on_sell():
    n, k = 128, 3
    op = graphs.graph_laplacian(n, seed=6, fmt="sell", shift=1.0,
                               backend="pallas")
    bs = jax.random.normal(jax.random.PRNGKey(6), (k, n))
    res = gmres_batched(op, bs, m=25, tol=1e-6, max_restarts=60)
    dense = np.asarray(op.todense())
    for i in range(k):
        r = np.linalg.norm(dense @ np.asarray(res.x[i]) - np.asarray(bs[i]))
        assert r <= 1e-6 * np.linalg.norm(np.asarray(bs[i])) * 1.5


def test_gmres_sstep_on_sell_operator():
    n = 128
    op = graphs.graph_laplacian(n, seed=7, fmt="sell", shift=1.0)
    b = jax.random.normal(jax.random.PRNGKey(7), (n,))
    res = gmres_sstep(op, b, s=2, blocks=8, tol=1e-6, max_restarts=60)
    assert bool(res.converged)
    r = np.asarray(op.todense()) @ np.asarray(res.x) - np.asarray(b)
    assert np.linalg.norm(r) <= 2e-6 * np.linalg.norm(np.asarray(b)) * 2


def test_sell_with_jacobi_precond():
    n = 128
    op = graphs.graph_laplacian(n, seed=8, fmt="sell", shift=1.0)
    from repro.core import preconditioners as pc
    b = jax.random.normal(jax.random.PRNGKey(8), (n,))
    res = gmres(op, b, m=20, tol=1e-6, max_restarts=60,
                precond=pc.jacobi(op))
    assert bool(res.converged)
    # diag/row-sum extraction must match the dense materialization
    d = np.asarray(pc._diag_of(op))
    np.testing.assert_allclose(d, np.diag(np.asarray(op.todense())),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# sstep x compute_dtype=bf16 (satellite: parity like the PR 3 fused path)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("gs", ["cgs2", "cgs2_pipelined"])
def test_sstep_bf16_compute_dtype_parity(gs):
    op = stencils.poisson_2d(16, 16)
    b = jnp.sin(jnp.arange(256, dtype=jnp.float32))
    r32 = gmres_sstep(op, b, s=4, blocks=5, tol=1e-5, max_restarts=60, gs=gs)
    rbf = gmres_sstep(op, b, s=4, blocks=5, tol=1e-5, max_restarts=60, gs=gs,
                      compute_dtype=jnp.bfloat16)
    assert bool(r32.converged) and bool(rbf.converged)
    # Convergence checks run on the full-precision residual, so both meet
    # the SAME tol; bf16 streams may cost extra restarts but not accuracy.
    a = np.asarray(op.todense())
    for res in (r32, rbf):
        rnorm = np.linalg.norm(a @ np.asarray(res.x) - np.asarray(b))
        assert rnorm <= 1e-5 * np.linalg.norm(np.asarray(b)) * 1.5
    np.testing.assert_allclose(np.asarray(rbf.x), np.asarray(r32.x),
                               rtol=2e-2, atol=2e-2)


def test_sstep_bf16_downcasts_operand_stream():
    """The power block must stream A in bf16 (with_dtype), while the
    restart-boundary residual stays f32 — spy on the powers input."""
    from repro.core import sstep as sstep_mod
    seen = []
    orig = sstep_mod._make_block_fns

    def spy(op, *a, **kw):
        seen.append(op.dtype)
        return orig(op, *a, **kw)

    sstep_mod._make_block_fns = spy
    try:
        op = stencils.poisson_2d(8, 8)
        b = jnp.ones((64,), jnp.float32)
        res = gmres_sstep(op, b, s=2, blocks=4, tol=1e-4, max_restarts=40,
                          compute_dtype=jnp.bfloat16)
    finally:
        sstep_mod._make_block_fns = orig
    assert seen == [jnp.bfloat16]
    assert res.residual.dtype == jnp.float32
    assert bool(res.converged)


# --------------------------------------------------------------------------
# sharded path (fake devices in a subprocess — XLA flag must precede jax)
# --------------------------------------------------------------------------
def test_sharded_sell_matches_single_device_8dev():
    import json as _json
    import os
    import subprocess
    import sys
    import textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import gmres, gmres_sharded, graphs
        mesh = make_mesh((8,), ('model',))
        op = graphs.graph_laplacian(256, seed=9, fmt='sell', shift=1.0)
        b = jax.random.normal(jax.random.PRNGKey(9), (256,))
        res_d = gmres_sharded(mesh, 'model', op, b, m=20, tol=1e-6,
                              max_restarts=60)
        res_s = gmres(op, b, m=20, tol=1e-6, max_restarts=60)
        err = float(jnp.linalg.norm(res_d.x - res_s.x)
                    / jnp.linalg.norm(res_s.x))
        print(json.dumps({"converged": bool(res_d.converged), "err": err}))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    got = _json.loads(out.stdout.strip().splitlines()[-1])
    assert got["converged"]
    assert got["err"] < 2e-3


# --------------------------------------------------------------------------
# serving: PageRank burst through SolverServer with a slicedell handle
# --------------------------------------------------------------------------
def test_pagerank_burst_through_solver_server():
    from repro.serve import SolverServer
    from repro.serve.handles import operator_fmt
    n, k = 96, 3
    op, make_rhs = graphs.pagerank_system(n, seed=10, fmt="sell")
    assert operator_fmt(op) == "slicedell"
    srv = SolverServer(op, m=12, k=k)
    rng = np.random.default_rng(10)
    rhss = {}
    for _ in range(7):
        b = np.asarray(make_rhs(rng.random(n) + 0.1))
        rhss[srv.submit(b, tol=1e-6, max_restarts=60)] = b
    srv.run()
    assert srv.handle.key.fmt == "slicedell"
    assert set(srv.results) == set(rhss)
    dense = np.asarray(op.todense())
    for rid, b in rhss.items():
        out = srv.results[rid]
        assert out.status == "done", (rid, out.status)
        x = np.asarray(out.x)
        assert abs(x.sum() - 1.0) < 1e-3    # each solve is a distribution
        assert np.linalg.norm(dense @ x - b) <= 1e-6 * np.linalg.norm(b) * 2
