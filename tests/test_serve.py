"""Serving layer: deterministic scheduler sim, queue, LRU, server, faults.

The scheduler is a pure tick machine (repro/serve/scheduler.py), so most
of this file runs with SCRIPTED residuals and no jax at all — the same
transitions the live server drives, stepped by hand.  The end-to-end and
fault-injection sections then run the real `SolverServer` on tiny
systems (interpret/ref dispatch; CPU-safe) with dispatch spies in the
style of test_pipelined.py.
"""
import numpy as np
import pytest

from repro.runtime import faultinject
from repro.serve import scheduler as sched
from repro.serve.queue import BackpressuredQueue
from repro.serve.request import (DONE, FAILED, REJECTED, TIMEOUT,
                                 AdmissionError, SolveRequest, validate_b,
                                 validate_params)


@pytest.fixture(autouse=True)
def _isolated_fault_schedule(monkeypatch):
    """These tests assert exact counters, so an ambient REPRO_FAULT (the
    CI injection leg) must not leak in; scoped injections via the context
    manager are unaffected."""
    monkeypatch.delenv("REPRO_FAULT", raising=False)
    faultinject.reset()
    yield
    faultinject.reset()


def _req(rid, n=4, tol=0.5, max_restarts=10, scale=1.0, deadline=None,
         retries=0):
    """A tiny host-side request; tol_abs = tol * scale * 2 (||ones*scale||₂
    of n=4 is 2*scale) keeps scripted-residual arithmetic readable."""
    return SolveRequest(rid=rid, b=np.full(n, scale), tol=tol,
                        max_restarts=max_restarts, deadline_ticks=deadline,
                        retries=retries)


# =====================================================================
# Pure scheduler simulation (no jax): admit -> pack -> retire -> refill
# =====================================================================

def test_init_all_lanes_idle():
    st = sched.init(4)
    assert st.k == 4 and st.active == 0
    assert st.idle_lanes == (0, 1, 2, 3)
    assert not st.busy
    assert st.occupancy == 0.0


def test_init_rejects_zero_lanes():
    with pytest.raises(ValueError):
        sched.init(0)


def test_admit_appends_fifo():
    st = sched.init(2, max_pending=8)
    for i in range(3):
        st, ok = sched.admit(st, _req(i))
        assert ok
    assert [r.rid for r in st.pending] == [0, 1, 2]
    assert st.admitted == 3 and st.rejected == 0
    assert st.busy  # backlog counts as busy even with idle lanes


def test_admit_backpressure_rejects_when_full():
    st = sched.init(2, max_pending=2)
    st, _ = sched.admit(st, _req(0))
    st, _ = sched.admit(st, _req(1))
    st, ok = sched.admit(st, _req(2))
    assert not ok
    assert st.rejected == 1 and st.admitted == 2
    assert len(st.pending) == 2  # the refused request never entered


def test_pack_fifo_admission_order():
    st = sched.init(3)
    for i in range(5):
        st, _ = sched.admit(st, _req(i))
    st, placed = sched.pack(st)
    assert [(lane, r.rid) for lane, r in placed] == [(0, 0), (1, 1), (2, 2)]
    assert [r.rid for r in st.pending] == [3, 4]
    assert st.active == 3


def test_pack_skips_busy_lanes():
    st = sched.init(3)
    for i in range(3):
        st, _ = sched.admit(st, _req(i))
    st, _ = sched.pack(st)
    # Retire ONLY lane 1 (residual under its tol_abs = 0.5*2 = 1.0).
    st, retired = sched.retire(st, [5.0, 0.1, 5.0])
    assert [r.lane for r in retired] == [1]
    st, _ = sched.admit(st, _req(9))
    st, placed = sched.pack(st)
    # The new request lands in the freed middle lane; 0 and 2 untouched.
    assert placed == [(1, st.lanes[1].req)]
    assert st.lanes[1].req.rid == 9
    assert st.lanes[0].req.rid == 0 and st.lanes[2].req.rid == 2
    # Lanes 0/2 keep their restart progress across the refill.
    assert st.lanes[0].restarts == 1 and st.lanes[2].restarts == 1
    assert st.lanes[1].restarts == 0


def test_pack_empty_backlog_is_noop():
    st = sched.init(2)
    st2, placed = sched.pack(st)
    assert placed == [] and st2 is st


def test_retire_done_at_restart_boundary():
    st = sched.init(2)
    st, _ = sched.admit(st, _req(0))        # tol_abs = 1.0
    st, _ = sched.admit(st, _req(1))
    st, _ = sched.pack(st)
    st, retired = sched.retire(st, [0.5, 2.0])
    assert len(retired) == 1
    r = retired[0]
    assert (r.lane, r.req.rid, r.status, r.restarts) == (0, 0, DONE, 1)
    assert r.residual == 0.5
    assert st.retired_done == 1 and st.retired_failed == 0
    assert st.lanes[0].idle and not st.lanes[1].idle


def test_retire_exactly_at_tol_counts_done():
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, tol=0.5))  # tol_abs = 1.0
    st, _ = sched.pack(st)
    st, retired = sched.retire(st, [1.0])      # boundary: <=, not <
    assert retired[0].status == DONE


def test_retire_failed_on_budget_exhaustion():
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, tol=1e-9, max_restarts=3))
    st, _ = sched.pack(st)
    for expected in (1, 2):
        st, retired = sched.retire(st, [5.0])
        assert retired == [] and st.lanes[0].restarts == expected
    st, retired = sched.retire(st, [5.0])
    assert retired[0].status == FAILED and retired[0].restarts == 3
    assert st.retired_failed == 1 and st.lanes[0].idle


def test_failed_lane_does_not_stall_cohort():
    st = sched.init(3)
    st, _ = sched.admit(st, _req(0, tol=1e-9, max_restarts=2))  # hopeless
    st, _ = sched.admit(st, _req(1))
    st, _ = sched.admit(st, _req(2))
    st, _ = sched.pack(st)
    st, r1 = sched.retire(st, [9.0, 0.1, 9.0])    # lane 1 retires DONE
    assert [(r.req.rid, r.status) for r in r1] == [(1, DONE)]
    st, r2 = sched.retire(st, [9.0, 9.0, 0.1])    # hopeless FAILs, 2 DONE
    assert sorted((r.req.rid, r.status) for r in r2) == [(0, FAILED),
                                                         (2, DONE)]
    assert st.active == 0 and st.retired_done == 2 and st.retired_failed == 1


def test_mid_solve_refill_cycle():
    """The continuous-batching loop: k=2 lanes, 4 requests, lane 0's
    occupants converge fast and keep refilling while lane 1 grinds."""
    st = sched.init(2, max_pending=8)
    for i in range(4):
        st, _ = sched.admit(st, _req(i, max_restarts=10))
    st, placed = sched.pack(st)
    assert [r.rid for _, r in placed] == [0, 1]
    order = []
    # Lane 0 converges every tick; lane 1 never does (until the end).
    for _ in range(3):
        st, retired = sched.retire(st, [0.0, 9.0])
        order.extend(r.req.rid for r in retired)
        st, _ = sched.pack(st)                    # refill mid-solve
    st, retired = sched.retire(st, [9.0, 0.0])
    order.extend(r.req.rid for r in retired)
    assert order == [0, 2, 3, 1]
    assert st.tick == 4 and not st.busy
    # Occupancy: lane 1 busy all 4 ticks, lane 0 busy 3 of 4.
    assert st.lane_cycles == 7
    assert st.occupancy == pytest.approx(7 / 8)


def test_retire_wrong_length_raises():
    st = sched.init(3)
    with pytest.raises(ValueError):
        sched.retire(st, [1.0, 2.0])


def test_retire_ignores_idle_lane_residuals():
    st = sched.init(2)
    st, _ = sched.admit(st, _req(0))
    st, _ = sched.pack(st)
    st, retired = sched.retire(st, [9.0, 0.0])   # lane 1 idle: 0.0 ignored
    assert retired == []
    assert st.lane_cycles == 1                   # only the occupied lane


def test_empty_drain_terminates():
    st = sched.init(2)
    for i in range(2):
        st, _ = sched.admit(st, _req(i))
    st, _ = sched.pack(st)
    st, _ = sched.retire(st, [0.0, 0.0])
    assert not st.busy
    st2, placed = sched.pack(st)                 # drain probe: nothing left
    assert placed == [] and not st2.busy


def test_metrics_shape():
    st = sched.init(2)
    st, _ = sched.admit(st, _req(0))
    st, _ = sched.pack(st)
    st, _ = sched.retire(st, [0.0, 0.0])
    m = sched.metrics(st)
    assert m["tick"] == 1 and m["retired_done"] == 1
    assert m["queue_depth"] == 0 and m["active_lanes"] == 0
    assert m["occupancy"] == pytest.approx(0.5)
    assert set(m) >= {"admitted", "rejected", "retired_failed",
                      "lane_cycles"}


# =====================================================================
# Pure scheduler: deadlines, lane faults, quarantine (still no jax)
# =====================================================================

def test_retire_timeout_at_deadline():
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, tol=1e-9, max_restarts=10, deadline=3))
    st, _ = sched.pack(st)
    for _ in range(2):
        st, retired = sched.retire(st, [9.0])
        assert retired == []
    st, retired = sched.retire(st, [9.0])
    r = retired[0]
    assert r.status == TIMEOUT and r.restarts == 3
    assert "deadline" in r.reason
    assert st.retired_timeout == 1 and st.retired_failed == 0


def test_retire_done_wins_deadline_tie():
    """A request that converges ON its deadline tick converged."""
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, tol=0.5, deadline=1))  # tol_abs = 1.0
    st, _ = sched.pack(st)
    st, retired = sched.retire(st, [0.5])
    assert retired[0].status == DONE
    assert st.retired_timeout == 0


def test_timeout_deadline_before_budget():
    """Deadline tighter than the restart budget: TIMEOUT, not FAILED."""
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, tol=1e-9, max_restarts=2, deadline=2))
    st, _ = sched.pack(st)
    st, _ = sched.retire(st, [9.0])
    st, retired = sched.retire(st, [9.0])
    assert retired[0].status == TIMEOUT    # deadline checked before budget


def test_timeout_does_not_stall_cohort():
    st = sched.init(2)
    st, _ = sched.admit(st, _req(0, tol=1e-9, deadline=1))   # doomed
    st, _ = sched.admit(st, _req(1))
    st, _ = sched.admit(st, _req(2))
    st, _ = sched.pack(st)
    st, retired = sched.retire(st, [9.0, 9.0])
    assert [(r.req.rid, r.status) for r in retired] == [(0, TIMEOUT)]
    st, placed = sched.pack(st)            # the freed lane refills NOW
    assert [(i, r.rid) for i, r in placed] == [(0, 2)]


def test_fault_requeues_occupant_at_front_with_retry():
    st = sched.init(2, max_pending=8)
    st, _ = sched.admit(st, _req(0))
    st, _ = sched.admit(st, _req(1))
    st, _ = sched.admit(st, _req(2))       # waits in pending
    st, _ = sched.pack(st)
    st, requeued, failed = sched.fault(st, [1], quarantine_ticks=2,
                                       max_retries=1)
    assert failed == [] and [r.rid for r in requeued] == [1]
    # Front of the queue (it has waited longest), retry count bumped.
    assert [r.rid for r in st.pending] == [1, 2]
    assert st.pending[0].retries == 1
    assert st.lanes[1].idle and st.quarantine[1] == 2
    assert st.lane_faults == 1 and st.requeued == 1


def test_fault_exhausted_retries_fails():
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, retries=1))
    st, _ = sched.pack(st)
    st, requeued, failed = sched.fault(st, [0], max_retries=1)
    assert requeued == []
    assert failed[0].status == FAILED and "lane fault" in failed[0].reason
    assert st.retired_failed == 1 and st.pending == ()


def test_fault_quarantine_blocks_pack_then_decays():
    st = sched.init(1, max_pending=8)
    st, _ = sched.admit(st, _req(0))
    st, _ = sched.pack(st)
    st, requeued, _ = sched.fault(st, [0], quarantine_ticks=2)
    st, placed = sched.pack(st)
    assert placed == []                    # quarantined: sit out
    st, _ = sched.retire(st, [9.0])        # decrement 2 -> 1
    st, placed = sched.pack(st)
    assert placed == []
    st, _ = sched.retire(st, [9.0])        # 1 -> 0
    st, placed = sched.pack(st)
    assert [r.rid for _, r in placed] == [0]   # retry lands at last


def test_fault_idle_lane_only_quarantines():
    st = sched.init(2)
    st, requeued, failed = sched.fault(st, [0])
    assert requeued == [] and failed == []
    assert st.quarantine[0] == 2 and st.lane_faults == 0


def test_faulted_lane_not_charged_a_restart():
    """fault() frees the lane BEFORE retire: the poisoned cycle costs the
    occupant no budget, and its retry starts with restarts=0."""
    st = sched.init(1)
    st, _ = sched.admit(st, _req(0, max_restarts=3))
    st, _ = sched.pack(st)
    st, _ = sched.retire(st, [9.0])
    assert st.lanes[0].restarts == 1
    st, requeued, _ = sched.fault(st, [0])
    assert requeued[0].retries == 1
    # Requeued request's budget is untouched -- it restarts from x = 0.
    assert requeued[0].max_restarts == 3


# =====================================================================
# Admission: solver-parameter validation
# =====================================================================

@pytest.mark.parametrize("tol", [0.0, -1.0, float("nan"), float("inf")])
def test_validate_params_rejects_bad_tol(tol):
    with pytest.raises(AdmissionError, match="tol"):
        validate_params(tol, 10)


@pytest.mark.parametrize("mr", [0, -3])
def test_validate_params_rejects_bad_budget(mr):
    with pytest.raises(AdmissionError, match="max_restarts"):
        validate_params(1e-5, mr)


def test_validate_params_rejects_bad_deadline():
    with pytest.raises(AdmissionError, match="deadline"):
        validate_params(1e-5, 10, deadline_ticks=0)
    validate_params(1e-5, 10, deadline_ticks=None)   # None = no deadline
    validate_params(1e-5, 10, deadline_ticks=1)


def test_validate_b_rejects_non_real_dtypes():
    with pytest.raises(AdmissionError, match="dtype"):
        validate_b(np.array([1 + 2j, 3 + 4j]))
    with pytest.raises(AdmissionError, match="dtype"):
        validate_b(np.array(["a", "b"]))
    with pytest.raises(AdmissionError, match="array-like"):
        validate_b([[1.0, 2.0], [3.0]])    # ragged: not array-like
    assert validate_b(np.array([1, 2, 3])).shape == (3,)   # ints are fine


# =====================================================================
# Backpressured queue (scripted clock — no real time, no threads)
# =====================================================================

class _Clock:
    """Scripted monotonic clock; sleep() advances it and may run a hook."""

    def __init__(self, on_sleep=None):
        self.t = 0.0
        self.on_sleep = on_sleep

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt
        if self.on_sleep is not None:
            self.on_sleep()


def test_queue_fifo():
    q = BackpressuredQueue(max_depth=4)
    assert all(q.push(i) for i in range(3))
    assert [q.pop(), q.pop(), q.pop()] == [0, 1, 2]
    assert q.pop() is None and q.pushed == 3


def test_queue_refuses_when_full():
    q = BackpressuredQueue(max_depth=2)
    assert q.push("a") and q.push("b")
    assert not q.push("c")
    assert q.refused == 1 and len(q) == 2 and q.full


def test_queue_rejects_bad_depth():
    with pytest.raises(ValueError):
        BackpressuredQueue(max_depth=0)


def test_wait_queue_returns_when_consumer_drains():
    q = BackpressuredQueue(max_depth=2)
    q.push("a"), q.push("b")
    clk = _Clock(on_sleep=q.pop)          # scripted consumer: pop per poll
    ok = q.wait_queue(1, clock=clk, sleep=clk.sleep, poll=0.01, max_wait=1.0)
    assert ok and len(q) == 1
    assert clk.t == pytest.approx(0.01)   # exactly one poll was needed


def test_wait_queue_times_out_deterministically():
    q = BackpressuredQueue(max_depth=1)
    q.push("a")
    clk = _Clock()                        # nobody drains
    ok = q.wait_queue(0, clock=clk, sleep=clk.sleep, poll=0.1, max_wait=0.5)
    assert not ok
    assert clk.t == pytest.approx(0.5)    # gave up exactly at the deadline


def test_backpressured_push_waits_then_succeeds():
    q = BackpressuredQueue(max_depth=1)
    q.push("a")
    clk = _Clock(on_sleep=q.pop)
    assert q.backpressured_push("b", clock=clk, sleep=clk.sleep,
                                poll=0.01, max_wait=1.0)
    assert q.pop() == "b" and q.refused == 0


def test_backpressured_push_rejects_on_timeout():
    q = BackpressuredQueue(max_depth=1)
    q.push("a")
    clk = _Clock()
    assert not q.backpressured_push("b", clock=clk, sleep=clk.sleep,
                                    poll=0.1, max_wait=0.3)
    assert q.refused == 1 and len(q) == 1 and q.peek() == "a"


def test_queue_drain_pops_everything():
    q = BackpressuredQueue(max_depth=8)
    for i in range(5):
        q.push(i)
    assert q.drain() == [0, 1, 2, 3, 4]
    assert len(q) == 0


# =====================================================================
# Request validation
# =====================================================================

def test_validate_rejects_nan_and_inf():
    for bad in (np.array([1.0, np.nan]), np.array([np.inf, 1.0]),
                np.array([1.0, -np.inf])):
        with pytest.raises(AdmissionError, match="NaN/Inf"):
            validate_b(bad)


def test_validate_rejects_shape_mismatch():
    with pytest.raises(AdmissionError, match="2-D|1-D"):
        validate_b(np.ones((2, 2)))
    with pytest.raises(AdmissionError, match="n=3"):
        validate_b(np.ones(3), n=4)


def test_request_tol_abs_is_relative():
    r = SolveRequest(rid=0, b=np.array([3.0, 4.0]), tol=0.1)
    assert r.tol_abs == pytest.approx(0.5)   # 0.1 * ||b|| = 0.1*5


# =====================================================================
# LRU handle cache (tuning.LruCache + serve.HandleCache)
# =====================================================================

def test_lru_hit_miss_counters():
    from repro.kernels.tuning import LruCache
    lru = LruCache(maxsize=2)
    assert lru.get_or_create("a", lambda: 1) == 1     # miss
    assert lru.get_or_create("a", lambda: 99) == 1    # hit keeps old value
    s = lru.stats()
    assert (s["hits"], s["misses"], s["evictions"]) == (1, 1, 0)


def test_lru_evicts_least_recently_used():
    from repro.kernels.tuning import LruCache
    lru = LruCache(maxsize=2)
    lru.get_or_create("a", lambda: 1)
    lru.get_or_create("b", lambda: 2)
    lru.get_or_create("a", lambda: 0)     # touch a: b is now coldest
    lru.get_or_create("c", lambda: 3)     # evicts b
    assert "b" not in lru and "a" in lru and "c" in lru
    assert lru.stats()["evictions"] == 1


def test_lru_rejects_bad_maxsize():
    from repro.kernels.tuning import LruCache
    with pytest.raises(ValueError):
        LruCache(maxsize=0)


def _dense_op(n=32, seed=0):
    import jax
    from repro.core import operators
    return operators.DenseOperator(
        operators.random_diagdom(jax.random.PRNGKey(seed), n))


def test_handle_cache_hit_on_same_bucket():
    from repro.serve import HandleCache
    cache = HandleCache(maxsize=4)
    op = _dense_op()
    h1 = cache.get(op, m=8, k=2)
    h2 = cache.get(op, m=8, k=2)
    assert h1 is h2
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and len(cache) == 1


def test_handle_cache_miss_on_different_bucket():
    from repro.serve import HandleCache
    cache = HandleCache(maxsize=4)
    op = _dense_op()
    h1 = cache.get(op, m=8, k=2)
    h2 = cache.get(op, m=8, k=4)          # k differs -> new lowering
    h3 = cache.get(op, m=16, k=2)         # m differs
    assert h1 is not h2 and h1 is not h3
    assert cache.stats()["misses"] == 3


def test_handle_cache_eviction():
    from repro.serve import HandleCache
    cache = HandleCache(maxsize=2)
    op = _dense_op()
    k1 = cache.get(op, m=4, k=2).key
    cache.get(op, m=8, k=2)
    cache.get(op, m=16, k=2)              # evicts the m=4 handle
    assert k1 not in cache
    assert cache.stats()["evictions"] == 1


def test_handle_key_fields():
    from repro.serve import HandleCache, operator_fmt
    import jax.numpy as jnp
    op = _dense_op(n=24)
    assert operator_fmt(op) == "dense"
    h = HandleCache().get(op, m=8, k=3, dtype=jnp.float32)
    assert (h.key.n, h.key.fmt, h.key.m, h.key.k, h.key.dtype) == (
        24, "dense", 8, 3, "float32")
    # Identity half of the key: which system this compiled cycle solves.
    assert h.key.gs == "cgs2"
    assert h.key.op_token == id(op) and h.key.precond_token == 0


def test_handle_cache_never_crosses_operators():
    """Two same-shaped operators through ONE shared cache must get two
    handles — the handle jit-closes over the concrete A, so a shape-only
    key would silently solve the first server's system for the second."""
    from repro.serve import HandleCache
    cache = HandleCache(maxsize=4)
    op1, op2 = _dense_op(n=32, seed=0), _dense_op(n=32, seed=1)
    h1 = cache.get(op1, m=8, k=2)
    h2 = cache.get(op2, m=8, k=2)         # same (n, fmt, m, k, dtype)
    assert h1 is not h2 and h1.op is op1 and h2.op is op2
    assert cache.get(op1, m=8, k=2) is h1  # identity hit still works
    assert cache.stats()["misses"] == 2 and cache.stats()["hits"] == 1


def test_handle_cache_keyed_by_gs_and_precond():
    from repro.serve import HandleCache
    cache = HandleCache(maxsize=8)
    op = _dense_op(n=32)
    h1 = cache.get(op, m=8, k=2, gs="cgs2")
    h2 = cache.get(op, m=8, k=2, gs="mgs")
    jacobi = lambda v: v * 0.5
    h3 = cache.get(op, m=8, k=2, gs="cgs2", precond=jacobi)
    assert len({id(h1), id(h2), id(h3)}) == 3
    assert h3.precond is jacobi           # strong ref keeps token valid


def test_shared_cache_servers_solve_their_own_systems():
    """The review scenario end-to-end: two servers over same-shaped but
    DIFFERENT operators sharing one HandleCache; each result must match
    a standalone solve of its own system."""
    import jax.numpy as jnp
    from repro.core.gmres import gmres
    from repro.serve import HandleCache, SolverServer
    cache = HandleCache(maxsize=4)
    n = 48
    op1, op2 = _dense_op(n=n, seed=3), _dense_op(n=n, seed=4)
    s1 = SolverServer(op1, m=12, k=2, handle_cache=cache)
    s2 = SolverServer(op2, m=12, k=2, handle_cache=cache)
    assert s1.handle is not s2.handle
    b = _rhs(n, 11)
    r1, r2 = s1.submit(b, tol=1e-6), s2.submit(b, tol=1e-6)
    s1.run(), s2.run()
    for srv, rid, op in ((s1, r1, op1), (s2, r2, op2)):
        ref = gmres(op, jnp.asarray(b, jnp.float32), m=12, tol=1e-6,
                    max_restarts=50)
        err = np.linalg.norm(srv.results[rid].x - np.asarray(ref.x))
        assert err / np.linalg.norm(np.asarray(ref.x)) < 1e-3


def test_handle_block_shape_validated():
    from repro.serve import HandleCache
    import jax.numpy as jnp
    h = HandleCache().get(_dense_op(n=16), m=4, k=2)
    with pytest.raises(ValueError, match="expects"):
        h.cycle(jnp.zeros((3, 16)), jnp.zeros((3, 16)),
                jnp.zeros(3), jnp.ones(3, bool))


# =====================================================================
# Server end-to-end (tiny systems; interpret/ref dispatch, CPU-safe)
# =====================================================================

def _server(n=48, k=4, m=12, seed=0, **kw):
    import jax
    from repro.serve import SolverServer
    op = _dense_op(n=n, seed=seed)
    return op, SolverServer(op, m=m, k=k, **kw)


def _rhs(n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n)


def test_server_drains_heterogeneous_workload():
    n, k = 48, 4
    op, srv = _server(n=n, k=k)
    rids = {}
    for i in range(10):
        tol = [1e-3, 1e-5, 1e-6][i % 3]
        b = _rhs(n, i)
        rids[srv.submit(b, tol=tol, max_restarts=40)] = (b, tol)
    srv.run()
    for rid, (b, tol) in rids.items():
        out = srv.results[rid]
        assert out.status == DONE, (rid, out.status)
        assert out.residual <= tol * np.linalg.norm(b) * (1 + 1e-6)
    m = srv.metrics()
    assert m["retired_done"] == 10 and m["queue_depth"] == 0


def test_server_packs_fewer_cycles_than_sequential():
    """The throughput claim, in miniature: total ticks < sum of per-
    request restarts a sequential loop would pay."""
    import jax.numpy as jnp
    from repro.core.gmres import gmres
    n, k = 48, 4
    op, srv = _server(n=n, k=k)
    work = [(_rhs(n, 100 + i), [1e-3, 1e-5][i % 2]) for i in range(12)]
    for b, tol in work:
        srv.submit(b, tol=tol, max_restarts=40)
    ticks = srv.run()
    seq = sum(int(gmres(op, jnp.asarray(b, jnp.float32), m=12, tol=tol,
                        max_restarts=40).restarts) for b, tol in work)
    assert ticks < seq, (ticks, seq)


def test_server_solution_matches_standalone():
    import jax.numpy as jnp
    from repro.core.gmres import gmres
    n = 48
    op, srv = _server(n=n, k=2)
    b = _rhs(n, 7)
    rid = srv.submit(b, tol=1e-6, max_restarts=50)
    srv.run()
    out = srv.results[rid]
    ref = gmres(op, jnp.asarray(b, jnp.float32), m=12, tol=1e-6,
                max_restarts=50)
    err = np.linalg.norm(out.x - np.asarray(ref.x))
    assert err / np.linalg.norm(np.asarray(ref.x)) < 1e-3


def test_server_mid_solve_refill():
    """More requests than lanes: loose-tol occupants retire and their
    lanes refill while tight-tol neighbours are still mid-solve."""
    n, k = 48, 2
    op, srv = _server(n=n, k=k)
    # Lane-hog: tight tol. Quick turnover: loose tol.
    hog = srv.submit(_rhs(n, 0), tol=1e-6, max_restarts=50)
    quick = [srv.submit(_rhs(n, i + 1), tol=5e-2, max_restarts=50)
             for i in range(4)]
    refills = 0
    while srv.state.busy or srv.ingress.peek() is not None:
        hog_running = (not srv.state.lanes[0].idle
                       and srv.state.lanes[0].req.rid == hog)
        before = srv.state.active
        srv.step()
        if hog_running and srv.state.lanes[1].idle and srv.state.pending:
            pass
        refills += 1 if (hog_running and before == k
                         and srv.state.active < k
                         and srv.state.pending) else 0
    for rid in quick + [hog]:
        assert srv.results[rid].status == DONE
    # All 5 solves fit in k=2 lanes in fewer ticks than 5 sequential solves
    # would need -- refill worked. (The hog needs several restarts alone.)
    assert srv.metrics()["retired_done"] == 5


def test_server_nonblocking_backpressure_rejects():
    n = 48
    op, srv = _server(n=n, k=2, max_pending=4, queue_depth=2)
    rids = [srv.submit(_rhs(n, i)) for i in range(4)]
    statuses = [srv.results.get(r) for r in rids]
    # Queue depth 2: the 3rd and 4th submits are refused at admission.
    assert statuses[0] is None and statuses[1] is None
    assert statuses[2].status == REJECTED and "backpressure" in statuses[2].reason
    assert statuses[3].status == REJECTED
    srv.run()
    assert srv.results[rids[0]].status == DONE
    assert srv.results[rids[1]].status == DONE


def test_server_blocking_submit_waits_for_drain():
    """wait=True submit succeeds once the scripted sleep hook ticks the
    server (the consumer), draining the full ingress queue."""
    n = 48
    op, srv = _server(n=n, k=2, queue_depth=1,
                      clock=(clk := _Clock()), sleep=None)
    clk.on_sleep = lambda: srv.step()
    srv._sleep = clk.sleep
    r1 = srv.submit(_rhs(n, 0), tol=1e-2)
    r2 = srv.submit(_rhs(n, 1), tol=1e-2, wait=True, max_wait=5.0)
    assert srv.results.get(r2) is None     # admitted, not rejected
    srv.run()
    assert srv.results[r1].status == DONE
    assert srv.results[r2].status == DONE


def test_server_blocking_submit_self_drains_single_threaded():
    """wait=True with the REAL clock and no helper hooks: the server is
    single-threaded, so the wait loop itself must tick the scheduler to
    free queue depth — nothing else ever pops the ingress.  (A plain
    blocking push would burn the whole max_wait and reject.)"""
    import time
    n = 48
    op, srv = _server(n=n, k=2, queue_depth=1)
    r1 = srv.submit(_rhs(n, 0), tol=1e-2)
    t0 = time.monotonic()
    r2 = srv.submit(_rhs(n, 1), tol=1e-2, wait=True, max_wait=30.0)
    assert srv.results.get(r2) is None     # admitted, not rejected
    assert time.monotonic() - t0 < 25.0    # did not just sleep out max_wait
    srv.run()
    assert srv.results[r1].status == DONE
    assert srv.results[r2].status == DONE


def test_submit_quantizes_tol_abs_to_handle_dtype():
    """Host retirement and the compiled (float32) cycle must agree on
    'converged': the admitted request carries tol_abs rounded to the
    handle's compute dtype, not the raw float64 product."""
    n = 48
    op, srv = _server(n=n, k=2)
    b = _rhs(n, 5)
    srv.submit(b, tol=1e-3)
    raw = 1e-3 * np.linalg.norm(b)                  # float64 threshold
    req = srv.ingress.peek()
    assert req.tol_abs == float(np.float32(raw))
    assert req.tol_abs != raw                       # quantization happened


def test_inner_steps_reports_actual_arnoldi_work():
    """A loose-tolerance solve converges mid-cycle: the outcome must
    carry the per-lane Arnoldi count from the cycle, not restarts*m
    (which overstates the work of every early-stopping lane)."""
    n, m = 48, 12
    op, srv = _server(n=n, k=2, m=m)
    rid = srv.submit(_rhs(n, 3), tol=1e-1, max_restarts=40)
    srv.run()
    out = srv.results[rid]
    assert out.status == DONE
    assert 1 <= out.inner_steps <= out.restarts * m
    assert out.inner_steps < out.restarts * m       # stopped mid-cycle


def test_pack_loads_only_refilled_rows():
    """_pack writes the placed lanes' rows in place (b set, x zeroed,
    inner counter reset) and leaves resident rows untouched on device."""
    n = 48
    op, srv = _server(n=n, k=3)
    marker = np.full(n, 7.0)
    srv._x = srv._x.at[2].set(99.0)        # pretend lane 2 is mid-solve
    srv._b = srv._b.at[2].set(marker)
    srv._inner[2] = 5
    st, _ = sched.admit(srv.state, _req(0, n=n))
    srv.state, _ = sched.admit(st, _req(1, n=n))
    # Occupy lane 2 first so pack only places lanes 0 and 1.
    lanes = list(srv.state.lanes)
    lanes[2] = sched.Lane(req=_req(9, n=n), restarts=1)
    import dataclasses as dc
    srv.state = dc.replace(srv.state, lanes=tuple(lanes))
    srv._pack()
    b_host, x_host = np.asarray(srv._b), np.asarray(srv._x)
    np.testing.assert_allclose(b_host[0], np.ones(n), rtol=1e-6)
    np.testing.assert_allclose(x_host[:2], 0.0)
    assert srv._inner[0] == 0 and srv._inner[1] == 0
    np.testing.assert_allclose(b_host[2], marker)   # resident lane kept
    np.testing.assert_allclose(x_host[2], 99.0)
    assert srv._inner[2] == 5


def test_server_empty_run_is_noop():
    op, srv = _server()
    assert srv.run() == 0
    m = srv.metrics()
    assert m["tick"] == 0 and m["occupancy"] == 0.0


def test_server_metrics_occupancy_and_cache():
    n = 48
    op, srv = _server(n=n, k=4)
    for i in range(8):
        srv.submit(_rhs(n, i), tol=1e-4, max_restarts=40)
    srv.run()
    m = srv.metrics()
    assert 0.0 < m["occupancy"] <= 1.0
    assert m["handle_cache"]["misses"] >= 1
    assert m["cycles_run"] == m["tick"]
    assert m["retirement_rate"] > 0


# =====================================================================
# Fault injection (dispatch spies, test_pipelined.py style)
# =====================================================================

def _spy(monkeypatch, mod, name, calls):
    orig = getattr(mod, name)

    def wrapper(*args, **kw):
        calls[name] = calls.get(name, 0) + 1
        return orig(*args, **kw)

    monkeypatch.setattr(mod, name, wrapper)


def test_nan_request_rejected_before_any_cycle(monkeypatch):
    """A poisoned b must terminate at admission: no queue entry, no lane,
    and — asserted via spy — not a single device cycle on its behalf."""
    from repro.serve import handles
    n = 48
    op, srv = _server(n=n)
    calls = {}
    _spy(monkeypatch, srv.handle, "cycle", calls)
    bad = _rhs(n, 0)
    bad[5] = np.nan
    rid = srv.submit(bad)
    out = srv.results[rid]
    assert out.status == REJECTED and "NaN/Inf" in out.reason
    assert len(srv.ingress) == 0
    assert srv.run() == 0                 # nothing was admitted
    assert calls.get("cycle", 0) == 0


def test_inf_request_rejected_among_good_ones():
    n = 48
    op, srv = _server(n=n)
    good = [srv.submit(_rhs(n, i), tol=1e-3) for i in range(3)]
    bad = _rhs(n, 9)
    bad[0] = np.inf
    rbad = srv.submit(bad)
    srv.run()
    assert srv.results[rbad].status == REJECTED
    for rid in good:
        assert srv.results[rid].status == DONE


def test_wrong_n_rejected_at_admission():
    op, srv = _server(n=48)
    rid = srv.submit(np.ones(32))
    assert srv.results[rid].status == REJECTED
    assert "n=32" in srv.results[rid].reason


def test_budget_exhausted_retires_failed_without_stalling():
    """One hopeless request (tol below fp32's floor, budget 3) shares the
    block with solvable ones: it must retire FAILED after exactly its
    budget while every cohort member still converges."""
    n, k = 48, 3
    op, srv = _server(n=n, k=k)
    hopeless = srv.submit(_rhs(n, 0), tol=1e-14, max_restarts=3)
    good = [srv.submit(_rhs(n, i + 1), tol=1e-4, max_restarts=40)
            for i in range(5)]
    ticks = srv.run()
    out = srv.results[hopeless]
    assert out.status == FAILED and out.restarts == 3
    assert np.isfinite(out.residual)
    for rid in good:
        assert srv.results[rid].status == DONE
    # The failed lane freed at its budget boundary: total ticks stay far
    # below budget + sum(good restarts) sequential.
    assert ticks <= 6


def test_vmem_overflow_falls_back_to_jnp_ref(monkeypatch):
    """Force the block-GS fits-check to fail: the handle's cycle must
    lower through the vmapped jnp reference — the kernel entry point is
    booby-trapped to prove it is never touched — and still converge."""
    from repro.kernels import block_gs, tuning

    monkeypatch.setattr(tuning, "block_gs_fits", lambda *a, **k: False)

    def boom(*a, **k):
        raise AssertionError("kernel path used despite VMEM overflow")

    monkeypatch.setattr(block_gs, "batched_cgs2", boom)
    n = 48
    op, srv = _server(n=n)                # fresh handle -> fresh trace
    rids = [srv.submit(_rhs(n, i), tol=1e-4) for i in range(4)]
    srv.run()
    for rid in rids:
        assert srv.results[rid].status == DONE


def test_kernel_path_used_when_it_fits(monkeypatch):
    """Control for the overflow test: with fits passing on a kernel-
    capable backend, the batched block-GS kernel IS the traced path."""
    from repro.kernels import block_gs, tuning
    if tuning.kernel_mode() == "ref":
        pytest.skip("no kernel backend (REPRO_KERNELS=ref)")
    calls = {}
    _spy(monkeypatch, block_gs, "batched_cgs2", calls)
    n = 48
    op, srv = _server(n=n)
    srv.submit(_rhs(n, 0), tol=1e-3)
    srv.run()
    assert calls.get("batched_cgs2", 0) >= 1


# =====================================================================
# Self-healing server: deadlines, lane faults, breaker, checkpoint
# =====================================================================

def test_server_deadline_timeout_without_stalling_cohort():
    """A hopeless-tolerance request with a 2-tick deadline retires
    TIMEOUT at exactly that tick while its cohort converges normally."""
    n = 48
    op, srv = _server(n=n, k=4)
    hard = srv.submit(_rhs(n, 0), tol=1e-14, max_restarts=50,
                      deadline_ticks=2)
    easy = [srv.submit(_rhs(n, i + 1), tol=1e-4, max_restarts=40)
            for i in range(3)]
    ticks = srv.run()
    out = srv.results[hard]
    assert out.status == TIMEOUT and out.restarts == 2
    assert "deadline" in out.reason
    assert np.isfinite(out.residual)       # carries the best-so-far x
    for rid in easy:
        assert srv.results[rid].status == DONE
    assert ticks < 50                      # the doomed lane freed early
    assert srv.metrics()["retired_timeout"] == 1


def test_server_deadline_default_applies():
    n = 48
    op, srv = _server(n=n, k=2, deadline_default=1)
    rid = srv.submit(_rhs(n, 0), tol=1e-14, max_restarts=50)
    srv.run()
    assert srv.results[rid].status == TIMEOUT


def test_server_lane_nan_quarantines_and_retries():
    """serve.lane_nan at tick 0 poisons one lane; the occupant must win
    on a retry (fresh lane, fresh x) and every outcome still be DONE."""
    n = 48
    op, srv = _server(n=n, k=4, fault_retries=1, quarantine_ticks=2)
    rids = [srv.submit(_rhs(n, i), tol=1e-4, max_restarts=40)
            for i in range(4)]
    with faultinject.inject("serve.lane_nan", at=0):
        srv.run()
    for rid in rids:
        assert srv.results[rid].status == DONE, srv.results[rid]
    m = srv.metrics()
    assert m["lane_faults"] == 1 and m["requeued"] == 1
    assert faultinject.fired.get("serve.lane_nan") == 1


def test_server_lane_fault_exhausted_retries_fails():
    n = 48
    op, srv = _server(n=n, k=2, fault_retries=0)
    rid = srv.submit(_rhs(n, 0), tol=1e-4)
    with faultinject.inject("serve.lane_nan", times=1):
        srv.run()
    out = srv.results[rid]
    assert out.status == FAILED and "lane fault" in out.reason


def test_server_scrubs_poisoned_rows():
    """After a lane fault the device blocks must be NaN-free: the next
    cohort shares reductions with those rows."""
    n = 48
    op, srv = _server(n=n, k=2, fault_retries=1)
    srv.submit(_rhs(n, 0), tol=1e-4)
    with faultinject.inject("serve.lane_nan", at=0):
        srv.step()
    assert np.isfinite(np.asarray(srv._x)).all()
    assert np.isfinite(np.asarray(srv._b)).all()
    srv.run()                              # the retry still converges
    assert srv.results[0].status == DONE


def test_server_transient_cycle_fault_absorbed_by_retries():
    """Two injected raises on the same tick are absorbed by in-tick
    retries: no scheduler state is lost, the breaker stays closed."""
    n = 48
    op, srv = _server(n=n, k=2, cycle_retries=2)
    rid = srv.submit(_rhs(n, 0), tol=1e-4)
    with faultinject.inject("serve.cycle", at=0, times=2):
        srv.run()
    assert srv.results[rid].status == DONE
    assert srv.cycle_faults == 2
    assert srv.breaker.state == "closed"


def test_server_breaker_death_fails_backlog_and_rejects():
    """A permanent cycle fault trips the breaker to death; every queued
    and in-flight request gets a terminal FAILED outcome (run() must NOT
    wedge), and later submits are rejected while the handle is dead."""
    n = 48
    op, srv = _server(n=n, k=2, cycle_retries=0, breaker_threshold=2,
                      breaker_cooldown=2, breaker_max_trips=1)
    rids = [srv.submit(_rhs(n, i), tol=1e-4) for i in range(5)]
    with faultinject.inject("serve.cycle", times=None):
        srv.run(max_ticks=100)
    assert srv.breaker.dead
    for rid in rids:
        out = srv.results[rid]
        assert out.status == FAILED and "circuit breaker" in out.reason
    post = srv.submit(_rhs(n, 9))
    assert srv.results[post].status == REJECTED
    assert "circuit breaker" in srv.results[post].reason
    m = srv.metrics()
    assert m["breaker_state"] == "dead" and m["breaker_skips"] >= 1


def test_server_breaker_recovers_after_transient_outage():
    """Fault clears before the trip budget: a half-open trial succeeds,
    the breaker closes, and the backlog drains DONE."""
    n = 48
    op, srv = _server(n=n, k=2, cycle_retries=0, breaker_threshold=2,
                      breaker_cooldown=1, breaker_max_trips=3)
    rids = [srv.submit(_rhs(n, i), tol=1e-4) for i in range(3)]
    with faultinject.inject("serve.cycle", times=2):
        srv.run(max_ticks=200)
    for rid in rids:
        assert srv.results[rid].status == DONE
    # A success fully resets the breaker (trips included): only opens
    # WITHOUT an intervening success accumulate toward death.
    assert srv.breaker.state == "closed" and srv.breaker.trips == 0
    assert srv.cycle_faults == 2


def test_server_straggler_ticks_exposed(monkeypatch):
    n = 48
    clk = _Clock()
    op, srv = _server(n=n, k=2, clock=clk, sleep=clk.sleep,
                      straggler_window=50)
    assert "straggler_ticks" in srv.metrics()
    assert srv.metrics()["straggler_ticks"] == 0


def test_server_checkpoint_resume_bit_identical(tmp_path):
    """Kill the server mid-drain, restore into a FRESH server over the
    same operator: every remaining request must retire with the same
    status/restarts and bit-identical x as the uninterrupted run."""
    from repro.serve import SolverServer
    n, k = 48, 3
    op = _dense_op(n=n, seed=5)
    work = [(_rhs(n, 20 + i), [1e-3, 1e-5, 1e-6][i % 3]) for i in range(8)]

    ref = SolverServer(op, m=12, k=k)
    for b, tol in work:
        ref.submit(b, tol=tol, max_restarts=40)
    ref.run()

    srv = SolverServer(op, m=12, k=k)
    for b, tol in work:
        srv.submit(b, tol=tol, max_restarts=40)
    srv.step(), srv.step()                 # partially drained...
    path = srv.save_checkpoint(str(tmp_path))
    already = dict(srv.results)            # outcomes retired pre-kill

    srv2 = SolverServer(op, m=12, k=k).restore_checkpoint(str(tmp_path))
    srv2.results.update(already)
    srv2.run()

    assert set(srv2.results) == set(ref.results)
    for rid, a in ref.results.items():
        b2 = srv2.results[rid]
        assert (a.status, a.restarts) == (b2.status, b2.restarts), rid
        assert a.residual == b2.residual
        assert np.array_equal(a.x, b2.x)
    assert ref.metrics()["tick"] == srv2.metrics()["tick"]


def test_server_checkpoint_preserves_quarantine_and_queue(tmp_path):
    """Checkpoint taken right after a lane fault: the restored server
    must keep the quarantine countdown and the front-of-queue retry."""
    from repro.serve import SolverServer
    n = 48
    op = _dense_op(n=n, seed=6)
    srv = SolverServer(op, m=12, k=2, fault_retries=1, quarantine_ticks=3)
    rids = [srv.submit(_rhs(n, i), tol=1e-4, max_restarts=40)
            for i in range(2)]
    with faultinject.inject("serve.lane_nan", at=0):
        srv.step()
    assert srv.metrics()["lane_faults"] == 1
    srv.save_checkpoint(str(tmp_path))

    srv2 = SolverServer(op, m=12, k=2, fault_retries=1,
                        quarantine_ticks=3).restore_checkpoint(str(tmp_path))
    assert srv2.state.quarantine == srv.state.quarantine
    assert [r.rid for r in srv2.state.pending] == \
           [r.rid for r in srv.state.pending]
    assert srv2.state.pending[0].retries == 1
    srv2.results.update(srv.results)
    srv2.run()
    for rid in rids:
        assert srv2.results[rid].status == DONE


def test_server_checkpoint_geometry_mismatch_raises(tmp_path):
    from repro.serve import SolverServer
    op = _dense_op(n=48, seed=0)
    SolverServer(op, m=12, k=2).save_checkpoint(str(tmp_path))
    with pytest.raises(ValueError, match="geometry"):
        SolverServer(op, m=12, k=4).restore_checkpoint(str(tmp_path))
    with pytest.raises(ValueError, match="geometry"):
        SolverServer(op, m=8, k=2).restore_checkpoint(str(tmp_path))
